"""Serving driver (the paper's deployment mode): batched top-K retrieval
requests through the RetrievalEngine at Booking.com catalogue scale,
comparing all scoring methods' mRT — a live miniature of Table 3.

  PYTHONPATH=src python examples/serve_catalogue.py --requests 128
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import PQConfig, SeqRecConfig
from repro.models import seqrec as m
from repro.serving.engine import Request, RetrievalEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=34_742)   # Booking.com
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=50)
    ap.add_argument("--max-batch", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = SeqRecConfig(name="serve-example", backbone="sasrec",
                       n_items=args.items, d_model=args.d_model,
                       n_blocks=2, n_heads=8, d_ff=args.d_model,
                       max_seq_len=args.seq_len,
                       pq=PQConfig(m=8, b=256))
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    streams = [rng.integers(1, args.items + 1, rng.integers(3, args.seq_len))
               for _ in range(args.requests)]

    for method in ("dense", "recjpq", "pqtopk"):
        def serve_fn(seqs, k, _method=method):
            return m.serve_topk(params, seqs, cfg, k=k, method=_method)

        engine = RetrievalEngine(serve_fn, seq_len=args.seq_len, k=10,
                                 max_batch=args.max_batch)
        t0 = time.monotonic()
        for i, s in enumerate(streams):
            engine.submit(Request(i, s, k=10))
        results = engine.drain()
        wall = time.monotonic() - t0
        st = engine.stats()
        print(f"{method:8s} {len(results)} reqs in {wall:6.2f}s "
              f"({len(results) / wall:7.1f} req/s)  mRT={st['mRT_ms']:8.2f}ms "
              f"p99={st['p99_ms']:8.2f}ms")


if __name__ == "__main__":
    main()
