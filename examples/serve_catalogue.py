"""Serving driver (the paper's deployment mode): batched top-K retrieval
requests through the RetrievalEngine at Booking.com catalogue scale,
comparing all scoring methods' mRT — a live miniature of Table 3.

  PYTHONPATH=src python examples/serve_catalogue.py --requests 128

With ``--kill-and-recover`` it instead demonstrates the durable
catalogue path (ISSUE 10): churn a mutable catalogue through a
checksummed WAL, tear the writer mid-append at ``--crash-at``, then
stand a new process up from ``CatalogueLog.recover()`` and prove the
recovered catalogue — and everything served from it — is bit-identical
to an oracle that replayed the durable prefix.  Exits non-zero on any
parity mismatch, so CI can gate on it:

  PYTHONPATH=src python examples/serve_catalogue.py --kill-and-recover \\
      --items 2000 --d-model 64 --requests 16 --crash-at 11
"""
import argparse
import sys
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import PQConfig, SeqRecConfig
from repro.models import seqrec as m
from repro.serving.engine import Request, RetrievalEngine


def _churn(mstate, rng, n):
    """n random valid ops, applied to ``mstate`` as drawn."""
    from repro.core.mutation import apply_op
    ops = []
    for _ in range(n):
        live = np.where(np.asarray(mstate.live))[0]
        live = live[live > 0]
        row = np.asarray(rng.integers(0, mstate.b, mstate.m, np.int64),
                         np.asarray(mstate.codes).dtype)
        kind = rng.choice(["insert", "delete", "update"], p=[0.3, 0.35, 0.35])
        if kind == "insert" and not mstate.free \
                and mstate.n_rows >= mstate.cap:
            kind = "delete"
        if kind == "insert":
            op = ("insert", row)
        elif kind == "delete":
            op = ("delete", int(rng.choice(live)))
        else:
            op = ("update", int(rng.choice(live)), row)
        apply_op(mstate, op)
        ops.append(op)
    return ops


def kill_and_recover(args):
    """Kill-and-recover demonstration; exits non-zero on parity loss."""
    from repro.core.mutation import apply_op
    from repro.serving.catalogue_log import CatalogueLog
    from repro.training.fault_tolerance import SimulatedFailure

    def fail(msg):
        print(f"FAIL: {msg}")
        sys.exit(1)

    cfg = SeqRecConfig(name="serve-durable", backbone="sasrec",
                       n_items=args.items, d_model=args.d_model,
                       n_blocks=2, n_heads=8, d_ff=args.d_model,
                       max_seq_len=args.seq_len,
                       pq=PQConfig(m=8, b=256))
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    specs = [(i, rng.integers(1, args.items + 1, rng.integers(3, 20)))
             for i in range(args.requests)]
    log_dir = args.log_dir or tempfile.mkdtemp(prefix="serve_catalogue_wal_")

    from repro.core.mutation import MutableHeadState
    mstate = MutableHeadState.build(params["item_emb"]["codes"], cfg.pq.b,
                                    tile=64)
    base = mstate.clone()                   # lsn-0 image for the oracle
    stream = []                             # every op ever handed to append

    # ---- process 1: serve + churn through the WAL, then tear ----------
    log = CatalogueLog(log_dir, fsync_every=4,
                       snapshot_every=args.snapshot_every)
    log.snapshot(mstate)                    # genesis
    eng = RetrievalEngine.for_seqrec_mutable(params, cfg, mstate, k=10,
                                             max_batch=args.max_batch,
                                             calibrate=False)
    log.fail_at_lsn = args.crash_at
    torn = False
    try:
        for _ in range(args.batches):
            ops = _churn(mstate.clone(), rng, args.churn)
            for op in ops:
                stream.append(op)
                log.append(op)              # append-before-apply (WAL)
                apply_op(mstate, op)
            eng.swap_head_state(mstate)     # zero-recompile propagation
            log.maybe_snapshot(mstate)
    except SimulatedFailure:
        torn = True
        print(f"writer torn mid-append at lsn {args.crash_at} "
              f"(half a record is on disk)")
    if not torn:
        fail(f"--crash-at {args.crash_at} never fired; raise --batches")
    for rid, seq in specs:                  # the old fleet still serves
        eng.submit(Request(rid, seq, k=10))
    eng.drain()

    # ---- process 2: recover the durable prefix from the log -----------
    log2 = CatalogueLog(log_dir, fsync_every=4)
    state, lsn = log2.recover(verify=True)
    print(f"recovered {log_dir} at lsn {lsn} "
          f"(torn bytes dropped: {log2.torn_bytes_dropped}, "
          f"snapshots: {int(log2.stats()['n_snapshots'])})")
    if lsn != args.crash_at - 1:
        fail(f"recovered lsn {lsn}, expected durable prefix "
             f"{args.crash_at - 1}")

    # the oracle replays exactly the durable prefix from the lsn-0 image
    oracle = base.clone()
    for op in stream[:lsn]:
        apply_op(oracle, op)
    for name in ("codes", "live"):
        if not np.array_equal(np.asarray(getattr(state, name)),
                              np.asarray(getattr(oracle, name))):
            fail(f"recovered catalogue diverges from oracle on {name!r}")
    if state.free != oracle.free or state.n_rows != oracle.n_rows:
        fail("recovered freelist/occupancy diverges from oracle")

    # and everything SERVED from the recovered state is bit-identical
    rec_eng = RetrievalEngine.for_seqrec_mutable(
        params, cfg, state, k=10, max_batch=args.max_batch,
        ladder=eng.ladder, calibrate=False)
    ora_eng = RetrievalEngine.for_seqrec_mutable(
        params, cfg, oracle, k=10, max_batch=args.max_batch,
        ladder=eng.ladder, calibrate=False)
    for rid, seq in specs:
        rec_eng.submit(Request(rid, seq, k=10))
        ora_eng.submit(Request(rid, seq, k=10))
    got = {r.request_id: r for r in rec_eng.drain()}
    want = {r.request_id: r for r in ora_eng.drain()}
    for rid in want:
        if not (np.array_equal(got[rid].items, want[rid].items)
                and np.array_equal(got[rid].scores, want[rid].scores)):
            fail(f"served results diverge on request {rid}")
    # the recovered log is a live writer: commits keep flowing
    more = _churn(oracle, rng, 3)
    log2.append_many(more)
    log2.sync()
    print(f"recovery parity OK: {len(want)} requests bit-identical, "
          f"log continues at lsn {log2.lsn}")
    log2.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=34_742)   # Booking.com
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=50)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--kill-and-recover", action="store_true",
                    help="durable-WAL demo: tear the writer, recover, "
                         "verify bit-parity (exits non-zero on mismatch)")
    ap.add_argument("--log-dir", default=None,
                    help="WAL directory (default: fresh temp dir)")
    ap.add_argument("--crash-at", type=int, default=11,
                    help="LSN whose append tears mid-record")
    ap.add_argument("--churn", type=int, default=4,
                    help="mutation ops per committed batch")
    ap.add_argument("--batches", type=int, default=5,
                    help="churn batches to attempt before/through the tear")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="cut an LSN-keyed snapshot every N committed ops")
    args = ap.parse_args(argv)

    if args.kill_and_recover:
        return kill_and_recover(args)

    cfg = SeqRecConfig(name="serve-example", backbone="sasrec",
                       n_items=args.items, d_model=args.d_model,
                       n_blocks=2, n_heads=8, d_ff=args.d_model,
                       max_seq_len=args.seq_len,
                       pq=PQConfig(m=8, b=256))
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    streams = [rng.integers(1, args.items + 1, rng.integers(3, args.seq_len))
               for _ in range(args.requests)]

    for method in ("dense", "recjpq", "pqtopk"):
        def serve_fn(seqs, k, _method=method):
            return m.serve_topk(params, seqs, cfg, k=k, method=_method)

        engine = RetrievalEngine(serve_fn, seq_len=args.seq_len, k=10,
                                 max_batch=args.max_batch)
        t0 = time.monotonic()
        for i, s in enumerate(streams):
            engine.submit(Request(i, s, k=10))
        results = engine.drain()
        wall = time.monotonic() - t0
        st = engine.stats()
        print(f"{method:8s} {len(results)} reqs in {wall:6.2f}s "
              f"({len(results) / wall:7.1f} req/s)  mRT={st['mRT_ms']:8.2f}ms "
              f"p99={st['p99_ms']:8.2f}ms")


if __name__ == "__main__":
    main()
