"""Quickstart: the paper's technique in 60 lines.

Builds a PQ-compressed item catalogue, scores it with all three algorithms
(Transformer-Default matmul, RecJPQ Alg. 2, PQTopK Alg. 1), verifies they
produce identical rankings, and shows the memory compression.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PQConfig
from repro.core import pq, retrieval_head

N_ITEMS = 100_000
D_MODEL = 512
PQ_CFG = PQConfig(m=8, b=256)


def main():
    key = jax.random.PRNGKey(0)
    print(f"catalogue: {N_ITEMS:,} items, d={D_MODEL}, "
          f"m={PQ_CFG.m} splits x b={PQ_CFG.b} sub-ids")

    # 1. PQ item representation (Eq. 1-2): codes + sub-embeddings.
    head = retrieval_head.init(key, N_ITEMS, D_MODEL, PQ_CFG)
    ratio = pq.compression_ratio(PQ_CFG, N_ITEMS, D_MODEL)
    print(f"embedding memory: dense {N_ITEMS * D_MODEL * 4 / 1e6:.0f} MB -> "
          f"PQ {head['codes'].size * 4 / 1e6 + head['sub_emb'].size * 4 / 1e6:.1f} MB "
          f"({ratio:.0f}x compression)")

    # 2. A batch of "sequence embeddings" phi (normally from a Transformer).
    phi = jax.random.normal(jax.random.PRNGKey(1), (4, D_MODEL))

    # 3. Score all items three ways.
    scores = {m: retrieval_head.score_all(head, phi, m)
              for m in ("dense", "recjpq", "pqtopk")}
    for m in ("recjpq", "pqtopk"):
        np.testing.assert_allclose(scores[m], scores["dense"],
                                   rtol=1e-4, atol=1e-4)
    print("scores identical across Default / RecJPQ / PQTopK: OK")

    # 4. Top-10 recommendation per user.
    vals, ids = retrieval_head.top_items(head, phi, 10, method="pqtopk")
    print("top-10 items, user 0:", np.asarray(ids[0]))

    # 5. The TPU kernel path (Pallas, interpret mode on CPU).
    from repro.kernels.pqtopk import ops as kops
    from repro.core import scoring
    s = scoring.subid_scores(head["sub_emb"], phi)
    kv, ki = kops.pq_topk(head["codes"], s, 10)
    np.testing.assert_allclose(np.asarray(kv), np.asarray(vals), rtol=1e-5)
    print("Pallas pqtopk kernel matches: OK")


if __name__ == "__main__":
    main()
