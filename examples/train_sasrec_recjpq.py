"""End-to-end training driver: SASRec + RecJPQ on a synthetic Gowalla-mini
dataset — data generation -> SVD codebook -> gBCE training with
checkpointing -> NDCG@10 eval vs a popularity baseline.

  PYTHONPATH=src python examples/train_sasrec_recjpq.py \
      --items 50000 --users 2000 --steps 300

Scale knobs go up to the real Gowalla config (--items 1271638) on a bigger
host; on TPU the same step function runs under the production mesh via
``repro.launch.dryrun``-style shardings.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PQConfig, SeqRecConfig
from repro.core import codebook
from repro.data.sequences import SeqRecDataset
from repro.models import seqrec as S
from repro.training import checkpoint as ckpt_lib, optimizer as O, train_loop as TL


def ndcg_at_k(ranks, k=10):
    g = np.where((ranks >= 0) & (ranks < k), 1.0 / np.log2(ranks + 2), 0.0)
    return float(g.mean())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=int, default=50_000)
    ap.add_argument("--users", type=int, default=2_000)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=50)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--b", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/sasrec_recjpq_ckpt")
    args = ap.parse_args(argv)

    cfg = SeqRecConfig(
        name="sasrec-recjpq-example", backbone="sasrec", n_items=args.items,
        d_model=args.d_model, n_blocks=2, n_heads=8, d_ff=args.d_model,
        max_seq_len=args.seq_len, n_negatives=128,
        pq=PQConfig(m=args.m, b=args.b, assign="svd"))

    print(f"generating {args.users:,} users x ~12 interactions over "
          f"{args.items:,} items ...")
    ds = SeqRecDataset.synthetic(args.users, args.items, 12,
                                 args.seq_len + 1, seed=0)
    users, items = ds.interactions()

    print("building RecJPQ codebook (truncated SVD + per-split k-means) ...")
    t0 = time.time()
    codes, _ = codebook.build_codebook(
        cfg.pq, cfg.n_items + 1, d_model=cfg.d_model,
        interactions=(users, items + 1, args.users))
    print(f"  codebook built in {time.time() - t0:.1f}s; "
          f"codes shape {codes.shape}")

    params = S.init_seqrec(jax.random.PRNGKey(0), cfg, codes=codes)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    dense_equiv = (cfg.n_items + 1) * cfg.d_model + n_params - (
        params["item_emb"]["codes"].size + params["item_emb"]["sub_emb"].size)
    print(f"  params: {n_params / 1e6:.1f}M (dense-equivalent "
          f"{dense_equiv / 1e6:.1f}M -> RecJPQ compression)")

    ocfg = O.AdamWConfig(lr=1e-3, warmup_steps=args.steps // 10,
                         total_steps=args.steps)
    opt_state = TL.init_opt_state(params, ocfg)
    step_fn = jax.jit(TL.make_train_step(
        lambda p, b: S.seqrec_loss(p, b, cfg), ocfg), donate_argnums=(0, 1))
    mgr = ckpt_lib.CheckpointManager(args.ckpt, keep=2)

    it = ds.batches(args.batch, cfg.n_negatives, backbone="sasrec", seed=1)
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % 25 == 0 or step == args.steps - 1:
            rate = args.batch * (step + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"({rate:.1f} seq/s)")
    mgr.save(args.steps, {"params": params, "opt_state": opt_state},
             block=True)

    # --- eval: hold out the last item, rank with PQTopK ------------------
    seqs = ds.sequences
    valid = seqs[:, -1] != 0
    prefix, held = jnp.asarray(seqs[valid][:, :-1]), seqs[valid][:, -1]
    k = 100
    ids, _ = S.serve_topk(params, prefix, cfg, k=k, method="pqtopk")
    ids = np.asarray(ids)
    ranks = np.full(len(held), -1)
    for u in range(len(held)):
        w = np.nonzero(ids[u] == held[u])[0]
        if len(w):
            ranks[u] = w[0]
    # popularity baseline
    pop = np.bincount(seqs[valid][:, :-1].ravel(),
                      minlength=cfg.n_items + 1)
    pop[0] = 0
    pop_top = np.argsort(-pop)[:k]
    pop_ranks = np.full(len(held), -1)
    for u in range(len(held)):
        w = np.nonzero(pop_top == held[u])[0]
        if len(w):
            pop_ranks[u] = w[0]
    print(f"NDCG@10  model={ndcg_at_k(ranks):.4f}  "
          f"popularity={ndcg_at_k(pop_ranks):.4f}")
    print(f"checkpoint saved to {args.ckpt}")


if __name__ == "__main__":
    main()
