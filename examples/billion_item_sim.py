"""RQ2 reproduction: PQTopK efficiency with very large simulated catalogues
(paper Fig. 2 + the 'pre-computing scenario' up to 10^9 items).

The backbone is excluded (random phi), sub-id scores are random, codes are
int8 (b=256) so a billion-item codebook is 8 GB — and scoring streams over
item chunks with a running top-k, so peak memory stays at chunk size.

Three properties the streaming loop guarantees (each had a real bug in the
first version of this file):

* **ids never wrap.**  Item ids can exceed 2^31 on 10^9-item catalogues
  (and always do when several hosts shard one catalogue via ``id_base``).
  ``jnp.int64`` silently downcasts to int32 without x64 mode, so the
  device only ever sees CHUNK-LOCAL int32 ids; the int64 ``start`` offset
  is applied in host numpy, where int64 is real.
* **one compile for the whole run.**  Every chunk the device scores has
  the same static shape: the final ragged chunk is padded up to ``chunk``
  and its padding rows are masked to ``-inf`` in-graph (``n_valid`` is
  traced data), so the timed loop never recompiles mid-run.
  ``streaming_pqtopk`` returns its trace count so callers can assert
  exactly-one-compile.
* **uint8 over the wire.**  Codes transfer as uint8 and are cast to int32
  in-graph (consistent with the kernel's native int8/uint8 path) — the
  old host-side ``.astype(np.int32)`` quadrupled the promised per-chunk
  transfer size.

``--mode hier`` compares the flat pruned cascade against the hierarchical
super-tile cascade (``pruning.with_super``) on a tile-coherent catalogue,
checking bit-exactness against the streaming oracle and reporting the
pass-1 bound-work reduction plus the peak RSS ceiling.  The ``hier``
BENCH section in ``benchmarks/run.py`` drives the same entry points.

  PYTHONPATH=src python examples/billion_item_sim.py --items 1e7
  PYTHONPATH=src python examples/billion_item_sim.py --items 1e9 --chunk 2e7
  PYTHONPATH=src python examples/billion_item_sim.py --mode hier --items 16777216
"""
import argparse
import resource
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning, scoring

D_MODEL = 512
K = 10


def merge_topk_host(best_v, best_i, v, i_local, start, k):
    """Fold one chunk's local winners into the running top-k, on host.

    ``i_local`` are chunk-local int32 ids; ``start`` is a Python int (so
    ``start + id`` never wraps) applied here in int64 numpy.  Order is
    (score desc, id asc) — the same tie-break as ``jax.lax.top_k`` over
    the one-shot score vector, which keeps the stream bit-identical to
    the oracle even through score ties.
    """
    cand_v = np.concatenate([best_v, np.asarray(v, np.float32)], axis=1)
    cand_i = np.concatenate(
        [best_i, np.asarray(i_local, np.int64) + np.int64(start)], axis=1)
    out_v = np.empty((cand_v.shape[0], k), np.float32)
    out_i = np.empty((cand_v.shape[0], k), np.int64)
    for q in range(cand_v.shape[0]):
        order = np.lexsort((cand_i[q], -cand_v[q]))[:k]
        out_v[q] = cand_v[q][order]
        out_i[q] = cand_i[q][order]
    return out_v, out_i


def streaming_pqtopk(codes: np.ndarray, s: jax.Array, k: int,
                     chunk: int, id_base: int = 0) -> tuple:
    """Chunked PQTopK with a running top-k merge — O(chunk) device memory
    regardless of |I| (the 'pre-computing scenario' at 10^8-10^9 items).

    Returns ``(values (B, k) f32, ids (B, k) int64, n_traces)``.  Ids are
    ``id_base + position``; ``id_base`` lets one host of a multi-host
    shard emit globally-unique ids past 2^31 (and is how the regression
    test exercises the wrap without allocating 10^9 rows).  ``n_traces``
    counts ``score_chunk`` compiles — 1 for any n/chunk combination,
    ragged final chunk included.
    """
    n = codes.shape[0]
    chunk = int(min(chunk, n))
    kk = min(k, chunk)      # per-chunk candidates; the host merge carries
    traces = {"n": 0}       # survivors across chunks when k > chunk

    @jax.jit
    def score_chunk(c_u8, s_, n_valid):
        traces["n"] += 1
        # uint8 → int32 on device; padded tail rows masked before top-k.
        r = scoring.score_pqtopk(c_u8.astype(jnp.int32), s_)
        valid = jnp.arange(chunk, dtype=jnp.int32)[None, :] < n_valid
        return jax.lax.top_k(jnp.where(valid, r, -jnp.inf), kk)

    bq = s.shape[0]
    best_v = np.full((bq, k), -np.inf, np.float32)
    best_i = np.full((bq, k), -1, np.int64)
    for start in range(0, n, chunk):
        n_valid = min(chunk, n - start)
        c_np = codes[start:start + chunk]
        if n_valid < chunk:
            c_np = np.concatenate(
                [c_np, np.zeros((chunk - n_valid, codes.shape[1]),
                                codes.dtype)], axis=0)
        v, i = score_chunk(jnp.asarray(c_np), s, np.int32(n_valid))
        best_v, best_i = merge_topk_host(best_v, best_i, v, i,
                                         id_base + start, k)
    return best_v, best_i, traces["n"]


def make_clustered_codes(n: int, m: int, b: int, grain: int,
                         width: int = 8, seed: int = 0) -> np.ndarray:
    """Popularity-sorted tile-coherent catalogue: every ``grain``
    consecutive items draw codes from one narrow band [base, base+width),
    with bases increasing across groups.  Paired with a score table that
    decays in the code index (:func:`make_popularity_scores`) this is the
    regime hierarchical pruning exists for — a clustered/sorted catalogue
    where a few coherent regions hold all the high scorers (real
    catalogues are coherent after any clustering pass; uniform-random
    codes defeat all bounds equally and measure nothing)."""
    rng = np.random.default_rng(seed)
    n_groups = -(-n // grain)
    span = max(1, b - width)
    base = np.minimum((np.arange(n_groups, dtype=np.int64) * span)
                      // max(1, n_groups - 1), span - 1)
    codes = np.empty((n, m), np.uint8)
    for g in range(n_groups):
        lo, hi = g * grain, min((g + 1) * grain, n)
        codes[lo:hi] = base[g] + rng.integers(0, width, (hi - lo, m))
    return codes


def make_popularity_scores(bq: int, m: int, b: int, seed: int = 0,
                           scale: float = 4.0) -> jax.Array:
    """Sub-id scores decaying in the code index (head-tail popularity):
    low codes — the first catalogue bands — score high, so super-tile
    bounds separate and theta can prune most of the catalogue in pass 0."""
    key = jax.random.PRNGKey(seed)
    decay = -scale * jnp.arange(b, dtype=jnp.float32) / b
    noise = 0.5 * jax.random.normal(key, (bq, m, b), dtype=jnp.float32)
    return decay[None, None, :] + noise


def peak_rss_mb() -> float:
    """Process high-water RSS in MB (ru_maxrss is KB on Linux)."""
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_hier_compare(n: int, *, m: int = 8, b: int = 256, tile: int = 1024,
                     factor: int = pruning.DEFAULT_SUPER_FACTOR,
                     bq: int = 2, k: int = K, repeats: int = 3,
                     backend: str = "bitmask", seed: int = 0) -> dict:
    """Flat vs hierarchical cascade on a tile-coherent catalogue of n
    items: bit-exactness vs the streaming oracle, pass-1 bound work
    (``bounds_computed``), latency, and peak RSS.  Returns one result
    dict consumed by the ``hier`` BENCH section and the CI smoke."""
    tile = min(tile, n)
    codes_np = make_clustered_codes(n, m, b, grain=tile * factor, seed=seed)
    codes = jnp.asarray(codes_np)
    s = make_popularity_scores(bq, m, b, seed=seed)

    flat = pruning.build_pruned_state(codes, b, tile, backend=backend)
    hier = pruning.with_super(flat, factor)

    # Stats once, eagerly (the stats dict holds a str and cannot cross
    # jit); timing below uses the jitted no-stats calls.
    fv, fi, fstats = pruning.cascade_topk_ingraph(codes, s, k, flat,
                                                  tile=tile,
                                                  return_stats=True)
    hv, hi, hstats = pruning.cascade_topk_ingraph(codes, s, k, hier,
                                                  tile=tile,
                                                  return_stats=True)
    ov, oi, _ = streaming_pqtopk(codes_np, s, k, chunk=min(n, 1 << 20))
    mismatches = int((np.asarray(fv) != np.asarray(hv)).sum()
                     + (np.asarray(fi) != np.asarray(hi)).sum()
                     + (np.asarray(hv) != ov).sum()
                     + (np.asarray(hi) != oi.astype(np.int32)).sum())

    f_flat = jax.jit(lambda c, s_: pruning.cascade_topk_ingraph(
        c, s_, k, flat, tile=tile))
    f_hier = jax.jit(lambda c, s_: pruning.cascade_topk_ingraph(
        c, s_, k, hier, tile=tile))

    def _time(fn):
        jax.block_until_ready(fn(codes, s))
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(codes, s))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    flat_bounds = int(fstats["bounds_computed"])
    hier_bounds = int(hstats["bounds_computed"])
    return {
        "n_items": n, "m": m, "b": b, "tile": tile,
        "super_factor": factor, "backend": backend, "k": k, "bq": bq,
        "n_tiles": flat.n_tiles, "n_super": hier.n_super,
        "flat_bounds": flat_bounds, "hier_bounds": hier_bounds,
        "bound_reduction": flat_bounds / max(hier_bounds, 1),
        "n_super_survived": int(hstats["n_super_survived"]),
        "mismatches": mismatches,
        "flat_s": _time(f_flat), "hier_s": _time(f_hier),
        "peak_rss_mb": peak_rss_mb(),
    }


def _main_stream(args) -> None:
    n, chunk = int(args.items), int(args.chunk)
    print(f"simulating |I| = {n:,} items, m={args.m}, b={args.b} "
          f"(codes: {n * args.m / 1e9:.2f} GB int8)")
    rng = np.random.default_rng(0)
    # uint8 holds b=256 sub-ids exactly (cast to int32 happens in-graph).
    codes = rng.integers(0, args.b, (n, args.m), dtype=np.uint8)
    s = jax.random.normal(jax.random.PRNGKey(0), (1, args.m, args.b))

    # warmup + timed runs
    streaming_pqtopk(codes[:min(n, chunk)], s, K, chunk)
    times = []
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        v, i, n_traces = streaming_pqtopk(codes, s, K, chunk)
        times.append(time.perf_counter() - t0)
    med = float(np.median(times))
    print(f"PQTopK scoring + top-{K}: median {med * 1e3:.1f} ms/user "
          f"({n / med / 1e6:.1f}M items/s, {n_traces} compile, "
          f"peak RSS {peak_rss_mb():.0f} MB)")
    print("top items:", i[0][:5], "scores:", np.round(v[0][:5], 3))


def _main_hier(args) -> None:
    n = int(args.items)
    for backend in ("bitmask", "range"):
        r = run_hier_compare(n, m=args.m, b=args.b, tile=int(args.tile),
                             factor=int(args.factor),
                             repeats=args.repeats, backend=backend)
        print(f"[hier/{backend}] N={r['n_items']:,} T={r['n_tiles']} "
              f"S={r['n_super']} bounds {r['flat_bounds']} -> "
              f"{r['hier_bounds']} ({r['bound_reduction']:.1f}x) "
              f"mismatches={r['mismatches']} "
              f"flat {r['flat_s'] * 1e3:.1f} ms / hier "
              f"{r['hier_s'] * 1e3:.1f} ms, peak RSS "
              f"{r['peak_rss_mb']:.0f} MB")
        if r["mismatches"]:
            raise SystemExit(f"hier/{backend}: exactness violated "
                             f"({r['mismatches']} mismatches)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["stream", "hier"], default="stream")
    ap.add_argument("--items", type=float, default=1e7)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--b", type=int, default=256)
    ap.add_argument("--chunk", type=float, default=1e7)
    ap.add_argument("--tile", type=float, default=1024)
    ap.add_argument("--factor", type=float,
                    default=pruning.DEFAULT_SUPER_FACTOR)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    if args.mode == "hier":
        _main_hier(args)
    else:
        _main_stream(args)


if __name__ == "__main__":
    main()
