"""RQ2 reproduction: PQTopK efficiency with very large simulated catalogues
(paper Fig. 2 + the 'pre-computing scenario' up to 10^9 items).

The backbone is excluded (random phi), sub-id scores are random, codes are
int8 (b=256) so a billion-item codebook is 8 GB — and scoring streams over
item chunks with a running top-k, so peak memory stays at chunk size.

  PYTHONPATH=src python examples/billion_item_sim.py --items 1e7
  PYTHONPATH=src python examples/billion_item_sim.py --items 1e9 --chunk 2e7
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring

D_MODEL = 512
K = 10


def streaming_pqtopk(codes: np.ndarray, s: jax.Array, k: int,
                     chunk: int) -> tuple:
    """Chunked PQTopK with a running top-k merge — O(chunk) device memory
    regardless of |I| (the 'pre-computing scenario' at 10^8-10^9 items)."""
    n = codes.shape[0]

    @jax.jit
    def score_chunk(c, s_):
        r = scoring.score_pqtopk(c, s_)
        return jax.lax.top_k(r, k)

    best_v = jnp.full((s.shape[0], k), -jnp.inf)
    best_i = jnp.zeros((s.shape[0], k), jnp.int64)
    for start in range(0, n, chunk):
        c = jnp.asarray(codes[start:start + chunk].astype(np.int32))
        v, i = score_chunk(c, s)
        cand_v = jnp.concatenate([best_v, v], axis=1)
        cand_i = jnp.concatenate([best_i, i.astype(jnp.int64) + start], axis=1)
        best_v, sel = jax.lax.top_k(cand_v, k)
        best_i = jnp.take_along_axis(cand_i, sel, axis=1)
    return best_v, best_i


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--items", type=float, default=1e7)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--b", type=int, default=256)
    ap.add_argument("--chunk", type=float, default=1e7)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args(argv)
    n, chunk = int(args.items), int(args.chunk)

    print(f"simulating |I| = {n:,} items, m={args.m}, b={args.b} "
          f"(codes: {n * args.m / 1e9:.2f} GB int8)")
    rng = np.random.default_rng(0)
    # uint8 holds b=256 sub-ids exactly (the kernel casts to int32 in VMEM).
    codes = rng.integers(0, args.b, (n, args.m), dtype=np.uint8)
    s = jax.random.normal(jax.random.PRNGKey(0), (1, args.m, args.b))

    # warmup + timed runs
    streaming_pqtopk(codes[:min(n, chunk)], s, K, chunk)
    times = []
    for _ in range(args.repeats):
        t0 = time.perf_counter()
        v, i = streaming_pqtopk(codes, s, K, chunk)
        jax.block_until_ready(v)
        times.append(time.perf_counter() - t0)
    med = float(np.median(times))
    print(f"PQTopK scoring + top-{K}: median {med * 1e3:.1f} ms/user "
          f"({n / med / 1e6:.1f}M items/s)")
    print("top items:", np.asarray(i[0])[:5], "scores:",
          np.round(np.asarray(v[0])[:5], 3))


if __name__ == "__main__":
    main()
