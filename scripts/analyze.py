#!/usr/bin/env python
"""Convenience launcher for the serve-path static analysis.

Identical to ``python -m repro.analysis`` (see docs/ANALYSIS.md); exists
so the analysis is discoverable next to the other CI entry scripts.
"""
import sys

if __name__ == "__main__":
    from repro.analysis.__main__ import main
    sys.exit(main())
