#!/usr/bin/env python
"""Cross-PR benchmark trajectory: join every ``BENCH_pr*.json`` and print
each benchmark's ``items_per_s`` across PRs, highlighting regressions.

Rows are joined on ``(section, method, n_items, m, B, bound_backend,
code_layout, grouping, hier, fsync_every, tail_len, snapshot_every)`` —
the tags that identify *what* was measured — rather than on the display
name, which PRs have renamed as sweeps grew.
Rows whose ``items_per_s`` is null (interpret-mode Pallas timings, delta
rows) never enter the comparison.  A drop of more than ``--threshold``
(default 20%) between consecutive PRs that measured the same row is a
*candidate* regression; since PR 7 every row also carries its timing
quartiles (``q25_us``/``q75_us``), and a candidate is only flagged
``REGRESSION`` when the two rows' IQR intervals *separate* — the new
median throughput sits strictly below the old row's q25-derived lower
bound and vice versa.  Overlapping intervals are run-to-run noise, not
evidence.  Rows missing quartiles on either side (pre-PR7 files) fall
back to the bare threshold rule.  ``--strict`` turns any flag into a
non-zero exit for CI gating (the default smoke run in ``scripts/ci.sh``
only reports).

Provenance: every file written since PR 6 carries an environment
``fingerprint`` (python/jax/jaxlib versions, backend, thread pinning).
Numbers measured on different stacks are not comparable — two files with
*different* fingerprints refuse to join (exit 2) unless ``--allow-mixed``
is passed.  Legacy files without a fingerprint only warn, so the existing
trajectory keeps printing.  ``--split-environments`` instead partitions
the files by fingerprint and reports each partition as its own
trajectory — the strict-gating mode for a history that spans an
environment change (e.g. the PR 8 switch to pinned threads): regressions
are only ever judged within one environment, never across the seam.

Usage:
  python scripts/bench_compare.py              # repo-root BENCH_pr*.json
  python scripts/bench_compare.py --threshold 0.1 --strict
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys


def _pr_number(path: str) -> int:
    m = re.search(r"pr(\d+)", os.path.basename(path))
    return int(m.group(1)) if m else -1


def row_key(row: dict) -> tuple:
    tags = row.get("tags") or {}
    # The second display-name segment is the benchmark *cell* family
    # (pq_scoring_262k vs pq_retrieval_262k, the table3 dataset, ...) —
    # stable across PRs, and without it two cells sharing section/method/
    # tags (scoring vs retrieval at the same N) would collide and max()
    # would silently drop one from the trend.
    name = row.get("name", "")
    cell = name.split("/")[1] if "/" in name else ""
    # Tags added by later PRs default to the value earlier PRs implicitly
    # measured (pr2/3 pruned rows were bitmask bounds on the legacy wrap
    # layout with batch-any survival) — otherwise a new tag splits the
    # series at the PR that introduced it and hides the transition.
    return (row.get("section", ""), cell, row.get("method", ""),
            tags.get("n_items"), tags.get("m"), tags.get("B"),
            tags.get("bound_backend") or "bitmask",
            tags.get("code_layout") or "wrap",
            tags.get("grouping") or "batchany",
            # PR 9: hierarchical rows must never join against flat rows
            # at the same N — the super level changes what pass-1 costs.
            bool(tags.get("hier", False)),
            tags.get("super_tile") or 0,
            # PR 10: durable-log rows sweep the WAL knobs — a row's
            # fsync group, replay-tail length, and snapshot cadence each
            # define a different measurement; joining across them would
            # average the sweep away.  None (every non-recovery row)
            # stays None so existing series are untouched.
            tags.get("fsync_every"),
            tags.get("tail_len"),
            tags.get("snapshot_every"))


def _ips_interval(row, ips):
    """Map the row's latency quartiles into an (lo, hi) throughput
    interval around ``items_per_s``.  Throughput is n/latency, so the
    q75 latency bounds throughput from below and q25 from above.
    Returns None for rows predating the variance fields (pre-PR7)."""
    med, q25, q75 = (row.get("median_us"), row.get("q25_us"),
                     row.get("q75_us"))
    if not med or not q25 or not q75:
        return None
    return (ips * med / q75, ips * med / q25)


def load(paths):
    """-> (sorted pr numbers,
    {key: {pr: {"ips": float, "interval": (lo, hi)|None}}},
    {path: fingerprint-or-None})."""
    prs, table, fingerprints = [], {}, {}
    for path in sorted(paths, key=_pr_number):
        with open(path) as f:
            doc = json.load(f)
        pr = doc.get("pr", _pr_number(path))
        prs.append(pr)
        fingerprints[path] = doc.get("fingerprint")
        for row in doc.get("rows", []):
            ips = row.get("items_per_s")
            if ips is None:
                continue
            # Keep the best row per (key, pr): reruns of the same cell in
            # one file (e.g. repeated smoke invocations) must not fan out.
            cell = table.setdefault(row_key(row), {})
            best = cell.get(pr)
            if best is None or float(ips) > best["ips"]:
                cell[pr] = {"ips": float(ips),
                            "interval": _ips_interval(row, float(ips))}
    return prs, table, fingerprints


def check_fingerprints(fingerprints: dict, allow_mixed: bool) -> bool:
    """Refuse cross-fingerprint joins: numbers from different software
    stacks (jax version, backend, thread pinning) are not a trajectory.
    Files predating the fingerprint (PR <= 5) warn but join — there is
    nothing to compare them against.  Returns False when the join must be
    refused."""
    legacy = sorted(p for p, fp in fingerprints.items() if fp is None)
    if legacy:
        print(f"# WARN: {len(legacy)} file(s) without an environment "
              f"fingerprint (pre-PR6): {', '.join(legacy)}",
              file=sys.stderr)
    distinct = {}
    for path, fp in fingerprints.items():
        if fp is not None:
            distinct.setdefault(json.dumps(fp, sort_keys=True),
                                []).append(path)
    if len(distinct) <= 1:
        return True
    msg = " vs ".join(f"{sorted(ps)} {json.loads(k)}"
                      for k, ps in sorted(distinct.items()))
    if allow_mixed:
        print(f"# WARN: joining {len(distinct)} distinct environment "
              f"fingerprints (--allow-mixed): {msg}", file=sys.stderr)
        return True
    print(f"REFUSING to join benchmarks from {len(distinct)} different "
          f"environments: {msg}\n(rerun with --allow-mixed to override)",
          file=sys.stderr)
    return False


def fmt_key(key: tuple) -> str:
    (section, cell, method, n, m, bq, backend, layout, grouping,
     hier, super_tile, fsync_every, tail_len, snapshot_every) = key
    parts = [section, cell, method]
    if n is not None:
        parts.append(f"n={n}")
    if m is not None:
        parts.append(f"m={m}")
    if bq is not None:
        parts.append(f"B={bq}")
    # Baseline defaults (bitmask/wrap/batchany) are implicit — only label
    # the variants.
    if backend != "bitmask":
        parts.append(backend)
    if layout != "wrap":
        parts.append(layout)
    if grouping != "batchany":
        parts.append(grouping)
    if hier:
        parts.append(f"hier{super_tile}" if super_tile else "hier")
    if fsync_every is not None:
        parts.append(f"fsync={fsync_every}")
    if tail_len is not None:
        parts.append(f"tail={tail_len}")
    if snapshot_every is not None:
        parts.append(f"snap={snapshot_every}")
    return "/".join(str(p) for p in parts)


def report(prs, table, args) -> int:
    """Print the trajectory table for one environment partition; returns
    the number of flagged regressions."""
    header = ["benchmark"] + [f"pr{p}" for p in prs] + ["trend"]
    print(",".join(header))
    n_regressions = 0
    for key in sorted(table, key=fmt_key):
        cell = table[key]
        vals = [cell.get(p) for p in prs]
        flags = []
        prev = None
        for v in vals:
            if v is None:
                continue
            if (prev is not None and prev["ips"] > 0
                    and v["ips"] < prev["ips"] * (1 - args.threshold)):
                pi, vi = prev["interval"], v["interval"]
                # With quartiles on both sides, demand *separated* IQR
                # intervals; otherwise the drop is within measured noise.
                if pi is None or vi is None or vi[1] < pi[0]:
                    flags.append(
                        f"REGRESSION {-100 * (1 - v['ips'] / prev['ips']):.0f}%")
                else:
                    flags.append(
                        f"noise {-100 * (1 - v['ips'] / prev['ips']):.0f}%")
            prev = v
        n_regressions += sum(f.startswith("REGRESSION") for f in flags)
        cells = ["-" if v is None else f"{v['ips']:.3e}" for v in vals]
        print(",".join([fmt_key(key)] + cells + [";".join(flags) or "ok"]))
    print(f"# {len(table)} joined rows across PRs {prs}; "
          f"{n_regressions} regression(s) at threshold "
          f"{args.threshold:.0%}", file=sys.stderr)
    return n_regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*", default=None,
                    help="BENCH json files (default: ./BENCH_pr*.json)")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="fractional drop between consecutive PRs flagged "
                         "as a regression (default 0.20)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when any regression is flagged")
    ap.add_argument("--allow-mixed", action="store_true",
                    help="join files whose environment fingerprints "
                         "differ (numbers are NOT comparable; trend is "
                         "indicative only)")
    ap.add_argument("--split-environments", action="store_true",
                    help="partition the files by environment fingerprint "
                         "and report each partition as its own trajectory "
                         "(regressions judged only within a partition)")
    args = ap.parse_args(argv)

    paths = args.paths or sorted(glob.glob("BENCH_pr*.json"))
    if not paths:
        print("no BENCH_pr*.json files found", file=sys.stderr)
        return 1
    prs, table, fingerprints = load(paths)

    if args.split_environments:
        groups: dict = {}
        for p in sorted(paths, key=_pr_number):
            fp = fingerprints[p]
            key = (json.dumps(fp, sort_keys=True) if fp is not None
                   else "<no-fingerprint>")
            groups.setdefault(key, []).append(p)
        # Only the partition containing the newest PR gates --strict:
        # that is the environment the head PR actually measured in.
        # Regressions frozen into historical partitions (e.g. the
        # unpinned pre-PR8 files) are reported but can never fail a CI
        # run that did not produce them.
        latest = max(prs) if prs else -1
        n_gated = 0
        for key, ps in sorted(groups.items(),
                              key=lambda kv: _pr_number(kv[1][0])):
            print(f"# environment partition ({len(ps)} file(s): "
                  f"{', '.join(os.path.basename(p) for p in ps)}): {key}")
            prs_g, table_g, _ = load(ps)
            prs_g = sorted(dict.fromkeys(prs_g))
            n = report(prs_g, table_g, args)
            if latest in prs_g:
                n_gated += n
            elif n:
                print(f"# {n} historical regression(s) in a partition "
                      f"without pr{latest}: reported, not gated",
                      file=sys.stderr)
        return 1 if (args.strict and n_gated) else 0

    if not check_fingerprints(fingerprints, args.allow_mixed):
        return 2
    n_regressions = report(sorted(dict.fromkeys(prs)), table, args)
    return 1 if (args.strict and n_regressions) else 0


if __name__ == "__main__":
    sys.exit(main())
