#!/usr/bin/env python
"""CI guard: the pruned serve route is ONE device dispatch per query batch.

Three independent checks on a reduced sasrec-recjpq engine with
``method="pqtopk_pruned"``:

1. **Traceability** — the whole serve function (backbone -> bounds -> theta
   -> in-graph compaction -> compacted scoring) traces into a single jaxpr.
   Any host orchestration (the PR 2 ``np.nonzero`` compaction) would blow
   up here with a TracerArrayConversionError.
2. **Dispatch counting** — wrap every memoised compiled serve variant in a
   counter and serve a batch: exactly one entry must fire per ``run_once``.
   The legacy cascade took 2+ dispatches (bound pass, then one compacted
   pass per slot bucket) through a non-jitted serve fn.
3. **Negative control** — the PR 2 host two-pass cascade must FAIL check 1
   (its ``np.nonzero`` compaction cannot trace), proving the trace check
   actually discriminates single-dispatch from host-orchestrated routes.
   The serve step also runs under ``jax.transfer_guard("disallow")``,
   which additionally catches implicit device->host syncs on accelerator
   backends (on the CPU backend D2H is zero-copy and unguarded, so the
   trace check is the load-bearing one there).
4. **Grouped per-query route** — checks 1 and 2 repeat on an engine with
   ``PQConfig.query_grouping`` enabled: per-query theta seeding, the
   greedy overlap-bucketing scan, the stable-argsort permutation, the 2D
   (group, slot) compaction and the group-keyed kernel grid must ALL live
   inside the same single dispatch per query batch.

Exits non-zero on any violation; ci.sh runs this before the bench smoke.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np


def main() -> int:
    from repro.configs import get_reduced
    from repro.models import seqrec as seqrec_lib
    from repro.serving.engine import Request, RetrievalEngine

    from dataclasses import replace

    # A catalogue large enough for several pruning tiles, with position-
    # clustered codes (the favourable regime: tiles get distinct bounds),
    # so build-time calibration produces a genuine multi-rung ladder and
    # the dispatch-count proof covers the nested lax.cond rung chain.
    cfg = replace(get_reduced("sasrec-recjpq").model, n_items=16384)
    rng0 = np.random.default_rng(7)
    centers = (np.arange(cfg.n_items + 1) / (cfg.n_items + 1)
               * cfg.pq.b).astype(np.int64)
    codes = jnp.asarray(
        (centers[:, None] + rng0.integers(-1, 2, (cfg.n_items + 1,
                                                  cfg.pq.m))) % cfg.pq.b,
        jnp.int32)
    params = seqrec_lib.init_seqrec(jax.random.PRNGKey(0), cfg, codes=codes)
    k = 5
    eng = RetrievalEngine.for_seqrec(params, cfg, k=k, max_batch=8,
                                     method="pqtopk_pruned")
    assert eng._jit_serve, "pruned route must be a jitted serve fn"
    # The calibrated slot-budget ladder must be active: the single-
    # dispatch guarantee has to hold WITH the nested lax.cond rung chain
    # in the trace (every rung is a branch of the same computation).
    assert eng.ladder is not None and len(eng.ladder) >= 2, (
        f"expected a calibrated ladder on the pruned engine, got "
        f"{eng.ladder!r}")
    print(f"calibrated ladder active: {eng.ladder}")

    # 1. single-jaxpr traceability
    sds = jax.ShapeDtypeStruct((4, cfg.max_seq_len), jnp.int32)
    jaxpr = jax.make_jaxpr(lambda seqs: eng._serve_fn(seqs, k))(sds)
    n_eqns = len(jaxpr.jaxpr.eqns)
    print(f"traceable: serve fn -> one jaxpr ({n_eqns} eqns)")

    # 3. negative control: the legacy host cascade must NOT trace (its
    # compaction is a device->host sync) — otherwise check 1 proves nothing.
    from repro.core import retrieval_head

    def host_cascade(seqs):
        phi = seqrec_lib.sequence_embedding(params, seqs, cfg)
        return retrieval_head.top_items_pruned(params["item_emb"], phi, k)

    try:
        jax.make_jaxpr(host_cascade)(sds)
    except Exception as e:
        print(f"negative control: host two-pass cascade fails tracing "
              f"({type(e).__name__}) as expected")
    else:
        print("FAIL: host cascade traced — the check cannot discriminate")
        return 1

    # Warm the compile cache outside the guards.
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(i, rng.integers(1, cfg.n_items + 1, 8), k=k))
    eng.drain()

    # 2 + 3. count compiled-variant entries fired during one guarded batch
    calls = []
    for key, fn in list(eng._compiled.items()):
        eng._compiled[key] = (
            lambda seqs, _f=fn, _key=key: (calls.append(_key), _f(seqs))[1])
    for i in range(4):
        eng.submit(Request(10 + i, rng.integers(1, cfg.n_items + 1, 8), k=k))
    with jax.transfer_guard("disallow"):
        results = eng.run_once()
    assert len(results) == 4, f"served {len(results)}/4"
    assert len(calls) == 1, (
        f"pruned route issued {len(calls)} dispatches per query batch "
        f"(expected exactly 1): {calls}")
    stats = eng.stats()
    print(f"single dispatch: 1 compiled call per batch {calls[0]}, "
          f"transfer guard clean, "
          f"n_compiles={int(stats['n_compiles'])}, "
          f"rung_counts={stats['rung_counts']}")

    # 4. the grouped per-query route: same single-dispatch guarantee with
    # per-query thetas, the bucketing scan + argsort permutation, and the
    # 2D (group, slot) compacted table all in the trace.
    cfg_g = replace(cfg, pq=replace(cfg.pq, query_grouping=True,
                                    n_groups=4))
    eng_g = RetrievalEngine.for_seqrec(params, cfg_g, k=k, max_batch=8,
                                       method="pqtopk_pruned")
    assert eng_g._jit_serve and eng_g.ladder is not None
    jaxpr_g = jax.make_jaxpr(lambda seqs: eng_g._serve_fn(seqs, k))(sds)
    print(f"traceable: grouped serve fn -> one jaxpr "
          f"({len(jaxpr_g.jaxpr.eqns)} eqns), ladder={eng_g.ladder}")
    for i in range(4):
        eng_g.submit(Request(20 + i, rng.integers(1, cfg.n_items + 1, 8),
                             k=k))
    eng_g.drain()
    calls_g = []
    for key, fn in list(eng_g._compiled.items()):
        eng_g._compiled[key] = (
            lambda seqs, _f=fn, _key=key: (calls_g.append(_key),
                                           _f(seqs))[1])
    for i in range(4):
        eng_g.submit(Request(30 + i, rng.integers(1, cfg.n_items + 1, 8),
                             k=k))
    with jax.transfer_guard("disallow"):
        results_g = eng_g.run_once()
    assert len(results_g) == 4, f"grouped served {len(results_g)}/4"
    assert len(calls_g) == 1, (
        f"grouped per-query route issued {len(calls_g)} dispatches per "
        f"query batch (expected exactly 1): {calls_g}")
    print(f"single dispatch (grouped): 1 compiled call per batch "
          f"{calls_g[0]}, transfer guard clean")
    print("OK: pqtopk_pruned serve path is a single in-graph dispatch "
          "(calibrated ladder enabled; per-query grouped route included)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
