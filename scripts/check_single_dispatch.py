#!/usr/bin/env python
"""CI guard: the pruned serve route is ONE device dispatch per query batch.

Since ISSUE 6 this is a thin wrapper over the ``repro.analysis`` framework
(docs/ANALYSIS.md): its historical checks are registry passes now —

1. **Traceability** (checks 1 & 4) — the ``engine_aot`` /
   ``engine_aot_grouped`` entrypoints trace the whole serve function
   (backbone -> bounds -> theta -> in-graph compaction -> compacted
   scoring; plus the grouped route's bucketing scan, argsort permutation
   and 2D compaction) into a single jaxpr under the ``dispatch-count``
   pass.
2. **Dispatch counting** (check 2) — the same entrypoints carry a runtime
   dispatch counter: every memoised compiled variant is wrapped and one
   guarded batch is served; exactly one entry must fire per ``run_once``
   (under ``jax.transfer_guard("disallow")``).
3. **Negative control** (check 3) — retained HERE as a framework-level
   self-test: the PR 2 host two-pass cascade (``np.nonzero`` compaction)
   is registered as an ad-hoc entrypoint and must FAIL the
   ``dispatch-count`` pass — and only that pass — proving the framework
   actually discriminates single-dispatch from host-orchestrated routes.

Exits non-zero on any violation; ci.sh runs this before the bench smoke.
The broader invariants (host transfers, recompile hazards, Pallas kernel
contracts, AST lint) run in ci.sh's ``python -m repro.analysis`` step.
"""
from __future__ import annotations

import sys


def main() -> int:
    from repro.analysis import run_default
    from repro.analysis.core import run_analysis
    from repro.analysis.entrypoints import BuiltEntry, Entrypoint
    from repro.analysis.passes import default_passes

    # Checks 1, 2 and 4: the engine entrypoints under the full pass list.
    report = run_default(entrypoints=["engine_aot", "engine_aot_grouped"])
    print(report.render())
    if not report.ok:
        print("FAIL: pruned serve route violates a serve-path invariant")
        return 1
    for name in ("engine_aot", "engine_aot_grouped"):
        res = report.result(name, "dispatch-count")
        assert res is not None and res.info.get("runtime_dispatches") == 1, (
            f"{name}: runtime dispatch count not proven "
            f"({res.info if res else None})")

    # Check 3 (negative control / framework self-test): the PR 2 host
    # cascade must fail dispatch-count — and nothing else.
    from repro.analysis.entrypoints import _seq_sds, _seqrec_setup

    def build_host_cascade() -> BuiltEntry:
        from repro.core import retrieval_head
        from repro.models import seqrec as seqrec_lib

        params, cfg = _seqrec_setup()

        def host_cascade(seqs):
            phi = seqrec_lib.sequence_embedding(params, seqs, cfg)
            return retrieval_head.top_items_pruned(params["item_emb"],
                                                   phi, 5)

        return BuiltEntry(host_cascade, (_seq_sds(cfg),))

    neg = Entrypoint("host_cascade_negative_control",
                     "PR 2 host two-pass cascade (np.nonzero compaction)",
                     build_host_cascade)
    neg_report = run_analysis({neg.name: neg}, default_passes(),
                              lambda _n: build_host_cascade())
    failing = neg_report.failing_passes(neg.name)
    if failing != ["dispatch-count"]:
        print(neg_report.render())
        print(f"FAIL: host cascade should fail exactly ['dispatch-count'], "
              f"failed {failing} — the framework cannot discriminate")
        return 1
    print("negative control: host two-pass cascade fails dispatch-count "
          "(and only dispatch-count) as expected")
    print("OK: pqtopk_pruned serve path is a single in-graph dispatch "
          "(calibrated ladder enabled; per-query grouped route included)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
