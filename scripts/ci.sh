#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps (best-effort — offline containers
# rely on the importorskip guards), then run the suite in two tiers (fast
# first, the marked matrix second), guard against silent test deletion,
# prove the single-dispatch property, and smoke the benchmarks.
set -euo pipefail

cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "WARN: could not install dev deps; property tests fall back to deterministic grids / skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Lint gate (crash-level rules only — see ruff.toml).  Best-effort like
# the hypothesis install: offline containers without ruff warn and skip;
# the repro.analysis ast-lint pass below covers the codebase-specific
# hazards regardless.
if python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check .
else
    echo "WARN: ruff not installed; skipping lint gate (pip install -r requirements-dev.txt)"
fi

# Bytecode must never be committed: .gitignore covers __pycache__/*.pyc,
# and this guard fails CI if a tracked .pyc ever reappears (it happened
# once — a PR 4 follow-up commit shipped tests/__pycache__).
if git ls-files | grep -q '\.pyc$'; then
    echo "FAIL: tracked .pyc files in the repo:" >&2
    git ls-files | grep '\.pyc$' >&2
    exit 1
fi

# Anti-test-deletion guard: the collected count must never drop below the
# previous tier-1 baseline (bump this when a PR adds tests; a drop means a
# test file stopped importing or someone deleted coverage).  pytest also
# exits non-zero on collection errors, so a broken import fails CI rather
# than silently shrinking the suite.
TIER1_BASELINE=321
collected=$(python -m pytest --collect-only -q 2>/dev/null | tail -1 \
            | grep -o '[0-9]\+ tests collected' | grep -o '^[0-9]\+' || echo 0)
if [ "${collected}" -lt "${TIER1_BASELINE}" ]; then
    echo "FAIL: collected ${collected} tests < tier-1 baseline ${TIER1_BASELINE}" >&2
    exit 1
fi
echo "collected ${collected} tests (baseline ${TIER1_BASELINE})"

# Fast tier first (quick signal), then the full marked matrix (slow /
# sharded / hypothesis) — together they cover the whole suite exactly once.
python -m pytest -x -q -m "not slow and not sharded and not hypothesis" "$@"
python -m pytest -x -q -m "slow or sharded or hypothesis" "$@"

# Serve-path static analysis (docs/ANALYSIS.md): every registered
# entrypoint (flat fused/pruned, grouped per-query, sharded, lm decode,
# compacted-tile kernels, engine AOT routes) under every pass
# (dispatch-count, host-transfer, recompile-hazard, kernel-contract,
# ast-lint).  Exits non-zero on ANY finding; the JSON report is a CI
# artifact, not tracked.
python -m repro.analysis --json ANALYSIS_REPORT.json

# The pruned serve route must be ONE device dispatch per query batch —
# since ISSUE 6 a thin wrapper over repro.analysis adding the *runtime*
# dispatch counter (trace-level checks alone can't see host replay) and
# the host-cascade negative control that proves the framework
# discriminates.
python scripts/check_single_dispatch.py

# Fast benchmark smoke: exercises the kernel paths (fused interpret-mode,
# single-dispatch pruned cascade, bound-backend comparison sweep, the
# per-query mixed-batch sweep, the catalogue-churn section with its
# sampled exactness checks, figure2) end to end so kernel-path breakage
# surfaces in CI, not just in unit tests, and refreshes the
# machine-readable BENCH_pr7.json (stamped with an environment
# fingerprint — python/jax/jaxlib, backend, thread pinning — so
# bench_compare refuses cross-environment joins; every row carries
# median + IQR so bench_compare only flags IQR-separated drops).
# table3/roofline stay out (slow dataset builds / artifact-dependent).
# --repeats 3 (up from 1): quartiles over one sample are degenerate,
# and the IQR-separation rule needs real spread to be meaningful.
python -m benchmarks.run --skip table3 --skip roofline --repeats 3 \
    --json BENCH_pr7.json > /dev/null

# Cross-PR perf trajectory: join all BENCH_pr*.json and report the
# items_per_s trend per benchmark (regressions are highlighted in the
# printed table, not fatal — CPU container timings are too noisy to
# gate on).
python scripts/bench_compare.py
