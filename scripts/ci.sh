#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps (best-effort — offline containers
# rely on the importorskip guards), then run the suite.  pytest exits
# non-zero on collection errors, so a broken import fails CI rather than
# silently shrinking the suite.
set -euo pipefail

cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "WARN: could not install dev deps; property tests will skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
