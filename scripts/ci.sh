#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps (best-effort — offline containers
# rely on the importorskip guards), then run the suite in two tiers (fast
# first, the marked matrix second), guard against silent test deletion,
# prove the single-dispatch property, and smoke the benchmarks.
set -euo pipefail

cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "WARN: could not install dev deps; property tests fall back to deterministic grids / skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Bytecode must never be committed: .gitignore covers __pycache__/*.pyc,
# and this guard fails CI if a tracked .pyc ever reappears (it happened
# once — a PR 4 follow-up commit shipped tests/__pycache__).
if git ls-files | grep -q '\.pyc$'; then
    echo "FAIL: tracked .pyc files in the repo:" >&2
    git ls-files | grep '\.pyc$' >&2
    exit 1
fi

# Anti-test-deletion guard: the collected count must never drop below the
# previous tier-1 baseline (bump this when a PR adds tests; a drop means a
# test file stopped importing or someone deleted coverage).  pytest also
# exits non-zero on collection errors, so a broken import fails CI rather
# than silently shrinking the suite.
TIER1_BASELINE=279
collected=$(python -m pytest --collect-only -q 2>/dev/null | tail -1 \
            | grep -o '[0-9]\+ tests collected' | grep -o '^[0-9]\+' || echo 0)
if [ "${collected}" -lt "${TIER1_BASELINE}" ]; then
    echo "FAIL: collected ${collected} tests < tier-1 baseline ${TIER1_BASELINE}" >&2
    exit 1
fi
echo "collected ${collected} tests (baseline ${TIER1_BASELINE})"

# Fast tier first (quick signal), then the full marked matrix (slow /
# sharded / hypothesis) — together they cover the whole suite exactly once.
python -m pytest -x -q -m "not slow and not sharded and not hypothesis" "$@"
python -m pytest -x -q -m "slow or sharded or hypothesis" "$@"

# The pruned serve route must be ONE device dispatch per query batch
# (single-jaxpr trace + compiled-call counting + a negative control on the
# legacy host cascade) — now with the calibrated slot-budget ladder
# enabled, so the nested lax.cond rung chain is part of the proof.
python scripts/check_single_dispatch.py

# Fast benchmark smoke: exercises the kernel paths (fused interpret-mode,
# single-dispatch pruned cascade, bound-backend comparison sweep, the
# per-query mixed-batch sweep, figure2) end to end so kernel-path
# breakage surfaces in CI, not just in unit tests, and refreshes the
# machine-readable BENCH_pr5.json (grouped-vs-batch-any slot·query pairs
# at N=2^20 / B in {8, 64, 256} with exactness counters, plus the PR 4
# sweeps).  table3/roofline stay out (slow dataset builds /
# artifact-dependent).
python -m benchmarks.run --skip table3 --skip roofline --repeats 1 \
    --json BENCH_pr5.json > /dev/null

# Cross-PR perf trajectory: join all BENCH_pr*.json and report the
# items_per_s trend per benchmark (regressions are highlighted in the
# printed table, not fatal — CPU container timings are too noisy to
# gate on).
python scripts/bench_compare.py
