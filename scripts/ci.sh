#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps (best-effort — offline containers
# rely on the importorskip guards), then run the suite.  pytest exits
# non-zero on collection errors, so a broken import fails CI rather than
# silently shrinking the suite.
set -euo pipefail

cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "WARN: could not install dev deps; property tests will skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

# The pruned serve route must be ONE device dispatch per query batch
# (single-jaxpr trace + compiled-call counting + a negative control on the
# legacy host cascade) — the structural guarantee behind the PR 3 cascade.
python scripts/check_single_dispatch.py

# Fast benchmark smoke: exercises the kernel paths (fused interpret-mode,
# single-dispatch pruned cascade, figure2 sweep) end to end so kernel-path
# breakage surfaces in CI, not just in unit tests, and refreshes the
# machine-readable BENCH_pr3.json (pruned-vs-exhaustive sweep at N=2^20
# with survival-fraction and seed-size tags).  table3/roofline stay out
# (slow dataset builds / artifact-dependent).
python -m benchmarks.run --skip table3 --skip roofline --repeats 1 \
    --json BENCH_pr3.json > /dev/null
