#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps (best-effort — offline containers
# rely on the importorskip guards), then run the suite in two tiers (fast
# first, the marked matrix second), guard against silent test deletion,
# prove the single-dispatch property, and smoke the benchmarks.
set -euo pipefail

cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "WARN: could not install dev deps; property tests fall back to deterministic grids / skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Lint gate (crash-level rules only — see ruff.toml).  Best-effort like
# the hypothesis install: offline containers without ruff warn and skip;
# the repro.analysis ast-lint pass below covers the codebase-specific
# hazards regardless.
if python -m ruff --version >/dev/null 2>&1; then
    python -m ruff check .
else
    echo "WARN: ruff not installed; skipping lint gate (pip install -r requirements-dev.txt)"
fi

# Bytecode must never be committed: .gitignore covers __pycache__/*.pyc,
# and this guard fails CI if a tracked .pyc ever reappears (it happened
# once — a PR 4 follow-up commit shipped tests/__pycache__).
if git ls-files | grep -q '\.pyc$'; then
    echo "FAIL: tracked .pyc files in the repo:" >&2
    git ls-files | grep '\.pyc$' >&2
    exit 1
fi

# Anti-test-deletion guard: the collected count must never drop below the
# previous tier-1 baseline (bump this when a PR adds tests; a drop means a
# test file stopped importing or someone deleted coverage).  pytest also
# exits non-zero on collection errors, so a broken import fails CI rather
# than silently shrinking the suite.
TIER1_BASELINE=394
collected=$(python -m pytest --collect-only -q 2>/dev/null | tail -1 \
            | grep -o '[0-9]\+ tests collected' | grep -o '^[0-9]\+' || echo 0)
if [ "${collected}" -lt "${TIER1_BASELINE}" ]; then
    echo "FAIL: collected ${collected} tests < tier-1 baseline ${TIER1_BASELINE}" >&2
    exit 1
fi
echo "collected ${collected} tests (baseline ${TIER1_BASELINE})"

# Fast tier first (quick signal), then the full marked matrix (slow /
# sharded / hypothesis) — together they cover the whole suite exactly once.
python -m pytest -x -q -m "not slow and not sharded and not hypothesis" "$@"
python -m pytest -x -q -m "slow or sharded or hypothesis" "$@"

# Serve-path static analysis (docs/ANALYSIS.md): every registered
# entrypoint (flat fused/pruned, grouped per-query, sharded, lm decode,
# compacted-tile kernels, engine AOT routes) under every pass
# (dispatch-count, host-transfer, recompile-hazard, kernel-contract,
# ast-lint).  Exits non-zero on ANY finding; the JSON report is a CI
# artifact, not tracked.
python -m repro.analysis --json ANALYSIS_REPORT.json

# The pruned serve route must be ONE device dispatch per query batch —
# since ISSUE 6 a thin wrapper over repro.analysis adding the *runtime*
# dispatch counter (trace-level checks alone can't see host replay) and
# the host-cascade negative control that proves the framework
# discriminates.
python scripts/check_single_dispatch.py

# Billion-item simulator smoke (ISSUE 9): the streaming scorer at a
# CI-sized N with a ragged final chunk (exactly-one-compile + padded
# tail), then the flat-vs-hierarchical cascade comparison, which exits
# non-zero on any exactness mismatch.  Small N keeps it seconds-fast;
# the real N in {2^24, 2^27} runs live in the `hier` BENCH section.
python examples/billion_item_sim.py --items 2e5 --chunk 65536 --repeats 1
python examples/billion_item_sim.py --mode hier --items 262144 \
    --tile 256 --factor 16 --repeats 1

# Crash-recovery smoke (ISSUE 10): churn a mutable catalogue through the
# checksummed WAL, tear the writer mid-append, recover in a "new
# process" and verify the recovered catalogue AND everything served from
# it are bit-identical to an oracle replay of the durable prefix.  The
# example exits non-zero on any parity mismatch or if the tear never
# fires.
python examples/serve_catalogue.py --kill-and-recover --items 2000 \
    --d-model 64 --requests 16 --crash-at 11

# Fast benchmark smoke: exercises the kernel paths (fused interpret-mode,
# single-dispatch pruned cascade, bound-backend comparison sweep, the
# per-query mixed-batch sweep, the catalogue-churn section with its
# sampled exactness checks, the replicated-fabric latency-under-load
# section, the durable-log recovery section, figure2) end to end so
# kernel-path breakage surfaces in CI, not just in unit tests, and
# refreshes the machine-readable BENCH_pr10.json.  table3/roofline/hier
# stay out (slow dataset builds / artifact-dependent).  --repeats 3
# (up from 1): quartiles over one
# sample are degenerate, and the IQR-separation rule needs real spread
# to be meaningful.
#
# Thread pinning (PR 8): single-threaded BLAS/Eigen and a one-core
# affinity mask where taskset exists.  Unpinned thread pools made every
# latency number hostage to scheduler noise; the pinning lands in the
# environment fingerprint, so pinned and unpinned files can never be
# silently joined into one trajectory.
export OMP_NUM_THREADS=1 MKL_NUM_THREADS=1 OPENBLAS_NUM_THREADS=1
export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_cpu_multi_thread_eigen=false"
PIN=""
if command -v taskset >/dev/null 2>&1; then
    PIN="taskset -c 0"
fi
${PIN} python -m benchmarks.run --skip table3 --skip roofline \
    --skip hier --repeats 3 --json BENCH_pr10.json > /dev/null

# Cross-PR perf trajectory, two views.  Informational: the whole history
# joined across the pinning seam (--allow-mixed; trend only, never
# gated).  Gate: --split-environments partitions files by environment
# fingerprint and --strict fails CI on an IQR-separated regression
# WITHIN the current (pinned) partition — the first trajectory stable
# enough to gate on; historical unpinned regressions report but cannot
# fail a run that did not produce them.
python scripts/bench_compare.py --allow-mixed
python scripts/bench_compare.py --strict --split-environments
