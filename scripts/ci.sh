#!/usr/bin/env bash
# Tier-1 CI entrypoint: install dev deps (best-effort — offline containers
# rely on the importorskip guards), then run the suite.  pytest exits
# non-zero on collection errors, so a broken import fails CI rather than
# silently shrinking the suite.
set -euo pipefail

cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" 2>/dev/null; then
    pip install -r requirements-dev.txt 2>/dev/null \
        || echo "WARN: could not install dev deps; property tests will skip"
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

# Fast benchmark smoke: exercises the kernel paths (fused interpret-mode,
# pruned cascade, figure2 sweep) end to end so kernel-path breakage
# surfaces in CI, not just in unit tests.  table3/roofline stay out (slow
# dataset builds / artifact-dependent); --json '' keeps the smoke from
# overwriting the recorded BENCH_pr2.json perf artifact.
python -m benchmarks.run --skip table3 --skip roofline --repeats 1 \
    --json '' > /dev/null
