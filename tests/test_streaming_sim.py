"""Streaming billion-item simulator: bit-parity with the one-shot oracle,
ragged-final-chunk handling, k > chunk carry-over, exactly-one-compile,
and the int64 id-offset regression (ISSUE 9 satellite bugfixes).

The simulator lives in ``examples/`` (not the package), so it is loaded
by file path like the other example-under-test (tests/test_analysis.py).
"""
import importlib.util

import jax
import numpy as np
import pytest

from repro.core import scoring

spec = importlib.util.spec_from_file_location(
    "billion_item_sim", "examples/billion_item_sim.py")
sim = importlib.util.module_from_spec(spec)
spec.loader.exec_module(sim)


def _oracle(codes_np, s, k):
    """One-shot exact reference: score everything, one lax.top_k."""
    r = scoring.score_pqtopk(np.asarray(codes_np, np.int32), s)
    v, i = jax.lax.top_k(r, k)
    return np.asarray(v), np.asarray(i, np.int64)


def _case(n, m=4, b=16, bq=3, seed=0):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, b, (n, m), dtype=np.uint8)
    s = jax.random.normal(jax.random.PRNGKey(seed), (bq, m, b))
    return codes, s


@pytest.mark.parametrize("n,chunk", [
    (256, 64),     # even split
    (300, 64),     # ragged final chunk (300 = 4*64 + 44)
    (100, 256),    # single chunk larger than n
    (65, 64),      # ragged final chunk of 1 row
])
def test_streaming_matches_oneshot_oracle(n, chunk):
    codes, s = _case(n)
    k = 10
    ov, oi = _oracle(codes, s, k)
    v, i, n_traces = sim.streaming_pqtopk(codes, s, k, chunk)
    np.testing.assert_array_equal(v, ov)
    np.testing.assert_array_equal(i, oi)
    assert n_traces == 1


def test_k_larger_than_chunk_carries_survivors_across_chunks():
    """k > chunk: each chunk can contribute at most ``chunk`` candidates,
    so the top-k must accumulate survivors across chunk merges."""
    codes, s = _case(200)
    k, chunk = 48, 32
    ov, oi = _oracle(codes, s, k)
    v, i, n_traces = sim.streaming_pqtopk(codes, s, k, chunk)
    np.testing.assert_array_equal(v, ov)
    np.testing.assert_array_equal(i, oi)
    assert n_traces == 1


def test_exactly_one_compile_despite_ragged_final_chunk():
    """The recompile bug: a ragged final chunk used to change the traced
    input shape mid-run.  The padded chunk keeps ONE static shape, so the
    trace counter must read 1; a second run with a different ragged tail
    length must not retrace either (n_valid is traced data)."""
    codes, s = _case(300)
    _, _, n1 = sim.streaming_pqtopk(codes, s, 5, 64)       # tail of 44
    assert n1 == 1
    _, _, n2 = sim.streaming_pqtopk(codes[:290], s, 5, 64)  # tail of 34
    assert n2 == 1


def test_int64_id_offset_past_2_31():
    """The overflow bug: ids accumulated as ``jnp.int64`` silently wrap
    to int32 without x64 mode.  ``id_base`` simulates a catalogue shard
    whose global ids start beyond 2^31 without allocating 10^9 rows; the
    returned ids must carry the exact int64 offset."""
    codes, s = _case(128)
    k, chunk = 10, 32
    base = 3 * (2 ** 31)           # far past int32 range
    ov, oi = _oracle(codes, s, k)
    v, i, _ = sim.streaming_pqtopk(codes, s, k, chunk, id_base=base)
    np.testing.assert_array_equal(v, ov)
    assert i.dtype == np.int64
    np.testing.assert_array_equal(i, oi + np.int64(base))
    assert int(i.min()) >= base    # nothing wrapped


def test_transfer_stays_uint8():
    """The host-cast bug: chunks must ship as uint8 (the docstring's
    memory promise), with the int32 cast inside the jitted graph."""
    codes, s = _case(96)
    seen = []
    orig = jax.numpy.asarray

    def spy(x, *a, **kw):
        if isinstance(x, np.ndarray) and x.ndim == 2:
            seen.append(x.dtype)
        return orig(x, *a, **kw)

    jax.numpy.asarray, jnp_asarray = spy, jax.numpy.asarray
    try:
        sim.streaming_pqtopk(codes, s, 5, 32)
    finally:
        jax.numpy.asarray = jnp_asarray
    assert seen and all(dt == np.uint8 for dt in seen)


def test_hier_compare_small_n_exact_and_reduced():
    """`run_hier_compare` (the hier BENCH entry point) on a CI-sized
    catalogue: zero mismatches on both backends and strictly less pass-1
    bound work than the flat cascade."""
    for backend in ("bitmask", "range"):
        r = sim.run_hier_compare(1 << 15, m=4, b=64, tile=128, factor=8,
                                 bq=2, repeats=1, backend=backend)
        assert r["mismatches"] == 0
        assert r["hier_bounds"] < r["flat_bounds"]
