"""Hierarchical super-tile bounds (ISSUE 9 tentpole): dominance, flat vs
hierarchical bit-parity against the exhaustive oracle (both backends,
flat + sharded, under jit, after churn), super-ladder escalation, and the
mutable catalogue's loosen-only super maintenance with retighten parity.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PQConfig
from repro.core import mutation, pruning, retrieval_head, scoring

BACKENDS = ("bitmask", "range")


def _case(n, m=4, b=16, bq=3, seed=0):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, b, (n, m), dtype=np.uint8))
    s = jax.random.normal(jax.random.PRNGKey(seed), (bq, m, b),
                          dtype=jnp.float32)
    return codes, s


def _oracle(codes, s, k):
    return jax.lax.top_k(scoring.score_pqtopk(codes, s), k)


# ---------------------------------------------------------------------------
# with_super: shapes + dominance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n,tile,factor", [(1000, 32, 4), (999, 16, 8),
                                           (257, 32, 4)])
def test_with_super_shapes_and_dominance(backend, n, tile, factor):
    """Every super bound dominates each of its children's bounds (the
    pass-0 invariant), including the ragged last super."""
    codes, s = _case(n)
    st = pruning.build_pruned_state(codes, 16, tile, backend=backend)
    sth = pruning.with_super(st, factor)
    assert sth.has_super and sth.super_factor == factor
    assert sth.n_super == -(-st.n_tiles // factor)
    child = pruning.tile_bounds(st, s)                      # (B, T)
    sup = pruning.bounds_from_parts(backend, sth.super_meta_arrays(), s)
    for g in range(sth.n_super):
        lo, hi = g * factor, min((g + 1) * factor, st.n_tiles)
        assert bool((sup[:, g:g + 1] >= child[:, lo:hi]).all()), (g,)
    # factor <= 1 strips the level
    assert not pruning.with_super(sth, 1).has_super


@pytest.mark.parametrize("backend", BACKENDS)
def test_with_super_sharded_groups_per_shard(backend):
    """Supers are grouped per shard, so a super never straddles a shard
    boundary and the sharded metadata splits evenly over the mesh."""
    codes, _ = _case(1024)
    st = pruning.build_pruned_state(codes, 16, 32, shards=4,
                                    backend=backend)
    sth = pruning.with_super(st, 4)
    assert sth.n_super % 4 == 0
    assert sth.supers_per_shard == sth.n_super // 4
    for a in sth.super_meta_arrays():
        assert a.shape[0] == sth.n_super


# ---------------------------------------------------------------------------
# flat route: bit-parity, jit, ladder escalation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [512, 999, 1021])
def test_hier_cascade_bit_identical(backend, n):
    codes, s = _case(n, seed=n)
    k = 7
    st = pruning.build_pruned_state(codes, 16, 32, backend=backend)
    sth = pruning.with_super(st, 4)
    ov, oi = _oracle(codes, s, k)
    fv, fi = pruning.cascade_topk_ingraph(codes, s, k, st, tile=32)
    hv, hi, stats = pruning.cascade_topk_ingraph(codes, s, k, sth, tile=32,
                                                 return_stats=True)
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(fv))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(fi))
    assert set(stats) == set(pruning.STATS_KEYS)
    assert int(stats["n_super"]) == sth.n_super
    # under jit (stats hold a str and stay outside the jitted call)
    jv, ji = jax.jit(lambda c, s_: pruning.cascade_topk_ingraph(
        c, s_, k, sth, tile=32))(codes, s)
    np.testing.assert_array_equal(np.asarray(jv), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(ji), np.asarray(oi))


@pytest.mark.parametrize("backend", BACKENDS)
def test_super_ladder_escalation_exact_at_every_rung(backend):
    """Forcing tiny super rungs exercises every escalation branch
    (including the exhaustive final rung) without changing answers."""
    codes, s = _case(1024, seed=5)
    k = 9
    sth = pruning.with_super(
        pruning.build_pruned_state(codes, 16, 32, backend=backend), 4)
    ov, oi = _oracle(codes, s, k)
    for sup_ladder in [(1,), (1, 2), (2, 4, 8), None]:
        hv, hi = pruning.cascade_topk_ingraph(codes, s, k, sth, tile=32,
                                              super_ladder=sup_ladder)
        np.testing.assert_array_equal(np.asarray(hv), np.asarray(ov))
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(oi))


def test_hier_rejects_query_grouping():
    codes, s = _case(512)
    sth = pruning.with_super(pruning.build_pruned_state(codes, 16, 32), 4)
    with pytest.raises(ValueError, match="query_grouping"):
        pruning.cascade_topk_ingraph(codes, s, 5, sth, tile=32,
                                     query_grouping=True, n_groups=2)
    with pytest.raises(ValueError, match="mutually exclusive"):
        PQConfig(m=4, b=16, super_factor=4, query_grouping=True)


def test_hier_reduces_bound_work_on_clustered_codes():
    """On a tile-coherent catalogue pass 0 prunes supers before any child
    bound is gathered: bounds_computed < T (the flat pass-1 floor)."""
    rng = np.random.default_rng(0)
    n, m, b, tile, factor = 1 << 13, 4, 64, 64, 8
    grain = tile * factor
    codes = np.empty((n, m), np.uint8)
    for g in range(n // grain):
        base = (g * 48) // max(1, n // grain - 1)
        codes[g * grain:(g + 1) * grain] = base + rng.integers(
            0, 8, (grain, m))
    decay = -4.0 * jnp.arange(b, dtype=jnp.float32) / b
    s = decay[None, None, :] + 0.5 * jax.random.normal(
        jax.random.PRNGKey(1), (2, m, b))
    codes = jnp.asarray(codes)
    st = pruning.build_pruned_state(codes, b, tile)
    sth = pruning.with_super(st, factor)
    ov, oi = _oracle(codes, s, 10)
    hv, hi, stats = pruning.cascade_topk_ingraph(codes, s, 10, sth,
                                                 tile=tile,
                                                 return_stats=True)
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(oi))
    assert int(stats["bounds_computed"]) < st.n_tiles
    assert int(stats["n_super_survived"]) < sth.n_super


# ---------------------------------------------------------------------------
# sharded route: parity + shard-skip
# ---------------------------------------------------------------------------


@pytest.mark.sharded
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [999, 1021])
def test_sharded_hier_bit_identical(backend, n):
    mesh = jax.make_mesh((1,), ("model",))
    params = retrieval_head.init(jax.random.PRNGKey(3), n, 16,
                                 PQConfig(m=4, b=8, bound_backend=backend))
    phi = jax.random.normal(jax.random.PRNGKey(4), (3, 16))
    k = 7
    ov, oi = retrieval_head.top_items(params, phi, k, method="pqtopk")
    ph = retrieval_head.ensure_sharded_pruned_state(
        dict(params), mesh, super_factor=4)
    assert ph["pruned"].has_super
    hv, hi, stats = retrieval_head.top_items_pruned_sharded(
        ph, phi, k, mesh, return_stats=True)
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(oi))
    assert set(stats) == set(pruning.STATS_KEYS)
    # under jit
    jv, ji = jax.jit(lambda p, x: retrieval_head.top_items_pruned_sharded(
        p, x, k, mesh))(ph, phi)
    np.testing.assert_array_equal(np.asarray(jv), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(ji), np.asarray(oi))


@pytest.mark.sharded
def test_sharded_hier_skip_branch_stats_shape():
    """The shard-skip cond must produce well-formed candidates even when
    a shard prunes everything: force it by making one tail region score
    uniformly terribly (single-shard mesh still traces both branches)."""
    mesh = jax.make_mesh((1,), ("model",))
    params = retrieval_head.init(jax.random.PRNGKey(0), 4096, 16,
                                 PQConfig(m=4, b=8))
    phi = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    ph = retrieval_head.ensure_sharded_pruned_state(
        dict(params), mesh, tile=64, super_factor=8)
    v, i, stats = retrieval_head.top_items_pruned_sharded(
        ph, phi, 5, mesh, tile=64, return_stats=True)
    ov, oi = retrieval_head.top_items(params, phi, 5, method="pqtopk")
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(oi))
    assert int(stats["n_super"]) == ph["pruned"].n_super


# ---------------------------------------------------------------------------
# mutation: loosen-only supers + retighten parity
# ---------------------------------------------------------------------------


def _random_row(rng, m=4, b=16):
    return jnp.asarray(rng.integers(0, b, (m,), dtype=np.uint8))


@pytest.mark.parametrize("backend", BACKENDS)
def test_mutable_super_stays_exact_under_churn(backend):
    """After arbitrary insert/delete/update churn the hierarchical serve
    path must still bit-match the exhaustive oracle over live items —
    loose (stale) super metadata costs work, never answers."""
    rng = np.random.default_rng(11)
    n, m, b, k = 300, 4, 16, 7
    codes0 = jnp.asarray(rng.integers(0, b, (n, m), dtype=np.uint8))
    st = mutation.MutableHeadState.build(codes0, b, tile=32,
                                         backend=backend, super_factor=4,
                                         capacity=1024)
    for _ in range(25):
        st.insert(_random_row(rng, m, b))
    for i in range(1, 60, 7):
        st.delete(i)
    for i in range(61, 120, 11):
        st.update(i, _random_row(rng, m, b))
    snap = st.head_arrays()
    s = jax.random.normal(jax.random.PRNGKey(2), (3, m, b),
                          dtype=jnp.float32)
    scores = scoring.score_pqtopk(snap["codes"], s)
    scores = jnp.where(jnp.asarray(snap["live"])[None, :], scores, -jnp.inf)
    ov, oi = jax.lax.top_k(scores, k)
    hv, hi = pruning.cascade_topk_ingraph(snap["codes"], s, k,
                                          snap["pruned"], tile=st.tile,
                                          live=snap["live"])
    dead = np.asarray(ov) == -np.inf
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(ov))
    np.testing.assert_array_equal(np.asarray(hi)[~dead],
                                  np.asarray(oi)[~dead])


@pytest.mark.parametrize("backend", BACKENDS)
def test_mutable_super_retighten_parity(backend):
    """Full retighten == from-scratch rebuild, super metadata included
    (tree-leaf equality covers super_packed / super_lo / super_hi)."""
    rng = np.random.default_rng(3)
    n, m, b = 400, 4, 16
    codes0 = jnp.asarray(rng.integers(0, b, (n, m), dtype=np.uint8))
    st = mutation.MutableHeadState.build(codes0, b, tile=32,
                                         backend=backend, super_factor=4,
                                         capacity=1024)
    for _ in range(30):
        st.insert(_random_row(rng, m, b))
    for i in range(1, 40, 3):
        st.delete(i)
    for i in range(41, 90, 5):
        st.update(i, _random_row(rng, m, b))
    st.retighten()
    oracle = st.rebuild_oracle()
    assert oracle.has_super and st.state.has_super
    for got, want in zip(jax.tree.leaves(st.state),
                         jax.tree.leaves(oracle)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("backend", BACKENDS)
def test_mutable_super_capacity_is_super_grain_multiple(backend):
    st = mutation.MutableHeadState.build(
        jnp.zeros((100, 4), jnp.uint8), 16, tile=32, backend=backend,
        super_factor=4)
    assert st.cap % (32 * 4) == 0
    assert st.state.n_tiles % 4 == 0


# ---------------------------------------------------------------------------
# survival_count on hierarchical states (serve-path theta matching)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_survival_count_hier_seeds_from_super(backend):
    codes, s = _case(1024, seed=9)
    sth = pruning.with_super(
        pruning.build_pruned_state(codes, 16, 32, backend=backend), 4)
    n_surv = pruning.survival_count(codes, s, 8, sth)
    assert 0 < int(n_surv) <= sth.n_tiles
