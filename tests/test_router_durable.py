"""Durable versioned mutation through the replicated fabric (ISSUE 10).

The contracts on top of PR 8's chaos invariants:

* ``apply_mutations`` is WAL-disciplined: ops land in the durable log
  before any replica applies them, every replica replays the same
  LSN-ordered stream, and propagation costs ZERO recompiles (the heads
  hot-swap).
* Every Result carries the serving replica's applied-LSN watermark;
  results served past the staleness budget are tagged
  ``stale_catalogue`` — never silently stale.
* A crashed replica recovers snapshot+tail from the log and is kept out
  of HEALTHY until it has caught up (gated re-admission).
* A writer crash mid-append (torn record) loses at most the un-acked
  suffix: a restarted router recovers the durable prefix bit-identically
  to a from-scratch oracle.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.mutation import MutableHeadState, apply_op
from repro.models import seqrec as S
from repro.serving import (CatalogueLog, ReplicaRouter, Request,
                           RetrievalEngine)
from repro.training.fault_tolerance import SimulatedFailure

CFG = get_reduced("sasrec-recjpq").model
K = 5


@pytest.fixture(scope="module")
def params():
    return S.init_seqrec(jax.random.PRNGKey(0), CFG)


def _mk_state(params):
    return MutableHeadState.build(params["item_emb"]["codes"], CFG.pq.b,
                                  tile=64)


def _gen_ops(shadow, rng, n=10):
    """n random valid ops, applied to ``shadow`` as they are drawn (the
    caller's oracle of what the fleet should converge to)."""
    ops = []
    for _ in range(n):
        live = np.where(np.asarray(shadow.live))[0]
        live = live[live > 0]
        kind = rng.choice(["insert", "delete", "update"], p=[0.3, 0.35, 0.35])
        row = np.asarray(rng.integers(0, shadow.b, shadow.m, np.int64),
                         np.asarray(shadow.codes).dtype)
        if kind == "insert" and not shadow.free \
                and shadow.n_rows >= shadow.cap:
            kind = "delete"
        if kind == "insert":
            op = ("insert", row)
        elif kind == "delete":
            op = ("delete", int(rng.choice(live)))
        else:
            op = ("update", int(rng.choice(live)), row)
        apply_op(shadow, op)
        ops.append(op)
    return ops


def _specs(n, base=0, seed=0):
    rng = np.random.default_rng(seed)
    return [(base + i, rng.integers(1, CFG.n_items + 1, 8)) for i in range(n)]


def _wait(cond, timeout_s=30.0):
    t0 = time.monotonic()
    while not cond():
        if time.monotonic() - t0 > timeout_s:
            return False
        time.sleep(0.01)
    return True


def _caught_up(router):
    return lambda: all(
        rep["lag"] == 0 for rep in router.stats()["replicas"].values())


def test_mutations_propagate_zero_recompiles_and_watermarks(params, tmp_path):
    log = CatalogueLog(str(tmp_path), fsync_every=4)
    mstate = _mk_state(params)
    shadow = mstate.clone()
    rng = np.random.default_rng(0)
    with ReplicaRouter.for_seqrec_mutable(
            params, CFG, mstate, n_replicas=2, k=K, max_batch=8,
            calibrate=False, log=log, hedge=False) as router:
        router.warmup()
        specs0 = _specs(16, base=0)
        for rid_, seq in specs0:
            router.submit(Request(rid_, seq, k=K))
        r0 = router.drain()
        assert all(r.lsn == 0 for r in r0)          # pre-mutation watermark
        compiles0 = [rep["n_compiles"]
                     for rep in router.stats()["replicas"].values()]

        ops = _gen_ops(shadow, rng, n=12)
        deleted = [op[1] for op in ops if op[0] == "delete"]
        lsn = router.apply_mutations(ops)
        assert lsn == 12
        assert _wait(_caught_up(router)), "replicas never caught up"

        specs1 = _specs(16, base=100, seed=1)
        for rid_, seq in specs1:
            router.submit(Request(rid_, seq, k=K))
        r1 = router.drain()
        st = router.stats()
        # zero recompiles: propagation is a hot swap, not a new program
        assert [rep["n_compiles"]
                for rep in st["replicas"].values()] == compiles0
        assert st["committed_lsn"] == 12.0
        assert st["stale_served"] == 0.0
        assert st["log"]["lsn"] == 12.0
        for r in r1:
            assert r.lsn == 12 and not r.degraded and not r.shed
            assert not np.isin(np.asarray(r.items), deleted).any()

        # bit-parity vs a single-engine oracle on an independently
        # mutated state, sharing the fleet's ladder
        oracle = RetrievalEngine.for_seqrec_mutable(
            params, CFG, shadow, k=K, max_batch=8,
            ladder=router.engines[0].ladder, calibrate=False)
        for rid_, seq in specs1:
            oracle.submit(Request(rid_, seq, k=K))
        want = {r.request_id: r for r in oracle.drain()}
        for r in r1:
            np.testing.assert_array_equal(r.items, want[r.request_id].items)
            np.testing.assert_array_equal(r.scores,
                                          want[r.request_id].scores)


def test_stale_tagging_and_immutable_guards(params, tmp_path):
    mstate = _mk_state(params)
    shadow = mstate.clone()
    rng = np.random.default_rng(1)
    with ReplicaRouter.for_seqrec_mutable(
            params, CFG, mstate, n_replicas=1, k=K, max_batch=8,
            calibrate=False, staleness_budget=2) as router:
        router.warmup()
        # invalid op: rejected BEFORE anything becomes durable
        with pytest.raises(ValueError):
            router.apply_mutations([("delete", 0)])   # padding row
        assert router.stats()["committed_lsn"] == 0.0

        router.pause_mutations(0)
        router.apply_mutations(_gen_ops(shadow, rng, n=5))
        for rid_, seq in _specs(8, base=0, seed=2):
            router.submit(Request(rid_, seq, k=K))
        stale = router.drain()
        st = router.stats()
        assert st["stale_served"] >= 1.0
        for r in stale:                    # lag 5 > budget 2: all tagged
            assert r.degraded == "stale_catalogue"
            assert r.lsn == 0              # served from the genesis state
            assert not r.shed and r.items.shape == (K,)

        router.resume_mutations(0)
        assert _wait(_caught_up(router))
        for rid_, seq in _specs(8, base=100, seed=3):
            router.submit(Request(rid_, seq, k=K))
        fresh = router.drain()
        for r in fresh:
            assert r.lsn == 5 and not r.degraded

    # an immutable router refuses the mutation API outright
    with ReplicaRouter.for_seqrec(params, CFG, n_replicas=1, k=K,
                                  max_batch=8, method="pqtopk_pruned",
                                  calibrate=False) as plain:
        with pytest.raises(ValueError, match="immutable"):
            plain.apply_mutations([("delete", 1)])
        assert all(r.lsn == -1 for r in _serve(plain, 4))


def _serve(router, n, base=0, seed=9):
    for rid_, seq in _specs(n, base=base, seed=seed):
        router.submit(Request(rid_, seq, k=K))
    return router.drain()


@pytest.mark.slow
def test_crash_replica_recovers_with_gated_readmission(params, tmp_path):
    log = CatalogueLog(str(tmp_path), fsync_every=4)
    mstate = _mk_state(params)
    shadow = mstate.clone()
    rng = np.random.default_rng(2)
    with ReplicaRouter.for_seqrec_mutable(
            params, CFG, mstate, n_replicas=2, k=K, max_batch=8,
            calibrate=False, log=log, hedge=False, eject_after=1,
            cooldown_ms=20.0) as router:
        router.warmup()
        router.apply_mutations(_gen_ops(shadow, rng, n=6))
        assert _wait(_caught_up(router))
        all_results = list(_serve(router, 16, base=0))

        # Crash replica 1 AND freeze its catch-up: probes answer but the
        # health FSM must refuse re-admission while recovery is pending.
        router.pause_mutations(1)
        router.crash_replica(1)
        router.apply_mutations(_gen_ops(shadow, rng, n=4))
        base = 1000
        for _ in range(6):
            all_results += _serve(router, 8, base=base, seed=base)
            base += 8
        assert router.replicas[1].readmissions == 0, \
            "re-admitted before catching up"

        # Un-freeze: the worker recovers snapshot+tail from the log,
        # catches up, and the next probe re-admits it.
        router.resume_mutations(1)
        while router.replicas[1].readmissions == 0:
            all_results += _serve(router, 8, base=base, seed=base)
            base += 8
            assert base < 3000, "replica 1 never re-admitted"
        st = router.stats()
        assert st["catchup_events"] >= 1.0
        assert st["replicas"][1]["lag"] == 0
        assert st["replicas"][1]["applied_lsn"] == 10

        # exactly-once through crash + recovery
        seen = sorted(r.request_id for r in all_results)
        assert seen == sorted(router._expected)

        # the recovered replica serves bit-identically to the writer's
        # catalogue: compare against an oracle engine on the shadow
        oracle = RetrievalEngine.for_seqrec_mutable(
            params, CFG, shadow, k=K, max_batch=8,
            ladder=router.engines[0].ladder, calibrate=False)
        specs = _specs(16, base=9000, seed=7)
        for rid_, seq in specs:
            router.submit(Request(rid_, seq, k=K))
            oracle.submit(Request(rid_, seq, k=K))
        got = {r.request_id: r for r in router.drain()}
        want = {r.request_id: r for r in oracle.drain()}
        for i in got:
            if got[i].degraded or got[i].shed:
                continue
            np.testing.assert_array_equal(got[i].items, want[i].items)
            np.testing.assert_array_equal(got[i].scores, want[i].scores)


@pytest.mark.slow
def test_writer_torn_crash_and_full_router_recovery(params, tmp_path):
    """Kill the writer mid-append (torn record on disk), kill the router,
    stand a new one up from CatalogueLog.recover(): the recovered fleet
    serves the durable prefix bit-identically to a from-scratch oracle."""
    log = CatalogueLog(str(tmp_path), fsync_every=4)
    mstate = _mk_state(params)
    shadow = mstate.clone()            # tracks the DURABLE prefix only
    rng = np.random.default_rng(3)
    ladder = None
    with ReplicaRouter.for_seqrec_mutable(
            params, CFG, mstate, n_replicas=2, k=K, max_batch=8,
            calibrate=False, log=log, hedge=False) as router:
        ladder = router.engines[0].ladder
        router.apply_mutations(_gen_ops(shadow, rng, n=6))

        batch2 = _gen_ops(shadow.clone(), rng, n=5)   # NOT applied to shadow
        log.fail_at_lsn = 9            # third op of batch2 tears
        with pytest.raises(SimulatedFailure, match="mid-append"):
            router.apply_mutations(batch2)
        # ops 7..8 are durable and were fanned out; op 9 died mid-record
        for op in batch2[:2]:
            apply_op(shadow, op)
        assert _wait(_caught_up(router))
        res = _serve(router, 8)
        assert all(r.lsn == 8 for r in res)
        # the crashed log refuses further commits: the router must be
        # rebuilt from recovery, not limp on with a diverged writer state
        with pytest.raises(RuntimeError, match="crashed"):
            router.apply_mutations([("delete", 1)])

    # ---- restart: recover the durable prefix, stand up a new fleet ----
    log2 = CatalogueLog(str(tmp_path), fsync_every=4)
    assert log2.torn_bytes_dropped > 0
    state, lsn = log2.recover(verify=True)
    assert lsn == 8
    np.testing.assert_array_equal(np.asarray(state.codes),
                                  np.asarray(shadow.codes))
    np.testing.assert_array_equal(np.asarray(state.live),
                                  np.asarray(shadow.live))
    assert state.free == shadow.free and state.n_rows == shadow.n_rows

    with ReplicaRouter.for_seqrec_mutable(
            params, CFG, state, n_replicas=2, k=K, max_batch=8,
            calibrate=False, ladder=ladder, log=log2,
            hedge=False) as router2:
        assert router2.stats()["committed_lsn"] == 8.0
        # a fresh-built oracle over the same durable catalogue
        oracle = RetrievalEngine.for_seqrec_mutable(
            params, CFG, shadow, k=K, max_batch=8, ladder=ladder,
            calibrate=False)
        specs = _specs(16, base=0, seed=11)
        for rid_, seq in specs:
            router2.submit(Request(rid_, seq, k=K))
            oracle.submit(Request(rid_, seq, k=K))
        got = {r.request_id: r for r in router2.drain()}
        want = {r.request_id: r for r in oracle.drain()}
        assert set(got) == set(want)
        for i in got:
            assert got[i].lsn == 8
            np.testing.assert_array_equal(got[i].items, want[i].items)
            np.testing.assert_array_equal(got[i].scores, want[i].scores)
        # and the recovered log keeps committing
        more = _gen_ops(shadow, rng, n=3)
        assert router2.apply_mutations(more) == 11
        assert _wait(_caught_up(router2))
        assert all(r.lsn == 11 for r in _serve(router2, 8, base=100))
