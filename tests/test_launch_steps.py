"""Integration tests for the dry-run step builders: every (arch × active
shape) bundle must build with consistent args/shardings on a tiny mesh —
this is the CI guard for the 40-cell production matrix."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.launch.steps import build_step

pytestmark = pytest.mark.sharded


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _cells():
    out = []
    for arch_id in list_archs():
        for sh in get_config(arch_id).active_shapes():
            out.append((arch_id, sh.name))
    return out


@pytest.mark.parametrize("arch_id,shape_name", _cells())
def test_bundle_builds(arch_id, shape_name, mesh):
    bundle = build_step(arch_id, shape_name, mesh)
    # one sharding per arg, pytree structures compatible
    assert len(bundle.args) == len(bundle.in_shardings)
    for a in jax.tree.leaves(bundle.args):
        assert isinstance(a, jax.ShapeDtypeStruct)
    assert bundle.meta["family"] == get_config(arch_id).family


def test_documented_skips_raise(mesh):
    with pytest.raises(ValueError, match="documented skip"):
        build_step("qwen2.5-14b", "long_500k", mesh)


def test_cell_count_matches_brief():
    """36 assigned-arch cells (40 - 4 documented long_500k skips) + 4
    paper-arch cells."""
    assigned = [a for a in list_archs()
                if a not in ("sasrec-recjpq", "gbert4rec-recjpq")]
    n_assigned = sum(len(get_config(a).active_shapes()) for a in assigned)
    n_skips = sum(1 for a in assigned for s in get_config(a).shapes
                  if s.skip_reason)
    assert n_assigned == 36
    assert n_skips == 4
    n_paper = sum(len(get_config(a).active_shapes())
                  for a in ("sasrec-recjpq", "gbert4rec-recjpq"))
    assert n_paper == 4


def test_smallest_cell_lowers_on_tiny_mesh(mesh):
    """End-to-end lower() of one real cell (fm retrieval) on 1 device."""
    from repro.distributed import sharding as shd
    bundle = build_step("fm", "retrieval_cand", mesh)
    with shd.activation_plan(bundle.plan):
        lowered = jax.jit(bundle.step_fn, in_shardings=bundle.in_shardings,
                          donate_argnums=bundle.donate).lower(*bundle.args)
    assert "fusion" in lowered.as_text() or len(lowered.as_text()) > 0


def test_adafactor_state_is_factored():
    from repro.training import optimizer as O
    params = {"w": jnp.zeros((512, 256)), "b": jnp.zeros((16,))}
    cfg = O.AdafactorConfig()
    state = O.adafactor_init(params, cfg)
    assert state["v"]["w"]["vr"].shape == (512,)
    assert state["v"]["w"]["vc"].shape == (256,)
    assert state["v"]["b"]["v"].shape == (16,)
    adam_bytes = 2 * 4 * (512 * 256 + 16)
    assert O.adafactor_state_bytes(params) < 0.01 * adam_bytes + 4 * 16 * 3


def test_adafactor_converges_quadratic():
    import numpy as np
    from repro.training import optimizer as O
    target = jnp.asarray([[1.0, -2.0, 0.5], [0.5, 3.0, -1.0]])
    params = {"w": jnp.zeros((2, 3))}
    cfg = O.AdafactorConfig(lr=0.3, warmup_steps=1, schedule="constant")
    state = O.adafactor_init(params, cfg)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda pp: jnp.mean((pp["w"] - target) ** 2))(p)
        return O.adafactor_update(g, s, p, cfg)

    for _ in range(400):
        params, state, m = step(params, state)
    assert float(jnp.mean((params["w"] - target) ** 2)) < 1e-2