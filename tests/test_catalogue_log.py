"""Durable catalogue state (serving/catalogue_log.py, ISSUE 10).

The recovery-exactness contract: for ANY op stream and ANY crash point —
including mid-record, mid-fsync-window, or with the newest snapshot
corrupted — ``CatalogueLog.recover()`` returns a catalogue bit-identical
to an oracle that applied exactly the durable prefix of the stream, and
never raises past ``recover()`` on crash damage.
"""
import os

import numpy as np
import pytest

from repro.core.mutation import MutableHeadState, apply_op
from repro.serving.catalogue_log import (CatalogueLog, _scan, decode_op,
                                         encode_op)
from repro.training.checkpoint import CorruptCheckpointError
from repro.training.fault_tolerance import SimulatedFailure

M, B, TILE = 4, 16, 64
N0 = 500


def _mk_state(seed=0, n=N0):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, B, (n, M), np.int64).astype(np.int8)
    return MutableHeadState.build(codes, B, TILE), rng


def _rand_op(mstate, rng):
    """One random valid op against ``mstate`` (not applied)."""
    live = np.where(np.asarray(mstate.live))[0]
    live = live[live > 0]
    row = rng.integers(0, B, M, np.int64).astype(np.int8)
    kind = rng.choice(["insert", "delete", "update"], p=[0.3, 0.35, 0.35])
    if kind == "insert" and not mstate.free and mstate.n_rows >= mstate.cap:
        kind = "delete"
    if kind == "insert":
        return ("insert", row)
    if kind == "delete":
        return ("delete", int(rng.choice(live)))
    return ("update", int(rng.choice(live)), row)


def _assert_states_equal(got, want):
    np.testing.assert_array_equal(np.asarray(got.codes),
                                  np.asarray(want.codes))
    np.testing.assert_array_equal(np.asarray(got.live), np.asarray(want.live))
    assert got.free == want.free            # FIFO order is part of the state
    assert got.n_rows == want.n_rows


def test_encode_decode_roundtrip():
    rng = np.random.default_rng(0)
    row = rng.integers(0, B, M, np.int64).astype(np.int8)
    for op in [("insert", row), ("delete", 123),
               ("update", 45, row)]:
        back = decode_op(encode_op(op))
        assert back[0] == op[0]
        if op[0] == "insert":
            np.testing.assert_array_equal(np.asarray(back[1], np.int8), row)
        elif op[0] == "delete":
            assert back[1] == op[1]
        else:
            assert back[1] == op[1]
            np.testing.assert_array_equal(np.asarray(back[2], np.int8), row)
    with pytest.raises(ValueError, match="unknown"):
        encode_op(("grow", 1))
    with pytest.raises(ValueError, match="unknown"):
        decode_op(b"X123")


def test_append_read_roundtrip_and_lsn_monotonic(tmp_path):
    mstate, rng = _mk_state()
    ops = [_rand_op(mstate, rng) for _ in range(40)]
    with CatalogueLog(str(tmp_path), fsync_every=8) as log:
        for i, op in enumerate(ops):
            assert log.append(op) == i + 1
        log.sync()
        assert log.lsn == 40
        got = list(log.read_ops())
        assert [l for l, _ in got] == list(range(1, 41))
        # windowed read
        win = list(log.read_ops(after=10, upto=20))
        assert [l for l, _ in win] == list(range(11, 21))
    # reopen: LSN recovered from the scan, appends continue the sequence
    with CatalogueLog(str(tmp_path)) as log2:
        assert log2.lsn == 40
        assert log2.append(ops[0]) == 41


def test_torn_tail_truncated_on_writer_open(tmp_path):
    mstate, rng = _mk_state()
    with CatalogueLog(str(tmp_path), fsync_every=1) as log:
        for _ in range(10):
            log.append(_rand_op(mstate, rng))
    # simulate a torn final record: append garbage half-record bytes
    with open(os.path.join(str(tmp_path), "wal.log"), "ab") as f:
        f.write(b"\x57\x43\x41\x4c partial")
    ro = CatalogueLog(str(tmp_path), read_only=True)
    assert ro.lsn == 10                     # reader stops at the tear...
    size_before = os.path.getsize(ro.path)
    assert ro.torn_bytes_dropped > 0
    assert os.path.getsize(ro.path) == size_before   # ...without truncating
    log2 = CatalogueLog(str(tmp_path))      # writer open truncates
    assert log2.lsn == 10
    records, valid_end = _scan(log2.path)
    assert os.path.getsize(log2.path) == valid_end
    assert len(records) == 10


def test_simulated_writer_crash_mid_append(tmp_path):
    """The chaos hook: fail_at_lsn writes half a record then raises; the
    crashed handle refuses further appends; reopen truncates and recovers
    the durable prefix exactly."""
    mstate, rng = _mk_state(seed=1)
    log = CatalogueLog(str(tmp_path), fsync_every=4)
    log.snapshot(mstate)
    oracle = mstate.clone()
    log.fail_at_lsn = 8
    with pytest.raises(SimulatedFailure, match="mid-append"):
        for _ in range(20):
            op = _rand_op(oracle, rng)
            log.append(op)                  # append-before-apply (WAL)
            apply_op(oracle, op)
    assert oracle.stats()["n_mutations"] == 7.0   # op 8 never made it
    with pytest.raises(RuntimeError, match="crashed"):
        log.append(("delete", 1))
    log2 = CatalogueLog(str(tmp_path))
    assert log2.lsn == 7
    rec, lsn = log2.recover(verify=True)
    assert lsn == 7
    _assert_states_equal(rec, oracle)


def test_recover_snapshot_plus_tail_bit_parity(tmp_path):
    """Snapshot mid-stream, keep appending: recover() = snapshot + tail
    replay is bit-identical to the writer's state, and verify=True checks
    the pruning metadata against rebuild_oracle()."""
    mstate, rng = _mk_state(seed=2)
    with CatalogueLog(str(tmp_path), fsync_every=8) as log:
        log.snapshot(mstate)                # genesis at lsn 0
        for i in range(120):
            op = _rand_op(mstate, rng)
            log.append(op)
            apply_op(mstate, op)
            if i == 60:
                log.snapshot(mstate)        # mid-stream snapshot
        assert log.latest_snapshot_lsn() == 61
        # inside the fsync window an independent reader only sees the
        # flushed prefix — the durability window is real and bounded
        _, lagged = log.recover()
        assert 120 - log.fsync_every < lagged <= 120
        log.sync()
        rec, lsn = log.recover(verify=True)
        assert lsn == 120
        _assert_states_equal(rec, mstate)
        # upto: point-in-time recovery fences the tail
        rec50, l50 = log.recover(upto=50)
        assert l50 == 50
        st = log.stats()
        assert st["n_snapshots"] == 2.0 and st["lsn"] == 120.0


def test_recover_falls_back_past_corrupt_snapshot(tmp_path):
    mstate, rng = _mk_state(seed=3)
    with CatalogueLog(str(tmp_path), fsync_every=4) as log:
        log.snapshot(mstate)
        for i in range(40):
            op = _rand_op(mstate, rng)
            log.append(op)
            apply_op(mstate, op)
            if i in (10, 30):
                log.snapshot(mstate)
        # corrupt the NEWEST snapshot's npz (truncation = torn write)
        log.sync()
        snap = os.path.join(str(tmp_path), "snapshots", "step_0000000031",
                            "catalogue.npz")
        with open(snap, "r+b") as f:
            f.truncate(os.path.getsize(snap) // 2)
        rec, lsn = log.recover(verify=True)      # falls back to lsn-11 snap
        assert lsn == 40
        _assert_states_equal(rec, mstate)


def test_recover_without_snapshot_raises_named_error(tmp_path):
    with CatalogueLog(str(tmp_path)) as log:
        with pytest.raises(CorruptCheckpointError, match="meta"):
            log.recover()


def test_meta_guards_static_shape(tmp_path):
    mstate, _ = _mk_state(seed=4)
    other = MutableHeadState.build(np.asarray(mstate.codes), B, TILE,
                                   capacity=4 * mstate.cap)
    with CatalogueLog(str(tmp_path)) as log:
        log.snapshot(mstate)
        with pytest.raises(ValueError, match="fresh log"):
            log.snapshot(other)


def test_fsync_batching_counts(tmp_path):
    mstate, rng = _mk_state(seed=5)
    with CatalogueLog(str(tmp_path), fsync_every=16) as log:
        for _ in range(32):
            log.append(_rand_op(mstate, rng))
        assert log.n_fsyncs == 2            # 32 appends / 16 per group
        log.sync()
        assert log.n_fsyncs == 2            # nothing unsynced: no-op


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(4))
def test_fuzz_crash_anywhere_recovers_durable_prefix(tmp_path, seed):
    """Property: truncate the log at ANY byte offset (simulating a crash
    at an arbitrary point of the append stream) — recovery never raises
    and lands exactly on the durable prefix an oracle gets by replaying
    the records that survived whole."""
    rng = np.random.default_rng(seed)
    base, _ = _mk_state(seed=10 + seed, n=120)
    d = str(tmp_path / "log")
    with CatalogueLog(d, fsync_every=4) as log:
        log.snapshot(base)
        stream = []
        shadow = base.clone()
        for _ in range(50):
            op = _rand_op(shadow, rng)
            log.append(op)
            apply_op(shadow, op)
            stream.append(op)
    records, valid_end = _scan(os.path.join(d, "wal.log"))
    for _ in range(12):
        cut = int(rng.integers(0, valid_end + 1))
        blob = open(os.path.join(d, "wal.log"), "rb").read()
        trial = str(tmp_path / f"trial_{cut}")
        os.makedirs(trial)
        os.symlink(os.path.join(d, "snapshots"),
                   os.path.join(trial, "snapshots"))
        import shutil
        shutil.copy(os.path.join(d, "meta.json"),
                    os.path.join(trial, "meta.json"))
        with open(os.path.join(trial, "wal.log"), "wb") as f:
            f.write(blob[:cut])
        # the durable prefix: every record wholly inside the cut
        n_whole = sum(1 for (_, _, end) in records if end <= cut)
        oracle = base.clone()
        for op in stream[:n_whole]:
            apply_op(oracle, op)
        rec_log = CatalogueLog(trial, read_only=True)
        assert rec_log.lsn == n_whole
        rec, lsn = rec_log.recover()
        assert lsn == n_whole
        _assert_states_equal(rec, oracle)
