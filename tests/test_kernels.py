"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp oracle in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag import ops as eb_ops, ref as eb_ref
from repro.kernels.pqtopk import ops as pq_ops, ref as pq_ref


@pytest.mark.parametrize("n,m,b,bq,tile", [
    (4096, 8, 256, 4, 1024),
    (4096, 8, 256, 1, 2048),
    (5000, 4, 64, 2, 1024),     # N not a tile multiple -> padding path
    (300, 2, 16, 8, 256),
    (128, 1, 8, 1, 128),
    (8192, 16, 128, 3, 512),
])
def test_pq_scores_kernel_vs_ref(n, m, b, bq, tile):
    codes = jax.random.randint(jax.random.PRNGKey(0), (n, m), 0, b,
                               dtype=jnp.int32)
    s = jax.random.normal(jax.random.PRNGKey(1), (bq, m, b), jnp.float32)
    r_ref = pq_ref.pq_scores(codes, s)
    r_ker = pq_ops.pq_scores(codes, s, tile=tile)
    np.testing.assert_allclose(np.asarray(r_ker), np.asarray(r_ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.int32, jnp.int8])
def test_pq_scores_kernel_code_dtypes(dtype):
    codes = jax.random.randint(jax.random.PRNGKey(0), (1024, 4), 0, 100
                               ).astype(dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 128), jnp.float32)
    r_ref = pq_ref.pq_scores(codes.astype(jnp.int32), s)
    r_ker = pq_ops.pq_scores(codes, s, tile=256)
    np.testing.assert_allclose(np.asarray(r_ker), np.asarray(r_ref),
                               rtol=1e-6)


@pytest.mark.parametrize("k", [1, 10, 100])
def test_pq_topk_fused_kernel(k):
    codes = jax.random.randint(jax.random.PRNGKey(2), (4096, 8), 0, 64,
                               dtype=jnp.int32)
    s = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 64), jnp.float32)
    v_ref, i_ref = pq_ref.pq_topk(codes, s, k)
    v_ker, i_ker = pq_ops.pq_topk(codes, s, k, tile=512)
    np.testing.assert_allclose(np.asarray(v_ker), np.asarray(v_ref),
                               rtol=1e-6)
    # indices must produce identical scores
    r = np.asarray(pq_ref.pq_scores(codes, s))
    np.testing.assert_allclose(
        np.take_along_axis(r, np.asarray(i_ker), 1), np.asarray(v_ref),
        rtol=1e-6)


@pytest.mark.parametrize("v,d,n_bags,bag,mode,weighted", [
    (512, 16, 32, 4, "sum", False),
    (1000, 32, 17, 6, "mean", True),     # odd bag count -> padding path
    (64, 8, 8, 3, "sum", True),
    (2048, 64, 64, 8, "mean", False),
    (128, 128, 9, 1, "sum", False),
])
def test_embedding_bag_kernel_vs_ref(v, d, n_bags, bag, mode, weighted):
    table = jax.random.normal(jax.random.PRNGKey(0), (v, d))
    idx = jax.random.randint(jax.random.PRNGKey(1), (n_bags, bag), -1, v)
    w = (jax.random.uniform(jax.random.PRNGKey(2), (n_bags, bag))
         if weighted else None)
    out_ref = eb_ref.embedding_bag(table, idx, w, mode)
    out_ker = eb_ops.embedding_bag(table, idx, w, mode=mode)
    np.testing.assert_allclose(np.asarray(out_ker), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-6)


def test_embedding_bag_all_padding_bag():
    table = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    idx = jnp.full((4, 3), -1, jnp.int32)
    out = eb_ops.embedding_bag(table, idx, mode="mean")
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


def test_pq_scores_kernel_bf16_subid_scores():
    """bf16 S input with fp32 accumulation inside the kernel."""
    codes = jax.random.randint(jax.random.PRNGKey(4), (2048, 8), 0, 256,
                               dtype=jnp.int32)
    s32 = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 256), jnp.float32)
    s16 = s32.astype(jnp.bfloat16)
    r_ref = pq_ref.pq_scores(codes, s32)
    r_ker = pq_ops.pq_scores(codes, s16.astype(jnp.float32), tile=512)
    # bf16-rounded inputs: tolerance per kernel-taxonomy Part E
    np.testing.assert_allclose(np.asarray(r_ker), np.asarray(r_ref),
                               rtol=2e-2, atol=2e-2)


def test_pq_topk_kernel_tile_sweep():
    """Exactness across tile sizes (tile-local winners are supersets of
    global winners for k <= tile)."""
    codes = jax.random.randint(jax.random.PRNGKey(6), (4096, 4), 0, 64,
                               dtype=jnp.int32)
    s = jax.random.normal(jax.random.PRNGKey(7), (1, 4, 64))
    v_ref, _ = pq_ref.pq_topk(codes, s, 16)
    for tile in (128, 256, 1024, 4096):
        v, _ = pq_ops.pq_topk(codes, s, 16, tile=tile)
        np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref),
                                   rtol=1e-6)
