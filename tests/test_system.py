"""End-to-end behaviour tests: train a small SASRec-RecJPQ on synthetic
data with an SVD codebook and verify the whole pipeline improves ranking —
the paper's system running top to bottom (data -> codebook -> train ->
serve -> metrics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import codebook
from repro.data.sequences import SeqRecDataset
from repro.models import seqrec as S
from repro.training import optimizer as O, train_loop as TL


def _ndcg_at_k(ranks: np.ndarray, k: int = 10) -> float:
    """ranks: 0-based rank of the held-out item per user (or -1 if miss)."""
    hit = (ranks >= 0) & (ranks < k)
    gains = np.zeros(ranks.shape, np.float64)
    # Gains only on valid ranks: np.where evaluates 1/log2(ranks+2) for the
    # misses too (ranks=-1 -> 1/log2(1) = 1/0) and warns on the division.
    gains[hit] = 1.0 / np.log2(ranks[hit] + 2)
    return float(gains.mean())


@pytest.fixture(scope="module")
def trained_model():
    arch = get_reduced("sasrec-recjpq")
    cfg = arch.model
    ds = SeqRecDataset.synthetic(400, cfg.n_items, 12, cfg.max_seq_len + 1,
                                 seed=0)
    users, items = ds.interactions()
    codes, cents = codebook.build_codebook(
        cfg.pq, cfg.n_items + 1, d_model=cfg.d_model,
        interactions=(users, items + 1, len(ds.sequences)))
    params = S.init_seqrec(jax.random.PRNGKey(0), cfg, codes=codes)
    ocfg = O.AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=400)
    opt_state = TL.init_opt_state(params, ocfg)
    step = jax.jit(TL.make_train_step(
        lambda p, b: S.seqrec_loss(p, b, cfg), ocfg))
    it = ds.batches(32, cfg.n_negatives, backbone="sasrec", seed=1)
    first = last = None
    for i in range(150):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, m = step(params, opt_state, batch)
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    return cfg, ds, params, first, last


def test_training_reduces_loss(trained_model):
    _, _, _, first, last = trained_model
    assert last < first * 0.7, (first, last)


def test_serving_beats_random_ndcg(trained_model):
    cfg, ds, params, _, _ = trained_model
    # hold out the last item of each sequence, serve on the prefix
    seqs = ds.sequences
    valid = seqs[:, -1] != 0
    prefix = jnp.asarray(seqs[valid][:, :-1])
    held = seqs[valid][:, -1]
    ids, _ = S.serve_topk(params, prefix, cfg, k=50, method="pqtopk")
    ids = np.asarray(ids)
    ranks = np.full(len(held), -1)
    for u in range(len(held)):
        where = np.nonzero(ids[u] == held[u])[0]
        if len(where):
            ranks[u] = where[0]
    ndcg = _ndcg_at_k(ranks, 10)
    random_ndcg = 10 / cfg.n_items   # expected hits for random ranking
    assert ndcg > 5 * random_ndcg, (ndcg, random_ndcg)


def test_scoring_method_ndcg_invariance(trained_model):
    """Paper Table 3: NDCG identical across scoring methods."""
    cfg, ds, params, _, _ = trained_model
    prefix = jnp.asarray(ds.sequences[:64, :-1])
    results = {}
    for meth in ("dense", "recjpq", "pqtopk", "pqtopk_onehot"):
        ids, vals = S.serve_topk(params, prefix, cfg, k=10, method=meth)
        results[meth] = (np.asarray(ids), np.asarray(vals))
    for meth in ("recjpq", "pqtopk", "pqtopk_onehot"):
        np.testing.assert_allclose(results[meth][1], results["dense"][1],
                                   rtol=1e-3, atol=1e-4)


def test_pq_memory_compression_vs_dense(trained_model):
    cfg, _, params, _, _ = trained_model
    dense_bytes = (cfg.n_items + 1) * cfg.d_model * 4
    pq_bytes = (params["item_emb"]["codes"].size * 4
                + params["item_emb"]["sub_emb"].size * 4)
    assert pq_bytes < dense_bytes
