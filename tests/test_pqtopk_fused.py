"""End-to-end coverage for the fused PQTopK retrieval route
(``method="pqtopk_fused"``): kernel-vs-oracle bit-exactness, parity with the
unfused ``pqtopk`` + ``tiled_topk`` path through every layer (retrieval
head, item-sharded shard_map, serving engine)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import PQConfig
from repro.core import retrieval_head, scoring
from repro.kernels.pqtopk import ops as pq_ops, ref as pq_ref
from repro.serving.engine import Request, RetrievalEngine


def _pq_head(n, d=32, m=4, b=16, bq=3, seed=0):
    params = retrieval_head.init(jax.random.PRNGKey(seed), n, d,
                                 PQConfig(m=m, b=b))
    phi = jax.random.normal(jax.random.PRNGKey(seed + 1), (bq, d))
    return params, phi


# ---------------------------------------------------------------------------
# kernel parity: interpret mode must be BIT-exact against the jnp oracle
# (shared tree_sum accumulation order; one-hot matmuls are exact in f32).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,b,tile", [
    (999, 4, 16, 256),       # odd N -> padding tail inside the last tile
    (1021, 3, 100, 128),     # prime N, b neither 256 nor a power of two
    (4096, 8, 64, 2048),     # b != 256, exact tiling
    (300, 2, 256, 256),      # b == lane width, N < 2 tiles
])
def test_pq_scores_kernel_bitexact_vs_oracle(n, m, b, tile):
    codes = jax.random.randint(jax.random.PRNGKey(0), (n, m), 0, b,
                               dtype=jnp.int32)
    s = jax.random.normal(jax.random.PRNGKey(1), (2, m, b), jnp.float32)
    r_ref = np.asarray(pq_ref.pq_scores(codes, s))
    r_ker = np.asarray(pq_ops.pq_scores(codes, s, tile=tile, interpret=True))
    np.testing.assert_array_equal(r_ker, r_ref)
    # ... and both match Algorithm 1's gather form bit-for-bit.
    r_alg1 = np.asarray(scoring.score_pqtopk(codes, s))
    np.testing.assert_array_equal(r_alg1, r_ref)


def test_pq_scores_kernel_bitexact_small_magnitude():
    """The seed-suite regression: near-zero scores at rtol=1e-6, atol=0
    (1-ulp accumulation-order drift used to fail here)."""
    codes = jax.random.randint(jax.random.PRNGKey(0), (1024, 4), 0, 100
                               ).astype(jnp.int8)
    s = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 128), jnp.float32)
    r_ref = np.asarray(pq_ref.pq_scores(codes.astype(jnp.int32), s))
    r_ker = np.asarray(pq_ops.pq_scores(codes, s, tile=256))
    np.testing.assert_array_equal(r_ker, r_ref)


# ---------------------------------------------------------------------------
# retrieval head: fused route == unfused pqtopk + tiled_topk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1000, 4096, 100_000])
def test_top_items_fused_matches_pqtopk(n):
    params, phi = _pq_head(n)
    k = 10
    v_ref, i_ref = retrieval_head.top_items(params, phi, k, method="pqtopk")
    v_fus, i_fus = retrieval_head.top_items(params, phi, k,
                                            method="pqtopk_fused")
    np.testing.assert_array_equal(np.asarray(v_fus), np.asarray(v_ref))
    # Tie-breaking is index-consistent in both routes (lowest id first), so
    # ids agree exactly, not just score-wise.
    np.testing.assert_array_equal(np.asarray(i_fus), np.asarray(i_ref))


def test_top_items_fused_ties_broken_by_lowest_id():
    """All-identical codes => every item ties; both routes must pick ids
    0..k-1 in order (lax.top_k tie-break semantics)."""
    params, phi = _pq_head(512, m=2, b=8)
    params = dict(params, codes=jnp.zeros_like(params["codes"]))
    v_ref, i_ref = retrieval_head.top_items(params, phi, 5, method="pqtopk")
    v_fus, i_fus = retrieval_head.top_items(params, phi, 5,
                                            method="pqtopk_fused")
    np.testing.assert_array_equal(np.asarray(i_fus), np.asarray(i_ref))
    assert (np.asarray(i_fus) == np.arange(5)[None, :]).all()


def test_top_items_fused_requires_pq():
    params = retrieval_head.init(jax.random.PRNGKey(0), 64, 16, pq=None)
    phi = jax.random.normal(jax.random.PRNGKey(1), (1, 16))
    with pytest.raises(ValueError, match="pqtopk_fused"):
        retrieval_head.top_items(params, phi, 3, method="pqtopk_fused")


def test_score_candidates_fused_subset():
    params, phi = _pq_head(200)
    v_ids = jnp.asarray([0, 7, 63, 199])
    r_sub = retrieval_head.score_candidates(params, phi, v_ids,
                                            method="pqtopk_fused")
    r_all = retrieval_head.score_all(params, phi, "pqtopk")
    np.testing.assert_array_equal(np.asarray(r_sub),
                                  np.asarray(r_all[:, v_ids]))


# ---------------------------------------------------------------------------
# item-sharded: fused per-shard top-k + O(k * shards) merge
# ---------------------------------------------------------------------------

@pytest.mark.sharded
@pytest.mark.parametrize("n", [128, 101])   # 101: shard-padding rows masked
def test_top_items_sharded_fused_matches_plain(n):
    mesh = jax.make_mesh((1,), ("model",))
    params, phi = _pq_head(n, d=16, m=4, b=8, bq=2)
    v1, i1 = retrieval_head.top_items(params, phi, 7, method="pqtopk")
    v2, i2 = retrieval_head.top_items_sharded(params, phi, 7, mesh,
                                              method="pqtopk_fused")
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert (np.asarray(i2) < n).all()


# ---------------------------------------------------------------------------
# serving engine on the fused route
# ---------------------------------------------------------------------------

def _engine(method):
    from repro.models import seqrec as S
    cfg = get_reduced("sasrec-recjpq").model
    params = S.init_seqrec(jax.random.PRNGKey(0), cfg)
    eng = RetrievalEngine.for_seqrec(params, cfg, k=5, max_batch=8,
                                     method=method)
    return eng, cfg


def test_retrieval_engine_fused_matches_pqtopk():
    rng = np.random.default_rng(0)
    seqs = [rng.integers(1, 1000, 8) for _ in range(8)]
    results = {}
    for method in ("pqtopk", "pqtopk_fused"):
        engine, cfg = _engine(method)
        assert engine.method == method
        for i, s in enumerate(seqs):
            engine.submit(Request(i, s, k=5))
        results[method] = {r.request_id: r for r in engine.drain()}
    assert len(results["pqtopk_fused"]) == 8
    for i in range(8):
        np.testing.assert_array_equal(results["pqtopk_fused"][i].scores,
                                      results["pqtopk"][i].scores)
        np.testing.assert_array_equal(results["pqtopk_fused"][i].items,
                                      results["pqtopk"][i].items)


def test_engine_method_defaults_to_config():
    cfg = get_reduced("sasrec-recjpq").model
    from repro.models import seqrec as S
    params = S.init_seqrec(jax.random.PRNGKey(0), cfg)
    eng = RetrievalEngine.for_seqrec(params, cfg)
    assert eng.method == cfg.serve_method
