import numpy as np
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests see 1 device
# (only launch/dryrun.py forces 512 placeholder devices, per the brief).


@pytest.fixture
def rng():
    return np.random.default_rng(0)
