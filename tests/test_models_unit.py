"""Unit tests for model building blocks: attention (chunked vs naive,
windows, GQA), MoE routing, norms, RoPE, embedding bag substrate, AUGRU."""
import pytest

pytest.importorskip("hypothesis")  # keep tier-1 collection green without dev deps
pytestmark = pytest.mark.hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import AttentionConfig, MoEConfig
from repro.models import attention as A, embedding as E, layers as L
from repro.models import moe as M


def _naive_attention(q, k, v, causal=True, window=0):
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) * d ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    q_pos, k_pos = jnp.arange(sq), jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)


@pytest.mark.parametrize("causal,window,chunk", [
    (True, 0, 8), (True, 0, 16), (False, 0, 8),
    (True, 4, 8), (True, 7, 16), (False, 5, 8),
])
def test_chunked_attention_matches_naive(causal, window, chunk):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 24, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 24, 2, 8))
    out = A.chunked_attention(q, k, v, causal=causal, window=window,
                              kv_chunk=chunk)
    ref = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_dynamic_window_matches_static():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 2, 8))
    out_s = A.chunked_attention(q, k, v, causal=True, window=4, kv_chunk=8)
    out_d = A._chunked_attention_dyn_window(q, k, v, causal=True,
                                            window=jnp.int32(4), kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=1e-5, atol=1e-6)


def test_rope_relative_property():
    """RoPE: <rot(q,p1), rot(k,p2)> depends only on p1-p2."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))

    def dot_at(p1, p2):
        qr = L.apply_rope(q, jnp.asarray([[p1]]), 10_000.0)
        kr = L.apply_rope(k, jnp.asarray([[p2]]), 10_000.0)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(7, 7)) < 1e-4


def test_norms():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32)) * 5 + 3
    p_rms = L.norm_init(32, "rmsnorm")
    y = L.apply_norm(p_rms, x, "rmsnorm")
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
    p_ln = L.norm_init(32, "layernorm")
    y2 = np.asarray(L.apply_norm(p_ln, x, "layernorm"))
    np.testing.assert_allclose(y2.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(y2.std(-1), 1.0, rtol=1e-3)


def test_sqrelu_activation():
    f = L.activation("sqrelu")
    x = jnp.asarray([-2.0, 0.0, 3.0])
    np.testing.assert_allclose(np.asarray(f(x)), [0.0, 0.0, 9.0])


def test_moe_routing_topk_and_capacity():
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=2.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, 8, gated=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
    out, aux = M.moe_ffn(p, cfg, x, "silu")
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.5   # load-balance loss near 1 for random router


def test_moe_matches_dense_single_expert():
    """1 expert top-1 == plain MLP with the same weights."""
    cfg = MoEConfig(n_experts=1, top_k=1, d_ff_expert=16,
                    capacity_factor=8.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, 8, gated=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8))
    out, _ = M.moe_ffn(p, cfg, x, "silu")
    mlp_p = {"up": {"w": p["up"][0]}, "gate": {"w": p["gate"][0]},
             "down": {"w": p["down"][0]}}
    ref = L.mlp(mlp_p, x, "silu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(v=st.integers(8, 64), d=st.sampled_from([4, 8]),
       bags=st.integers(1, 10), bag=st.integers(1, 6),
       seed=st.integers(0, 100))
def test_embedding_bag_substrate_matches_manual(v, d, bags, bag, seed):
    table = jax.random.normal(jax.random.PRNGKey(seed), (v, d))
    idx = jax.random.randint(jax.random.PRNGKey(seed + 1), (bags, bag), -1, v)
    out = E.lookup_bag(table, idx, mode="sum")
    ref = np.zeros((bags, d), np.float32)
    t, ix = np.asarray(table), np.asarray(idx)
    for i in range(bags):
        for j in range(bag):
            if ix[i, j] >= 0:
                ref[i] += t[ix[i, j]]
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)


def test_segment_embedding_bag_matches_padded():
    table = jax.random.normal(jax.random.PRNGKey(0), (32, 8))
    idx = jnp.asarray([[1, 2, -1], [5, -1, -1]])
    dense = E.lookup_bag(table, idx, mode="mean")
    flat = jnp.asarray([1, 2, 5])
    seg = jnp.asarray([0, 0, 1])
    ragged = E.segment_embedding_bag(table, flat, seg, 2, mode="mean")
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ragged),
                               rtol=1e-5)


def test_augru_attention_gates_update():
    """AUGRU with attention 0 must keep hidden state unchanged."""
    from repro.models.recsys import _gru_init, gru_scan
    p = _gru_init(jax.random.PRNGKey(0), 4, 6, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 4))
    att0 = jnp.zeros((2, 5))
    hs = gru_scan(p, xs, att0)
    # a_t = 0 => z=0 => h_t = candidate... wait: z scaled by a => z=0 =>
    # h_t = n (candidate); with a=1 it's plain GRU. Verify shape + finite and
    # difference from plain GRU.
    hs_plain = gru_scan(p, xs)
    assert hs.shape == (2, 5, 6)
    assert np.isfinite(np.asarray(hs)).all()
    assert float(jnp.abs(hs - hs_plain).max()) > 1e-6
