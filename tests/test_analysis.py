"""Tests for the serve-path static-analysis framework (repro.analysis).

Two families:

* **framework mechanics** — the jaxpr walker, the report/JSON shapes, the
  CLI, the registry contract (>= 5 entrypoints covering every serving
  route, >= 5 passes).
* **adversarial negative controls** (ISSUE 6 satellite): one deliberately
  broken route per pass — a host-syncing cascade, a callback-smuggling
  serve fn, an unbucketed-k engine spec, an oversized VMEM block spec, an
  unclamped sentinel index map, and tracer-leak / mutable-default
  sources.  Each must FAIL its pass **and only its pass** (skips caused
  by a shared root cause are not failures), proving every pass both
  catches its hazard and stays quiet otherwise.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro import compat
from repro.analysis import run_default
from repro.analysis.core import (Finding, Report, STATUS_FAIL, STATUS_PASS,
                                 STATUS_SKIP, count_primitives, find_eqns,
                                 iter_eqns, run_analysis)
from repro.analysis.entrypoints import (REGISTRY, BuiltEntry, Entrypoint,
                                        StaticArgSpec)
from repro.analysis.passes import default_passes
from repro.analysis.passes.astlint import AstLintPass
from repro.serving.engine import MicroBatcher, RetrievalEngine


def run_on(built: BuiltEntry, name: str = "probe") -> Report:
    """Run the default pass list on one ad-hoc entrypoint."""
    entry = Entrypoint(name, "ad-hoc test entrypoint", lambda: built)
    return run_analysis({name: entry}, default_passes(), lambda _n: built)


# ---------------------------------------------------------------------------
# framework mechanics
# ---------------------------------------------------------------------------

def test_walker_descends_into_nested_jaxprs():
    """iter_eqns must see primitives buried under pjit and cond."""

    def inner(x):
        return jnp.cumsum(x) * 2

    def fn(x):
        return jax.lax.cond(x.sum() > 0, lambda v: jax.jit(inner)(v),
                            lambda v: v, x)

    jaxpr = jax.make_jaxpr(fn)(jnp.ones(4))
    counts = count_primitives(jaxpr)
    assert counts.get("cumsum", 0) >= 1, counts
    hits = find_eqns(jaxpr, ["cumsum"])
    assert hits and all("cond" in path for _, path in hits)


def test_report_json_and_failing_passes():
    report = run_on(BuiltEntry(lambda x: x * 2, (jnp.ones(3),)))
    doc = json.loads(json.dumps(report.to_json()))
    assert doc["ok"] is True
    cells = {(r["entrypoint"], r["pass"]) for r in doc["results"]}
    assert ("probe", "dispatch-count") in cells
    assert report.failing_passes("probe") == []


def test_registry_covers_required_routes():
    """ISSUE 6 acceptance: >= 5 registered entrypoints spanning flat
    fused, pruned, grouped per-query, sharded, and the decode step."""
    required = {"flat_fused", "flat_pruned", "grouped_perquery",
                "sharded_pruned", "lm_decode_step",
                # ISSUE 9: the hierarchical serve routes stay covered by
                # the dispatch/kernel-contract passes.
                "flat_hier", "sharded_hier"}
    assert required <= set(REGISTRY), sorted(REGISTRY)
    assert len(REGISTRY) >= 5
    assert len(default_passes()) >= 5


def test_cli_runs_and_writes_json(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "report.json"
    assert main(["--list"]) == 0
    assert main(["-e", "pruned_tiles_kernel", "--json", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert doc["ok"] is True and doc["results"]


def test_kernel_entrypoints_pass_all(tmp_path):
    """The real compacted-tile kernel routes satisfy every contract on the
    traced pallas_call (static grid, VMEM, tiling, sentinel clamp)."""
    report = run_default(entrypoints=["pruned_tiles_kernel",
                                      "grouped_tiles_kernel"])
    assert report.ok, report.render()
    for name in ("pruned_tiles_kernel", "grouped_tiles_kernel"):
        res = report.result(name, "kernel-contract")
        assert res.status == STATUS_PASS
        assert res.info["n_pallas_calls"] == 1


@pytest.mark.slow
def test_serve_entrypoints_pass_all():
    """Every serve_topk route in the registry is clean under every pass
    (the heavyweight positive control; ci.sh runs the same via the CLI)."""
    names = ["flat_fused", "flat_pruned", "grouped_perquery",
             "sharded_pruned", "lm_decode_step"]
    report = run_default(entrypoints=names)
    assert report.ok, report.render()
    fused = report.result("flat_fused", "kernel-contract")
    assert fused.info["n_pallas_calls"] >= 1


# ---------------------------------------------------------------------------
# adversarial negative controls: each fails its pass, and only its pass
# ---------------------------------------------------------------------------

def _failing(report: Report, name: str = "probe"):
    return report.failing_passes(name)


def test_host_syncing_route_fails_dispatch_only():
    """The PR 2 class of bug: host compaction (np.nonzero on a traced
    value) cannot live in one dispatch.  dispatch-count fails with a
    trace-failure; jaxpr-dependent passes SKIP (one root cause, one
    failure)."""

    def host_route(x):
        mask = np.asarray(x > 0)          # device->host sync under trace
        (idx,) = np.nonzero(mask)
        return x[idx]

    report = run_on(BuiltEntry(host_route, (jnp.arange(8.0),)))
    assert _failing(report) == ["dispatch-count"]
    f = report.result("probe", "dispatch-count").findings[0]
    assert f.code == "trace-failure"
    assert report.result("probe", "host-transfer").status == STATUS_SKIP
    assert report.result("probe", "kernel-contract").status == STATUS_SKIP
    # recompile does not need the trace: it passes (no specs declared)
    assert report.result("probe", "recompile-hazard").status == STATUS_PASS


def test_callback_route_fails_transfer_only():
    """A pure_callback traces fine (single jaxpr!) — only the static
    host-transfer pass catches the per-dispatch Python re-entry."""

    def cb_route(x):
        y = jax.pure_callback(
            lambda a: np.asarray(a) * 2,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1

    report = run_on(BuiltEntry(cb_route, (jnp.ones(4),)))
    assert _failing(report) == ["host-transfer"]
    codes = [f.code for f in report.result("probe", "host-transfer").findings]
    assert "host-callback" in codes


def test_debug_print_is_flagged_as_callback():
    def noisy(x):
        jax.debug.print("x={x}", x=x.sum())
        return x * 2

    report = run_on(BuiltEntry(noisy, (jnp.ones(4),)))
    assert _failing(report) == ["host-transfer"]


def test_big_host_constant_fails_transfer_only():
    big = np.random.default_rng(0).normal(size=(1 << 19,)).astype(np.float32)

    def const_route(x):
        return x + jnp.asarray(big)[: x.shape[0]]

    report = run_on(BuiltEntry(const_route, (jnp.ones(4),)))
    assert _failing(report) == ["host-transfer"]
    codes = [f.code for f in report.result("probe", "host-transfer").findings]
    assert codes == ["host-constant"]


def test_device_params_closure_is_not_flagged():
    """The normal pattern — serve fns closing over device-resident params
    — must NOT look like a host round-trip."""
    w = jnp.asarray(np.random.default_rng(0).normal(size=(1 << 16, 8)),
                    jnp.float32)

    def serve(x):
        return (w * x).sum(axis=0)

    report = run_on(BuiltEntry(serve, (jnp.ones(8),)))
    assert report.ok, report.render()


def test_unbucketed_k_fails_recompile_only():
    """An identity client-k -> static-k mapping (no pow2 bucketing) lets
    every distinct client value key a fresh compile."""
    spec = StaticArgSpec(
        "k", sample=tuple(range(1, 200)), mapper=lambda kv: kv,
        allowed=None, max_variants=12,
        note="deliberately unbucketed")
    report = run_on(BuiltEntry(lambda x: x, (jnp.ones(3),),
                               static_specs=(spec,)))
    assert _failing(report) == ["recompile-hazard"]
    f = report.result("probe", "recompile-hazard").findings[0]
    assert f.code == "unbounded-static-arg"


def test_out_of_bucket_values_fail_recompile():
    spec = StaticArgSpec(
        "batch", sample=(1, 2, 3, 64), mapper=lambda n: n,
        allowed=frozenset({1, 2, 4, 8}), max_variants=8)
    report = run_on(BuiltEntry(lambda x: x, (jnp.ones(3),),
                               static_specs=(spec,)))
    assert _failing(report) == ["recompile-hazard"]
    codes = {f.code for f in
             report.result("probe", "recompile-hazard").findings}
    assert "out-of-bucket" in codes


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def test_oversized_vmem_block_fails_kernel_contract_only():
    """2 x (in + out) f32 blocks of (1024, 2048) ~= 33 MiB >> the 8 MiB
    budget."""
    n = 2048

    def fat(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(2,),
            in_specs=[pl.BlockSpec((1024, n), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((1024, n), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((2048, n), jnp.float32),
            interpret=True,
        )(x)

    report = run_on(BuiltEntry(fat, (jnp.ones((2048, n)),)))
    assert _failing(report) == ["kernel-contract"]
    codes = {f.code for f in
             report.result("probe", "kernel-contract").findings}
    assert codes == {"vmem-budget"}


def test_misaligned_int8_block_fails_tiling():
    """int8 codes tiles must be a multiple of 32 sublanes (or the full
    array): a 48-row block lowers in interpret mode but violates the TPU
    (32, 128) int8 tile."""

    def skewed(c):
        return pl.pallas_call(
            _copy_kernel,
            grid=(2,),
            in_specs=[pl.BlockSpec((48, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((48, 128), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((96, 128), jnp.int8),
            interpret=True,
        )(c)

    report = run_on(BuiltEntry(skewed, (jnp.ones((96, 128), jnp.int8),)))
    assert _failing(report) == ["kernel-contract"]
    codes = {f.code for f in
             report.result("probe", "kernel-contract").findings}
    assert codes == {"tiling"}


def _sentinel_call(clamped: bool):
    """A miniature compacted-tile kernel: codes block driven by a scalar-
    prefetched slot table, with or without the -1 -> 0 clamp."""
    tile, m = 128, 8

    def kernel(idx_ref, codes_ref, o_ref):
        del idx_ref
        o_ref[...] = codes_ref[...].astype(jnp.float32)

    def fn(codes, idx):
        index_map = ((lambda i, idx_ref: (jnp.maximum(idx_ref[i], 0), 0))
                     if clamped else
                     (lambda i, idx_ref: (idx_ref[i], 0)))
        grid_spec = compat.prefetch_scalar_grid_spec(
            num_scalar_prefetch=1,
            grid=(2,),
            in_specs=[pl.BlockSpec((tile, m), index_map)],
            out_specs=pl.BlockSpec((tile, m), lambda i, idx_ref: (i, 0)),
        )
        return pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((2 * tile, m), jnp.float32),
            interpret=True,
        )(idx, codes)

    codes = jnp.ones((2 * tile, m), jnp.int8)
    idx = jnp.asarray([0, -1], jnp.int32)
    return BuiltEntry(fn, (codes, idx), expect_pallas=1)


def test_unclamped_sentinel_index_map_fails_kernel_contract():
    report = run_on(_sentinel_call(clamped=False))
    assert _failing(report) == ["kernel-contract"]
    codes = {f.code for f in
             report.result("probe", "kernel-contract").findings}
    assert codes == {"sentinel-clamp"}


def test_clamped_sentinel_index_map_passes():
    report = run_on(_sentinel_call(clamped=True))
    assert report.ok, report.render()


def test_missing_kernel_is_flagged():
    """An entrypoint promising a Pallas kernel (expect_pallas) that lowers
    to plain XLA fails kernel-contract — the route fell off the kernel."""
    report = run_on(BuiltEntry(lambda x: x * 2, (jnp.ones(4),),
                               expect_pallas=1))
    assert _failing(report) == ["kernel-contract"]
    codes = {f.code for f in
             report.result("probe", "kernel-contract").findings}
    assert codes == {"missing-kernel"}


# ---------------------------------------------------------------------------
# ast-lint negative controls (pure source-level, no imports executed)
# ---------------------------------------------------------------------------

def test_astlint_flags_module_level_jnp_constant():
    src = ("import jax.numpy as jnp\n"
           "NEG_INF = jnp.float32(-jnp.inf)\n"
           "def ok():\n"
           "    return jnp.float32(0)\n")
    findings = AstLintPass(roots=[]).lint_source(src, "fake.py")
    assert [f.code for f in findings] == ["module-jnp-const"]
    assert findings[0].details["line"] == 2


def test_astlint_flags_mutable_default():
    src = "def f(x, acc=[]):\n    return acc\n"
    findings = AstLintPass(roots=[]).lint_source(src, "fake.py")
    assert [f.code for f in findings] == ["mutable-default"]


def test_astlint_clean_module_and_call_time_jnp_ok():
    src = ("import jax.numpy as jnp\n"
           "NEG_INF = float('-inf')\n"
           "class C:\n"
           "    def m(self):\n"
           "        return jnp.zeros(3)\n"
           "def f(x, acc=None):\n"
           "    return jnp.asarray(x)\n")
    assert AstLintPass(roots=[]).lint_source(src, "fake.py") == []


def test_astlint_flags_class_body_jnp_constant():
    src = ("import jax.numpy as jnp\n"
           "class C:\n"
           "    BAD = jnp.zeros(3)\n")
    findings = AstLintPass(roots=[]).lint_source(src, "fake.py")
    assert [f.code for f in findings] == ["module-jnp-const"]


def test_repro_sources_are_astlint_clean():
    """The live tree stays clean (this is what caught and now guards the
    topk.py NEG_INF tracer-leak instance)."""
    findings, info = AstLintPass().run("<sources>", None, None)
    assert findings == [], "\n".join(f.message for f in findings)
    assert info["n_files"] > 50


# ---------------------------------------------------------------------------
# engine bucketing: the real mapping the recompile pass probes
# ---------------------------------------------------------------------------

def _dummy_engine(k=5, max_k=100, max_batch=8):
    return RetrievalEngine(lambda seqs, kk: (seqs[:, :kk], seqs[:, :kk]),
                           seq_len=16, k=k, max_k=max_k,
                           max_batch=max_batch, jit_serve=False)


def test_engine_batch_k_is_bounded_and_clamped():
    eng = _dummy_engine()
    image = {eng.batch_k([kv]) for kv in range(1, 1000)}
    allowed = {1, 2, 4, 8, 16, 32, 64, 100}
    assert image <= allowed, image
    assert len(image) <= eng.max_k.bit_length() + 1
    assert eng.batch_k([10 ** 9]) == 100          # clamped to max_k
    assert eng.batch_k([0]) == 8                  # floored at engine k=5
    assert eng.batch_k([3, 40, 2]) == 64          # batch max, bucketed


def test_engine_batch_k_matches_run_once_policy():
    """batch_k is the factored-out run_once policy: max over clamped
    client ks, floored at engine k, pow2-bucketed."""
    eng = _dummy_engine(k=2, max_k=64)
    for ks in ([1], [2, 7], [63], [64, 1], [200, 3]):
        kk = max(max(min(int(kv), eng.max_k) for kv in ks), eng.k, 1)
        assert eng.batch_k(ks) == MicroBatcher.bucket(kk, eng.max_k)


def test_micro_batcher_bucket_pow2():
    assert [MicroBatcher.bucket(n, 64) for n in (1, 2, 3, 5, 33, 64, 200)] \
        == [1, 2, 4, 8, 64, 64, 64]


# ---------------------------------------------------------------------------
# bench provenance (fingerprint refusal in bench_compare)
# ---------------------------------------------------------------------------

def _bench_doc(pr, fingerprint):
    doc = {"pr": pr, "rows": [{"section": "kernel",
                               "name": f"kernel/cell/pr{pr}",
                               "method": "pqtopk", "median_us": 1.0,
                               "items_per_s": 1e6, "tags": {}}]}
    if fingerprint is not None:
        doc["fingerprint"] = fingerprint
    return doc


def _write_benches(tmp_path, fps):
    paths = []
    for i, fp in enumerate(fps):
        p = tmp_path / f"BENCH_pr{i + 1}.json"
        p.write_text(json.dumps(_bench_doc(i + 1, fp)))
        paths.append(str(p))
    return paths


def test_bench_compare_refuses_mixed_fingerprints(tmp_path):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_compare", "scripts/bench_compare.py")
    bc = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(bc)

    fp_a = {"jax": "0.4.37", "backend": "cpu", "threads": "unpinned"}
    fp_b = {"jax": "0.5.0", "backend": "tpu", "threads": "unpinned"}

    same = _write_benches(tmp_path, [fp_a, fp_a])
    assert bc.main(same) == 0
    mixed = _write_benches(tmp_path, [fp_a, fp_b])
    assert bc.main(mixed) == 2                       # refused
    assert bc.main(mixed + ["--allow-mixed"]) == 0   # explicit override
    legacy = _write_benches(tmp_path, [None, fp_a])  # pre-PR6 file: warn
    assert bc.main(legacy) == 0


def test_bench_run_fingerprint_shape():
    from benchmarks.run import environment_fingerprint
    fp = environment_fingerprint()
    assert {"python", "jax", "jaxlib", "backend", "threads"} <= set(fp)
    assert fp["jax"] == jax.__version__


# ---------------------------------------------------------------------------
# engine entrypoints under the framework (the heavyweight runtime proof)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_aot_single_dispatch_via_framework():
    report = run_default(entrypoints=["engine_aot"])
    assert report.ok, report.render()
    res = report.result("engine_aot", "dispatch-count")
    assert res.info["runtime_dispatches"] == 1
    rec = report.result("engine_aot", "recompile-hazard")
    assert rec.info["n_specs"] >= 3


@pytest.mark.slow
def test_router_replicated_single_dispatch_via_framework():
    """PR 8 acceptance: the replicated fabric serves a healthy-path batch
    as exactly one compiled dispatch on exactly one replica, with the
    replica id provably never keying a compile."""
    report = run_default(entrypoints=["router_replicated"])
    assert report.ok, report.render()
    res = report.result("router_replicated", "dispatch-count")
    assert res.info["runtime_dispatches"] == 1
    rec = report.result("router_replicated", "recompile-hazard")
    assert rec.info["n_specs"] >= 4
