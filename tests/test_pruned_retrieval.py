"""Cascaded pruned retrieval (``method="pqtopk_pruned"``) + the rebuilt
fused kernel: exactness against the ``score_pqtopk`` + ``tiled_topk``
oracle across the acceptance matrix (odd N, b in {64, 256}, int8/uint8/
int32 codes, B in {1, 8, 200}, item-sharded), batch-tiling parity, bound
tightness, and the satellite fixes (tiled_topk -inf padding, approx
route, per-request k in the serving engine)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import PQConfig, min_code_dtype
from repro.core import pruning, retrieval_head, scoring, topk as topk_lib
from repro.kernels.pqtopk import ops as pq_ops, ref as pq_ref
from repro.serving.engine import Request, RetrievalEngine


def _oracle(codes, s, k):
    r = scoring.score_pqtopk(codes.astype(jnp.int32), s)
    return topk_lib.tiled_topk(r, k)


def _make_case(n, m, b, bq, *, code_dtype=jnp.int32, clustered=False,
               skewed=False, seed=0):
    rng = np.random.default_rng(seed)
    if clustered:
        centers = (np.arange(n) / n * b).astype(np.int64)
        codes_np = (centers[:, None] + rng.integers(-1, 2, (n, m))) % b
    else:
        codes_np = rng.integers(0, b, (n, m))
    codes = jnp.asarray(codes_np, code_dtype)
    g = rng.standard_normal((bq, m, b))
    if skewed:
        g = np.sign(g) * np.abs(g) ** 3
    s = jnp.asarray(g, jnp.float32)
    return codes, s


# ---------------------------------------------------------------------------
# cascade exactness: bit-identical values AND ids vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bq", [1, 8, 200])
@pytest.mark.parametrize("n,b,dtype", [
    (999, 64, jnp.int8),       # odd N, int8 codes
    (1021, 256, jnp.uint8),    # prime N, uint8 codes (b=256 > int8 range)
    (2048, 64, jnp.int32),     # exact tiling, int32 fallback
    (3001, 256, jnp.int32),
])
def test_cascade_matches_oracle(n, b, dtype, bq):
    m = 4
    codes, s = _make_case(n, m, b, bq, code_dtype=dtype, seed=n + bq)
    k = 10
    v_ref, i_ref = _oracle(codes, s, k)
    v, i = pruning.cascade_topk(codes, s, k, tile=256)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_cascade_actually_prunes_and_stays_exact():
    """Clustered codes + skewed scores: the favourable regime — assert the
    survival fraction is < 1 AND the result is still bit-exact."""
    codes, s = _make_case(1 << 14, 8, 256, 2, clustered=True, skewed=True)
    k = 10
    v_ref, i_ref = _oracle(codes, s, k)
    v, i, stats = pruning.cascade_topk(codes, s, k, tile=512,
                                       return_stats=True)
    assert stats["survival_fraction"] < 1.0, stats
    assert stats["n_survived"] < stats["n_tiles"]
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_cascade_kernel_path_matches_xla_path():
    codes, s = _make_case(5000, 4, 64, 3, code_dtype=jnp.int8,
                          clustered=True, skewed=True)
    k = 7
    out = [pruning.cascade_topk(codes, s, k, tile=512, use_kernel=uk,
                                interpret=True) for uk in (False, True)]
    np.testing.assert_array_equal(np.asarray(out[0][0]),
                                  np.asarray(out[1][0]))
    np.testing.assert_array_equal(np.asarray(out[0][1]),
                                  np.asarray(out[1][1]))


def test_cascade_ties_broken_by_lowest_id():
    """All-identical codes -> every item ties; the cascade must preserve
    lax.top_k's lowest-id-first order through compaction and merge."""
    n, m, b = 700, 2, 8
    codes = jnp.zeros((n, m), jnp.int32)
    s = jax.random.normal(jax.random.PRNGKey(0), (2, m, b), jnp.float32)
    v_ref, i_ref = _oracle(codes, s, 5)
    v, i = pruning.cascade_topk(codes, s, 5, tile=128)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    assert (np.asarray(i) == np.arange(5)[None, :]).all()


# ---------------------------------------------------------------------------
# retrieval head routes (host cascade, in-graph fallback, sharded)
# ---------------------------------------------------------------------------

def _pq_head(n, d=32, m=4, b=16, bq=3, seed=0, code_dtype="int32"):
    params = retrieval_head.init(jax.random.PRNGKey(seed), n, d,
                                 PQConfig(m=m, b=b, code_dtype=code_dtype))
    phi = jax.random.normal(jax.random.PRNGKey(seed + 1), (bq, d))
    return params, phi


@pytest.mark.parametrize("n,bq", [(1000, 1), (4097, 8)])
def test_top_items_pruned_matches_pqtopk(n, bq):
    params, phi = _pq_head(n, bq=bq)
    k = 9
    v_ref, i_ref = retrieval_head.top_items(params, phi, k, method="pqtopk")
    v, i, stats = retrieval_head.top_items_pruned(params, phi, k, tile=512,
                                                  return_stats=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    assert stats["n_tiles"] == -(-n // 512)


def test_top_items_pruned_ingraph_jit():
    """method="pqtopk_pruned" through top_items is jit-compatible (masked
    in-graph cascade) and bit-exact."""
    params, phi = _pq_head(3000, bq=2)
    v_ref, i_ref = retrieval_head.top_items(params, phi, 6, method="pqtopk")
    fn = jax.jit(lambda p, x: retrieval_head.top_items(
        p, x, 6, method="pqtopk_pruned"))
    v, i = fn(params, phi)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_top_items_pruned_requires_pq():
    params = retrieval_head.init(jax.random.PRNGKey(0), 64, 16, pq=None)
    phi = jax.random.normal(jax.random.PRNGKey(1), (1, 16))
    with pytest.raises(ValueError, match="pqtopk_pruned"):
        retrieval_head.top_items(params, phi, 3, method="pqtopk_pruned")
    with pytest.raises(ValueError, match="PQ head"):
        retrieval_head.top_items_pruned(params, phi, 3)


@pytest.mark.sharded
@pytest.mark.parametrize("n", [128, 1013])   # odd N -> padding tail
def test_top_items_pruned_sharded_matches_plain(n):
    mesh = jax.make_mesh((1,), ("model",))
    params, phi = _pq_head(n, d=16, m=4, b=8, bq=2, code_dtype="uint8")
    v1, i1 = retrieval_head.top_items(params, phi, 7, method="pqtopk")
    v2, i2 = retrieval_head.top_items_sharded(params, phi, 7, mesh,
                                              method="pqtopk_pruned")
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert (np.asarray(i2) < n).all()


# ---------------------------------------------------------------------------
# rebuilt fused kernel: batch tiling + int8 codes, interpret parity atol=0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.int8, jnp.uint8, jnp.int32])
def test_fused_kernel_batch_tiled_parity(dtype):
    """B=200 > batch_tile=64 engages the batch-tile grid axis; parity with
    the oracle must be exact (atol=0) for 8-bit and int32 codes."""
    n, m, b, bq, k = 2500, 4, 100, 200, 11
    codes, s = _make_case(n, m, b, bq, code_dtype=dtype, seed=5)
    v_ref, i_ref = pq_ref.pq_topk(codes.astype(jnp.int32), s, k)
    v, i = pq_ops.pq_topk(codes, s, k, tile=512, batch_tile=64,
                          interpret=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_fused_kernel_single_pass_blocks():
    """pick_blocks: k-oversampled, power-of-two, divides the tile."""
    from repro.kernels.pqtopk.kernel import pick_blocks
    assert pick_blocks(2048, 10) == 32          # >= 2*k, pow2
    assert pick_blocks(2048, 100) == 128        # capped at lane width
    assert pick_blocks(128, 10) == 32
    for tile in (128, 256, 2048):
        for k in (1, 5, 64, 128):
            c = pick_blocks(tile, k)
            assert tile % c == 0 and c >= 1


def test_pq_topk_tiles_sentinel_padding():
    """Sentinel-padded slots emit -inf and never reach the top-k."""
    n, m, b, tile, k = 1000, 4, 16, 256, 5
    codes, s = _make_case(n, m, b, 2, seed=9)
    v_ref, i_ref = _oracle(codes, s, k)
    t = pq_ops.n_tiles(n, tile)
    idx = np.full(8, pq_ops.sentinel_tile(n, tile), np.int32)
    idx[:t] = np.arange(t)
    for uk in (False, True):
        v, i = pq_ops.pq_topk_tiles(codes, s, k, jnp.asarray(idx), tile=tile,
                                    use_kernel=uk, interpret=True)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


# ---------------------------------------------------------------------------
# bound semantics
# ---------------------------------------------------------------------------

def test_tile_bounds_dominate_tile_scores():
    codes, s = _make_case(2000, 4, 32, 3, seed=2)
    tile = 256
    meta = pruning.build_tile_metadata(codes, 32, tile)
    bounds = np.asarray(pruning.tile_upper_bounds(meta.present, s))
    r = np.asarray(scoring.score_pqtopk(codes, s))
    for t in range(meta.n_tiles):
        seg = r[:, t * tile:(t + 1) * tile].max(axis=1)
        assert (bounds[:, t] >= seg).all()


def test_tile_bound_tight_for_single_item_tile():
    """tile=1: the bound IS the item's score, bit-for-bit (shared tree_sum
    accumulation order)."""
    codes, s = _make_case(64, 4, 16, 2, seed=3)
    meta = pruning.build_tile_metadata(codes, 16, 1)
    bounds = np.asarray(pruning.tile_upper_bounds(meta.present, s))
    r = np.asarray(scoring.score_pqtopk(codes, s))
    np.testing.assert_array_equal(bounds, r)


def test_theta_is_certified():
    """At least k items must score >= theta for every query."""
    codes, s = _make_case(5000, 4, 64, 4, seed=4)
    k, tile = 10, 512
    meta = pruning.build_tile_metadata(codes, 64, tile)
    bounds = pruning.tile_upper_bounds(meta.present, s)
    theta = np.asarray(pruning.theta_from_seed(codes, s, bounds, k,
                                               tile=tile, n_seed=2))
    r = np.asarray(scoring.score_pqtopk(codes, s))
    assert ((r >= theta[:, None]).sum(axis=1) >= k).all()


def test_metadata_cache_reuses_and_rebuilds():
    codes, _ = _make_case(1000, 2, 16, 1)
    m1 = pruning.get_tile_metadata(codes, 16, 256)
    m2 = pruning.get_tile_metadata(codes, 16, 256)
    assert m1 is m2
    assert pruning.get_tile_metadata(codes, 16, 128) is not m1


# ---------------------------------------------------------------------------
# satellite: tiled_topk pads odd N with -inf (no full-sort fallback)
# ---------------------------------------------------------------------------

def test_tiled_topk_odd_n_regression(monkeypatch):
    tile = 1024
    n = 3 * tile + 17
    scores = jax.random.normal(jax.random.PRNGKey(0), (2, n), jnp.float32)
    v_ref, i_ref = jax.lax.top_k(scores, 9)
    widths = []
    orig = jax.lax.top_k

    def spy(x, kk):
        widths.append(x.shape[-1])
        return orig(x, kk)

    monkeypatch.setattr(jax.lax, "top_k", spy)
    v, i = topk_lib.tiled_topk(scores, 9, tile=tile)
    monkeypatch.undo()
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    # The perf cliff was a full lax.top_k sort over all N columns; the
    # padded path must never sort wider than one tile (+ the winner merge).
    assert max(widths) <= tile, widths


def test_tiled_topk_padding_never_wins():
    scores = jnp.full((1, 2 * 8192 + 1), -1e30, jnp.float32)
    v, i = topk_lib.tiled_topk(scores, 4)
    assert (np.asarray(i) < scores.shape[1]).all()
    assert np.isfinite(np.asarray(v)).all()


# ---------------------------------------------------------------------------
# satellite: approximate block-max route wired as pqtopk_approx
# ---------------------------------------------------------------------------

def test_pqtopk_approx_recall_vs_oracle():
    params, phi = _pq_head(50_000, d=32, m=4, b=64, bq=4, seed=7)
    k = 10
    v_ref, i_ref = retrieval_head.top_items(params, phi, k, method="pqtopk")
    v, i = retrieval_head.top_items(params, phi, k, method="pqtopk_approx")
    i, i_ref = np.asarray(i), np.asarray(i_ref)
    recall = np.mean([len(set(i[q]) & set(i_ref[q])) / k
                      for q in range(i.shape[0])])
    # Block-max with oversample=2 gives ~1 - k/(2*n_blocks) expected
    # recall (~0.75 here); assert a loose floor for seed stability.
    assert recall >= 0.5, recall
    # Returned values are genuine scores of the returned ids.
    r = np.asarray(retrieval_head.score_all(params, phi, "pqtopk"))
    np.testing.assert_array_equal(
        np.asarray(v), np.take_along_axis(r, i, axis=1))


def test_pqtopk_approx_in_methods_tuple():
    assert "pqtopk_approx" in retrieval_head.TOP_ITEMS_METHODS
    assert "pqtopk_pruned" in retrieval_head.TOP_ITEMS_METHODS


# ---------------------------------------------------------------------------
# satellite: per-request k in the serving engine
# ---------------------------------------------------------------------------

def _engine(method, k=5):
    from repro.models import seqrec as S
    cfg = get_reduced("sasrec-recjpq").model
    params = S.init_seqrec(jax.random.PRNGKey(0), cfg)
    eng = RetrievalEngine.for_seqrec(params, cfg, k=k, max_batch=8,
                                     method=method)
    return eng, cfg


def test_engine_mixed_k_batch():
    """Requests with different k in ONE batch: score at max(k), slice per
    request — the k=7 request must get 7 genuine winners, not a truncated
    or padded 5."""
    rng = np.random.default_rng(0)
    seqs = [rng.integers(1, 1000, 8) for _ in range(4)]
    ks = [3, 7, 5, 2]
    eng, _ = _engine("pqtopk", k=2)
    for i, (sq, kk) in enumerate(zip(seqs, ks)):
        eng.submit(Request(i, sq, k=kk))
    res = {r.request_id: r for r in eng.run_once()}
    assert len(res) == 4
    for i, kk in enumerate(ks):
        assert res[i].items.shape == (kk,)
        assert res[i].scores.shape == (kk,)
    # Every result is the exact prefix of a reference engine run at k=7.
    ref_eng, _ = _engine("pqtopk", k=7)
    for i, sq in enumerate(seqs):
        ref_eng.submit(Request(100 + i, sq, k=7))
    ref = {r.request_id - 100: r for r in ref_eng.drain()}
    for i, kk in enumerate(ks):
        np.testing.assert_array_equal(res[i].items, ref[i].items[:kk])
        np.testing.assert_array_equal(res[i].scores, ref[i].scores[:kk])


def test_engine_clamps_and_buckets_client_k():
    """Client-supplied Request.k is untrusted: oversized k must be clamped
    to max_k (not forwarded to serve_fn, where it would abort the whole
    batch), k<1 must not produce empty/negative slices, and distinct
    in-range values must collapse onto power-of-two buckets so adversarial
    or merely diverse traffic cannot drive unbounded jit recompiles."""
    calls = []

    def serve_fn(seqs, kk):
        calls.append(kk)
        ids = jnp.tile(jnp.arange(kk, dtype=jnp.int32)[None],
                       (seqs.shape[0], 1))
        return ids, jnp.zeros((seqs.shape[0], kk), jnp.float32)

    eng = RetrievalEngine(serve_fn, seq_len=4, k=2, max_k=16,
                          jit_serve=False)
    eng.submit(Request(0, np.asarray([1]), k=5000))
    eng.submit(Request(1, np.asarray([1]), k=0))
    res = {r.request_id: r for r in eng.run_once()}
    assert calls == [16]                    # clamped, batch not aborted
    assert res[0].items.shape == (16,)      # oversized k -> max_k winners
    assert res[1].items.shape == (1,)       # degenerate k -> 1 winner
    for i, kk in enumerate((5, 6, 7, 8)):   # one batch per distinct k
        eng.submit(Request(10 + i, np.asarray([1]), k=kk))
        eng.run_once()
    assert calls[1:] == [8, 8, 8, 8]        # one bucket, one compile
    # Without an explicit max_k the cap defaults to the engine's own k —
    # the only k a bare serve_fn is guaranteed to support (e.g. small
    # catalogues where 1024 winners don't exist).
    calls.clear()
    eng2 = RetrievalEngine(serve_fn, seq_len=4, k=3, jit_serve=False)
    eng2.submit(Request(0, np.asarray([1]), k=999))
    res2 = eng2.run_once()
    assert calls == [3] and res2[0].items.shape == (3,)


def test_engine_pruned_route_matches_pqtopk():
    rng = np.random.default_rng(1)
    seqs = [rng.integers(1, 1000, 8) for _ in range(4)]
    results = {}
    for method in ("pqtopk", "pqtopk_pruned"):
        eng, _ = _engine(method)
        assert eng.method == method
        for i, sq in enumerate(seqs):
            eng.submit(Request(i, sq, k=5))
        results[method] = {r.request_id: r for r in eng.drain()}
    for i in range(4):
        np.testing.assert_array_equal(results["pqtopk_pruned"][i].items,
                                      results["pqtopk"][i].items)
        np.testing.assert_array_equal(results["pqtopk_pruned"][i].scores,
                                      results["pqtopk"][i].scores)


# ---------------------------------------------------------------------------
# satellite: int8/uint8 code storage config validation
# ---------------------------------------------------------------------------

def test_pqconfig_code_dtype_validation():
    PQConfig(m=2, b=128, code_dtype="int8")        # fits
    PQConfig(m=2, b=256, code_dtype="uint8")       # fits
    with pytest.raises(ValueError, match="does not fit"):
        PQConfig(m=2, b=256, code_dtype="int8")
    with pytest.raises(ValueError, match="unsupported code_dtype"):
        PQConfig(m=2, b=16, code_dtype="float32")
    assert min_code_dtype(256) == "uint8"
    assert min_code_dtype(512) == "uint16"


def test_pq_head_stores_narrow_codes():
    params, _ = _pq_head(100, b=16, code_dtype="uint8")
    assert params["codes"].dtype == jnp.uint8
