"""PQ embedding + codebook builder invariants (hypothesis where useful)."""
import pytest

pytest.importorskip("hypothesis")  # keep tier-1 collection green without dev deps
pytestmark = pytest.mark.hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import PQConfig
from repro.core import codebook, pq


def test_reconstruct_concat_matches_manual():
    cfg = PQConfig(m=4, b=8)
    params = pq.init_pq_embedding(jax.random.PRNGKey(0), cfg, 20, 16)
    ids = jnp.asarray([0, 7, 19])
    w = pq.reconstruct(params, ids)
    assert w.shape == (3, 16)
    codes = np.asarray(params["codes"])
    sub = np.asarray(params["sub_emb"])
    for r, i in enumerate([0, 7, 19]):
        manual = np.concatenate([sub[k, codes[i, k]] for k in range(4)])
        np.testing.assert_allclose(np.asarray(w[r]), manual, rtol=1e-6)


def test_compression_ratio_formula():
    cfg = PQConfig(m=8, b=256)
    # Gowalla-like: 1.27M items, d=512 -> paper reports up to ~50x for
    # RecJPQ-scale settings; with int32 codes the ratio is ~47x here.
    r = pq.compression_ratio(cfg, 1_271_638, 512)
    assert r > 40, r


@settings(max_examples=10, deadline=None)
@given(n=st.integers(5, 200), m=st.sampled_from([2, 4]),
       b=st.sampled_from([4, 16]), seed=st.integers(0, 1000))
def test_random_codebook_in_range(n, m, b, seed):
    cfg = PQConfig(m=m, b=b, assign="random")
    codes, cents = codebook.build_codebook(cfg, n, seed=seed)
    assert codes.shape == (n, m)
    assert codes.min() >= 0 and codes.max() < b
    assert cents is None


def test_kmeans_codebook_reconstruction_quality():
    """PQ on clusterable data: k-means reconstruction must beat random."""
    rng = np.random.default_rng(0)
    centers = rng.normal(0, 5, (8, 32))
    data = (centers[rng.integers(0, 8, 500)]
            + rng.normal(0, 0.1, (500, 32))).astype(np.float32)
    cfg = PQConfig(m=4, b=8, assign="kmeans")
    codes, cents = codebook.build_codebook(cfg, 500, embeddings=data)
    recon = np.concatenate(
        [cents[k][codes[:, k]] for k in range(4)], axis=1)
    err_pq = np.mean((recon - data) ** 2)
    rand_codes = codebook.build_random(500, cfg)
    recon_r = np.concatenate(
        [cents[k][rand_codes[:, k]] for k in range(4)], axis=1)
    err_rand = np.mean((recon_r - data) ** 2)
    assert err_pq < 0.5 * err_rand, (err_pq, err_rand)


def test_svd_codebook_groups_cooccurring_items():
    """RecJPQ SVD assignment: items with identical interaction patterns
    should land in the same sub-id cells more often than random pairs."""
    rng = np.random.default_rng(0)
    n_users, n_items = 200, 60
    # Two disjoint item communities.
    users, items = [], []
    for u in range(n_users):
        com = u % 2
        its = rng.integers(0, 30, 10) + com * 30
        users += [u] * len(its)
        items += list(its)
    cfg = PQConfig(m=4, b=4, assign="svd")
    codes, _ = codebook.build_codebook(
        cfg, n_items, d_model=32,
        interactions=(np.asarray(users), np.asarray(items), n_users))
    same_com, diff_com = [], []
    for a in range(0, 30, 3):
        for b_ in range(a + 1, 30, 7):
            same_com.append((codes[a] == codes[b_]).mean())
            diff_com.append((codes[a] == codes[b_ + 30]).mean())
    assert np.mean(same_com) > np.mean(diff_com)


def test_abstract_matches_concrete_shapes():
    cfg = PQConfig(m=4, b=16)
    abs_p = pq.abstract_pq_embedding(cfg, 100, 32)
    con_p = pq.init_pq_embedding(jax.random.PRNGKey(0), cfg, 100, 32)
    for a, c in zip(jax.tree.leaves(abs_p), jax.tree.leaves(con_p)):
        assert a.shape == c.shape and a.dtype == c.dtype
