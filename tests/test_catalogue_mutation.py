"""Streaming catalogue mutation: exactness under churn, hot swap, faults.

The load-bearing property (docs/PRUNING.md §Catalogue mutation): after ANY
interleaving of insert / delete / update with queries, the pruned cascade
over the incrementally maintained ``MutableHeadState`` — stale bounds,
tombstone mask and all — returns bit-identical top-k to an exhaustive
oracle over the current live catalogue, and a full ``retighten()`` makes
the metadata bit-identical to a from-scratch rebuild.  On top of that the
serving engine must hot-swap mutated heads with ZERO recompiles and
degrade gracefully (retry, shed) under injected faults.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pruning, scoring
from repro.core.mutation import CapacityError, MutableHeadState, next_pow2

M, B, D, K, TILE = 4, 16, 32, 8, 64
N0 = 500                       # initial rows -> capacity 512 = 8 tiles


def _mk_catalogue(seed=0, n=N0):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, B, (n, M), np.int32).astype(np.int8))
    sub_emb = jnp.asarray(rng.normal(size=(M, B, D // M)).astype(np.float32))
    return codes, sub_emb, rng


def _oracle_fn(sub_emb):
    """Exhaustive masked top-k with THE accumulation order (tree_sum)."""

    @jax.jit
    def oracle(codes, live, phi):
        s = scoring.subid_scores(sub_emb, phi)
        parts = [s[:, j, codes[:, j].astype(jnp.int32)] for j in range(M)]
        sc = jnp.where(live[None, :], scoring.tree_sum(parts), -jnp.inf)
        return jax.lax.top_k(sc, K)

    return oracle


def _churn_step(mstate, rng):
    """One random mutation; returns the op applied (for diagnostics)."""
    live_np = np.asarray(mstate.live)
    live_ids = np.where(live_np)[0]
    live_ids = live_ids[live_ids > 0]          # row 0 is the padding id
    op = rng.choice(["insert", "delete", "update"], p=[0.3, 0.35, 0.35])
    row = jnp.asarray(rng.integers(0, B, M, np.int64).astype(np.int8))
    if op == "insert":
        try:
            mstate.insert(row)
        except CapacityError:
            op = "delete"
    if op == "delete" and live_ids.size > K + 4:
        mstate.delete(int(rng.choice(live_ids)))
    elif op == "update":
        mstate.update(int(rng.choice(live_ids)), row)
    return op


@pytest.mark.parametrize("backend", ["bitmask", "range"])
def test_churn_flat_exactness(backend):
    """>= 200 interleaved mutation/query steps, flat route, under jit:
    every query bit-matches the exhaustive masked oracle and never
    surfaces a tombstoned item."""
    codes, sub_emb, rng = _mk_catalogue()
    mstate = MutableHeadState.build(codes, B, TILE, backend=backend)
    oracle = _oracle_fn(sub_emb)

    @jax.jit
    def cascade(c, lv, state, phi):
        s = scoring.subid_scores(sub_emb, phi)
        v, i, *_ = pruning.cascade_topk_ingraph(c, s, K, state, tile=TILE,
                                                live=lv)
        return v, i

    n_steps, n_queries = 220, 0
    for step in range(n_steps):
        if rng.random() < 0.3 or step == n_steps - 1:
            phi = jnp.asarray(rng.normal(size=(3, D)).astype(np.float32))
            ha = mstate.head_arrays()
            v, i = cascade(ha["codes"], ha["live"], ha["pruned"], phi)
            ov, oi = oracle(ha["codes"], ha["live"], phi)
            np.testing.assert_array_equal(np.asarray(v), np.asarray(ov),
                                          err_msg=f"step {step}")
            np.testing.assert_array_equal(np.asarray(i), np.asarray(oi),
                                          err_msg=f"step {step}")
            dead = np.where(~np.asarray(ha["live"]))[0]
            assert not np.isin(np.asarray(i), dead).any(), f"step {step}"
            n_queries += 1
        else:
            _churn_step(mstate, rng)
    assert n_queries >= 40
    assert mstate.stats()["n_mutations"] > 0


@pytest.mark.parametrize("backend", ["bitmask", "range"])
def test_churn_sharded_exactness(backend):
    """The same churn property through the item-sharded route (ONE
    shard_map; 1-device 'model' mesh) under jit."""
    from repro.configs.base import PQConfig
    from repro.core import retrieval_head as rh

    codes, sub_emb, rng = _mk_catalogue(seed=1)
    mstate = MutableHeadState.build(codes, B, TILE, backend=backend)
    oracle = _oracle_fn(sub_emb)
    mesh = jax.make_mesh((1,), ("model",))
    cfg = PQConfig(m=M, b=B, bound_backend=backend)

    @jax.jit
    def sharded(c, lv, state, phi):
        params = {"codes": c, "sub_emb": sub_emb, "live": lv,
                  "pruned": state}
        return rh.top_items_pruned_sharded(params, phi, K, mesh,
                                           pq_cfg=cfg)

    n_steps, n_queries = 200, 0
    for step in range(n_steps):
        if rng.random() < 0.25 or step == n_steps - 1:
            phi = jnp.asarray(rng.normal(size=(3, D)).astype(np.float32))
            ha = mstate.head_arrays()
            v, i = sharded(ha["codes"], ha["live"], ha["pruned"], phi)
            ov, oi = oracle(ha["codes"], ha["live"], phi)
            np.testing.assert_array_equal(np.asarray(v), np.asarray(ov),
                                          err_msg=f"step {step}")
            np.testing.assert_array_equal(np.asarray(i), np.asarray(oi),
                                          err_msg=f"step {step}")
            n_queries += 1
        else:
            _churn_step(mstate, rng)
    assert n_queries >= 30


@pytest.mark.parametrize("backend", ["bitmask", "range"])
def test_retighten_matches_rebuild(backend):
    """After churn, retighten() makes the incremental state bit-identical
    to a from-scratch masked rebuild, and resets the staleness tally."""
    codes, _, rng = _mk_catalogue(seed=2)
    mstate = MutableHeadState.build(codes, B, TILE, backend=backend)
    for _ in range(120):
        _churn_step(mstate, rng)
    assert mstate.stats()["stale_tiles"] > 0
    mstate.retighten()
    assert mstate.stats()["stale_tiles"] == 0.0
    got = jax.tree_util.tree_leaves(mstate.state)
    want = jax.tree_util.tree_leaves(mstate.rebuild_oracle())
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_insert_is_exact_without_retighten():
    """Inserts alone never loosen bounds: the incremental state stays
    bit-identical to the oracle with zero staleness."""
    codes, _, rng = _mk_catalogue(seed=3, n=100)
    mstate = MutableHeadState.build(codes, B, TILE, capacity=256)
    for _ in range(50):
        mstate.insert(jnp.asarray(rng.integers(0, B, M, np.int64)
                                  .astype(np.int8)))
    assert mstate.stats()["stale_tiles"] == 0.0
    got = jax.tree_util.tree_leaves(mstate.state)
    want = jax.tree_util.tree_leaves(mstate.rebuild_oracle())
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_capacity_freelist_and_validation():
    codes, _, rng = _mk_catalogue(seed=4, n=62)
    mstate = MutableHeadState.build(codes, B, tile=16)
    assert mstate.cap == next_pow2(62)         # 64, a tile multiple
    row = jnp.asarray(rng.integers(0, B, M, np.int64).astype(np.int8))
    s1 = mstate.insert(row)
    s2 = mstate.insert(row)
    assert {s1, s2} == {62, 63}
    with pytest.raises(CapacityError):
        mstate.insert(row)
    mstate.delete(s1)
    mstate.delete(s2)
    assert mstate.insert(row) == s1            # FIFO freelist reuse
    with pytest.raises(ValueError):
        mstate.delete(0)                       # padding row is not yours
    with pytest.raises(ValueError):
        mstate.delete(s2 + 1000)
    with pytest.raises(ValueError):
        mstate.update(s2, row)                 # s2 is tombstoned
    mstate.delete(s1)
    with pytest.raises(ValueError):
        mstate.delete(s1)                      # double delete


def test_live_guard_on_non_pruned_methods():
    """A head carrying a tombstone mask must refuse methods that would
    ignore it (they could return delisted items)."""
    from repro.core import retrieval_head as rh

    codes, sub_emb, rng = _mk_catalogue(seed=5, n=64)
    params = {"codes": codes, "sub_emb": sub_emb,
              "live": jnp.ones(64, jnp.bool_)}
    phi = jnp.asarray(rng.normal(size=(2, D)).astype(np.float32))
    with pytest.raises(ValueError, match="tombstone"):
        rh.top_items(params, phi, K, method="pqtopk")
    with pytest.raises(ValueError, match="tombstone"):
        rh.top_items_sharded(params, phi, K, jax.make_mesh((1,), ("model",)),
                             method="pqtopk_fused")


# ---------------------------------------------------------------------------
# engine: hot swap, parity, graceful degradation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def seqrec_fixture():
    from repro.configs import get_reduced
    from repro.models import seqrec as m

    cfg = get_reduced("sasrec-recjpq").model
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _mutate(mstate, rng, n_del=12, n_upd=6, n_ins=3):
    deleted = []
    live_ids = [int(i) for i in np.where(np.asarray(mstate.live))[0] if i > 0]
    for iid in rng.choice(live_ids, n_del + n_upd, replace=False):
        if len(deleted) < n_del:
            mstate.delete(int(iid))
            deleted.append(int(iid))
        else:
            mstate.update(int(iid), jnp.asarray(
                rng.integers(0, mstate.b, mstate.m, np.int64),
                mstate.codes.dtype))
    for _ in range(n_ins):
        mstate.insert(jnp.asarray(
            rng.integers(0, mstate.b, mstate.m, np.int64),
            mstate.codes.dtype))
    return deleted


def test_engine_hot_swap_zero_recompiles_and_parity(seqrec_fixture):
    from repro.models import seqrec as m
    from repro.serving.engine import Request, RetrievalEngine

    params, cfg = seqrec_fixture
    head = params["item_emb"]
    mstate = MutableHeadState.build(head["codes"], cfg.pq.b, tile=64)
    eng = RetrievalEngine.for_seqrec_mutable(params, cfg, mstate, k=5,
                                             max_batch=8)
    rng = np.random.default_rng(0)

    def serve(base, nreq=8):
        for i in range(nreq):
            seq = rng.integers(1, cfg.n_items + 1, rng.integers(2, 16))
            eng.submit(Request(base + i, seq, k=5))
        return eng.drain()

    serve(0)
    nc0 = eng.stats()["n_compiles"]
    deleted = _mutate(mstate, rng)
    eng.swap_head_state(mstate)
    res = serve(100)
    st = eng.stats()
    assert st["n_compiles"] == nc0, "hot swap must not mint a new compile"
    assert st["n_swaps"] == 1.0
    for r in res:
        assert not np.isin(np.asarray(r.items), deleted).any()

    # Bit parity vs a from-scratch oracle state, with the head threaded
    # as a traced argument exactly like the engine threads it (a closure
    # constant would let XLA fold differently and break bit-comparison).
    ha = mstate.head_arrays()
    oracle_head = {"codes": ha["codes"], "pruned": mstate.rebuild_oracle(),
                   "live": ha["live"]}
    ofn = jax.jit(lambda s, h: m.serve_topk(
        {**params, "item_emb": {**head, **h}}, s, cfg, k=5,
        method="pqtopk_pruned"))
    qs = rng.integers(1, cfg.n_items + 1,
                      (4, cfg.max_seq_len)).astype(np.int32)
    oi, ov = ofn(jnp.asarray(qs), oracle_head)
    for i in range(4):
        eng.submit(Request(200 + i, qs[i], k=5))
    got = {r.request_id: r for r in eng.drain()}
    for i in range(4):
        np.testing.assert_array_equal(got[200 + i].items, np.asarray(oi)[i])
        np.testing.assert_array_equal(got[200 + i].scores,
                                      np.asarray(ov)[i])


def test_engine_swap_validation(seqrec_fixture):
    from repro.serving.engine import RetrievalEngine

    params, cfg = seqrec_fixture
    head = params["item_emb"]
    mstate = MutableHeadState.build(head["codes"], cfg.pq.b, tile=64)
    eng = RetrievalEngine.for_seqrec_mutable(params, cfg, mstate, k=5,
                                             max_batch=8, calibrate=False)
    with pytest.raises(ValueError, match="structure"):
        eng.swap_head_state({"codes": mstate.codes, "live": mstate.live})
    other = MutableHeadState.build(head["codes"], cfg.pq.b, tile=64,
                                   capacity=4 * mstate.cap)
    with pytest.raises(ValueError):
        eng.swap_head_state(other)             # capacity growth: rebuild
    # a plain engine refuses swapping outright
    eng2 = RetrievalEngine.for_seqrec(params, cfg, k=5, max_batch=8,
                                      method="pqtopk_pruned",
                                      calibrate=False)
    with pytest.raises(ValueError, match="swappable"):
        eng2.swap_head_state(mstate)


def test_engine_fault_injection_retry_and_shed(seqrec_fixture):
    from repro.serving.engine import Request, RetrievalEngine
    from repro.training.fault_tolerance import ServeFaultInjector

    params, cfg = seqrec_fixture
    mstate = MutableHeadState.build(params["item_emb"]["codes"], cfg.pq.b,
                                    tile=64)
    rng = np.random.default_rng(1)

    # batch 0 fails once (retry recovers); batch 1 out-fails the budget
    # (batch shed, loop alive); batch 2 is slowed (straggler flagged).
    faults = ServeFaultInjector(fail_at_batches=(0, 1), fail_repeats=1,
                                slow_at_batches=(2,), slow_ms=30.0)
    faults._fail_counts[1] = -10               # batch 1: 11 failures
    eng = RetrievalEngine.for_seqrec_mutable(
        params, cfg, mstate, k=5, max_batch=4, faults=faults,
        max_retries=1, retry_backoff_ms=0.1, calibrate=False)
    eng.straggler_monitor.factor = 1.5
    eng.straggler_monitor._times = [0.01] * 10  # prime the rolling median

    def one_batch(base):
        for i in range(4):
            eng.submit(Request(base + i,
                               rng.integers(1, cfg.n_items + 1, 8), k=5))
        return eng.run_once()

    r0 = one_batch(0)                          # fails once, retried, OK
    assert len(r0) == 4 and not any(r.shed for r in r0)
    r1 = one_batch(10)                         # retries exhausted -> shed
    assert len(r1) == 4 and all(r.shed for r in r1)
    assert all(r.items.size == 0 for r in r1)
    r2 = one_batch(20)                         # slowed, still served
    assert len(r2) == 4 and not any(r.shed for r in r2)
    st = eng.stats()
    assert st["retried"] >= 2.0
    assert st["shed"] == 4.0
    assert st["stragglers"] >= 1.0


def test_engine_sheds_expired_before_dispatch(seqrec_fixture):
    from repro.serving.engine import Request, RetrievalEngine

    params, cfg = seqrec_fixture
    mstate = MutableHeadState.build(params["item_emb"]["codes"], cfg.pq.b,
                                    tile=64)
    eng = RetrievalEngine.for_seqrec_mutable(params, cfg, mstate, k=5,
                                             max_batch=8, calibrate=False)
    rng = np.random.default_rng(2)
    stale = time.monotonic() - 10.0
    eng.submit(Request(0, rng.integers(1, cfg.n_items + 1, 8), k=5,
                       arrival=stale, deadline_ms=1.0))
    # Generous deadline: the first dispatch compiles, and on a loaded CI
    # host that can exceed the 1s default — this test is about the
    # *expired* request being shed pre-dispatch, not about timing.
    eng.submit(Request(1, rng.integers(1, cfg.n_items + 1, 8), k=5,
                       deadline_ms=600_000.0))
    res = {r.request_id: r for r in eng.run_once()}
    assert res[0].shed and res[0].timed_out and res[0].items.size == 0
    assert not res[1].shed and res[1].items.shape == (5,)
    st = eng.stats()
    assert st["shed"] == 1.0 and st["timeouts"] == 1.0
