"""The paper's central invariant: Default / RecJPQ (Alg. 2) / PQTopK (Alg. 1)
compute the SAME score distribution (the paper checks this via identical
NDCG; we assert exact score equality), property-tested with hypothesis."""
import pytest

pytest.importorskip("hypothesis")  # keep tier-1 collection green without dev deps
pytestmark = pytest.mark.hypothesis
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.configs.base import PQConfig
from repro.core import retrieval_head, scoring, topk

jax.config.update("jax_enable_x64", False)


def _setup(n, d, m, b, bq, seed=0):
    pq = PQConfig(m=m, b=b)
    params = retrieval_head.init(jax.random.PRNGKey(seed), n, d, pq)
    phi = jax.random.normal(jax.random.PRNGKey(seed + 1), (bq, d))
    return params, phi


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(10, 400),
    m=st.sampled_from([1, 2, 4, 8]),
    b=st.sampled_from([4, 16, 64]),
    bq=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
def test_all_scorers_equal_dense(n, m, b, bq, seed):
    d = m * 8
    params, phi = _setup(n, d, m, b, bq, seed)
    r_dense = retrieval_head.score_all(params, phi, "dense")
    for meth in ("recjpq", "pqtopk", "pqtopk_onehot"):
        r = retrieval_head.score_all(params, phi, meth)
        np.testing.assert_allclose(np.asarray(r), np.asarray(r_dense),
                                   rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(50, 500),
    k=st.integers(1, 20),
    seed=st.integers(0, 10_000),
)
def test_topk_identical_items(n, k, seed):
    """Top-K sets agree between scoring algorithms (ties broken by score)."""
    params, phi = _setup(n, 32, 4, 16, 2, seed)
    k = min(k, n)
    v1, i1 = retrieval_head.top_items(params, phi, k, method="pqtopk")
    v2, i2 = retrieval_head.top_items(params, phi, k, method="recjpq")
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(20, 2000),
    k=st.integers(1, 16),
    tile=st.sampled_from([32, 128, 512]),
    seed=st.integers(0, 10_000),
)
def test_tiled_topk_exact(n, k, tile, seed):
    scores = jax.random.normal(jax.random.PRNGKey(seed), (3, n))
    k = min(k, n)
    v_ref, i_ref = topk.topk(scores, k)
    v, i = topk.tiled_topk(scores, k, tile)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v_ref), rtol=1e-6)
    # indices must point at equal scores (ties may permute)
    np.testing.assert_allclose(
        np.take_along_axis(np.asarray(scores), np.asarray(i), 1),
        np.asarray(v_ref), rtol=1e-6)


def test_candidate_subset_scoring():
    params, phi = _setup(100, 32, 4, 16, 2)
    v_ids = jnp.asarray([3, 17, 42, 99])
    r_all = retrieval_head.score_all(params, phi, "pqtopk")
    r_sub = retrieval_head.score_candidates(params, phi, v_ids)
    np.testing.assert_allclose(np.asarray(r_sub),
                               np.asarray(r_all[:, v_ids]), rtol=1e-5)


def test_approx_topk_recall():
    params, phi = _setup(4096, 32, 4, 64, 4)
    r = retrieval_head.score_all(params, phi, "pqtopk")
    _, exact = topk.topk(r, 10)
    _, approx = topk.approx_topk_maxblock(r, 10, oversample=4)
    recall = np.mean([
        len(set(np.asarray(exact[i])) & set(np.asarray(approx[i]))) / 10
        for i in range(4)
    ])
    assert recall >= 0.5, recall


def test_sharded_topk_matches_single_device():
    """shard_map path on a 1-device mesh must equal the plain path."""
    mesh = jax.make_mesh((1,), ("model",))
    params, phi = _setup(128, 32, 4, 16, 2)
    v1, i1 = retrieval_head.top_items(params, phi, 5)
    v2, i2 = retrieval_head.top_items_sharded(params, phi, 5, mesh)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
