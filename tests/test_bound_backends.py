"""Exactness property harness for the pluggable bound backends (PR 4).

The pruned cascade is only worth its speedups if (a) every backend's tile
bound *dominates* the true max item score in that tile and (b) the pruned
top-k is *bit-identical* to the exhaustive oracle — including ties — for
every (backend, ladder-rung, sharded/unsharded) combination.  This module
is the property-based oracle for both invariants, plus the calibrated
slot-budget ladder's safety properties (final rung always exhaustive;
``run_once`` never returns fewer than k valid items) and the unified
cascade stats schema (`pruning.STATS_KEYS`).

Any future bound backend or budget policy must keep this file green —
that is the whole point of the harness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PQConfig
from repro.core import pruning, retrieval_head, scoring, topk as topk_lib

BACKENDS = pruning.BOUND_BACKENDS


def _property_test(strategy_fn, fallback, max_examples=20):
    """Property-test shim: with hypothesis installed the check runs under
    ``@given`` over ``strategy_fn(st)``'s strategies; in offline
    containers (no hypothesis wheel) it runs the deterministic
    ``fallback`` example grid instead — the invariants are always
    exercised, just without randomised search."""
    def deco(check):
        def run():
            try:
                from hypothesis import given, settings, strategies as st
            except ImportError:
                for ex in fallback:
                    check(*ex)
                return
            settings(max_examples=max_examples, deadline=None)(
                given(*strategy_fn(st))(check))()
        return run
    return deco


def _oracle(codes, s, k):
    r = scoring.score_pqtopk(codes.astype(jnp.int32), s)
    return topk_lib.tiled_topk(r, k)


def _make_case(n, m, b, bq, *, code_dtype=jnp.int32, clustered=False,
               skewed=False, seed=0):
    rng = np.random.default_rng(seed)
    if clustered:
        centers = (np.arange(n) / n * b).astype(np.int64)
        codes_np = (centers[:, None] + rng.integers(-1, 2, (n, m))) % b
    else:
        codes_np = rng.integers(0, b, (n, m))
    codes = jnp.asarray(codes_np, code_dtype)
    g = rng.standard_normal((bq, m, b))
    if skewed:
        g = np.sign(g) * np.abs(g) ** 3
    s = jnp.asarray(g, jnp.float32)
    return codes, s


def _tile_true_max(codes, s, tile):
    """Per-tile max true item score (the quantity every bound must
    dominate) -> (B, T)."""
    r = np.asarray(scoring.score_pqtopk(codes.astype(jnp.int32), s))
    n = r.shape[1]
    pad = (-n) % tile
    if pad:
        r = np.pad(r, ((0, 0), (0, pad)), constant_values=-np.inf)
    return r.reshape(r.shape[0], -1, tile).max(axis=-1)


# ---------------------------------------------------------------------------
# range backend: state layout, footprint, tightness ordering
# ---------------------------------------------------------------------------


def test_range_state_layout_and_footprint():
    codes, _ = _make_case(1 << 14, 8, 256, 1, seed=1)
    bm = pruning.build_pruned_state(codes, 256, 1024, backend="bitmask")
    rg = pruning.build_pruned_state(codes, 256, 1024, backend="range")
    assert rg.backend == "range" and rg.packed is None
    assert rg.code_lo.shape == rg.code_hi.shape == (16, 8)
    assert rg.code_lo.dtype == rg.code_hi.dtype == jnp.int16
    assert rg.nbytes == 16 * 8 * 4                 # lo + hi int16
    # The headline claim: at b=256 the range metadata is 1/8 of the packed
    # bitmasks (and 1/64 of the PR 2 bool layout).
    assert rg.nbytes * 8 == bm.nbytes
    assert rg.nbytes * 64 == rg.bool_nbytes
    assert int(np.asarray(rg.code_lo).min()) >= 0
    assert int(np.asarray(rg.code_hi).max()) < 256


def test_range_build_excludes_tile_padding():
    """Tile-alignment padding rows must not drag code_lo to 0."""
    codes = jnp.full((10, 2), 7, jnp.int32)        # tile=8 -> last tile has 2
    st = pruning.build_pruned_state(codes, 16, 8, backend="range")
    np.testing.assert_array_equal(np.asarray(st.code_lo), 7)
    np.testing.assert_array_equal(np.asarray(st.code_hi), 7)


def test_single_item_tile_range_bound_is_bitexact():
    """lo == hi -> the range max IS that item's sub-score; with the shared
    tree_sum order the bound equals the score bit-for-bit."""
    codes, s = _make_case(13, 4, 64, 3, seed=2)
    st = pruning.build_pruned_state(codes, 64, 1, backend="range")
    bounds = pruning.tile_bounds(st, s)
    r = scoring.score_pqtopk(codes, s)
    np.testing.assert_array_equal(np.asarray(bounds), np.asarray(r))


def test_range_bounds_at_least_as_loose_as_bitmask():
    """The range bound relaxes the presence set to its convex hull, so it
    can only be >= the bitmask bound (equal when codes fill the range)."""
    codes, s = _make_case(3000, 4, 64, 3, clustered=True, seed=3)
    bm = pruning.build_pruned_state(codes, 64, 256, backend="bitmask")
    rg = pruning.build_pruned_state(codes, 64, 256, backend="range")
    b_bm = np.asarray(pruning.tile_bounds(bm, s))
    b_rg = np.asarray(pruning.tile_bounds(rg, s))
    assert (b_rg >= b_bm).all()


def test_backend_validation():
    codes, _ = _make_case(100, 2, 16, 1)
    with pytest.raises(ValueError, match="unknown bound backend"):
        pruning.build_pruned_state(codes, 16, 64, backend="interval")
    with pytest.raises(ValueError, match="int16"):
        pruning.build_pruned_state(codes, 2 ** 16, 64, backend="range")
    with pytest.raises(ValueError, match="bound_backend"):
        PQConfig(bound_backend="interval")
    with pytest.raises(ValueError, match="int16"):
        PQConfig(b=2 ** 16, code_dtype="uint16", bound_backend="range")
    PQConfig(bound_backend="range")                # valid


def test_range_state_is_a_pytree_in_head_params():
    params = retrieval_head.init(jax.random.PRNGKey(0), 500, 32,
                                 PQConfig(m=4, b=16, bound_backend="range"))
    state = params["pruned"]
    assert state.backend == "range" and state.packed is None
    leaves, treedef = jax.tree_util.tree_flatten(params)
    assert jax.tree_util.tree_unflatten(treedef, leaves)[
        "pruned"].backend == "range"
    abs_params = retrieval_head.abstract(500, 32,
                                         PQConfig(m=4, b=16,
                                                  bound_backend="range"))
    assert (jax.tree.structure(abs_params) == jax.tree.structure(params))
    assert abs_params["pruned"].code_lo.shape == state.code_lo.shape


def test_ensure_sharded_state_preserves_backend():
    mesh = jax.make_mesh((1,), ("model",))
    params = retrieval_head.init(jax.random.PRNGKey(0), 1000, 16,
                                 PQConfig(m=4, b=16, bound_backend="range"))
    phi = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    p2 = retrieval_head.ensure_sharded_pruned_state(params, mesh, k_hint=7)
    assert p2["pruned"].backend == "range"
    p3 = retrieval_head.ensure_sharded_pruned_state(p2, mesh, k_hint=7)
    assert p3["pruned"] is p2["pruned"]            # idempotent
    del phi


# ---------------------------------------------------------------------------
# property suite: dominance invariant (every backend, every regime)
# ---------------------------------------------------------------------------


@pytest.mark.hypothesis
def test_property_bounds_dominate_true_tile_max():
    """(a) of the acceptance matrix: for random catalogues (odd N, b in
    {64, 256}, int8/uint8/int32 codes, skewed and uniform distributions),
    EVERY backend's tile bound dominates the true max item score in the
    tile."""
    @_property_test(
        lambda st: (st.integers(0, 2 ** 31 - 1),
                    st.sampled_from([257, 999, 1021, 2048]),  # odd + exact
                    st.sampled_from([64, 256]),
                    st.sampled_from(["int8", "uint8", "int32"]),
                    st.booleans(), st.booleans(),
                    st.sampled_from([64, 256, 512])),
        fallback=[(0, 999, 64, "int8", True, True, 256),
                  (1, 1021, 256, "uint8", False, True, 256),
                  (2, 257, 256, "int32", True, False, 64),
                  (3, 2048, 64, "int32", False, False, 512)],
        max_examples=25)
    def check(seed, n, b, dtype, clustered, skewed, tile):
        if b > 128 and dtype == "int8":
            dtype = "uint8"
        codes, s = _make_case(n, 3, b, 2, code_dtype=jnp.dtype(dtype),
                              clustered=clustered, skewed=skewed, seed=seed)
        tmax = _tile_true_max(codes, s, min(tile, n))
        for backend in BACKENDS:
            st_ = pruning.build_pruned_state(codes, b, tile, backend=backend)
            bounds = np.asarray(pruning.tile_bounds(st_, s))
            assert (bounds >= tmax).all(), (backend, seed)

    check()


# ---------------------------------------------------------------------------
# property suite: end-to-end bit parity vs the exhaustive oracle
# ---------------------------------------------------------------------------


@pytest.mark.hypothesis
@pytest.mark.parametrize("backend", BACKENDS)
def test_property_pruned_topk_bit_identical(backend):
    """(b): pruned top-k == exhaustive oracle bit-for-bit (values AND ids,
    tie policy included) for every ladder-rung configuration, flat."""
    @_property_test(
        lambda st: (st.integers(0, 2 ** 31 - 1),
                    st.sampled_from([999, 1021, 2048]),
                    st.sampled_from([64, 256]),
                    st.sampled_from(["int8", "uint8", "int32"]),
                    st.booleans(),
                    st.sampled_from([None, (1,), (2, 8)])),
        fallback=[(0, 999, 64, "int8", True, None),
                  (1, 1021, 256, "uint8", True, (1,)),
                  (2, 2048, 64, "int32", False, (2, 8)),
                  (3, 999, 256, "int32", True, (2, 8))],
        max_examples=12)
    def check(seed, n, b, dtype, clustered, ladder):
        if b > 128 and dtype == "int8":
            dtype = "uint8"
        codes, s = _make_case(n, 3, b, 2, code_dtype=jnp.dtype(dtype),
                              clustered=clustered, skewed=clustered,
                              seed=seed)
        k = 10
        v_ref, i_ref = _oracle(codes, s, k)
        st_ = pruning.build_pruned_state(codes, b, 256, backend=backend)
        v, i = pruning.cascade_topk_ingraph(codes, s, k, st_, ladder=ladder)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))

    check()


@pytest.mark.hypothesis
@pytest.mark.sharded
@pytest.mark.parametrize("backend", BACKENDS)
def test_property_pruned_topk_bit_identical_sharded(backend):
    """(b), sharded leg: the one-shard_map cascade with pmax-shared theta
    matches the exhaustive route bit-for-bit for both backends and ladder
    shapes (odd N exercises the shard-padding mask)."""
    mesh = jax.make_mesh((1,), ("model",))

    @_property_test(
        lambda st: (st.integers(0, 10_000),
                    st.sampled_from([999, 1021]),
                    st.sampled_from([None, (2, 8)])),
        fallback=[(0, 999, None), (1, 1021, (2, 8))],
        max_examples=6)
    def check(seed, n, ladder):
        params, phi = _pq_head(n, d=16, m=4, b=8, bq=2, seed=seed % 97,
                               backend=backend)
        k = 7
        v1, i1 = retrieval_head.top_items(params, phi, k, method="pqtopk")
        v2, i2, stats = retrieval_head.top_items_pruned_sharded(
            params, phi, k, mesh, ladder=ladder, return_stats=True)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        assert set(stats) == set(pruning.STATS_KEYS)

    check()


# ---------------------------------------------------------------------------
# acceptance legs: under jit, inside lm_decode_step, sharded (per backend)
# ---------------------------------------------------------------------------


def _pq_head(n, d=32, m=4, b=16, bq=3, seed=0, backend="bitmask"):
    params = retrieval_head.init(jax.random.PRNGKey(seed), n, d,
                                 PQConfig(m=m, b=b, bound_backend=backend))
    phi = jax.random.normal(jax.random.PRNGKey(seed + 1), (bq, d))
    return params, phi


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_under_jit_with_threaded_state(backend):
    params, phi = _pq_head(4097, bq=4, backend=backend)
    k = 9
    v_ref, i_ref = retrieval_head.top_items(params, phi, k, method="pqtopk")
    fn = jax.jit(lambda p, x: retrieval_head.top_items(
        p, x, k, method="pqtopk_pruned", ladder=(2, 8)))
    v, i = fn(params, phi)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_traces_single_jaxpr_with_ladder(backend):
    """The whole pruned route (either backend, ladder enabled) must trace
    into one jaxpr — any host sync in the rung chain would throw."""
    params, phi = _pq_head(4097, bq=2, backend=backend)
    jaxpr = jax.make_jaxpr(lambda p, x: retrieval_head.top_items(
        p, x, 5, method="pqtopk_pruned", ladder=(1, 2),
        return_rung=True))(params, phi)
    assert len(jaxpr.jaxpr.eqns) > 0


@pytest.mark.slow
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_inside_lm_decode_step(backend):
    from dataclasses import replace
    from repro.configs.base import get_reduced
    from repro.models import transformer as T
    arch = get_reduced("qwen2.5-14b")
    cfg = replace(arch.model,
                  pq_head=replace(arch.model.pq_head, bound_backend=backend))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    assert params["pq_head"]["pruned"].backend == backend
    caches = T.init_caches(cfg, 2, 16)
    tok = jnp.asarray([3, 5], jnp.int32)
    pos = jnp.int32(0)
    outs = {}
    for meth in ("pqtopk", "pqtopk_pruned"):
        step = jax.jit(lambda p, t_, c, m_=meth: T.lm_decode_step(
            p, t_, pos, c, cfg, k=8, head_method=m_))
        ids, vals, _ = step(params, tok, caches)
        outs[meth] = (np.asarray(ids), np.asarray(vals))
    np.testing.assert_array_equal(outs["pqtopk_pruned"][0],
                                  outs["pqtopk"][0])
    np.testing.assert_array_equal(outs["pqtopk_pruned"][1],
                                  outs["pqtopk"][1])


@pytest.mark.sharded
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [128, 1013])
def test_backend_sharded_matches_plain(backend, n):
    mesh = jax.make_mesh((1,), ("model",))
    params, phi = _pq_head(n, d=16, m=4, b=8, bq=2, backend=backend)
    v1, i1 = retrieval_head.top_items(params, phi, 7, method="pqtopk")
    v2, i2 = retrieval_head.top_items_sharded(params, phi, 7, mesh,
                                              method="pqtopk_pruned",
                                              ladder=(2, 4))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert (np.asarray(i2) < n).all()


# ---------------------------------------------------------------------------
# calibration-path properties (the overflow-escalation lax.cond chain)
# ---------------------------------------------------------------------------


def test_calibrate_ladder_adversarial_distributions():
    """all-survive / none-survive / bimodal must all yield a ladder whose
    FINAL rung is exhaustive, with strictly ascending budgets >= the k
    floor."""
    n_tiles, k, tile = 64, 10, 512
    floor = -(-k // tile)
    cases = {
        "all_survive": [n_tiles] * 10,
        "none_survive": [0] * 10,
        "bimodal": [1] * 8 + [n_tiles] * 2,
        "empty": [],
    }
    for name, counts in cases.items():
        ladder = pruning.calibrate_ladder(counts, n_tiles, k, tile)
        assert ladder[-1] == n_tiles, (name, ladder)
        assert list(ladder) == sorted(set(ladder)), (name, ladder)
        assert all(r >= floor for r in ladder), (name, ladder)
    # Bimodal keeps a cheap rung for the low mode.
    assert pruning.calibrate_ladder(cases["bimodal"], n_tiles, k,
                                    tile)[0] < n_tiles


@pytest.mark.hypothesis
def test_property_normalized_ladder_always_ends_exhaustive():
    @_property_test(
        lambda st: (st.lists(st.integers(-5, 10_000), max_size=6),
                    st.integers(1, 512), st.integers(1, 64),
                    st.integers(1, 2048)),
        fallback=[([], 1, 1, 1), ([0, -3, 9999], 512, 64, 1),
                  ([4, 4, 8], 16, 10, 512), ([1, 2, 4, 8], 3, 64, 2048),
                  ([512], 512, 1, 1)],
        max_examples=50)
    def check(ladder, n_tiles, k, tile):
        rungs = pruning.normalize_ladder(ladder, n_tiles, k, tile)
        assert rungs[-1] == n_tiles
        assert list(rungs) == sorted(set(rungs))
        floor = min(max(1, -(-k // tile)), n_tiles)
        assert all(floor <= r <= n_tiles for r in rungs)

    check()


@pytest.mark.hypothesis
def test_property_calibrated_ladder_stays_exact():
    """Whatever counts calibration saw, serving with the resulting ladder
    is bit-identical to the oracle (the final rung guarantees it)."""
    @_property_test(
        lambda st: (st.integers(0, 2 ** 31 - 1),
                    st.lists(st.integers(0, 64), min_size=1, max_size=8),
                    st.sampled_from(BACKENDS)),
        fallback=[(0, [0, 0, 0], "bitmask"), (1, [64] * 4, "range"),
                  (2, [1, 1, 30], "bitmask"), (3, [2, 5], "range")],
        max_examples=10)
    def check(seed, counts, backend):
        codes, s = _make_case(2048, 3, 64, 2, seed=seed)
        k = 5
        st_ = pruning.build_pruned_state(codes, 64, 256, backend=backend)
        ladder = pruning.calibrate_ladder(counts, st_.n_tiles, k, st_.tile)
        v_ref, i_ref = _oracle(codes, s, k)
        v, i = pruning.cascade_topk_ingraph(codes, s, k, st_, ladder=ladder)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))

    check()


def test_overflow_escalates_to_final_rung():
    """Uniform codes -> every tile survives -> every finite budget
    overflows -> the cond chain must land on the exhaustive final rung."""
    codes, s = _make_case(5000, 4, 64, 3, seed=11)
    k = 7
    st_ = pruning.build_pruned_state(codes, 64, 512)      # 10 tiles
    v_ref, i_ref = _oracle(codes, s, k)
    v, i, stats = pruning.cascade_topk_ingraph(
        codes, s, k, st_, ladder=(1, 2, 4), return_stats=True)
    assert int(stats["rung_hit"]) == int(stats["n_rungs"]) - 1
    assert bool(stats["slot_overflow"])
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def _engine(method, *, n_items=2000, k=5, **kw):
    from dataclasses import replace
    from repro.configs.base import get_reduced
    from repro.models import seqrec as seqrec_lib
    from repro.serving.engine import RetrievalEngine
    cfg = replace(get_reduced("sasrec-recjpq").model, n_items=n_items)
    params = seqrec_lib.init_seqrec(jax.random.PRNGKey(0), cfg)
    return RetrievalEngine.for_seqrec(params, cfg, k=k, max_batch=8,
                                      method=method, **kw), cfg


@pytest.mark.slow
def test_run_once_never_returns_fewer_than_k_valid_items():
    """Regression for the overflow-escalation chain: whatever survival
    stats calibration was fed — all-survive, none-survive, bimodal — every
    request gets its full k valid items, identical to the unpruned route."""
    from repro.serving.engine import Request
    rng = np.random.default_rng(3)
    seqs = [rng.integers(1, 2000, 8) for _ in range(4)]
    ref_eng, cfg = _engine("pqtopk", calibrate=False)
    for i, sq in enumerate(seqs):
        ref_eng.submit(Request(i, sq, k=5))
    ref = {r.request_id: r for r in ref_eng.drain()}
    n_tiles = 1          # reduced catalogue fits one tile; ladders degrade
    for stats_name, counts in {
            "all_survive": [n_tiles] * 6, "none_survive": [0] * 6,
            "bimodal": [0, 0, 0, n_tiles, n_tiles]}.items():
        eng, _ = _engine("pqtopk_pruned", survival_stats=counts)
        for i, sq in enumerate(seqs):
            eng.submit(Request(i, sq, k=5))
        out = {r.request_id: r for r in eng.drain()}
        assert len(out) == len(seqs), stats_name
        for i in range(len(seqs)):
            assert len(out[i].items) == 5, stats_name
            # Valid = real catalogue rows (row 0 is the padding embedding,
            # still a scoreable row — the invariant is "never an id past
            # the catalogue or a sentinel", not "never row 0").
            assert (out[i].items >= 0).all() and \
                (out[i].items <= cfg.n_items).all(), stats_name
            assert np.isfinite(out[i].scores).all(), stats_name
            np.testing.assert_array_equal(out[i].items, ref[i].items)
            np.testing.assert_array_equal(out[i].scores, ref[i].scores)


@pytest.mark.slow
def test_engine_calibrates_and_reports_rungs():
    from repro.serving.engine import Request
    eng, cfg = _engine("pqtopk_pruned", n_items=6000)
    assert eng.ladder is not None and eng.ladder[-1] >= 1
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(i, rng.integers(1, cfg.n_items, 8), k=5))
    eng.drain()
    stats = eng.stats()
    assert "ladder" in stats and "rung_hit_fraction" in stats
    assert 0.0 <= stats["rung_hit_fraction"] <= 1.0
    assert sum(stats["rung_counts"].values()) >= 1


# ---------------------------------------------------------------------------
# unified stats schema (host vs in-graph vs sharded)
# ---------------------------------------------------------------------------


def test_stats_schema_identical_flat_routes():
    codes, s = _make_case(3000, 4, 64, 2, clustered=True, skewed=True,
                          seed=5)
    k = 7
    _, _, st_host = pruning.cascade_topk(codes, s, k, tile=256,
                                         return_stats=True)
    state = pruning.build_pruned_state(codes, 64, 256)
    _, _, st_graph = pruning.cascade_topk_ingraph(codes, s, k, state,
                                                  ladder=(2, 4),
                                                  return_stats=True)
    assert set(st_host) == set(st_graph) == set(pruning.STATS_KEYS)
    for st_ in (st_host, st_graph):
        assert 0.0 <= float(st_["survival_fraction"]) <= 1.0
        assert int(st_["rung_hit"]) < int(st_["n_rungs"])
        assert st_["bound_backend"] in BACKENDS


@pytest.mark.sharded
def test_stats_schema_identical_sharded_route():
    mesh = jax.make_mesh((1,), ("model",))
    params, phi = _pq_head(1013, d=16, m=4, b=8, bq=2)
    _, _, st_sh = retrieval_head.top_items_pruned_sharded(
        params, phi, 7, mesh, ladder=(2, 4), return_stats=True)
    assert set(st_sh) == set(pruning.STATS_KEYS)
    assert 0.0 <= float(st_sh["survival_fraction"]) <= 1.0
    assert int(st_sh["rung_hit"]) < int(st_sh["n_rungs"])
    assert int(st_sh["n_scored"]) >= 1
