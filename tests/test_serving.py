"""Serving engine: batching, latency accounting, decode slots."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.serving.engine import (DecodeEngine, MicroBatcher, Request,
                                  RetrievalEngine)


def _make_retrieval_engine(method="pqtopk", max_batch=16):
    arch = get_reduced("sasrec-recjpq")
    cfg = arch.model
    from repro.models import seqrec as m
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)

    def serve_fn(seqs, k):
        return m.serve_topk(params, seqs, cfg, k=k, method=method)

    return RetrievalEngine(serve_fn, seq_len=cfg.max_seq_len, k=5,
                           max_batch=max_batch), cfg


def test_retrieval_engine_end_to_end():
    engine, cfg = _make_retrieval_engine()
    rng = np.random.default_rng(0)
    for i in range(40):
        seq = rng.integers(1, cfg.n_items + 1, rng.integers(2, 16))
        engine.submit(Request(i, seq, k=5))
    results = engine.drain()
    assert len(results) == 40
    ids = {r.request_id for r in results}
    assert ids == set(range(40))
    for r in results:
        assert r.items.shape == (5,)
        assert (r.items >= 0).all() and (r.items <= cfg.n_items).all()
        assert np.isfinite(r.scores).all()
    stats = engine.stats()
    assert stats["count"] == 40 and stats["mRT_ms"] >= 0


def test_retrieval_methods_agree_through_engine():
    rng = np.random.default_rng(1)
    seqs = [rng.integers(1, 100, 8) for _ in range(8)]
    all_items = {}
    for method in ("dense", "pqtopk", "recjpq"):
        engine, cfg = _make_retrieval_engine(method)
        for i, s in enumerate(seqs):
            engine.submit(Request(i, s, k=5))
        res = {r.request_id: r for r in engine.drain()}
        all_items[method] = res
    for i in range(8):
        np.testing.assert_allclose(all_items["dense"][i].scores,
                                   all_items["pqtopk"][i].scores,
                                   rtol=1e-4, atol=1e-5)


def test_microbatcher_bucketing():
    assert MicroBatcher.bucket(1, 64) == 1
    assert MicroBatcher.bucket(3, 64) == 4
    assert MicroBatcher.bucket(33, 64) == 64
    assert MicroBatcher.bucket(100, 64) == 64


def test_decode_engine_slots():
    from repro.models import transformer as T
    cfg = get_reduced("qwen2.5-14b").model
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    n_slots, max_len = 4, 32

    def decode_fn(tokens, pos, caches):
        # per-slot positions: use max (engine keeps slots in lockstep per
        # admission wave; fine for the test)
        ids, vals, caches = T.lm_decode_step(params, tokens, pos.max(),
                                             caches, cfg, k=4)
        return ids[:, 0], caches

    engine = DecodeEngine(decode_fn,
                          lambda b: T.init_caches(cfg, b, max_len),
                          n_slots=n_slots, max_len=max_len)
    for i in range(6):
        engine.submit(Request(i, np.asarray([i + 1]), k=1))
    finished = engine.run(max_new=4)
    assert len(finished) == 6
    for req, toks in finished:
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab for t in toks)
