"""Serving engine: batching, latency accounting, decode slots."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.serving.engine import (DecodeEngine, MicroBatcher, Request,
                                  RetrievalEngine)
from repro.training.fault_tolerance import ServeFaultInjector


def _make_retrieval_engine(method="pqtopk", max_batch=16):
    arch = get_reduced("sasrec-recjpq")
    cfg = arch.model
    from repro.models import seqrec as m
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)

    def serve_fn(seqs, k):
        return m.serve_topk(params, seqs, cfg, k=k, method=method)

    return RetrievalEngine(serve_fn, seq_len=cfg.max_seq_len, k=5,
                           max_batch=max_batch), cfg


def test_retrieval_engine_end_to_end():
    engine, cfg = _make_retrieval_engine()
    rng = np.random.default_rng(0)
    for i in range(40):
        seq = rng.integers(1, cfg.n_items + 1, rng.integers(2, 16))
        engine.submit(Request(i, seq, k=5))
    results = engine.drain()
    assert len(results) == 40
    ids = {r.request_id for r in results}
    assert ids == set(range(40))
    for r in results:
        assert r.items.shape == (5,)
        assert (r.items >= 0).all() and (r.items <= cfg.n_items).all()
        assert np.isfinite(r.scores).all()
    stats = engine.stats()
    assert stats["count"] == 40 and stats["mRT_ms"] >= 0


def test_retrieval_methods_agree_through_engine():
    rng = np.random.default_rng(1)
    seqs = [rng.integers(1, 100, 8) for _ in range(8)]
    all_items = {}
    for method in ("dense", "pqtopk", "recjpq"):
        engine, cfg = _make_retrieval_engine(method)
        for i, s in enumerate(seqs):
            engine.submit(Request(i, s, k=5))
        res = {r.request_id: r for r in engine.drain()}
        all_items[method] = res
    for i in range(8):
        np.testing.assert_allclose(all_items["dense"][i].scores,
                                   all_items["pqtopk"][i].scores,
                                   rtol=1e-4, atol=1e-5)


def test_microbatcher_bucketing():
    assert MicroBatcher.bucket(1, 64) == 1
    assert MicroBatcher.bucket(3, 64) == 4
    assert MicroBatcher.bucket(33, 64) == 64
    assert MicroBatcher.bucket(100, 64) == 64


def test_microbatcher_max_wait_dispatches_partial_batch():
    """A partial batch becomes ready once its oldest request has waited
    max_wait_ms — a trickle of traffic must not stall on a full bucket."""
    b = MicroBatcher(max_batch=8, max_wait_ms=20.0)
    assert not b.ready()                     # empty queue: nothing to do
    b.submit(Request(0, np.arange(4)))
    b.submit(Request(1, np.arange(4)))
    assert not b.ready()                     # partial and fresh: wait
    assert b.ready(now=time.monotonic() + 0.05)   # oldest out-waited it
    time.sleep(0.025)
    assert b.ready()
    got = b.next_batch()
    assert [r.request_id for r in got] == [0, 1]
    assert not b.queue and not b._enq_t      # both deques stay in lockstep
    for i in range(8):
        b.submit(Request(i, np.arange(4)))
    assert b.ready()                         # full bucket: ready instantly


def _slow_serve_fn(sleep_s, k_out=4):
    """A serve fn whose *device computation* stalls: the host callback
    runs inside the compiled program, so only a completion-based
    timestamp can see the cost."""
    def serve_fn(seqs, k):
        def host(x):
            time.sleep(sleep_s)
            return np.tile(np.arange(1, k + 1, dtype=np.int32),
                           (x.shape[0], 1))
        ids = jax.pure_callback(
            host, jax.ShapeDtypeStruct((seqs.shape[0], k), jnp.int32), seqs)
        return ids, jnp.zeros((seqs.shape[0], k), jnp.float32)
    return serve_fn


def test_latency_accounts_for_async_kernel_completion():
    """Regression (PR 8 satellite): JAX dispatch is asynchronous, so
    timestamping right after fn(seqs) measures enqueue, not completion.
    With a kernel that sleeps 120ms in-graph, the recorded latency must
    include the sleep — block_until_ready before the timestamp."""
    eng = RetrievalEngine(_slow_serve_fn(0.12), seq_len=4, k=4, max_batch=4)
    eng.submit(Request(0, np.arange(1, 5), k=4))
    eng.run_once()                           # warm: compile + first call
    eng.submit(Request(1, np.arange(1, 5), k=4))
    res = eng.run_once()
    assert len(res) == 1
    assert res[0].latency_ms >= 100.0, res[0].latency_ms
    # The straggler monitor reads the same completion-based clock.
    assert eng.straggler_monitor._times[-1] >= 0.1


def test_stats_empty_latencies_report_none_not_zero():
    """Regression (PR 8 satellite): the old [0.0] placeholder made a
    zero-traffic engine report mRT/p99 of 0.0ms — a real latency to any
    fleet aggregator.  Empty must be None."""
    eng, _ = _make_retrieval_engine()
    st = eng.stats()
    assert st["count"] == 0
    assert st["mRT_ms"] is None and st["p99_ms"] is None


def test_no_straggler_delay_after_exhausted_retries():
    """Regression (PR 8 satellite): a batch that exhausted its retry
    budget never dispatched, so the injector's slow_ms straggler delay
    must not fire — it would only inflate the shed results' latency."""
    faults = ServeFaultInjector(fail_at_batches=[0], fail_repeats=10,
                                slow_at_batches=[0], slow_ms=2_000.0)
    eng = RetrievalEngine(_slow_serve_fn(0.0), seq_len=4, k=4, max_batch=4,
                          faults=faults, max_retries=1,
                          retry_backoff_ms=0.1)
    eng.submit(Request(0, np.arange(1, 5), k=4))
    t0 = time.monotonic()
    res = eng.run_once()
    wall = time.monotonic() - t0
    assert len(res) == 1 and res[0].shed
    assert wall < 1.0, f"shed batch slept the straggler delay ({wall:.2f}s)"
    assert res[0].latency_ms < 1_000.0


def test_deadline_expiring_during_cold_compile_is_shed():
    """Regression (PR 8 satellite): a request whose deadline expires
    while the first dispatch AOT-compiles must come back shed with
    timed_out=True — not served seconds late as if nothing happened.
    Later identical requests (warm cache) serve normally."""
    def slow_compile_serve(seqs, k):
        time.sleep(0.3)                      # trace-time cost ~ slow XLA
        s = jnp.sum(seqs, axis=1, keepdims=True) + \
            jnp.arange(64, dtype=jnp.float32)[None, :]
        v, i = jax.lax.top_k(s, k)
        return i.astype(jnp.int32), v

    eng = RetrievalEngine(slow_compile_serve, seq_len=4, k=4, max_batch=4)
    eng.submit(Request(0, np.arange(1, 5), k=4, deadline_ms=100.0))
    res = eng.run_once()
    assert len(res) == 1
    assert res[0].shed and res[0].timed_out
    # The compile was not wasted: the same request shape now serves fine.
    eng.submit(Request(1, np.arange(1, 5), k=4, deadline_ms=100.0))
    res = eng.run_once()
    assert len(res) == 1
    assert not res[0].shed and not res[0].timed_out
    assert res[0].items.shape == (4,)


def test_degraded_tag_propagates_through_run_once():
    """k_cap below the batch k tags every result in the batch."""
    arch = get_reduced("sasrec-recjpq")
    cfg = arch.model
    from repro.models import seqrec as m
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)

    def serve_fn(seqs, k):
        return m.serve_topk(params, seqs, cfg, k=k, method="pqtopk")

    eng = RetrievalEngine(serve_fn, seq_len=cfg.max_seq_len, k=5, max_k=32,
                          max_batch=4)
    rng = np.random.default_rng(0)
    for i in range(2):
        eng.submit(Request(i, rng.integers(1, cfg.n_items + 1, 8), k=16))
    res = eng.run_once(k_cap=5)              # bucket(5)=8 < bucket(16)=16
    assert len(res) == 2
    for r in res:
        assert r.degraded == "k_cap"
        assert r.items.shape == (8,)         # capped to the pow2 bucket


def test_decode_engine_slots():
    from repro.models import transformer as T
    cfg = get_reduced("qwen2.5-14b").model
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    n_slots, max_len = 4, 32

    def decode_fn(tokens, pos, caches):
        # per-slot positions: use max (engine keeps slots in lockstep per
        # admission wave; fine for the test)
        ids, vals, caches = T.lm_decode_step(params, tokens, pos.max(),
                                             caches, cfg, k=4)
        return ids[:, 0], caches

    engine = DecodeEngine(decode_fn,
                          lambda b: T.init_caches(cfg, b, max_len),
                          n_slots=n_slots, max_len=max_len)
    for i in range(6):
        engine.submit(Request(i, np.asarray([i + 1]), k=1))
    finished = engine.run(max_new=4)
    assert len(finished) == 6
    for req, toks in finished:
        assert len(toks) == 4
        assert all(0 <= t < cfg.vocab for t in toks)
