"""Distribution-layer unit tests: sharding rules, plan stripping, variant
equivalences added during the §Perf iterations."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd

pytestmark = pytest.mark.sharded


def test_param_rules_match_lm_paths():
    rules = shd.lm_param_rules(scan_layers=True)
    mesh = jax.make_mesh((1,), ("model",))
    # stacked MLP weight: (L, d_in, d_out) -> (None, data->dropped, model)
    spec = shd._match(rules, "layers/mlp/up/w", 3)
    assert spec == P(None, "data", "model")
    spec = shd._match(rules, "layers/moe/up", 3 + 1)
    assert spec == P(None, "model", "data", None)
    assert shd._match(rules, "pq_head/codes", 2) == P("model", None)
    assert shd._match(rules, "final_norm/scale", 1) == P()


def test_param_shardings_drop_nondividing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"embed": {"table": jax.ShapeDtypeStruct((7, 5), jnp.float32)}}
    out = shd.param_shardings(mesh, params, shd.lm_param_rules())
    # 7 % 1 == 0 so both axes are kept on a 1x1 mesh
    assert out["embed"]["table"].spec == P("model", "data")


def test_strip_axis():
    mesh = jax.make_mesh((1,), ("model",))
    plan = shd.ShardingPlan(mesh, {
        "a": P(("pod", "data"), "model", None),
        "b": P("pod", None),
        "c": P(("pod",), "model"),
    })
    out = shd.strip_axis(plan, "pod")
    assert out.specs["a"] == P("data", "model", None)
    assert out.specs["b"] == P(None, None)
    assert out.specs["c"] == P(None, "model")


def test_constrain_noop_without_plan():
    x = jnp.ones((4, 4))
    assert shd.constrain(x, "hidden") is x


def test_constrain_applies_inside_plan():
    mesh = jax.make_mesh((1,), ("model",))
    plan = shd.ShardingPlan(mesh, {"hidden": P("model", None)})
    with shd.activation_plan(plan):
        y = jax.jit(lambda x: shd.constrain(x, "hidden"))(jnp.ones((4, 4)))
    assert np.asarray(y).sum() == 16


@pytest.mark.parametrize("impl", ["dense", "sort"])
def test_moe_impls_equivalent_no_drops(impl):
    from repro.configs.base import MoEConfig
    from repro.models import moe as M
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=16.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, 8, gated=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))
    ref, _ = M.moe_ffn(p, cfg, x, "relu", impl="dense")
    out, _ = M.moe_ffn(p, cfg, x, "relu", impl=impl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-4)


def test_moe_sort_capacity_drops_consistent():
    """With tight capacity both impls drop tokens; outputs stay finite and
    bounded by the no-drop output."""
    from repro.configs.base import MoEConfig
    from repro.models import moe as M
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8, capacity_factor=0.5)
    p = M.moe_init(jax.random.PRNGKey(0), cfg, 8, gated=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 8))
    for impl in ("dense", "sort"):
        out, aux = M.moe_ffn(p, cfg, x, "silu", impl=impl)
        assert np.isfinite(np.asarray(out)).all()
        assert np.isfinite(float(aux))


def test_serve_topk_sharded_matches_plain():
    from repro.configs import get_reduced
    from repro.models import seqrec as S
    mesh = jax.make_mesh((1,), ("model",))
    cfg = get_reduced("sasrec-recjpq").model
    params = S.init_seqrec(jax.random.PRNGKey(0), cfg)
    seqs = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 1,
                              cfg.n_items + 1)
    ids1, vals1 = S.serve_topk(params, seqs, cfg, k=5)
    ids2, vals2 = S.serve_topk(params, seqs, cfg, k=5, sharded_mesh=mesh)
    np.testing.assert_allclose(np.asarray(vals1), np.asarray(vals2),
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ids1), np.asarray(ids2))


def test_sharded_topk_pads_nondivisible_items():
    """1,271,639 rows (Gowalla + pad id) over a 2-shard axis."""
    from repro.configs.base import PQConfig
    from repro.core import retrieval_head
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("model",))
    params = retrieval_head.init(jax.random.PRNGKey(0), 101, 16,
                                 PQConfig(m=4, b=8))
    phi = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    v1, i1 = retrieval_head.top_items(params, phi, 7)
    v2, i2 = retrieval_head.top_items_sharded(params, phi, 7, mesh)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-5)
    assert (np.asarray(i2) < 101).all()


def test_grad_cast_identity_fwd_bf16_bwd():
    from repro.models.transformer import _grad_cast
    x = jnp.ones((3,), jnp.bfloat16)
    y, vjp = jax.vjp(lambda t: _grad_cast(t, jnp.bfloat16), x)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(x, np.float32))
    (g,) = vjp(jnp.ones((3,), jnp.bfloat16))
    assert g.dtype == jnp.bfloat16
