"""Training substrate: optimizer semantics, convergence, grad-accum
equivalence, checkpoint/restart, failure injection, PowerSGD compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.training import (checkpoint as ckpt_lib, compression,
                            fault_tolerance as ft, optimizer as O,
                            train_loop as TL)


def _quadratic_setup():
    target = jnp.asarray([[1.0, -2.0], [0.5, 3.0]])
    params = {"w": jnp.zeros((2, 2))}

    def loss_fn(p, batch):
        loss = jnp.mean((p["w"] - target) ** 2)
        return loss, {"l": loss}

    return params, loss_fn


def test_adamw_converges_quadratic():
    params, loss_fn = _quadratic_setup()
    cfg = O.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                        total_steps=500, schedule="constant")
    state = TL.init_opt_state(params, cfg)
    step = jax.jit(TL.make_train_step(loss_fn, cfg))
    for _ in range(300):
        params, state, m = step(params, state, {})
    assert float(m["loss"]) < 1e-3


def test_lr_schedule_warmup_and_decay():
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(O.schedule_lr(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert lrs[99] < lrs[50] < lrs[10] * 1.01


def test_grad_accum_matches_full_batch():
    """grad_accum=4 must equal one full-batch step (linear model => exact)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 4))
    y = jax.random.normal(jax.random.PRNGKey(1), (16,))
    params = {"w": jnp.zeros((4,))}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {}

    cfg = O.AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=0.0,
                        warmup_steps=1, schedule="constant")
    s1 = TL.init_opt_state(params, cfg)
    p1, _, m1 = jax.jit(TL.make_train_step(loss_fn, cfg))(
        params, s1, {"x": x, "y": y})
    # microbatch losses differ per slice, but *mean* grads are identical
    # for a mean loss over equal slices.
    s2 = TL.init_opt_state(params, cfg)
    p2, _, m2 = jax.jit(TL.make_train_step(loss_fn, cfg, grad_accum=4))(
        params, s2, {"x": x, "y": y})
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                               rtol=1e-5, atol=1e-6)


def test_frozen_paths_not_updated():
    params = {"codes": jnp.ones((3, 2), jnp.int32), "w": jnp.ones((2,))}

    def loss_fn(p, batch):
        return jnp.sum(p["w"] ** 2), {}

    cfg = O.AdamWConfig(lr=0.1)
    state = TL.init_opt_state(params, cfg)
    step = jax.jit(TL.make_train_step(loss_fn, cfg))
    p2, _, _ = step(params, state, {})
    np.testing.assert_array_equal(np.asarray(p2["codes"]),
                                  np.asarray(params["codes"]))
    assert float(jnp.abs(p2["w"] - params["w"]).max()) > 0


def test_checkpoint_restart_and_keep_k(tmp_path):
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=2, async_save=False)
    params = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for s in (10, 20, 30):
        mgr.save(s, {"params": params})
    assert mgr.all_steps() == [20, 30]
    assert mgr.latest_step() == 30
    out = mgr.restore(30, {"params": params})
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_save(tmp_path):
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=3, async_save=True)
    params = {"a": jnp.ones((128, 128))}
    mgr.save(1, {"params": params})
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_checkpoint_restore_skips_truncated(tmp_path):
    """Hardened restore (ISSUE 10): a truncated npz fails its manifest
    CRC32, ``restore`` raises CorruptCheckpointError instead of a numpy
    parse error, and ``restore_latest`` falls back to the previous valid
    step rather than crashing the run on its newest checkpoint."""
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=5, async_save=False)
    params = {"a": jnp.arange(64.0).reshape(8, 8)}
    mgr.save(10, {"params": params})
    mgr.save(20, {"params": params})
    victim = os.path.join(str(tmp_path), "step_0000000020", "params.npz")
    size = os.path.getsize(victim)
    with open(victim, "r+b") as f:          # torn write: drop the tail
        f.truncate(size // 2)
    assert not mgr.validate_step(20)
    assert mgr.valid_steps() == [10]
    with pytest.raises(ckpt_lib.CorruptCheckpointError, match="checksum"):
        mgr.restore(20, {"params": params})
    step, out = mgr.restore_latest({"params": params})
    assert step == 10
    np.testing.assert_array_equal(np.asarray(out["params"]["a"]),
                                  np.asarray(params["a"]))
    # every step damaged -> a hard, named error (not a numpy traceback)
    with open(os.path.join(str(tmp_path), "step_0000000010",
                           "params.npz"), "r+b") as f:
        f.truncate(8)
    with pytest.raises(ckpt_lib.CorruptCheckpointError, match="no valid"):
        mgr.restore_latest({"params": params})


@pytest.mark.sharded
def test_elastic_restore_reshards(tmp_path):
    """Restore onto a (trivially different) mesh sharding — the elastic
    path: full arrays re-placed by explicit NamedShardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), async_save=False)
    params = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(5, {"params": params})
    mesh = jax.make_mesh((1,), ("model",))
    shardings = {"params": {"w": NamedSharding(mesh, P("model", None))}}
    out = mgr.restore(5, {"params": params}, shardings)
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(params["w"]))
    assert out["params"]["w"].sharding.spec == P("model", None)


def test_failure_injection_and_resume(tmp_path):
    """End-to-end: train, crash at an injected step, auto-resume from the
    checkpoint, finish — via the real launcher."""
    from repro.launch import train as train_launcher
    out = train_launcher.main([
        "--arch", "sasrec-recjpq", "--reduced", "--steps", "40",
        "--batch", "8", "--ckpt", str(tmp_path), "--ckpt-every", "10",
        "--fail-at", "25", "--log-every", "100",
    ])
    assert out is not None
    mgr = ckpt_lib.CheckpointManager(str(tmp_path))
    assert mgr.latest_step() == 40


def test_straggler_monitor_flags_slow_steps():
    mon = ft.StragglerMonitor(factor=3.0)
    for s in range(20):
        mon.record(s, 0.01)
    assert mon.record(20, 0.5)
    assert 20 in mon.flagged


@pytest.mark.sharded
def test_powersgd_compression_properties():
    """Error feedback: compressed + residual == original (per matrix)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32))}
    e = compression.init_error_feedback(g)
    mesh = jax.make_mesh((1,), ("pod",))

    out_g, out_e = compression.compressed_psum_sharded(
        g, e, mesh, "pod", rank=4, min_size=1)
    # decompressed + error == original gradient
    np.testing.assert_allclose(
        np.asarray(out_g["w"] + out_e["w"]), np.asarray(g["w"]),
        rtol=1e-4, atol=1e-5)
    # low-rank: rank of compressed grad <= 4
    sv = np.linalg.svd(np.asarray(out_g["w"]), compute_uv=False)
    assert (sv[4:] < 1e-4).all()


def test_powersgd_compression_ratio():
    params = {"big": jnp.zeros((512, 512)), "small": jnp.zeros((8,))}
    r = compression.compression_ratio(params, rank=4, min_size=1024)
    expected = (4 * (512 + 512) + 8) / (512 * 512 + 8)
    assert abs(r - expected) < 1e-6
