"""Per-arch smoke tests: instantiate the REDUCED config of each assigned
architecture and run one forward/train step on CPU, asserting output shapes
and no NaNs (the FULL configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs

LM_ARCHS = ["qwen2.5-14b", "nemotron-4-340b", "gemma3-27b",
            "qwen3-moe-30b-a3b", "dbrx-132b"]
RECSYS_ARCHS = ["dcn-v2", "bst", "dien", "fm"]
SEQREC_ARCHS = ["sasrec-recjpq", "gbert4rec-recjpq"]


def _finite(x):
    return bool(np.isfinite(np.asarray(x)).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    from repro.models import transformer as T
    cfg = get_reduced(arch).model
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    loss, metrics = jax.jit(lambda p, b: T.lm_loss(p, b, cfg))(params, batch)
    assert _finite(loss) and float(loss) > 0
    hidden, _ = T.lm_hidden(params, tokens, cfg)
    assert hidden.shape == (2, 16, cfg.d_model)
    assert _finite(hidden)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_smoke(arch):
    from repro.models import transformer as T
    cfg = get_reduced(arch).model
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    caches = T.init_caches(cfg, 2, 32)
    tok = jnp.asarray([1, 2], jnp.int32)
    for head in ("pqtopk", "dense"):
        ids, vals, caches2 = jax.jit(
            lambda p, t, pos, c: T.lm_decode_step(p, t, pos, c, cfg, k=8,
                                                  head_method=head)
        )(params, tok, jnp.int32(0), caches)
        assert ids.shape == (2, 8) and vals.shape == (2, 8)
        assert _finite(vals)


def test_lm_decode_matches_forward():
    """Greedy decode hidden state must match the full-forward hidden at the
    same position (cache correctness)."""
    from repro.models import transformer as T
    cfg = get_reduced("qwen2.5-14b").model
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    hidden, _ = T.lm_hidden(params, tokens, cfg)
    logits_full = T.unembed(params, hidden, cfg)

    caches = T.init_caches(cfg, 1, 16)
    decode = jax.jit(lambda p, t, pos, c: T.lm_decode_step(
        p, t, pos, c, cfg, k=cfg.vocab, head_method="dense"))
    for pos in range(8):
        ids, vals, caches = decode(params, tokens[:, pos], jnp.int32(pos),
                                   caches)
    # top-1 of decode at last position == argmax of full forward
    assert int(ids[0, 0]) == int(jnp.argmax(logits_full[0, -1]))


def test_gemma3_sliding_window_cache_shapes():
    from repro.models import transformer as T
    cfg = get_reduced("gemma3-27b").model
    caches = T.init_caches(cfg, 2, 128)
    assert isinstance(caches, list)
    flags = T.layer_types(cfg)
    for i, c in enumerate(caches):
        expected = 128 if flags[i] else cfg.attention.window
        assert c["k"].shape[1] == expected
    assert not flags[:5].any() and flags[5]   # 5 local : 1 global


@pytest.mark.parametrize("arch", RECSYS_ARCHS)
def test_recsys_smoke(arch):
    from repro.data.recsys_data import ctr_batch
    from repro.models import recsys as R
    cfg = get_reduced(arch).model
    params = R.init_recsys(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in ctr_batch(cfg, 16).items()}
    loss, _ = jax.jit(lambda p, b: R.ctr_loss(p, b, cfg))(params, batch)
    assert _finite(loss)
    logits = R.ctr_logits(params, batch, cfg)
    assert logits.shape == (16,)
    ids, vals = jax.jit(lambda p, b: R.retrieve_topk(p, b, cfg, k=5))(params,
                                                                      batch)
    assert ids.shape == (16, 5) and _finite(vals)
    assert int(jnp.max(ids)) < cfg.n_items


@pytest.mark.parametrize("arch", SEQREC_ARCHS)
def test_seqrec_smoke(arch):
    from repro.data.sequences import SeqRecDataset
    from repro.models import seqrec as S
    cfg = get_reduced(arch).model
    ds = SeqRecDataset.synthetic(100, cfg.n_items, 8, cfg.max_seq_len)
    params = S.init_seqrec(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             next(ds.batches(8, cfg.n_negatives,
                             backbone=cfg.backbone)).items()}
    loss, _ = jax.jit(lambda p, b: S.seqrec_loss(p, b, cfg))(params, batch)
    assert _finite(loss)
    ids, vals = S.serve_topk(params, batch["input_seq"], cfg, k=10)
    assert ids.shape == (8, 10) and _finite(vals)


def test_gnn_smoke_all_shapes():
    from repro.data.graph import (NeighborSampler, molecule_batch,
                                  synthetic_graph)
    from repro.models import gnn as G
    cfg = get_reduced("graphsage-reddit").model
    g = synthetic_graph(300, 1200, 16, cfg.n_classes)
    params = G.init_gnn(jax.random.PRNGKey(0), cfg, 16)
    batch = {"feats": jnp.asarray(g.feats), "edges": jnp.asarray(g.edges),
             "labels": jnp.asarray(g.labels),
             "label_mask": jnp.ones(g.n_nodes)}
    loss, _ = jax.jit(lambda p, b: G.gnn_loss(p, b, cfg))(params, batch)
    assert _finite(loss)
    sampler = NeighborSampler(g)
    mb = {k: jnp.asarray(v) for k, v in sampler.sample_batch(
        np.arange(16), tuple(cfg.sample_sizes), np.random.default_rng(0)
    ).items()}
    loss2, _ = jax.jit(lambda p, b: G.gnn_minibatch_loss(p, b, cfg))(params, mb)
    assert _finite(loss2)
    mol = {k: jnp.asarray(v) for k, v in molecule_batch(
        4, 10, 20, 16, cfg.n_classes).items()}
    loss3, _ = jax.jit(lambda p, b: G.gnn_graph_batch_loss(p, b, cfg))(params,
                                                                       mol)
    assert _finite(loss3)


def test_all_archs_have_reduced_configs():
    for arch in list_archs():
        red = get_reduced(arch)
        assert red.arch_id == arch
        assert red.shapes, arch
