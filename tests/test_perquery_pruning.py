"""Per-query pruned retrieval (PR 5): per-query thetas + survival masks,
greedy query grouping, the 2D (group, slot) compacted kernel table, and
wrap-robust theta seeding.

Coverage: bit-exact parity of the grouped cascade vs the exhaustive
oracle AND vs the batch-any route across (bound backend, grouping on/off,
B in {1, 8, 200}, flat/sharded, under jit, inside ``lm_decode_step``,
Pallas-interpret 2D kernel path); an adversarial case where every query
survives a disjoint tile set (grouping must strictly reduce scored
slot·query pairs); the degenerate full-hull seed-ordering penalty on a
wraparound code layout; group-aware engine calibration; and the ladder's
max-per-group escalation rule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import PQConfig
from repro.core import pruning, retrieval_head, scoring, topk as topk_lib
from repro.kernels.pqtopk import ops as pq_ops
from repro.serving.engine import Request, RetrievalEngine


def _oracle(codes, s, k):
    r = scoring.score_pqtopk(codes.astype(jnp.int32), s)
    return topk_lib.tiled_topk(r, k)


def _mixed_case(n, m, b, bq, *, seed=0, code_dtype=jnp.int32, boost=6.0):
    """Clipped clustered codes + per-query window-boosted skewed scores:
    every query's survivor set concentrates on its own catalogue region —
    the mixed-batch regime the per-query route targets."""
    rng = np.random.default_rng(seed)
    centers = (np.arange(n) / n * b).astype(np.int64)
    codes = jnp.asarray(
        np.clip(centers[:, None] + rng.integers(-1, 2, (n, m)), 0, b - 1),
        code_dtype)
    g = rng.standard_normal((bq, m, b))
    g = np.sign(g) * np.abs(g) ** 3
    for q in range(bq):
        w = (q * b) // max(bq, 1)
        g[q, :, max(0, w - 1):w + 3] += boost
    return codes, jnp.asarray(g, jnp.float32)


def _assert_same(a, b):
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# ---------------------------------------------------------------------------
# per-query masks + thetas
# ---------------------------------------------------------------------------


def test_perquery_mask_union_is_batchany_mask():
    codes, s = _mixed_case(2048, 4, 64, 8)
    state = pruning.build_pruned_state(codes, 64, 256)
    bounds = pruning.tile_bounds(state, s)
    theta, _, _ = pruning.theta_seed_ingraph(codes, s, bounds, 5, tile=256)
    pq_mask = pruning.survival_mask_perquery(bounds, theta)
    np.testing.assert_array_equal(
        np.asarray(pq_mask.any(axis=0)),
        np.asarray(pruning.survival_mask(bounds, theta)))


def test_perquery_seeding_equals_shared_at_b1():
    """B=1: the per-query seed ordering IS the batch-max ordering and the
    scoring paths share the tree_sum accumulation — thetas bit-equal."""
    codes, s = _mixed_case(3001, 4, 32, 1, seed=3)
    state = pruning.build_pruned_state(codes, 32, 256)
    bounds = pruning.tile_bounds(state, s)
    for policy in ("greedy", "adaptive"):
        t1, n1, _ = pruning.theta_seed_ingraph(
            codes, s, bounds, 7, tile=256, seed_policy=policy)
        t2, n2, _ = pruning.theta_seed_perquery(
            codes, s, bounds, 7, tile=256, seed_policy=policy)
        np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
        assert int(n1) == int(n2)


def test_perquery_theta_certified():
    """Every query has >= k true scores >= its theta (the certification
    the exactness argument rests on)."""
    codes, s = _mixed_case(2048, 4, 64, 16, seed=4)
    state = pruning.build_pruned_state(codes, 64, 256)
    bounds = pruning.tile_bounds(state, s)
    theta, _, _ = pruning.theta_seed_perquery(codes, s, bounds, 5, tile=256)
    r = np.asarray(scoring.score_pqtopk(codes, s))
    at_least = (r >= np.asarray(theta)[:, None]).sum(axis=1)
    assert (at_least >= 5).all()


# ---------------------------------------------------------------------------
# query grouping + 2D compaction
# ---------------------------------------------------------------------------


def test_group_queries_identical_masks_share_group():
    mask = jnp.asarray(np.tile([True] * 4 + [False] * 12, (6, 1)))
    assign = np.asarray(pruning.group_queries(mask, 4))
    assert len(set(assign.tolist())) == 1


def test_group_queries_disjoint_masks_spread():
    m = np.zeros((4, 16), bool)
    for q in range(4):
        m[q, 4 * q:4 * q + 4] = True
    assign = np.asarray(pruning.group_queries(jnp.asarray(m), 4))
    assert len(set(assign.tolist())) == 4


def test_group_and_compact_layout():
    rng = np.random.default_rng(0)
    mask = jnp.asarray(rng.random((10, 32)) < 0.2)
    perm, inv, slots2d, counts = pruning.group_and_compact(
        mask, n_groups=4, batch_tile=4)
    perm, inv = np.asarray(perm), np.asarray(inv)
    assert sorted(perm.tolist()) == list(range(10))
    np.testing.assert_array_equal(perm[inv], np.arange(10))
    slots2d, counts = np.asarray(slots2d), np.asarray(counts)
    assert slots2d.shape == (3, 32) and counts.shape == (3,)   # ceil(10/4)
    mask_np = np.asarray(mask)[perm]
    mask_np = np.concatenate([mask_np, np.zeros((2, 32), bool)])
    for g in range(3):
        union = mask_np[4 * g:4 * (g + 1)].any(axis=0)
        want = np.flatnonzero(union)
        assert counts[g] == len(want)
        np.testing.assert_array_equal(slots2d[g, :len(want)], want)
        assert (slots2d[g, len(want):] == -1).all()


# ---------------------------------------------------------------------------
# parity matrix: grouped cascade vs oracle vs batch-any route
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["bitmask", "range"])
@pytest.mark.parametrize("n_groups", [1, 4])
@pytest.mark.parametrize("bq", [1, 8, 200])
def test_grouped_cascade_matches_oracle(backend, n_groups, bq):
    codes, s = _mixed_case(4099, 4, 64, bq, seed=bq)     # odd N
    state = pruning.build_pruned_state(codes, 64, 256, backend=backend)
    ref = _oracle(codes, s, 7)
    out = pruning.cascade_topk_ingraph(codes, s, 7, state,
                                       query_grouping=True,
                                       n_groups=n_groups)
    _assert_same(out, ref)
    # ... and vs the batch-any route (grouping must not change answers).
    out_any = pruning.cascade_topk_ingraph(codes, s, 7, state)
    _assert_same(out, out_any)


@pytest.mark.parametrize("code_dtype", [jnp.uint8, jnp.int32])
def test_grouped_kernel_interpret_parity(code_dtype):
    """The 2D (group, slot) Pallas path (interpret mode off TPU) is
    bit-identical to the oracle — sentinel rows, group-keyed grid and
    per-group merge included."""
    codes, s = _mixed_case(1021, 4, 32, 24, seed=7, code_dtype=code_dtype)
    state = pruning.build_pruned_state(codes, 32, 128)
    ref = _oracle(codes, s, 5)
    out = pruning.cascade_topk_ingraph(codes, s, 5, state,
                                       query_grouping=True, n_groups=4,
                                       use_kernel=True, interpret=True)
    _assert_same(out, ref)


def test_grouped_under_jit_and_with_ladder():
    codes, s = _mixed_case(4099, 4, 64, 32, seed=9)
    state = pruning.build_pruned_state(codes, 64, 256)
    ref = _oracle(codes, s, 7)

    @jax.jit
    def run(s_):
        return pruning.cascade_topk_ingraph(
            codes, s_, 7, state, query_grouping=True, n_groups=8,
            ladder=(2, 8))
    _assert_same(run(s), ref)


def test_grouped_pairs_never_exceed_union_pairs():
    codes, s = _mixed_case(4099, 4, 64, 64, seed=11)
    state = pruning.build_pruned_state(codes, 64, 256)
    _, _, st = pruning.cascade_topk_ingraph(
        codes, s, 7, state, query_grouping=True, n_groups=8,
        return_stats=True)
    assert set(st) == set(pruning.STATS_KEYS)
    assert int(st["pairs_scored"]) <= int(st["pairs_union"])
    assert int(st["max_group_survived"]) <= int(st["n_survived"])


def test_adversarial_disjoint_survivor_sets():
    """Every query survives a DISJOINT tile set — the worst case for the
    batch-any rule (its union is the sum of all sets) and the best case
    for grouping: scored pairs must shrink strictly, answers bit-equal."""
    codes, s = _mixed_case(8192, 4, 64, 32, seed=13, boost=20.0)
    state = pruning.build_pruned_state(codes, 64, 256)
    ref = _oracle(codes, s, 3)
    v, i, st = pruning.cascade_topk_ingraph(
        codes, s, 3, state, query_grouping=True, n_groups=8,
        return_stats=True)
    _assert_same((v, i), ref)
    assert int(st["pairs_scored"]) < int(st["pairs_union"]), st
    assert int(st["max_group_survived"]) < int(st["n_survived"])


# ---------------------------------------------------------------------------
# wrap-robust theta seeding (degenerate full-hull range tiles)
# ---------------------------------------------------------------------------


def _wrap_case(n=4096, m=4, b=64, tile=256, bq=4, seed=17):
    """Clustered clipped codes, except the FIRST tile's codes wrap the
    codebook ({0, b-1} rows alternating): its range hull is [0, b-1] in
    every split (degenerate), its range bound is the unconditional max —
    but its true items score ~nothing special."""
    rng = np.random.default_rng(seed)
    centers = (np.arange(n) / n * b).astype(np.int64)
    codes_np = np.clip(centers[:, None] + rng.integers(-1, 2, (n, m)),
                       0, b - 1)
    codes_np[:tile] = np.where((np.arange(tile) % 2)[:, None] == 0,
                               0, b - 1)
    g = rng.standard_normal((bq, m, b))
    g = np.sign(g) * np.abs(g) ** 3
    for q in range(bq):
        w = b // 2 + q
        g[q, :, w:w + 2] += 6.0
    return jnp.asarray(codes_np, jnp.int32), jnp.asarray(g, jnp.float32)


def test_degenerate_tile_mask_detects_wrap():
    codes, _ = _wrap_case()
    state = pruning.build_pruned_state(codes, 64, 256, backend="range")
    deg = np.asarray(pruning.degenerate_tile_mask(state))
    assert deg[0] and not deg[1:].any()
    assert pruning.degenerate_tile_mask(
        pruning.build_pruned_state(codes, 64, 256)) is None   # bitmask


def test_seed_order_key_pushes_degenerate_behind():
    bounds = jnp.asarray([10.0, 5.0, 8.0, 1.0])
    deg = jnp.asarray([True, False, False, False])
    order = np.asarray(jnp.argsort(-pruning.seed_order_key(bounds, deg)))
    # Tile 0 has the largest bound but is degenerate -> ordered last;
    # clean tiles keep their bound order.
    np.testing.assert_array_equal(order, [2, 1, 3, 0])


def test_wrap_penalty_tightens_survival_on_range_backend():
    codes, s = _wrap_case()
    state = pruning.build_pruned_state(codes, 64, 256, backend="range")
    bounds = pruning.tile_bounds(state, s)
    deg = pruning.degenerate_tile_mask(state)
    k = 5
    t_plain, _, sf_plain = pruning.theta_seed_ingraph(
        codes, s, bounds, k, tile=256, seed_tiles=1)
    t_pen, _, sf_pen = pruning.theta_seed_perquery(
        codes, s, bounds, k, tile=256, seed_tiles=1, degenerate=deg)
    # Without the penalty the single seed tile is the degenerate wrap tile
    # (largest range bound) and theta is loose; with it, each query seeds
    # its own informative tile and certifies a strictly tighter theta.
    assert float(sf_pen) < float(sf_plain)
    assert (np.asarray(t_pen) >= np.asarray(t_plain)).all()


def test_wrap_layout_cascade_still_exact_both_routes():
    codes, s = _wrap_case()
    for backend in ("bitmask", "range"):
        state = pruning.build_pruned_state(codes, 64, 256, backend=backend)
        ref = _oracle(codes, s, 5)
        for grouping in (False, True):
            out = pruning.cascade_topk_ingraph(
                codes, s, 5, state, query_grouping=grouping, n_groups=4)
            _assert_same(out, ref)


def test_adaptive_seeding_does_not_stall_on_wrap_tiles():
    """Adaptive growth with the penalty settles at no more seed tiles
    than without it (degenerate tiles can only inflate the seed set)."""
    codes, s = _wrap_case(bq=2)
    state = pruning.build_pruned_state(codes, 64, 256, backend="range")
    bounds = pruning.tile_bounds(state, s)
    deg = pruning.degenerate_tile_mask(state)
    _, n_plain, _ = pruning.theta_seed_ingraph(
        codes, s, bounds, 5, tile=256, seed_policy="adaptive",
        seed_tiles=1, seed_max_tiles=8)
    _, n_pen, _ = pruning.theta_seed_ingraph(
        codes, s, bounds, 5, tile=256, seed_policy="adaptive",
        seed_tiles=1, seed_max_tiles=8, degenerate=deg)
    assert int(n_pen) <= int(n_plain)


# ---------------------------------------------------------------------------
# ladder escalation on per-group counts
# ---------------------------------------------------------------------------


def test_ladder_escalates_on_max_group_count():
    codes, s = _mixed_case(2048, 4, 64, 16, seed=19)
    n_tiles = 8
    slots_small = jnp.full((4, 2), -1, jnp.int32).at[:, 0].set(0)
    slots_full = jnp.full((4, n_tiles), -1, jnp.int32).at[:, 0].set(0)
    for counts, want in ((jnp.asarray([1, 2, 1, 0]), 0),
                         (jnp.asarray([1, 3, 1, 0]), 1)):
        _, _, rung = pq_ops.pq_topk_tiles_ladder(
            codes, s, 5, (slots_small, slots_full), counts, tile=256,
            batch_tile=4)
        assert int(rung) == want


# ---------------------------------------------------------------------------
# flat/sharded routes + decode loop + engine
# ---------------------------------------------------------------------------


def _grouped_cfg(**kw):
    return PQConfig(m=4, b=16, code_dtype="uint8", query_grouping=True,
                    n_groups=4, **kw)


def test_top_items_grouped_route_matches_plain():
    params = retrieval_head.init(jax.random.PRNGKey(0), 1013, 16,
                                 _grouped_cfg())
    phi = jax.random.normal(jax.random.PRNGKey(1), (12, 16))
    v1, i1 = retrieval_head.top_items(params, phi, 7, method="pqtopk")
    v2, i2 = retrieval_head.top_items(params, phi, 7,
                                      method="pqtopk_pruned",
                                      pq_cfg=_grouped_cfg())
    _assert_same((v1, i1), (v2, i2))


@pytest.mark.sharded
def test_sharded_grouped_matches_plain():
    mesh = jax.make_mesh((1,), ("model",))
    params = retrieval_head.init(jax.random.PRNGKey(0), 1013, 16,
                                 _grouped_cfg())
    phi = jax.random.normal(jax.random.PRNGKey(2), (12, 16))
    v1, i1 = retrieval_head.top_items(params, phi, 7, method="pqtopk")
    v2, i2, st = retrieval_head.top_items_pruned_sharded(
        params, phi, 7, mesh, pq_cfg=_grouped_cfg(), return_stats=True)
    _assert_same((v1, i1), (v2, i2))
    assert set(st) == set(pruning.STATS_KEYS)
    # n_groups reports kernel group rows actually built: 12 queries at
    # the 8-row sublane floor -> 2 batch tiles, not the requested 4.
    assert int(st["n_groups"]) == 2
    assert int(st["pairs_scored"]) <= int(st["pairs_union"])


@pytest.mark.sharded
def test_sharded_grouped_is_jittable():
    mesh = jax.make_mesh((1,), ("model",))
    params = retrieval_head.init(jax.random.PRNGKey(0), 600, 16,
                                 _grouped_cfg())
    params = retrieval_head.ensure_sharded_pruned_state(params, mesh,
                                                        k_hint=7)
    fn = jax.jit(lambda p, x: retrieval_head.top_items_pruned_sharded(
        p, x, 7, mesh, pq_cfg=_grouped_cfg()))
    phi = jax.random.normal(jax.random.PRNGKey(3), (9, 16))
    v, i = fn(params, phi)
    v1, i1 = retrieval_head.top_items(params, phi, 7, method="pqtopk")
    _assert_same((v, i), (v1, i1))


@pytest.mark.slow
def test_grouped_head_inside_lm_decode_step():
    from dataclasses import replace
    from repro.models import transformer as T
    arch = get_reduced("qwen2.5-14b")
    cfg = replace(arch.model,
                  pq_head=replace(arch.model.pq_head, query_grouping=True,
                                  n_groups=2))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    caches = T.init_caches(cfg, 2, 16)
    tok = jnp.asarray([3, 5], jnp.int32)
    pos = jnp.int32(0)
    outs = {}
    for meth in ("pqtopk", "pqtopk_pruned"):
        step = jax.jit(lambda p, t_, c, m_=meth: T.lm_decode_step(
            p, t_, pos, c, cfg, k=8, head_method=m_))
        ids, vals, _ = step(params, tok, caches)
        outs[meth] = (np.asarray(ids), np.asarray(vals))
    np.testing.assert_array_equal(outs["pqtopk_pruned"][0],
                                  outs["pqtopk"][0])
    np.testing.assert_array_equal(outs["pqtopk_pruned"][1],
                                  outs["pqtopk"][1])


def test_survival_count_grouped_at_most_union():
    codes, s = _mixed_case(4099, 4, 64, 32, seed=23)
    state = pruning.build_pruned_state(codes, 64, 256)
    cg = int(pruning.survival_count_grouped(codes, s, 5, state, n_groups=8))
    cu = int(pruning.survival_count(codes, s, 5, state))
    assert cg <= cu


@pytest.mark.slow
def test_engine_grouped_calibration_and_parity():
    """Group-aware calibration installs a ladder; the grouped engine
    serves the same winners as the batch-any engine (both exact)."""
    from dataclasses import replace
    from repro.models import seqrec as seqrec_lib
    cfg = replace(get_reduced("sasrec-recjpq").model, n_items=2048)
    cfg_g = replace(cfg, pq=replace(cfg.pq, query_grouping=True,
                                    n_groups=4))
    params = seqrec_lib.init_seqrec(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    seqs = [rng.integers(1, cfg.n_items + 1, 8) for _ in range(6)]
    results = {}
    for name, c in (("any", cfg), ("grouped", cfg_g)):
        eng = RetrievalEngine.for_seqrec(params, c, k=5, max_batch=8,
                                         method="pqtopk_pruned")
        assert eng.ladder is not None
        for i, sq in enumerate(seqs):
            eng.submit(Request(i, sq, k=5))
        out = sorted(eng.drain(), key=lambda r: r.request_id)
        assert all(len(r.items) == 5 for r in out)
        results[name] = out
        if name == "grouped":
            assert sum(eng.rung_counts.values()) >= 1
    for ra, rg in zip(results["any"], results["grouped"]):
        np.testing.assert_array_equal(ra.items, rg.items)
        np.testing.assert_array_equal(ra.scores, rg.scores)
