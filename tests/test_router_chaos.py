"""Chaos harness for the replicated serving fabric (serving/router.py).

The invariants under fault injection (docs/SERVING.md):

* **Exactly one Result per request** — never silence, never duplicates,
  through replica crashes, re-dispatch, hedging and load shedding.
* **Healthy-path exactness** — every result NOT tagged degraded/shed is
  bit-identical to a single-engine oracle serving the same requests.
* **Observability** — stats() reports per-replica health, hedge wins,
  degradation counts, queue depth and latency percentiles; ejection ->
  probe -> re-admission and degradation -> recovery cycles are visible.
"""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import seqrec as S
from repro.serving import ReplicaRouter, Request, RetrievalEngine
from repro.training.fault_tolerance import ReplicaFaultPlan

# 8192 items -> 4 pruning tiles at the default 2048 tile, so LADDER's
# single 1-tile rung is genuinely non-exhaustive and the rung-pinned
# degraded route really is a different (cheaper, inexact-capable) program.
CFG = dataclasses.replace(get_reduced("sasrec-recjpq").model, n_items=8192)
LADDER = (1,)
K = 5
BIG_K = 16          # above the degrade k-cap's pow2 bucket, so capping bites


@pytest.fixture(scope="module")
def params():
    return S.init_seqrec(jax.random.PRNGKey(0), CFG)


def _request_specs(n, seed=0):
    rng = np.random.default_rng(seed)
    specs = []
    for i in range(n):
        seq = rng.integers(1, CFG.n_items + 1, int(rng.integers(2, 16)))
        specs.append((i, seq, BIG_K if i % 3 == 0 else K))
    return specs


@pytest.fixture(scope="module")
def oracle_results(params):
    """Single-engine oracle: the same requests served with no router, no
    faults, no degradation — the healthy-path ground truth."""
    eng = RetrievalEngine.for_seqrec(params, CFG, k=K, max_batch=8,
                                     method="pqtopk_pruned", ladder=LADDER,
                                     calibrate=False)
    for rid_, payload, kreq in _request_specs(260):
        eng.submit(Request(rid_, payload, k=kreq))
    return {r.request_id: r for r in eng.drain()}


def _mk_router(params, **kw):
    kw.setdefault("n_replicas", 3)
    kw.setdefault("max_batch", 8)
    kw.setdefault("method", "pqtopk_pruned")
    kw.setdefault("ladder", LADDER)
    kw.setdefault("calibrate", False)
    return ReplicaRouter.for_seqrec(params, CFG, k=K, **kw)


def _pump_until(router, cond, timeout_s=30.0, sleep_s=0.01):
    t0 = time.monotonic()
    while not cond():
        router.pump()
        if time.monotonic() - t0 > timeout_s:
            return False
        time.sleep(sleep_s)
    return True


def _parity(results, oracle):
    """Assert healthy-path (untagged, unshed) results match the oracle
    bit-for-bit; returns how many were checked."""
    checked = 0
    for r in results:
        if r.shed or r.degraded or r.request_id not in oracle:
            continue
        o = oracle[r.request_id]
        np.testing.assert_array_equal(
            r.items, o.items,
            err_msg=f"request {r.request_id} on replica {r.replica}")
        np.testing.assert_array_equal(r.scores, o.scores)
        checked += 1
    return checked


@pytest.mark.slow
def test_chaos_flat_exactly_once_and_bit_parity(params, oracle_results):
    """The flagship run: >= 200 requests over K=3 replicas with one
    replica crash-looping, the ladder driven through a degrade ->
    recover cycle, and the crashed replica ejected and re-admitted."""
    plans = {1: ReplicaFaultPlan(crash_windows=((0, 3),))}
    with _mk_router(params, fault_plans=plans, suspect_after=1,
                    eject_after=1, cooldown_ms=20.0,
                    hedge_floor_ms=500.0,
                    degrade_high=64, degrade_low=8,
                    degrade_patience=1, recover_patience=2) as router:
        router.warmup(ks=[BIG_K])
        specs = _request_specs(260)
        all_results = []

        # Phase 1 (steady state): trickle 120 requests with pumping —
        # the fabric stays at level 0 and replica 1 crashes into
        # ejection, half-open probes, and re-admission.
        for rid_, payload, kreq in specs[:120]:
            router.submit(Request(rid_, payload, k=kreq))
            if rid_ % 8 == 7:
                router.pump()
        all_results += router.drain()

        # Replica 1's crash window covers its first 3 dispatches; with
        # eject_after=1 the first failure ejects it and probes burn
        # through the window.  Keep traffic flowing so probes have jobs
        # to ride on.
        extra = 10_000
        rng = np.random.default_rng(42)
        while router.replicas[1].readmissions == 0:
            for j in range(8):
                router.submit(Request(
                    extra + j, rng.integers(1, CFG.n_items + 1, 8), k=K))
            extra += 8
            router.drain()
            assert extra < 11_000, "replica 1 never re-admitted"
        st = router.stats()
        assert st["replicas"][1]["ejections"] >= 1
        assert st["replicas"][1]["readmissions"] >= 1

        # Phase 2 (overload): burst the remaining 140 with no pumping —
        # depth over the high watermark walks the ladder, and BIG_K
        # requests served at level >= 1 come back k-capped and tagged.
        for rid_, payload, kreq in specs[120:]:
            router.submit(Request(rid_, payload, k=kreq))
        router.pump()
        assert router.level >= 1
        phase2 = router.drain()
        all_results += phase2
        assert any(r.degraded for r in phase2)

        # Recovery: idle pumps drop the level back to 0 with hysteresis.
        assert _pump_until(router, lambda: router.level == 0)
        st = router.stats()
        assert st["degrade_events"] >= 1
        assert st["recover_events"] >= 1

        # ---- exactly-once over EVERYTHING submitted -------------------
        assert router._expected == router._done_ids
        seen = [r.request_id for r in all_results if r.request_id < 10_000]
        assert sorted(seen) == list(range(260))

        # ---- healthy-path bit-parity vs the single-engine oracle ------
        assert _parity(all_results, oracle_results) >= 10

        # ---- degraded results are tagged with the ladder's own tags ---
        tags = set(st["degraded_results"])
        assert tags and tags <= {"k_cap", "rung_pin", "k_cap+rung_pin",
                                 "load_shed", "redispatch_exhausted"}

        # ---- stats() surface (the observability contract) -------------
        assert st["p50_ms"] is not None and st["p99_ms"] is not None
        for rep in st["replicas"].values():
            assert {"state", "strikes", "ejections", "readmissions",
                    "queue_depth"} <= set(rep)


def test_exactly_once_under_crash_and_redispatch(params):
    """Every request gets exactly one Result even when a replica crashes
    mid-stream and its in-flight work is re-dispatched."""
    plans = {0: ReplicaFaultPlan(crash_windows=((2, 5),))}
    with _mk_router(params, n_replicas=2, fault_plans=plans,
                    eject_after=1, cooldown_ms=10.0,
                    hedge=False) as router:
        router.warmup()
        n = 64
        rng = np.random.default_rng(3)
        for i in range(n):
            router.submit(Request(i, rng.integers(1, CFG.n_items + 1, 8),
                                  k=K))
            if i % 16 == 15:
                router.pump()
        results = router.drain()
        ids = sorted(r.request_id for r in results)
        assert ids == list(range(n))            # one Result each, no dupes
        assert all(not r.shed for r in results)  # redispatch recovered all
        assert router.stats()["redispatched"] >= 1


def test_hedge_rescues_straggler_and_suppresses_duplicate(params):
    """A straggling replica's batch is re-issued to a healthy spare; the
    hedge wins, and the loser's late results are suppressed."""
    plans = {0: ReplicaFaultPlan(slow_windows=((0, 2),), slow_ms=400.0)}
    with _mk_router(params, n_replicas=2, fault_plans=plans,
                    eject_after=10,       # keep the straggler in rotation
                    hedge_floor_ms=40.0) as router:
        router.warmup()
        rng = np.random.default_rng(4)
        for i in range(8):
            router.submit(Request(i, rng.integers(1, CFG.n_items + 1, 8),
                                  k=K))
        results = router.drain()
        assert sorted(r.request_id for r in results) == list(range(8))
        st = router.stats()
        assert st["hedges"] >= 1
        assert st["hedge_wins"] >= 1
        assert any(r.hedged for r in results)
        # The slow original eventually completes: its results must be
        # suppressed as duplicates, not delivered twice.
        assert _pump_until(router,
                           lambda: router.duplicates_suppressed >= 1)


def test_degradation_ladder_tags_and_recovers(params):
    """Driving depth over the high watermark walks the ladder (k-cap ->
    rung-pin -> shed); results are tagged; hysteresis recovers."""
    with _mk_router(params, n_replicas=2, hedge=False,
                    degrade_high=24, degrade_low=4,
                    degrade_patience=1, recover_patience=3) as router:
        router.warmup(ks=[BIG_K])
        rng = np.random.default_rng(5)
        nxt = 0

        def burst(n):
            nonlocal nxt
            for _ in range(n):
                router.submit(Request(
                    nxt, rng.integers(1, CFG.n_items + 1, 8), k=BIG_K))
                nxt += 1

        burst(40)
        router.pump()
        assert router.level >= 1             # over the high watermark
        # Keep the depth pinned above the watermark until the ladder has
        # walked all the way to shedding; jobs scheduled at level >= 2
        # ride the rung-pinned route.
        while router.level < 3:
            burst(8)
            router.pump()
            assert nxt < 400, "ladder never reached level 3"
        burst(8)                              # level 3: shed at submit
        results = router.drain()
        by_tag = {}
        for r in results:
            by_tag.setdefault(r.degraded, []).append(r)
        assert len(by_tag.get("load_shed", [])) >= 1
        for r in by_tag["load_shed"]:
            assert r.shed and r.items.size == 0
        capped = by_tag.get("k_cap", []) + by_tag.get("k_cap+rung_pin", [])
        assert capped, f"no k-capped results; tags: {list(by_tag)}"
        for r in capped:
            assert r.items.shape[0] <= 8     # BIG_K=16 capped to bucket 8
        assert any("rung_pin" in t for t in by_tag), list(by_tag)
        # Hysteresis-damped recovery back to full fidelity.
        assert _pump_until(router, lambda: router.level == 0)
        assert router.recover_events >= 1
        assert sorted(r.request_id for r in results) == list(range(nxt))


def test_rung_pinned_results_are_tagged_never_silent(params):
    """Level-2 serving uses the pinned cascade: results may differ from
    exact, but every one is tagged — the contract is about the route
    taken, not about whether the answer happened to match."""
    with _mk_router(params, n_replicas=2, hedge=False,
                    recover_patience=10_000) as router:
        assert all(e.has_pinned for e in router.engines)
        router.warmup()
        router.level = 2                      # hold the ladder at rung-pin
        rng = np.random.default_rng(6)
        for i in range(8):
            router.submit(Request(i, rng.integers(1, CFG.n_items + 1, 8),
                                  k=K))
        results = router.drain()
        assert sorted(r.request_id for r in results) == list(range(8))
        for r in results:
            assert r.degraded == "rung_pin"   # k=K is not capped -> no k_cap
            assert not r.shed and r.items.shape[0] == K
            assert np.isfinite(r.scores).all()


@pytest.mark.sharded
def test_chaos_sharded_serve_fn(params):
    """The fabric composes with the sharded serving route (shard-local
    cascade + merge): same exactly-once and health invariants.  Sharded
    engines have no rung-pinned route (pin_rung is flat-only), so
    degradation falls back to k-cap alone — still correctly tagged."""
    mesh = jax.make_mesh((1,), ("model",))
    plans = {1: ReplicaFaultPlan(crash_windows=((0, 2),))}
    with _mk_router(params, n_replicas=3, sharded_mesh=mesh,
                    fault_plans=plans, eject_after=1, cooldown_ms=10.0,
                    hedge=False) as router:
        assert not any(e.has_pinned for e in router.engines)
        router.warmup()
        rng = np.random.default_rng(7)
        n = 64
        for i in range(n):
            router.submit(Request(i, rng.integers(1, CFG.n_items + 1, 8),
                                  k=K))
            if i % 16 == 15:
                router.pump()
        results = router.drain()
        assert sorted(r.request_id for r in results) == list(range(n))
        assert all(not r.shed for r in results)
        assert router.stats()["replicas"][1]["failures"] >= 1


def test_router_single_replica_degenerates_to_engine(params):
    """K=1 keeps the API contract (no hedging possible, no failover) and
    matches the bare engine bit-for-bit."""
    eng = RetrievalEngine.for_seqrec(params, CFG, k=K, max_batch=8,
                                     method="pqtopk_pruned", ladder=LADDER,
                                     calibrate=False)
    rng = np.random.default_rng(8)
    seqs = [rng.integers(1, CFG.n_items + 1, 8) for _ in range(8)]
    for i, s in enumerate(seqs):
        eng.submit(Request(i, s, k=K))
    want = {r.request_id: r for r in eng.drain()}
    with _mk_router(params, n_replicas=1) as router:
        router.warmup()
        for i, s in enumerate(seqs):
            router.submit(Request(i, s, k=K))
        got = {r.request_id: r for r in router.drain()}
    assert set(got) == set(want)
    for i in want:
        np.testing.assert_array_equal(got[i].items, want[i].items)
        np.testing.assert_array_equal(got[i].scores, want[i].scores)


def test_all_replicas_ejected_forces_probe_liveness(params):
    """With every replica ejected, the router force-probes rather than
    deadlocking — requests still resolve once any crash window passes."""
    plans = {0: ReplicaFaultPlan(crash_windows=((0, 2),)),
             1: ReplicaFaultPlan(crash_windows=((0, 2),))}
    with _mk_router(params, n_replicas=2, fault_plans=plans,
                    eject_after=1, cooldown_ms=5_000.0,   # absurd cooldown
                    hedge=False) as router:
        router.warmup()
        rng = np.random.default_rng(9)
        for i in range(16):
            router.submit(Request(i, rng.integers(1, CFG.n_items + 1, 8),
                                  k=K))
        results = router.drain(timeout_s=60.0)
        assert sorted(r.request_id for r in results) == list(range(16))
        assert all(not r.shed for r in results)
