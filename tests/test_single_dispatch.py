"""Single-dispatch in-graph pruned cascade (PR 3): bit-exact parity of the
in-graph cascade vs the host two-pass cascade vs the exhaustive oracle
across the acceptance matrix (odd N, b in {64, 256}, int8/uint8/int32
codes, B in {1, 8, 200}), under jit, inside ``lm_decode_step``, and sharded
with pmax-shared theta — plus the bit-packed presence metadata (pack/unpack
round trip, 8x footprint, packed-vs-bool bound parity), the in-graph
cumsum-scatter compaction, adaptive theta seeding, the ``-1`` sentinel slot
contract of the fused kernel, and the engine's memoised compiled variants
(``stats()["n_compiles"]``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.configs.base import PQConfig
from repro.core import pruning, retrieval_head, scoring, topk as topk_lib
from repro.kernels.pqtopk import ops as pq_ops
from repro.serving.engine import Request, RetrievalEngine


def _oracle(codes, s, k):
    r = scoring.score_pqtopk(codes.astype(jnp.int32), s)
    return topk_lib.tiled_topk(r, k)


def _make_case(n, m, b, bq, *, code_dtype=jnp.int32, clustered=False,
               skewed=False, seed=0):
    rng = np.random.default_rng(seed)
    if clustered:
        centers = (np.arange(n) / n * b).astype(np.int64)
        codes_np = (centers[:, None] + rng.integers(-1, 2, (n, m))) % b
    else:
        codes_np = rng.integers(0, b, (n, m))
    codes = jnp.asarray(codes_np, code_dtype)
    g = rng.standard_normal((bq, m, b))
    if skewed:
        g = np.sign(g) * np.abs(g) ** 3
    s = jnp.asarray(g, jnp.float32)
    return codes, s


# ---------------------------------------------------------------------------
# bit-packed presence metadata
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [16, 32, 33, 64, 100, 256])
def test_pack_unpack_roundtrip(b):
    rng = np.random.default_rng(b)
    present = jnp.asarray(rng.random((7, 3, b)) < 0.3)
    packed = pruning.pack_presence(present)
    assert packed.dtype == jnp.uint32
    assert packed.shape == (7, 3, -(-b // 32))
    np.testing.assert_array_equal(
        np.asarray(pruning.unpack_presence(packed, b)), np.asarray(present))


@pytest.mark.hypothesis
def test_pack_unpack_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 80),
           st.integers(0, 2 ** 31 - 1))
    def roundtrip(t, m, b, seed):
        rng = np.random.default_rng(seed)
        present = jnp.asarray(rng.random((t, m, b)) < 0.5)
        out = pruning.unpack_presence(pruning.pack_presence(present), b)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(present))

    roundtrip()


def test_packed_bounds_match_bool_bounds_bitwise():
    codes, s = _make_case(3000, 4, 100, 5, seed=1)
    meta = pruning.build_tile_metadata(codes, 100, 256)
    packed = pruning.pack_presence(meta.present)
    b1 = pruning.tile_upper_bounds(meta.present, s)
    b2 = pruning.tile_upper_bounds_packed(packed, s)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


def test_state_footprint_is_eighth_of_pr2():
    codes, _ = _make_case(1 << 14, 8, 256, 1)
    state = pruning.build_pruned_state(codes, 256, 1024)
    assert state.nbytes * 8 == state.bool_nbytes
    assert state.packed.nbytes == state.nbytes


def test_state_is_a_pytree_in_head_params():
    """The metadata rides in the param tree: flattenable, abstract-able,
    and an integer (frozen) leaf to the optimizer."""
    params = retrieval_head.init(jax.random.PRNGKey(0), 500, 32,
                                 PQConfig(m=4, b=16))
    state = params["pruned"]
    assert isinstance(state, pruning.PrunedHeadState)
    leaves = jax.tree.leaves(params)
    assert any(leaf.dtype == jnp.uint32 for leaf in leaves)
    abs_params = retrieval_head.abstract(500, 32, PQConfig(m=4, b=16))
    assert (jax.tree.structure(abs_params) == jax.tree.structure(params))
    assert abs_params["pruned"].packed.shape == state.packed.shape


# ---------------------------------------------------------------------------
# in-graph compaction
# ---------------------------------------------------------------------------

def test_compact_mask_orders_and_pads():
    mask = jnp.asarray([False, True, False, True, True, False])
    slots, count = pruning.compact_mask(mask)
    np.testing.assert_array_equal(np.asarray(slots), [1, 3, 4, -1, -1, -1])
    assert int(count) == 3
    slots, count = pruning.compact_mask(mask, 2)       # over budget: dropped
    np.testing.assert_array_equal(np.asarray(slots), [1, 3])
    assert int(count) == 3                             # count stays honest


def test_compact_mask_empty_and_full():
    slots, count = pruning.compact_mask(jnp.zeros(4, bool))
    np.testing.assert_array_equal(np.asarray(slots), [-1, -1, -1, -1])
    assert int(count) == 0
    slots, count = pruning.compact_mask(jnp.ones(4, bool))
    np.testing.assert_array_equal(np.asarray(slots), [0, 1, 2, 3])
    assert int(count) == 4


# ---------------------------------------------------------------------------
# cascade parity: in-graph vs host vs oracle, the PR 2 acceptance matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bq", [1, 8, 200])
@pytest.mark.parametrize("n,b,dtype", [
    (999, 64, jnp.int8),       # odd N, int8 codes
    (1021, 256, jnp.uint8),    # prime N, uint8 codes (b=256 > int8 range)
    (2048, 64, jnp.int32),     # exact tiling, int32 fallback
    (3001, 256, jnp.int32),
])
def test_ingraph_matches_host_and_oracle(n, b, dtype, bq):
    m = 4
    codes, s = _make_case(n, m, b, bq, code_dtype=dtype, seed=n + bq)
    k = 10
    v_ref, i_ref = _oracle(codes, s, k)
    v_host, i_host = pruning.cascade_topk(codes, s, k, tile=256)
    state = pruning.build_pruned_state(codes, b, 256)
    v, i = pruning.cascade_topk_ingraph(codes, s, k, state)
    for vv, ii in ((v_host, i_host), (v, i)):
        np.testing.assert_array_equal(np.asarray(vv), np.asarray(v_ref))
        np.testing.assert_array_equal(np.asarray(ii), np.asarray(i_ref))


def test_ingraph_cascade_under_jit_with_threaded_state():
    """The serving shape: params built once, the whole route jitted."""
    params, phi = _pq_head(4097, bq=8)
    k = 9
    v_ref, i_ref = retrieval_head.top_items(params, phi, k, method="pqtopk")
    fn = jax.jit(lambda p, x: retrieval_head.top_items(
        p, x, k, method="pqtopk_pruned"))
    v, i = fn(params, phi)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_ingraph_cascade_actually_prunes_and_stays_exact():
    codes, s = _make_case(1 << 14, 8, 256, 2, clustered=True, skewed=True)
    k = 10
    v_ref, i_ref = _oracle(codes, s, k)
    state = pruning.build_pruned_state(codes, 256, 512)
    v, i, stats = pruning.cascade_topk_ingraph(codes, s, k, state,
                                               return_stats=True)
    assert float(stats["survival_fraction"]) < 1.0
    assert int(stats["n_survived"]) < int(stats["n_tiles"])
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


@pytest.mark.parametrize("budget", [1, 3, 64])
def test_slot_budget_overflow_cond_keeps_exactness(budget):
    """Uniform codes -> survival 1.0 -> every budget below T overflows; the
    in-graph lax.cond must fall back to the exhaustive buffer, bit-exact."""
    codes, s = _make_case(5000, 4, 64, 3, seed=11)
    k = 7
    v_ref, i_ref = _oracle(codes, s, k)
    state = pruning.build_pruned_state(codes, 64, 512)
    v, i, stats = pruning.cascade_topk_ingraph(
        codes, s, k, state, slot_budget=budget, return_stats=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    assert bool(stats["slot_overflow"]) == (int(stats["n_survived"]) > budget)


def test_slot_budget_prunes_when_skewed():
    """Favourable regime + budget: the compacted branch is taken (no
    overflow) and the result stays exact."""
    codes, s = _make_case(1 << 14, 8, 256, 1, clustered=True, skewed=True,
                          seed=3)
    k = 10
    v_ref, i_ref = _oracle(codes, s, k)
    state = pruning.build_pruned_state(codes, 256, 512)
    v, i, stats = pruning.cascade_topk_ingraph(
        codes, s, k, state, slot_budget=16, return_stats=True)
    assert not bool(stats["slot_overflow"])
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_ingraph_ties_broken_by_lowest_id():
    n, m, b = 700, 2, 8
    codes = jnp.zeros((n, m), jnp.int32)
    s = jax.random.normal(jax.random.PRNGKey(0), (2, m, b), jnp.float32)
    v_ref, i_ref = _oracle(codes, s, 5)
    state = pruning.build_pruned_state(codes, b, 128)
    v, i = pruning.cascade_topk_ingraph(codes, s, 5, state)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    assert (np.asarray(i) == np.arange(5)[None, :]).all()


# ---------------------------------------------------------------------------
# adaptive theta seeding
# ---------------------------------------------------------------------------

def test_seed_schedule():
    assert pruning.seed_schedule("greedy", 2, 16, 10, 512, 100) == (2,)
    assert pruning.seed_schedule("adaptive", 2, 16, 10, 512, 100) == \
        (2, 4, 8, 16)
    # floor: enough seed tiles to hold k
    assert pruning.seed_schedule("greedy", 1, 16, 1000, 256, 100)[0] == 4
    # clamped to the tile count
    assert pruning.seed_schedule("adaptive", 2, 16, 10, 512, 3) == (2, 3)


def test_adaptive_policy_exact_and_reports_seed_size():
    codes, s = _make_case(1 << 13, 4, 64, 2, clustered=True, skewed=True,
                          seed=7)
    k = 10
    v_ref, i_ref = _oracle(codes, s, k)
    state = pruning.build_pruned_state(codes, 64, 256)
    v, i, stats = pruning.cascade_topk_ingraph(
        codes, s, k, state, seed_policy="adaptive", seed_tiles=2,
        seed_max_tiles=16, return_stats=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))
    assert 2 <= int(stats["n_seed_used"]) <= 16
    assert 0.0 <= float(stats["seed_survival_est"]) <= 1.0


def test_adaptive_theta_at_least_as_tight_as_greedy():
    """More seeds can only raise (tighten) theta — never loosen it."""
    codes, s = _make_case(1 << 13, 4, 64, 3, clustered=True, skewed=True,
                          seed=9)
    state = pruning.build_pruned_state(codes, 64, 256)
    bounds = pruning.tile_upper_bounds_packed(state.packed, s)
    tg, _, _ = pruning.theta_seed_ingraph(
        codes, s, bounds, 10, tile=256, seed_policy="greedy", seed_tiles=2)
    ta, used, _ = pruning.theta_seed_ingraph(
        codes, s, bounds, 10, tile=256, seed_policy="adaptive", seed_tiles=2,
        seed_max_tiles=16, seed_stab_tol=1e-9)   # tol ~0 -> grows to max
    assert (np.asarray(ta) >= np.asarray(tg)).all()
    assert int(used) == 16


def test_pqconfig_seed_policy_validation():
    PQConfig(seed_policy="adaptive", seed_tiles=4, seed_max_tiles=32)
    with pytest.raises(ValueError, match="seed_policy"):
        PQConfig(seed_policy="eager")
    with pytest.raises(ValueError, match="seed_tiles"):
        PQConfig(seed_tiles=8, seed_max_tiles=4)
    with pytest.raises(ValueError, match="seed_stab_tol"):
        PQConfig(seed_stab_tol=0.0)


# ---------------------------------------------------------------------------
# -1 sentinel slots through the compacted scoring entry
# ---------------------------------------------------------------------------

def test_pq_topk_tiles_negative_sentinels():
    """A -1-padded compacted list must match the oracle on both the Pallas
    kernel path (@pl.when early-exit) and the XLA path (sentinel remap)."""
    n, m, b, tile, k = 1000, 4, 16, 256, 5
    codes, s = _make_case(n, m, b, 2, seed=9)
    v_ref, i_ref = _oracle(codes, s, k)
    t = pq_ops.n_tiles(n, tile)
    idx = np.full(8, -1, np.int32)
    idx[:t] = np.arange(t)
    for uk in (False, True):
        v, i = pq_ops.pq_topk_tiles(codes, s, k, jnp.asarray(idx), tile=tile,
                                    use_kernel=uk, interpret=True)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
        np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


def test_kernel_path_ingraph_cascade_end_to_end():
    codes, s = _make_case(3000, 4, 64, 3, code_dtype=jnp.int8,
                          clustered=True, skewed=True, seed=5)
    k = 7
    v_ref, i_ref = _oracle(codes, s, k)
    state = pruning.build_pruned_state(codes, 64, 512)
    v, i = pruning.cascade_topk_ingraph(codes, s, k, state, use_kernel=True,
                                        interpret=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


# ---------------------------------------------------------------------------
# decode loop + sharded
# ---------------------------------------------------------------------------

def _pq_head(n, d=32, m=4, b=16, bq=3, seed=0, code_dtype="int32"):
    params = retrieval_head.init(jax.random.PRNGKey(seed), n, d,
                                 PQConfig(m=m, b=b, code_dtype=code_dtype))
    phi = jax.random.normal(jax.random.PRNGKey(seed + 1), (bq, d))
    return params, phi


@pytest.mark.slow
def test_pruned_head_inside_lm_decode_step():
    """The cascade runs inside a jitted decode step off the cached
    metadata in params["pq_head"]["pruned"] — same winners as pqtopk."""
    from repro.configs.base import get_reduced as _gr
    from repro.models import transformer as T
    arch = _gr("qwen2.5-14b")
    cfg = arch.model
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    assert isinstance(params["pq_head"]["pruned"], pruning.PrunedHeadState)
    caches = T.init_caches(cfg, 2, 16)
    tok = jnp.asarray([3, 5], jnp.int32)
    pos = jnp.int32(0)
    outs = {}
    for meth in ("pqtopk", "pqtopk_pruned"):
        step = jax.jit(lambda p, t_, c, m_=meth: T.lm_decode_step(
            p, t_, pos, c, cfg, k=8, head_method=m_))
        ids, vals, _ = step(params, tok, caches)
        outs[meth] = (np.asarray(ids), np.asarray(vals))
    np.testing.assert_array_equal(outs["pqtopk_pruned"][0],
                                  outs["pqtopk"][0])
    np.testing.assert_array_equal(outs["pqtopk_pruned"][1],
                                  outs["pqtopk"][1])


@pytest.mark.sharded
@pytest.mark.parametrize("n", [128, 1013])   # odd N -> padding tail
def test_sharded_single_shardmap_matches_plain(n):
    mesh = jax.make_mesh((1,), ("model",))
    params, phi = _pq_head(n, d=16, m=4, b=8, bq=2, code_dtype="uint8")
    v1, i1 = retrieval_head.top_items(params, phi, 7, method="pqtopk")
    v2, i2 = retrieval_head.top_items_sharded(params, phi, 7, mesh,
                                              method="pqtopk_pruned")
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert (np.asarray(i2) < n).all()


@pytest.mark.sharded
def test_sharded_pruned_is_jittable_with_aligned_state():
    """The whole sharded cascade (pmax theta inside ONE shard_map) traces
    into a single jitted computation — the PR 2 host compaction could not."""
    mesh = jax.make_mesh((1,), ("model",))
    params, phi = _pq_head(1013, d=16, m=4, b=8, bq=2)
    params = retrieval_head.ensure_sharded_pruned_state(params, mesh,
                                                        k_hint=7)
    assert params["pruned"].shards == 1
    fn = jax.jit(lambda p, x: retrieval_head.top_items_pruned_sharded(
        p, x, 7, mesh))
    v2, i2 = fn(params, phi)
    v1, i1 = retrieval_head.top_items(params, phi, 7, method="pqtopk")
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.sharded
def test_ensure_sharded_state_is_idempotent():
    mesh = jax.make_mesh((1,), ("model",))
    params, _ = _pq_head(1000)
    p1 = retrieval_head.ensure_sharded_pruned_state(params, mesh, k_hint=7)
    p2 = retrieval_head.ensure_sharded_pruned_state(p1, mesh, k_hint=7)
    assert p2["pruned"] is p1["pruned"]


def test_flat_route_rejects_or_rebuilds_sharded_state():
    """A shard-aligned state tiles per shard; the flat cascade must never
    misread it (silent inexactness) — cascade_topk_ingraph rejects it, and
    top_items falls back to an in-graph shards=1 rebuild, staying exact."""
    codes, s = _make_case(1000, 4, 16, 2, seed=13)
    sharded = pruning.build_pruned_state(codes, 16, 300, shards=2)
    assert sharded.shards == 2
    with pytest.raises(ValueError, match="shards=1"):
        pruning.cascade_topk_ingraph(codes, s, 5, sharded)
    params, phi = _pq_head(1000, m=4, b=16)
    params["pruned"] = pruning.build_pruned_state(
        params["codes"], 16, 300, shards=2)
    v_ref, i_ref = retrieval_head.top_items(params, phi, 5, method="pqtopk")
    v, i = retrieval_head.top_items(params, phi, 5, method="pqtopk_pruned")
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(i_ref))


@pytest.mark.sharded
def test_sharded_explicit_seed_tiles_beats_pq_cfg():
    """The explicit seed_tiles argument must win over PQConfig knobs."""
    mesh = jax.make_mesh((1,), ("model",))
    params, phi = _pq_head(1 << 14, m=4, b=8)    # 8 tiles at tile=2048
    cfg = PQConfig(m=4, b=8, seed_tiles=1, seed_max_tiles=1)
    _, _, stats = retrieval_head.top_items_pruned_sharded(
        params, phi, 5, mesh, seed_tiles=3, pq_cfg=cfg, return_stats=True)
    assert int(stats["n_seed_used"]) == 3


# ---------------------------------------------------------------------------
# engine: memoised compiled variants, observable recompiles
# ---------------------------------------------------------------------------

def _engine(method, k=5):
    from repro.models import seqrec as S
    cfg = get_reduced("sasrec-recjpq").model
    params = S.init_seqrec(jax.random.PRNGKey(0), cfg)
    return RetrievalEngine.for_seqrec(params, cfg, k=k, max_batch=8,
                                      method=method), cfg


def test_engine_memoises_compiled_variants():
    rng = np.random.default_rng(0)
    eng, _ = _engine("pqtopk_pruned", k=2)
    assert eng.stats()["n_compiles"] == 0
    for i in range(3):                       # same (bucket=1, k=2) variant
        eng.submit(Request(i, rng.integers(1, 1000, 6), k=2))
        eng.run_once()
    assert eng.stats()["n_compiles"] == 1
    eng.submit(Request(10, rng.integers(1, 1000, 6), k=7))  # new k bucket
    eng.run_once()
    assert eng.stats()["n_compiles"] == 2
    for i in range(4):                       # new batch bucket (4), k=2
        eng.submit(Request(20 + i, rng.integers(1, 1000, 6), k=2))
    eng.run_once()
    assert eng.stats()["n_compiles"] == 3
    for i in range(4):                       # repeat: fully memoised
        eng.submit(Request(30 + i, rng.integers(1, 1000, 6), k=2))
    eng.run_once()
    assert eng.stats()["n_compiles"] == 3


def test_engine_pruned_single_dispatch_matches_pqtopk():
    rng = np.random.default_rng(1)
    seqs = [rng.integers(1, 1000, 8) for _ in range(4)]
    results = {}
    for method in ("pqtopk", "pqtopk_pruned"):
        eng, _ = _engine(method)
        for i, sq in enumerate(seqs):
            eng.submit(Request(i, sq, k=5))
        results[method] = {r.request_id: r for r in eng.drain()}
    for i in range(4):
        np.testing.assert_array_equal(results["pqtopk_pruned"][i].items,
                                      results["pqtopk"][i].items)
        np.testing.assert_array_equal(results["pqtopk_pruned"][i].scores,
                                      results["pqtopk"][i].scores)
