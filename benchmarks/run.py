"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table3/*    — scoring + total mRT per (dataset, backbone, method) [Table 3]
  figure2/*   — scoring latency vs catalogue size, m in {8, 64}   [Fig. 2]
  kernel/*    — PQ scoring algorithm micro-bench (XLA paths)
  roofline/*  — dry-run roofline terms, if artifacts exist        [§Roofline]

Full-scale sweeps (10^7+ items) are behind ``--full`` (CI keeps <= 10^6).
"""
from __future__ import annotations

import argparse
import sys


def _emit(name: str, us: float | None, derived: str = ""):
    us_s = f"{us:.1f}" if us is not None else "nan"
    print(f"{name},{us_s},{derived}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", action="append", default=[],
                    choices=["table3", "figure2", "kernel", "roofline"])
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")

    if "table3" not in args.skip:
        from benchmarks import table3
        datasets = ("booking", "gowalla") if args.full else ("booking",)
        # CI default keeps the 1.27M-item Gowalla build out (slow dense
        # reconstruction on host); --full reproduces the whole table.
        rows = table3.run(repeats=args.repeats, datasets=datasets)
        for r in rows:
            _emit(f"table3/{r['dataset']}/{r['backbone']}/{r['method']}/scoring",
                  r["scoring_ms"] * 1e3,
                  f"total_ms={r['total_ms']:.2f};backbone_ms={r['backbone_ms']:.2f}")

    if "figure2" not in args.skip:
        from benchmarks import figure2
        rows = figure2.run(full=args.full, repeats=args.repeats)
        for r in rows:
            us = None if r["scoring_ms"] is None else r["scoring_ms"] * 1e3
            guard = ("interp-guard" if r["method"] == "pqtopk_fused"
                     else "mem-wall")
            _emit(f"figure2/m{r['m']}/n{r['n_items']}/{r['method']}", us,
                  guard if us is None else "")

    if "kernel" not in args.skip:
        import jax
        import jax.numpy as jnp
        import numpy as np
        from benchmarks.timing import time_fn
        from repro.core import scoring
        rng = np.random.default_rng(0)
        n, m, b = 262_144, 8, 256
        codes = jnp.asarray(rng.integers(0, b, (n, m)), jnp.int32)
        s = jax.random.normal(jax.random.PRNGKey(0), (1, m, b))
        for name, alg in [("pqtopk", scoring.score_pqtopk),
                          ("recjpq", scoring.score_recjpq),
                          ("onehot", scoring.score_pqtopk_onehot)]:
            fn = jax.jit(alg)
            t = time_fn(lambda: fn(codes, s), repeats=args.repeats)
            _emit(f"kernel/pq_scoring_262k/{name}", t["median_s"] * 1e6,
                  f"items_per_s={n / t['median_s']:.3e}")
        # Retrieval (scoring + top-k) comparison: XLA two-stage vs the fused
        # Pallas kernel, whose HBM output is O(B*K*N/TN) not O(B*N).
        from repro import compat
        from repro.core import topk as topk_lib
        from repro.kernels.pqtopk import ops as pq_ops
        k = 10
        fn = jax.jit(lambda c_, s_: topk_lib.tiled_topk(
            scoring.score_pqtopk(c_, s_), k))
        t = time_fn(lambda: fn(codes, s), repeats=args.repeats)
        _emit(f"kernel/pq_retrieval_262k/pqtopk", t["median_s"] * 1e6,
              f"items_per_s={n / t['median_s']:.3e}")
        t = time_fn(lambda: pq_ops.pq_topk(codes, s, k), repeats=args.repeats)
        # Off TPU the fused kernel runs in interpret mode — the number times
        # the emulator, not the kernel; tag it so it can't be read as perf.
        tag = "" if compat.on_tpu() else ";interpret-mode"
        _emit(f"kernel/pq_retrieval_262k/pqtopk_fused", t["median_s"] * 1e6,
              f"items_per_s={n / t['median_s']:.3e}{tag}")

    if "roofline" not in args.skip:
        import os
        from benchmarks import roofline
        art = "benchmarks/artifacts/dryrun"
        if os.path.isdir(art):
            for r in roofline.table(art):
                if "error" in r:
                    _emit(f"roofline/{r['arch']}/{r['shape']}", None,
                          f"error={r['error'][:50]}")
                    continue
                rf = r.get("roofline_frac")
                _emit(f"roofline/{r['arch']}/{r['shape']}",
                      r["bound_s"] * 1e6,
                      f"dominant={r['dominant']};"
                      f"roofline_frac={rf:.3f}" if rf else
                      f"dominant={r['dominant']}")


if __name__ == "__main__":
    main()
