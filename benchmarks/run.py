"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table3/*    — scoring + total mRT per (dataset, backbone, method) [Table 3]
  figure2/*   — scoring latency vs catalogue size, m in {8, 64}   [Fig. 2]
  kernel/*    — PQ scoring algorithm micro-bench (XLA paths) + the
                pruned-vs-exhaustive retrieval sweep on skewed data
  serving/*   — latency under load through the replicated fabric
                (ReplicaRouter, K in {1, 2, 4}, with/without a chaos
                plan): per-request p50/p99 + req/s end to end
  recovery/*  — durable catalogue log (WAL): append latency vs the
                fsync batching knob, recover() wall time vs replay-tail
                length, and the snapshot-cadence trade-off
  roofline/*  — dry-run roofline terms, if artifacts exist        [§Roofline]

and also writes a machine-readable ``BENCH_pr10.json`` (``--json PATH``) so
the perf trajectory is tracked across PRs: every row carries its section,
method tag, median us/call, items/s where defined, and extra tags (survival
fraction + seed size + bound backend + ladder / rung-hit fraction for the
pruned route, interpret-mode markers, ...).  Timed rows additionally carry
``q25_us``/``q75_us``/``iqr_us``/``n_reps`` so trend tooling can require
IQR separation before calling a regression (noise-robust comparisons).
The ``churn`` section measures the mutable catalogue: interleaved
update+query streaming at N=2^20 through the incrementally maintained
``MutableHeadState`` (stale-but-dominating bounds, tombstone mask), with
per-sample exactness checks against the exhaustive masked oracle.  The document also carries an
environment ``fingerprint`` (python/jax/jaxlib versions, backend, thread
pinning) so ``scripts/bench_compare.py`` can refuse joins of numbers
measured on different software stacks (``--allow-mixed`` overrides).
Rows measured through the Pallas interpreter (``"interpret": true``) time
the emulator, not the kernel — their ``items_per_s`` is null so they can
never enter throughput trend comparisons (see README §Benchmarks).

Full-scale sweeps (10^7+ items) are behind ``--full`` (CI keeps <= 10^6).
"""
from __future__ import annotations

import argparse
import json
import sys


def environment_fingerprint() -> dict:
    """What was measured *on*: the software stack and thread pinning that
    make two benchmark numbers comparable.  Persisted into every BENCH
    json; ``scripts/bench_compare.py`` joins across PRs only when the
    fingerprints agree (identical dicts) or are absent (legacy files)."""
    import os
    import platform

    import jax as _jax
    import jaxlib as _jaxlib

    threads = {var: os.environ[var]
               for var in ("OMP_NUM_THREADS", "MKL_NUM_THREADS",
                           "OPENBLAS_NUM_THREADS", "XLA_FLAGS")
               if os.environ.get(var)}
    return {
        "python": platform.python_version(),
        "jax": _jax.__version__,
        "jaxlib": _jaxlib.__version__,
        "backend": _jax.default_backend(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
        # The cores this process may actually run on (taskset pinning in
        # ci.sh shows up here): a 1-core and an 8-core affinity mask are
        # different machines as far as latency numbers are concerned.
        "cpu_affinity": (sorted(os.sched_getaffinity(0))
                         if hasattr(os, "sched_getaffinity") else None),
        # Unpinned thread counts are themselves provenance: two runs with
        # different pinning must not be joined silently.
        "threads": threads or "unpinned",
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", action="append", default=[],
                    choices=["table3", "figure2", "kernel", "churn",
                             "serving", "recovery", "roofline", "hier"])
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--json", default="BENCH_pr10.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)

    rows = []

    def _emit(section: str, name: str, us: float | None, derived: str = "",
              *, method: str = "", items_per_s: float | None = None,
              tags: dict | None = None, timing: dict | None = None):
        us_s = f"{us:.1f}" if us is not None else "nan"
        print(f"{name},{us_s},{derived}")
        row = {"section": section, "name": name, "method": method,
               "median_us": us, "items_per_s": items_per_s,
               "tags": tags or {}}
        if timing is not None:
            # Variance alongside the median: trend tooling treats two
            # rows as distinguishable only when their IQR intervals
            # separate (scripts/bench_compare.py).
            row["q25_us"] = timing["q25_s"] * 1e6
            row["q75_us"] = timing["q75_s"] * 1e6
            row["iqr_us"] = timing["iqr_s"] * 1e6
            row["n_reps"] = timing["n_reps"]
        rows.append(row)

    print("name,us_per_call,derived")

    if "table3" not in args.skip:
        from benchmarks import table3
        datasets = ("booking", "gowalla") if args.full else ("booking",)
        # CI default keeps the 1.27M-item Gowalla build out (slow dense
        # reconstruction on host); --full reproduces the whole table.
        t3 = table3.run(repeats=args.repeats, datasets=datasets)
        for r in t3:
            _emit("table3",
                  f"table3/{r['dataset']}/{r['backbone']}/{r['method']}/scoring",
                  r["scoring_ms"] * 1e3,
                  f"total_ms={r['total_ms']:.2f};backbone_ms={r['backbone_ms']:.2f}",
                  method=r["method"],
                  tags={"total_ms": r["total_ms"],
                        "backbone_ms": r["backbone_ms"]},
                  timing=r.get("timing"))

    if "figure2" not in args.skip:
        from benchmarks import figure2
        f2 = figure2.run(full=args.full, repeats=args.repeats)
        for r in f2:
            us = None if r["scoring_ms"] is None else r["scoring_ms"] * 1e3
            tags = {"n_items": r["n_items"], "m": r["m"]}
            derived = ""
            if us is None:
                derived = ("interp-guard" if r["method"] == "pqtopk_fused"
                           else "mem-wall")
                tags["guard"] = derived
            if "survival_fraction" in r:
                tags["survival_fraction"] = r["survival_fraction"]
                derived = f"survival={r['survival_fraction']:.3f}"
            if "n_seed_used" in r:
                tags["n_seed_used"] = r["n_seed_used"]
            # Pruned rows are self-describing: backend + ladder + rung-hit
            # fraction travel with every row (None = no ladder in play).
            for tag in ("bound_backend", "ladder", "rung_hit_fraction"):
                if tag in r:
                    tags[tag] = r[tag]
            # Interpret-mode rows time the Pallas emulator, not the kernel
            # (the PR 2 figure2/m8/n10000/pqtopk_fused "anomaly" — 108 ms vs
            # 0.57 ms plain pqtopk, a 200x artefact of interpretation):
            # tag them and null items/s so trend tooling can never compare
            # them against compiled rows.
            interp = bool(r.get("interpret", False))
            if interp:
                tags["interpret"] = True
                derived = (derived + ";" if derived else "") + "interpret-mode"
            _emit("figure2", f"figure2/m{r['m']}/n{r['n_items']}/{r['method']}",
                  us, derived, method=r["method"],
                  items_per_s=(None if us is None or interp
                               else r["n_items"] / us * 1e6),
                  tags=tags, timing=r.get("timing"))

    if "kernel" not in args.skip:
        import jax
        import jax.numpy as jnp
        import numpy as np
        from benchmarks.timing import time_fn
        from repro.core import pruning, scoring, topk as topk_lib
        rng = np.random.default_rng(0)
        n, m, b = 262_144, 8, 256
        codes = jnp.asarray(rng.integers(0, b, (n, m)), jnp.int32)
        s = jax.random.normal(jax.random.PRNGKey(0), (1, m, b))
        for name, alg in [("pqtopk", scoring.score_pqtopk),
                          ("recjpq", scoring.score_recjpq),
                          ("onehot", scoring.score_pqtopk_onehot)]:
            fn = jax.jit(alg)
            t = time_fn(lambda: fn(codes, s), repeats=args.repeats)
            _emit("kernel", f"kernel/pq_scoring_262k/{name}",
                  t["median_s"] * 1e6, f"items_per_s={n / t['median_s']:.3e}",
                  method=name, items_per_s=n / t["median_s"],
                  tags={"n_items": n}, timing=t)
        # Retrieval (scoring + top-k) comparison: XLA two-stage vs the fused
        # Pallas kernel, whose HBM output is O(B*K*N/TN) not O(B*N).
        from repro import compat
        from repro.kernels.pqtopk import ops as pq_ops
        k = 10
        fn = jax.jit(lambda c_, s_: topk_lib.tiled_topk(
            scoring.score_pqtopk(c_, s_), k))
        t = time_fn(lambda: fn(codes, s), repeats=args.repeats)
        _emit("kernel", "kernel/pq_retrieval_262k/pqtopk",
              t["median_s"] * 1e6, f"items_per_s={n / t['median_s']:.3e}",
              method="pqtopk", items_per_s=n / t["median_s"],
              tags={"n_items": n}, timing=t)
        t = time_fn(lambda: pq_ops.pq_topk(codes, s, k),
                    repeats=args.repeats)
        # Off TPU the fused kernel runs in interpret mode — the number times
        # the emulator, not the kernel; tag it and null items/s so it can't
        # enter throughput comparisons (README §Benchmarks).
        interp = not compat.on_tpu()
        tag = ";interpret-mode" if interp else ""
        _emit("kernel", "kernel/pq_retrieval_262k/pqtopk_fused",
              t["median_s"] * 1e6, f"items_per_s={n / t['median_s']:.3e}{tag}",
              method="pqtopk_fused",
              items_per_s=None if interp else n / t["median_s"],
              tags={"n_items": n, "interpret": interp}, timing=t)
        # Pruned-vs-exhaustive retrieval on skewed-score synthetic data
        # (N = 2^20): codes clustered by catalogue position (as after a
        # popularity-ordered RecJPQ assignment) + heavy-tailed sub-id
        # scores, the regime arXiv:2505.00560 targets.  Three exact
        # competitors: the exhaustive XLA route, the exhaustive fused route
        # (Pallas on TPU / its XLA lowering off TPU — compiled either way,
        # never the interpreter), and the single-dispatch in-graph cascade.
        # The PR 2 host two-pass cascade is kept as a fourth row so the
        # dispatch-fusion win is visible in the same file.
        n_sk, tile_sk = 1 << 20, 1024
        centers = (np.arange(n_sk) / n_sk * b).astype(np.int64)
        codes_sk = jnp.asarray(
            (centers[:, None] + rng.integers(-1, 2, (n_sk, m))) % b,
            jnp.int32)
        g = rng.standard_normal((1, m, b))
        s_sk = jnp.asarray(np.sign(g) * np.abs(g) ** 3, jnp.float32)
        fn_ex = jax.jit(lambda c_, s_: topk_lib.tiled_topk(
            scoring.score_pqtopk(c_, s_), k))
        t = time_fn(lambda: fn_ex(codes_sk, s_sk), repeats=args.repeats)
        _emit("kernel", "kernel/pq_retrieval_1m_skewed/pqtopk",
              t["median_s"] * 1e6, f"items_per_s={n_sk / t['median_s']:.3e}",
              method="pqtopk", items_per_s=n_sk / t["median_s"],
              tags={"n_items": n_sk, "skewed": True}, timing=t)
        # Exhaustive fused: identity tile list through pq_topk_tiles — the
        # same compacted-scoring entry the cascade uses, with zero pruning.
        ident = jnp.arange(pq_ops.n_tiles(n_sk, tile_sk), dtype=jnp.int32)
        fn_fx = jax.jit(lambda c_, s_: pq_ops.pq_topk_tiles(
            c_, s_, k, ident, tile=tile_sk))
        t = time_fn(lambda: fn_fx(codes_sk, s_sk), repeats=args.repeats)
        _emit("kernel", "kernel/pq_retrieval_1m_skewed/pqtopk_fused",
              t["median_s"] * 1e6, f"items_per_s={n_sk / t['median_s']:.3e}",
              method="pqtopk_fused", items_per_s=n_sk / t["median_s"],
              tags={"n_items": n_sk, "skewed": True, "tile": tile_sk,
                    "lowering": "pallas" if compat.on_tpu() else "xla"},
              timing=t)
        # Bound-backend comparison sweep: the single-dispatch in-graph
        # cascade (adaptive theta seeding, CALIBRATED slot-budget ladder)
        # for both metadata backends at N=2^20 skewed, on two code
        # layouts: "wrap" (the legacy `% b` synthetic — its handful of
        # full-span wrap tiles are the range backend's worst case: the
        # convex hull of {0, .., b-1} is everything, bounds go loose and
        # theta seeding wastes its budget there) and "clip" (clipped
        # clustered codes — RecJPQ's popularity-ordered assignment never
        # wraps, the regime the range backend targets).  Per (layout,
        # backend): metadata bytes, bound tightness (survival fraction),
        # items/s through the ladder, and the rung-hit fraction over a
        # stream of fresh query batches with a per-batch exactness check
        # against the exhaustive oracle (zero correctness loss, counted).
        def fresh_s(i):
            gg = np.random.default_rng(1000 + i).standard_normal((1, m, b))
            return jnp.asarray(np.sign(gg) * np.abs(gg) ** 3, jnp.float32)

        codes_clip = jnp.asarray(
            np.clip(centers[:, None] + rng.integers(-1, 2, (n_sk, m)),
                    0, b - 1), jnp.int32)
        n_cal, n_stream = 5, 12
        for layout, codes_l in (("wrap", codes_sk), ("clip", codes_clip)):
            fn_ex_l = jax.jit(lambda c_, s_: topk_lib.tiled_topk(
                scoring.score_pqtopk(c_, s_), k))
            backend_rows = {}
            suffix = "" if layout == "wrap" else "_clip"
            for backend in pruning.BOUND_BACKENDS:
                state = pruning.build_pruned_state(codes_l, b, tile_sk,
                                                   backend=backend)
                count_fn = jax.jit(
                    lambda s_, c_=codes_l, st_=state: pruning.survival_count(
                        c_, s_, k, st_, seed_policy="adaptive"))
                counts = [int(count_fn(fresh_s(i))) for i in range(n_cal)]
                ladder = pruning.calibrate_ladder(counts, state.n_tiles, k,
                                                  state.tile)

                # One jitted variant returning the rung alongside the
                # winners (same trick as the serve path) — the stream
                # below scores each batch exactly once.
                def _pr(c_, s_, st_=state, ld_=ladder):
                    v_, i_, stats_ = pruning.cascade_topk_ingraph(
                        c_, s_, k, st_, seed_policy="adaptive",
                        ladder=ld_, return_stats=True)
                    return v_, i_, stats_["rung_hit"]

                fn_pr = jax.jit(_pr)
                n_rungs = len(ladder)       # calibrate_ladder output is
                hits = mismatches = 0       # already normalized
                for i in range(n_stream):
                    s_i = fresh_s(n_cal + i)
                    v_pr, i_pr, rung_i = fn_pr(codes_l, s_i)
                    hits += int(int(rung_i) < n_rungs - 1)
                    v_ex, i_ex = fn_ex_l(codes_l, s_i)
                    mismatches += int(
                        not (np.array_equal(np.asarray(v_pr),
                                            np.asarray(v_ex))
                             and np.array_equal(np.asarray(i_pr),
                                                np.asarray(i_ex))))
                _, _, stats = pruning.cascade_topk_ingraph(
                    codes_l, s_sk, k, state, seed_policy="adaptive",
                    ladder=ladder, return_stats=True)
                stats = {kk: vv.item() if hasattr(vv, "item") else vv
                         for kk, vv in stats.items()}
                t = time_fn(lambda: fn_pr(codes_l, s_sk),
                            repeats=args.repeats)
                backend_rows[backend] = (stats, state)
                _emit("kernel",
                      f"kernel/pq_retrieval_1m_skewed/"
                      f"pqtopk_pruned_{backend}{suffix}",
                      t["median_s"] * 1e6,
                      f"items_per_s={n_sk / t['median_s']:.3e};"
                      f"survival={stats['survival_fraction']:.4f};"
                      f"meta_bytes={state.nbytes};ladder={ladder};"
                      f"rung_hit={hits}/{n_stream};"
                      f"mismatches={mismatches}",
                      method="pqtopk_pruned",
                      items_per_s=n_sk / t["median_s"],
                      tags={"n_items": n_sk, "skewed": True,
                            "tile": tile_sk, "code_layout": layout,
                            "bound_backend": backend,
                            "survival_fraction":
                                stats["survival_fraction"],
                            "n_survived": stats["n_survived"],
                            "n_tiles": stats["n_tiles"],
                            "n_seed_used": stats["n_seed_used"],
                            "seed_policy": "adaptive",
                            "ladder": list(ladder),
                            "rung_hit_fraction": hits / n_stream,
                            "exactness_mismatches": mismatches,
                            "stream_batches": n_stream,
                            "dispatches_per_query": 1,
                            "meta_bytes": state.nbytes,
                            "meta_bytes_bool_pr2": state.bool_nbytes},
                      timing=t)
            # Headline deltas per layout: metadata footprint ratio and
            # bound-tightness loss (range survival - bitmask survival).
            st_bm, meta_bm = backend_rows["bitmask"]
            st_rg, meta_rg = backend_rows["range"]
            _emit("kernel",
                  f"kernel/pq_retrieval_1m_skewed/backend_delta{suffix}",
                  None,
                  f"meta_ratio={meta_rg.nbytes / meta_bm.nbytes:.3f};"
                  f"survival_delta="
                  f"{st_rg['survival_fraction'] - st_bm['survival_fraction']:.4f}",
                  method="backend_delta",
                  tags={"n_items": n_sk, "skewed": True,
                        "code_layout": layout,
                        "meta_bytes_bitmask": meta_bm.nbytes,
                        "meta_bytes_range": meta_rg.nbytes,
                        "meta_ratio_range_over_bitmask":
                            meta_rg.nbytes / meta_bm.nbytes,
                        "survival_fraction_bitmask":
                            st_bm["survival_fraction"],
                        "survival_fraction_range":
                            st_rg["survival_fraction"],
                        "survival_fraction_delta":
                            st_rg["survival_fraction"]
                            - st_bm["survival_fraction"]})
        t = time_fn(lambda: pruning.cascade_topk(codes_sk, s_sk, k,
                                                 tile=tile_sk),
                    repeats=args.repeats)
        _emit("kernel", "kernel/pq_retrieval_1m_skewed/pqtopk_pruned_host",
              t["median_s"] * 1e6,
              f"items_per_s={n_sk / t['median_s']:.3e};host-two-pass",
              method="pqtopk_pruned_host", items_per_s=n_sk / t["median_s"],
              tags={"n_items": n_sk, "skewed": True, "tile": tile_sk,
                    "bound_backend": "bitmask", "ladder": None,
                    "rung_hit_fraction": None,
                    "dispatches_per_query": 2}, timing=t)
        # ---------------------------------------------------------------
        # Mixed-batch per-query sweep (PR 5 headline): N=2^20 clipped
        # clustered codes, B in {8, 64, 256} queries whose score skew
        # concentrates on DIFFERENT code windows — so each query's
        # survivor set is a different catalogue region and the batch-any
        # union degrades toward exhaustive as B grows (the regime the
        # per-query grouped route exists for).  Per B, two single-
        # dispatch pruned routes are measured with their own calibrated
        # ladders: the batch-any union route and the query-grouped route
        # (G=8, per-query thetas, 2D (group, slot) table).  Reported:
        # items/s, scored slot·query pairs (grouped sum_g B_g*S_g vs the
        # union B*|union| — the headline ratio), per-group vs union
        # survival, and a per-batch exactness check against the chunked
        # exhaustive oracle for EVERY pruned row (zero tolerance).
        state_mx = pruning.build_pruned_state(codes_clip, b, tile_sk)
        n_groups_mx = 8

        def mixed_s(bq, i):
            rr = np.random.default_rng(5000 + 131 * bq + i)
            gg = rr.standard_normal((bq, m, b))
            gg = np.sign(gg) * np.abs(gg) ** 3
            for q in range(bq):
                w = (q * b) // bq
                gg[q, :, max(0, w - 1):w + 3] += 6.0
            return jnp.asarray(gg, jnp.float32)

        def oracle_chunked(s_b, chunk=16):
            outs = []
            for lo in range(0, s_b.shape[0], chunk):
                outs.append(fn_ex(codes_clip, s_b[lo:lo + chunk]))
            return (jnp.concatenate([o[0] for o in outs]),
                    jnp.concatenate([o[1] for o in outs]))

        fn_ex = jax.jit(lambda c_, s_: topk_lib.tiled_topk(
            scoring.score_pqtopk(c_, s_), k))
        n_cal_mx, n_stream_mx = 2, 2
        for bq_mx in (8, 64, 256):
            route_rows = {}
            # The stream batches (and hence their exhaustive oracles) are
            # identical for both routes — compute each oracle once, not
            # once per route (it is the most expensive part of the sweep).
            stream = [mixed_s(bq_mx, n_cal_mx + i)
                      for i in range(n_stream_mx)]
            oracles = [oracle_chunked(s_i) for s_i in stream]
            for grouping in (False, True):
                tag = "grouped" if grouping else "batchany"
                if grouping:
                    count_fn = jax.jit(lambda s_: pruning.survival_count_grouped(
                        codes_clip, s_, k, state_mx, n_groups=n_groups_mx,
                        seed_tiles=4))
                else:
                    count_fn = jax.jit(lambda s_: pruning.survival_count(
                        codes_clip, s_, k, state_mx, seed_tiles=4))
                counts = [int(count_fn(mixed_s(bq_mx, i)))
                          for i in range(n_cal_mx)]
                ladder = pruning.calibrate_ladder(counts, state_mx.n_tiles,
                                                  k, state_mx.tile)

                def _pr(s_, grouping=grouping, ladder=ladder):
                    v_, i_, st_ = pruning.cascade_topk_ingraph(
                        codes_clip, s_, k, state_mx, seed_tiles=4,
                        query_grouping=grouping, n_groups=n_groups_mx,
                        ladder=ladder, return_stats=True)
                    # jit outputs must be arrays: keep the numeric stats.
                    num = {kk: st_[kk] for kk in
                           ("pairs_scored", "pairs_union", "n_survived",
                            "max_group_survived", "survival_fraction",
                            "rung_hit", "n_groups")}
                    return v_, i_, num

                fn_pr = jax.jit(_pr)
                mismatches = 0
                pairs_scored = pairs_union = 0
                for i in range(n_stream_mx):
                    s_i = stream[i]
                    v_pr, i_pr, st_i = fn_pr(s_i)
                    v_ex, i_ex = oracles[i]
                    mismatches += int(
                        not (np.array_equal(np.asarray(v_pr),
                                            np.asarray(v_ex))
                             and np.array_equal(np.asarray(i_pr),
                                                np.asarray(i_ex))))
                    pairs_scored += int(st_i["pairs_scored"])
                    pairs_union += int(st_i["pairs_union"])
                # Time a HELD-OUT batch (neither calibration nor stream):
                # timing a batch the ladder was calibrated on would
                # guarantee a fitted rung and overstate throughput.
                s_t = mixed_s(bq_mx, n_cal_mx + n_stream_mx)
                v_, i_, st = fn_pr(s_t)
                st = {kk: vv.item() if hasattr(vv, "item") else vv
                      for kk, vv in st.items()}
                t = time_fn(lambda: fn_pr(s_t), repeats=args.repeats)
                ips = bq_mx * n_sk / t["median_s"]
                route_rows[tag] = (st, pairs_scored, pairs_union)
                _emit("kernel",
                      f"kernel/pq_retrieval_1m_mixed/B{bq_mx}/"
                      f"pqtopk_pruned_{tag}",
                      t["median_s"] * 1e6,
                      f"items_per_s={ips:.3e};"
                      f"pairs={pairs_scored}/{pairs_union};"
                      f"union_survival={st['survival_fraction']:.4f};"
                      f"max_group={st['max_group_survived']};"
                      f"ladder={ladder};mismatches={mismatches}",
                      method="pqtopk_pruned",
                      items_per_s=ips,
                      tags={"n_items": n_sk, "B": bq_mx, "mixed": True,
                            "tile": tile_sk, "grouping": tag,
                            "n_groups": st["n_groups"],
                            "bound_backend": "bitmask",
                            "survival_fraction": st["survival_fraction"],
                            "n_survived": st["n_survived"],
                            "max_group_survived": st["max_group_survived"],
                            "pairs_scored": pairs_scored,
                            "pairs_union": pairs_union,
                            "ladder": list(ladder),
                            "exactness_mismatches": mismatches,
                            "stream_batches": n_stream_mx,
                            "dispatches_per_query": 1}, timing=t)
            st_g, pg, pu = route_rows["grouped"]
            st_a, pa, _ = route_rows["batchany"]
            _emit("kernel",
                  f"kernel/pq_retrieval_1m_mixed/B{bq_mx}/grouping_delta",
                  None,
                  f"pairs_grouped={pg};pairs_batchany={pa};"
                  f"pair_ratio={pg / max(pa, 1):.3f};"
                  f"max_group={st_g['max_group_survived']}"
                  f"/union={st_a['n_survived']}",
                  method="grouping_delta",
                  tags={"n_items": n_sk, "B": bq_mx, "mixed": True,
                        "pairs_grouped": pg, "pairs_batchany": pa,
                        "pair_ratio_grouped_over_batchany":
                            pg / max(pa, 1),
                        "union_survived": st_a["n_survived"],
                        "max_group_survived":
                            st_g["max_group_survived"]})

    if "churn" not in args.skip:
        # -------------------------------------------------------------
        # Streaming catalogue mutation at N=2^20 (ISSUE 7 headline):
        # interleaved update+query through the incrementally maintained
        # MutableHeadState — queries run against STALE (loosened) bounds
        # plus the tombstone mask and must stay bit-exact vs the
        # exhaustive masked oracle; the section reports the mutation
        # cost, the stale-vs-fresh query latency gap (the price of
        # degradation), and the retighten cost that closes it.
        import jax
        import jax.numpy as jnp
        import numpy as np
        from benchmarks.timing import time_fn
        from repro.core import pruning, scoring, topk as topk_lib
        from repro.core.mutation import MutableHeadState

        rng_ch = np.random.default_rng(42)
        n_ch, m_ch, b_ch, tile_ch, k_ch = 1 << 20, 8, 256, 1024, 10
        centers_ch = (np.arange(n_ch) / n_ch * b_ch).astype(np.int64)
        codes_ch = jnp.asarray(
            np.clip(centers_ch[:, None]
                    + rng_ch.integers(-1, 2, (n_ch, m_ch)), 0, b_ch - 1),
            jnp.int32)
        g_ch = rng_ch.standard_normal((1, m_ch, b_ch))
        s_ch = jnp.asarray(np.sign(g_ch) * np.abs(g_ch) ** 3, jnp.float32)

        oracle_ch = jax.jit(lambda c_, lv_, s_: topk_lib.tiled_topk(
            jnp.where(lv_[None, :], scoring.score_pqtopk(c_, s_),
                      -jnp.inf), k_ch))

        def fresh_row():
            return jnp.asarray(rng_ch.integers(0, b_ch, m_ch), jnp.int32)

        for backend in pruning.BOUND_BACKENDS:
            mstate = MutableHeadState.build(codes_ch, b_ch, tile_ch,
                                            backend=backend)
            # Head arrays enter as traced ARGUMENTS — the same data-not-
            # constants contract the hot-swap engine compiles against.
            cascade_ch = jax.jit(
                lambda c_, lv_, st_, s_: pruning.cascade_topk_ingraph(
                    c_, s_, k_ch, st_, live=lv_)[:2])

            # Mutation cost (update = tombstone-free absorb + staleness).
            victims = rng_ch.integers(1, n_ch, 64)
            vi = iter(np.tile(victims, 100))
            t_mut = time_fn(
                lambda: mstate.update(int(next(vi)), fresh_row()),
                repeats=max(args.repeats * 4, 16), warmup=4)
            _emit("churn", f"churn/1m/update_{backend}",
                  t_mut["median_s"] * 1e6,
                  f"mutations_per_s={1 / t_mut['median_s']:.3e}",
                  method="mutation_update",
                  tags={"n_items": n_ch, "capacity": mstate.cap,
                        "tile": tile_ch, "bound_backend": backend},
                  timing=t_mut)

            # Interleaved stream: update -> query, exactness-checked.
            n_pairs, mismatches = 8, 0
            for i in range(n_pairs):
                if i % 4 == 3:
                    mstate.delete(int(rng_ch.integers(9, n_ch)))
                else:
                    mstate.update(1 + i, fresh_row())
                gg = np.random.default_rng(7000 + i).standard_normal(
                    (1, m_ch, b_ch))
                s_i = jnp.asarray(np.sign(gg) * np.abs(gg) ** 3,
                                  jnp.float32)
                ha = mstate.head_arrays()
                v_pr, i_pr = cascade_ch(ha["codes"], ha["live"],
                                        ha["pruned"], s_i)
                v_ex, i_ex = oracle_ch(ha["codes"], ha["live"], s_i)
                mismatches += int(
                    not (np.array_equal(np.asarray(v_pr),
                                        np.asarray(v_ex))
                         and np.array_equal(np.asarray(i_pr),
                                            np.asarray(i_ex))))

            # Query latency on the now-stale state vs after retighten.
            ha = mstate.head_arrays()
            stats_stale = mstate.stats()
            t_stale = time_fn(lambda: cascade_ch(ha["codes"], ha["live"],
                                                 ha["pruned"], s_ch),
                              repeats=args.repeats)
            _emit("churn", f"churn/1m/query_stale_{backend}",
                  t_stale["median_s"] * 1e6,
                  f"items_per_s={n_ch / t_stale['median_s']:.3e};"
                  f"stale_tiles={int(stats_stale['stale_tiles'])};"
                  f"mismatches={mismatches}",
                  method="pqtopk_pruned",
                  items_per_s=n_ch / t_stale["median_s"],
                  tags={"n_items": n_ch, "bound_backend": backend,
                        "tile": tile_ch, "churned": True,
                        "stale_tiles": stats_stale["stale_tiles"],
                        "n_mutations": stats_stale["n_mutations"],
                        "exactness_mismatches": mismatches,
                        "stream_pairs": n_pairs,
                        "dispatches_per_query": 1},
                  timing=t_stale)

            t_ret = time_fn(lambda: mstate.retighten() or None,
                            repeats=1, warmup=0)
            ha = mstate.head_arrays()
            t_fresh = time_fn(lambda: cascade_ch(ha["codes"], ha["live"],
                                                 ha["pruned"], s_ch),
                              repeats=args.repeats)
            _emit("churn", f"churn/1m/query_fresh_{backend}",
                  t_fresh["median_s"] * 1e6,
                  f"items_per_s={n_ch / t_fresh['median_s']:.3e};"
                  f"retighten_us={t_ret['median_s'] * 1e6:.1f}",
                  method="pqtopk_pruned",
                  items_per_s=n_ch / t_fresh["median_s"],
                  tags={"n_items": n_ch, "bound_backend": backend,
                        "tile": tile_ch, "churned": False,
                        "retighten_us": t_ret["median_s"] * 1e6,
                        "dispatches_per_query": 1},
                  timing=t_fresh)

    if "serving" not in args.skip:
        # -------------------------------------------------------------
        # Latency under load through the replicated fabric (ISSUE 8):
        # the same request stream through ReplicaRouter with K replicas,
        # healthy and under a deterministic chaos plan.  Per config:
        # per-request latency quartiles (the row's timing dict), req/s,
        # and the fabric counters (hedges, re-dispatches, sheds) so the
        # robustness cost is visible next to the latency it buys.
        import time as time_lib
        from dataclasses import replace as _replace

        import jax
        import numpy as np
        from repro.configs.base import get_reduced
        from repro.models import seqrec as seqrec_lib
        from repro.serving.engine import Request
        from repro.serving.router import ReplicaRouter
        from repro.training.fault_tolerance import ReplicaFaultPlan

        arch_srv = get_reduced("sasrec-recjpq")
        cfg_srv = _replace(arch_srv.model, n_items=8192)
        params_srv = seqrec_lib.init_seqrec(jax.random.PRNGKey(0), cfg_srv)
        n_req, mb_srv, k_srv = 192, 8, 10
        ladder_srv = None
        for n_rep in (1, 2, 4):
            for chaos in (False, True):
                if chaos and n_rep == 1:
                    continue            # replica-level chaos needs spares
                plans = ({1: ReplicaFaultPlan(crash_windows=((2, 5),))}
                         if chaos else None)
                router = ReplicaRouter.for_seqrec(
                    params_srv, cfg_srv, n_replicas=n_rep, k=k_srv,
                    max_batch=mb_srv, method="pqtopk_pruned",
                    ladder=ladder_srv, calibrate=ladder_srv is None,
                    fault_plans=plans, hedge=n_rep > 1)
                ladder_srv = router.engines[0].ladder
                rng_srv = np.random.default_rng(0)
                with router:
                    # Warm every pow2 padding bucket the trickle can form:
                    # a lazy compile inside the timed stream would read as
                    # a multi-second straggler and poison the p99.
                    router.warmup(buckets=tuple(
                        2 ** j for j in range(mb_srv.bit_length())))
                    t0 = time_lib.monotonic()
                    for i in range(n_req):
                        seq = rng_srv.integers(1, cfg_srv.n_items + 1, 16)
                        router.submit(Request(i, seq, k=k_srv))
                        router.pump()
                    res = router.drain()
                    wall = time_lib.monotonic() - t0
                    st_r = router.stats()
                assert len(res) == n_req, f"lost {n_req - len(res)} requests"
                lat_s = np.sort(np.asarray([r.latency_ms for r in res])) / 1e3
                q25, med, q75 = np.quantile(lat_s, (0.25, 0.5, 0.75))
                timing = {"median_s": med, "q25_s": q25, "q75_s": q75,
                          "iqr_s": q75 - q25, "n_reps": len(res)}
                n_shed = sum(1 for r in res if r.shed)
                ej = sum(r_["ejections"] for r_ in st_r["replicas"].values())
                re_ad = sum(r_["readmissions"]
                            for r_ in st_r["replicas"].values())
                suffix = "_chaos" if chaos else ""
                _emit("serving",
                      f"serving/load_K{n_rep}{suffix}/pqtopk_pruned",
                      med * 1e6,
                      f"req_per_s={n_req / wall:.1f};"
                      f"p99_ms={st_r['p99_ms']:.2f};"
                      f"hedges={int(st_r['hedges'])};"
                      f"redispatched={int(st_r['redispatched'])};"
                      f"shed={n_shed}",
                      method="pqtopk_pruned",
                      items_per_s=cfg_srv.n_items * (n_req - n_shed) / wall,
                      tags={"n_items": cfg_srv.n_items,
                            "n_replicas": n_rep, "chaos": chaos,
                            "n_requests": n_req, "max_batch": mb_srv,
                            "req_per_s": n_req / wall,
                            "p50_ms": st_r["p50_ms"],
                            "p99_ms": st_r["p99_ms"],
                            "hedges": int(st_r["hedges"]),
                            "hedge_wins": int(st_r["hedge_wins"]),
                            "redispatched": int(st_r["redispatched"]),
                            "duplicates_suppressed":
                                int(st_r["duplicates_suppressed"]),
                            "shed": n_shed,
                            "degrade_events": int(st_r["degrade_events"]),
                            "ejections": ej, "readmissions": re_ad,
                            "ladder": (list(ladder_srv)
                                       if ladder_srv else None)},
                      timing=timing)

    if "recovery" not in args.skip:
        # -------------------------------------------------------------
        # Durable catalogue log (ISSUE 10): what durability costs and
        # what recovery costs.  Three knobs, each a row family:
        #   * append latency vs fsync_every — the fsync amortization
        #     curve (fsync_every=1 is the durability ceiling, larger
        #     groups trade a bounded loss window for throughput);
        #   * recover() wall time vs tail length — snapshot restore +
        #     LSN-ordered replay, the crash-restart cost a stale replica
        #     or a restarted router actually pays;
        #   * snapshot cadence — the cost of cutting an LSN-keyed
        #     snapshot and the recover-time reduction it buys.
        import shutil
        import tempfile

        import numpy as np
        from benchmarks.timing import time_fn
        from repro.core.mutation import MutableHeadState, apply_op
        from repro.serving.catalogue_log import CatalogueLog

        rng_rc = np.random.default_rng(11)
        n_rc, m_rc, b_rc, tile_rc = 4096, 8, 256, 64
        codes_rc = rng_rc.integers(0, b_rc, (n_rc, m_rc)).astype(np.int32)

        def _mk_rc():
            return MutableHeadState.build(codes_rc, b_rc, tile_rc)

        def _ops_rc(mstate, n):
            ops = []
            for _ in range(n):
                live = np.where(np.asarray(mstate.live))[0]
                live = live[live > 0]
                row = rng_rc.integers(0, b_rc, m_rc).astype(np.int32)
                r = rng_rc.random()
                if (r < 0.3 and (mstate.free or mstate.n_rows < mstate.cap)) \
                        or live.size <= 1:
                    op = ("insert", row)
                elif r < 0.65:
                    op = ("delete", int(rng_rc.choice(live)))
                else:
                    op = ("update", int(rng_rc.choice(live)), row)
                apply_op(mstate, op)
                ops.append(op)
            return ops

        base_rc = _mk_rc()
        ops_pool = _ops_rc(base_rc.clone(), 1024)

        for fsync_every in (1, 8, 64):
            d = tempfile.mkdtemp(prefix="bench_wal_")
            log = CatalogueLog(d, fsync_every=fsync_every)
            it = iter(ops_pool * 8)
            t = time_fn(lambda: log.append(next(it)),
                        repeats=max(args.repeats * 64, 256), warmup=8)
            st_log = log.stats()
            log.close()
            shutil.rmtree(d)
            _emit("recovery", f"recovery/append/fsync{fsync_every}",
                  t["median_s"] * 1e6,
                  f"appends_per_s={1 / t['median_s']:.3e};"
                  f"log_bytes={int(st_log['log_bytes'])}",
                  method="wal_append",
                  items_per_s=1 / t["median_s"],
                  tags={"fsync_every": fsync_every,
                        "n_items": n_rc,
                        "log_bytes": int(st_log["log_bytes"]),
                        "n_fsyncs": int(st_log["n_fsyncs"])},
                  timing=t)

        # recover() = newest snapshot + tail replay: sweep the tail.
        for tail_len in (0, 256, 1024):
            d = tempfile.mkdtemp(prefix="bench_wal_")
            mstate = _mk_rc()
            with CatalogueLog(d, fsync_every=64) as log:
                log.snapshot(mstate)            # genesis at lsn 0
                for op in ops_pool[:tail_len]:
                    log.append(op)
                log.sync()
                t = time_fn(lambda: log.recover(), repeats=args.repeats,
                            warmup=1)
                _, lsn = log.recover()
                assert lsn == tail_len
            shutil.rmtree(d)
            _emit("recovery", f"recovery/recover/tail{tail_len}",
                  t["median_s"] * 1e6,
                  f"ops_replayed={tail_len};"
                  f"ops_per_s={tail_len / t['median_s']:.3e}"
                  if tail_len else "snapshot-only",
                  method="wal_recover",
                  items_per_s=(tail_len / t["median_s"]
                               if tail_len else None),
                  tags={"tail_len": tail_len, "n_items": n_rc,
                        "capacity": mstate.cap}, timing=t)

        # Snapshot cadence: amortized snapshot cost vs the recover-time
        # reduction it buys (0 = genesis-only, the full-replay extreme).
        for snap_every in (0, 128, 512):
            d = tempfile.mkdtemp(prefix="bench_wal_")
            mstate = _mk_rc()
            with CatalogueLog(d, fsync_every=64,
                              snapshot_every=snap_every) as log:
                log.snapshot(mstate)
                t_snap = None
                for op in ops_pool:
                    log.append(op)
                    apply_op(mstate, op)
                    if log.maybe_snapshot(mstate) is not None \
                            and t_snap is None:
                        # time one representative snapshot cut
                        t_snap = time_fn(lambda: log.snapshot(mstate),
                                         repeats=max(args.repeats, 3),
                                         warmup=0)
                log.sync()
                n_snaps = int(log.stats()["n_snapshots"])
                t_rec = time_fn(lambda: log.recover(),
                                repeats=args.repeats, warmup=1)
            shutil.rmtree(d)
            _emit("recovery", f"recovery/cadence/snap{snap_every}",
                  t_rec["median_s"] * 1e6,
                  f"n_snapshots={n_snaps};"
                  + (f"snapshot_us={t_snap['median_s'] * 1e6:.0f}"
                     if t_snap else "genesis-only"),
                  method="wal_cadence",
                  items_per_s=len(ops_pool) / t_rec["median_s"],
                  tags={"snapshot_every": snap_every,
                        "n_snapshots": n_snaps, "n_items": n_rc,
                        "stream_len": len(ops_pool),
                        "snapshot_us": (t_snap["median_s"] * 1e6
                                        if t_snap else None)},
                  timing=t_rec)

    if "hier" not in args.skip:
        # -------------------------------------------------------------
        # Hierarchical super-tile cascade at very large N (ISSUE 9
        # tentpole): flat vs hierarchical pruned cascade on a
        # popularity-sorted tile-coherent catalogue, bit-checked against
        # the streaming one-shot oracle.  Reports the pass-1 bound-work
        # reduction (the acceptance bar is >= 10x at N=2^24 with zero
        # mismatches) and the peak-RSS ceiling of the run.  N=2^27 only
        # under --full (1 GB of codes).
        import importlib.util as _ilu
        _spec = _ilu.spec_from_file_location("billion_item_sim",
                                             "examples/billion_item_sim.py")
        _sim = _ilu.module_from_spec(_spec)
        _spec.loader.exec_module(_sim)
        hier_ns = [1 << 24] + ([1 << 27] if args.full else [])
        for n_h in hier_ns:
            for backend_h in ("bitmask", "range"):
                r = _sim.run_hier_compare(n_h, repeats=args.repeats,
                                          backend=backend_h)
                tags_h = {"n_items": r["n_items"], "m": r["m"],
                          "bound_backend": backend_h, "hier": True,
                          "super_tile": r["super_factor"],
                          "n_tiles": r["n_tiles"],
                          "n_super": r["n_super"],
                          "flat_bounds": r["flat_bounds"],
                          "hier_bounds": r["hier_bounds"],
                          "bound_reduction": r["bound_reduction"],
                          "mismatches": r["mismatches"],
                          "peak_rss_mb": r["peak_rss_mb"]}
                _emit("hier",
                      f"hier/n{r['n_items']}/{backend_h}/super",
                      r["hier_s"] * 1e6,
                      f"flat_us={r['flat_s'] * 1e6:.0f};"
                      f"bound_reduction={r['bound_reduction']:.1f}x;"
                      f"mismatches={r['mismatches']};"
                      f"peak_rss_mb={r['peak_rss_mb']:.0f}",
                      method="pruned_hier",
                      items_per_s=r["n_items"] / max(r["hier_s"], 1e-9),
                      tags=tags_h)
                _emit("hier",
                      f"hier/n{r['n_items']}/{backend_h}/flat",
                      r["flat_s"] * 1e6,
                      f"bounds={r['flat_bounds']}",
                      method="pruned_flat",
                      items_per_s=r["n_items"] / max(r["flat_s"], 1e-9),
                      tags={"n_items": r["n_items"], "m": r["m"],
                            "bound_backend": backend_h, "hier": False,
                            "n_tiles": r["n_tiles"]})

    if "roofline" not in args.skip:
        import os
        from benchmarks import roofline
        art = "benchmarks/artifacts/dryrun"
        if os.path.isdir(art):
            for r in roofline.table(art):
                if "error" in r:
                    _emit("roofline", f"roofline/{r['arch']}/{r['shape']}",
                          None, f"error={r['error'][:50]}")
                    continue
                rf = r.get("roofline_frac")
                _emit("roofline", f"roofline/{r['arch']}/{r['shape']}",
                      r["bound_s"] * 1e6,
                      f"dominant={r['dominant']};"
                      f"roofline_frac={rf:.3f}" if rf else
                      f"dominant={r['dominant']}",
                      tags={"dominant": r["dominant"]})

    if args.json:
        import platform

        import jax as _jax
        doc = {
            "pr": 10,
            "backend": _jax.default_backend(),
            "platform": platform.platform(),
            "repeats": args.repeats,
            "fingerprint": environment_fingerprint(),
            "rows": rows,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
        print(f"# wrote {args.json} ({len(rows)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
