"""Regenerate the EXPERIMENTS.md dry-run + roofline tables from artifacts.

  PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import json

from benchmarks import roofline as R


def _fmt(x, pat="{:.2e}"):
    return pat.format(x) if x is not None else "-"


def dryrun_table(art_dir="benchmarks/artifacts/dryrun"):
    print("| arch | shape | mesh | compile s | args GB/dev | temp GB/dev |"
          " HLO GFLOP/dev | coll GB/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    for rec in R.load_records(art_dir, "baseline"):
        if not rec.get("ok"):
            print(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                  f"FAIL | | | | | {rec.get('error', '')[:40]} |")
            continue
        mem = rec.get("memory", {})
        args = (mem.get("argument_size_in_bytes") or 0) / 1e9
        temp = (mem.get("temp_size_in_bytes") or 0) / 1e9
        coll = rec.get("collective_bytes_per_device", 0) / 1e9
        colls = ",".join(f"{k}:{v['count']}" for k, v in
                         rec.get("collectives", {}).items())
        print(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
              f"{rec['compile_s']} | {args:.2f} | {temp:.2f} | "
              f"{rec['flops_per_device'] / 1e9:.1f} | {coll:.2f} | {colls} |")


def roofline_table(art_dir="benchmarks/artifacts/dryrun"):
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " MODEL_FLOPS | useful | roofline% | next lever |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in R.table(art_dir, "baseline", "single"):
        if "error" in r:
            continue
        roof = r["roofline"]
        mf = f"{r['model_flops']:.2e}" if r["model_flops"] else "-"
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        rf = f"{100 * r['roofline_frac']:.1f}" if r["roofline_frac"] else "-"
        print(f"| {r['arch']} | {r['shape']} | {roof['compute_s']:.2e} | "
              f"{roof['memory_s']:.2e} | {roof['collective_s']:.2e} | "
              f"{r['dominant']} | {mf} | {ur} | {rf} | "
              f"{_lever(r)} |")


def _lever(r) -> str:
    dom = r["dominant"]
    kind = r.get("meta", {}).get("kind", "")
    if dom == "collective":
        if kind == "retrieval":
            return "shard-local top-k merge (done: sharded_head)"
        return "layout: avoid seq<->weight axis conflicts; grad RS"
    if dom == "memory":
        if kind == "decode":
            return "KV-cache quantisation / paged layout"
        if kind == "retrieval":
            return "int8 codes; fused PQ kernel"
        return "fusion (TPU) / remat policy / bf16 masters"
    return "MXU utilisation: larger tiles, fewer transposes"


def variants_table(art_dir="benchmarks/artifacts/dryrun"):
    import glob, os
    print("| cell | variant | compute s | memory s | collective s | bound s |")
    print("|---|---|---|---|---|---|")
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(path))
        if rec.get("variant") == "baseline" or not rec.get("ok"):
            continue
        roof = rec["roofline"]
        bound = max(roof.values())
        print(f"| {rec['arch']}/{rec['shape']}/{rec['mesh']} | "
              f"{rec['variant']} | {roof['compute_s']:.2e} | "
              f"{roof['memory_s']:.2e} | {roof['collective_s']:.2e} | "
              f"{bound:.2e} |")


if __name__ == "__main__":
    print("## Dry-run matrix\n")
    dryrun_table()
    print("\n## Roofline (single-pod)\n")
    roofline_table()
    print("\n## Variants\n")
    variants_table()
