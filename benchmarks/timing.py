"""Shared wall-clock timing helpers (CPU algorithm-level benches).

The paper reports median response time (mRT) per user; we do the same:
jit, warm up, then median over repeats with block_until_ready.  Every
measurement also carries its quartiles (q25/q75) and IQR so downstream
trend tooling (scripts/bench_compare.py) can distinguish a real
regression from run-to-run noise: two medians whose IQR intervals
overlap are not evidence of a change.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable[[], object], *, repeats: int = 10,
            warmup: int = 2) -> dict:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    arr = np.asarray(times)
    q25, q75 = np.percentile(arr, (25, 75))
    return {
        "median_s": float(np.median(arr)),
        "mean_s": float(arr.mean()),
        "p99_s": float(np.percentile(arr, 99)),
        "min_s": float(arr.min()),
        "q25_s": float(q25),
        "q75_s": float(q75),
        "iqr_s": float(q75 - q25),
        "n_reps": int(repeats),
    }
