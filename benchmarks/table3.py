"""Paper Table 3: scoring-method latency per backbone per dataset scale.

Datasets are synthetic but size-matched to the paper's Table 1
(Booking.com ~34.7k items, Gowalla ~1.27M items).  We measure, per user
(batch=1, like the paper's per-request mRT):

  * backbone mRT     (Transformer only — independent of scoring method)
  * scoring mRT      (Default matmul / RecJPQ Alg.2 / PQTopK Alg.1)
  * total mRT

Absolute numbers are CPU-host timings (not the paper's Ryzen/TF stack) —
the *claims* under test are ordering and ratios: PQTopK < RecJPQ < Default
at Gowalla scale, and backbone-dominated totals at Booking scale.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import time_fn
from repro.configs.base import PQConfig, SeqRecConfig
from repro.core import retrieval_head, scoring, topk
from repro.models import seqrec as S

DATASETS = {
    "booking": 34_742,
    "gowalla": 1_271_638,
}
BACKBONES = {
    "sasrec": dict(backbone="sasrec", n_blocks=2, d_ff=512),
    "gbert4rec": dict(backbone="bert4rec", n_blocks=3, d_ff=2048),
}
METHODS = ("dense", "recjpq", "pqtopk")


def _make(backbone: str, n_items: int, *, d_model=512, m=8, b=512,
          seq_len=200):
    cfg = SeqRecConfig(name=f"bench-{backbone}", n_items=n_items,
                       d_model=d_model, max_seq_len=seq_len,
                       pq=PQConfig(m=m, b=b), **BACKBONES[backbone])
    params = S.init_seqrec(jax.random.PRNGKey(0), cfg)
    return cfg, params


def run(repeats: int = 7, datasets=("booking", "gowalla"),
        backbones=("sasrec", "gbert4rec"), k: int = 10):
    rows = []
    for ds_name in datasets:
        n_items = DATASETS[ds_name]
        for bb in backbones:
            cfg, params = _make(bb, n_items)
            rng = np.random.default_rng(0)
            seq = jnp.asarray(rng.integers(1, n_items, (1, cfg.max_seq_len)),
                              jnp.int32)

            phi_fn = jax.jit(lambda s: S.sequence_embedding(params, s, cfg))
            phi = jax.block_until_ready(phi_fn(seq))
            t_backbone = time_fn(lambda: phi_fn(seq), repeats=repeats)

            for method in METHODS:
                score_fn = jax.jit(functools.partial(
                    _score_and_topk, method=method, k=k))
                t_scoring = time_fn(
                    lambda: score_fn(params["item_emb"], phi),
                    repeats=repeats)
                rows.append({
                    "dataset": ds_name, "backbone": bb, "method": method,
                    "n_items": n_items,
                    "backbone_ms": t_backbone["median_s"] * 1e3,
                    "scoring_ms": t_scoring["median_s"] * 1e3,
                    "total_ms": (t_backbone["median_s"]
                                 + t_scoring["median_s"]) * 1e3,
                    "timing": t_scoring,
                })
    return rows


def _score_and_topk(head_params, phi, *, method: str, k: int):
    r = retrieval_head.score_all(head_params, phi, method)
    return jax.lax.top_k(r, k)


def main():
    rows = run()
    print(f"{'dataset':9s} {'backbone':10s} {'method':8s} "
          f"{'scoring_ms':>10s} {'total_ms':>9s}")
    for r in rows:
        print(f"{r['dataset']:9s} {r['backbone']:10s} {r['method']:8s} "
              f"{r['scoring_ms']:10.2f} {r['total_ms']:9.2f}")
    return rows


if __name__ == "__main__":
    main()
