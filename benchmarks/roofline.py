"""Roofline report: read dry-run artifacts and derive the three terms per
(arch × shape × mesh), the dominant bottleneck, MODEL_FLOPS and the
useful-compute ratio (EXPERIMENTS.md §Roofline).

  compute_s    = HLO_FLOPs_per_device / 197e12   (bf16 peak per v5e chip)
  memory_s     = HLO_bytes_per_device / 819e9    (HBM)
  collective_s = collective_bytes_per_device / 50e9 (ICI link)

MODEL_FLOPS (useful work, global):
  LM train     6 * N_active * tokens
  LM prefill   2 * N_active * tokens
  LM decode    2 * N_active * batch      (+ 2*KV attention flops, minor)
  seqrec serve 2 * N_backbone_tok * users + users * (2*b*d + 2*m*|I|)
  recsys/gnn   documented per-kind in _model_flops.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _lm_cfg(arch_id: str):
    from repro.configs.base import get_config
    return get_config(arch_id)


def _model_flops(rec: Dict) -> Optional[float]:
    from repro.configs.base import get_config
    arch = get_config(rec["arch"])
    meta = rec.get("meta", {})
    kind = meta.get("kind")
    m = arch.model
    if arch.family == "lm":
        n = m.active_param_count()
        if kind == "train":
            return 6.0 * n * meta["tokens"]
        if kind == "prefill":
            return 2.0 * n * meta["tokens"]
        if kind == "decode":
            a = m.attention
            kv_len = meta.get("kv_len", 0)
            # Window-aware: sliding layers attend over O(window), not O(L).
            n_global = sum(a.layer_is_global(i) for i in range(m.n_layers))
            n_local = m.n_layers - n_global
            eff = (n_global * kv_len
                   + n_local * min(a.window or kv_len, kv_len))
            kv_flops = 2 * a.n_heads * a.head_dim * 2 * eff
            return (2.0 * n + kv_flops) * meta["tokens"]
    if arch.family == "seqrec":
        d, L = m.d_model, m.n_blocks
        # per-token backbone ~ 12*d^2 per block (attn+ffn), + PQ scoring.
        if kind == "train":
            return 3 * 12 * d * d * L * meta["tokens"]
        users = meta.get("users", 1)
        seq = 200
        backbone = 12 * d * d * L * users * seq
        scoring = users * (2 * m.pq.b * d + 2 * m.pq.m * m.n_items)
        return backbone + scoring
    if arch.family == "recsys":
        ex = meta.get("examples", meta.get("n_candidates", 1))
        dense_params = sum(
            w_in * w_out for w_in, w_out in _recsys_mats(m))
        per_ex = 2.0 * (dense_params + m.n_sparse * m.embed_dim)
        mult = 3.0 if kind == "train" else 1.0
        if kind == "retrieval":
            return 2.0 * m.pq.m * m.n_items + 2.0 * m.pq.b * m.embed_dim
        return mult * per_ex * ex
    if arch.family == "gnn":
        return None
    return None


def _recsys_mats(m):
    d0 = m.n_dense + m.n_sparse * m.embed_dim
    mats = []
    prev = d0
    for w in m.mlp:
        mats.append((prev, w))
        prev = w
    mats.append((prev, 1))
    for _ in range(m.n_cross_layers):
        mats.append((d0, d0))
    return mats


def load_records(art_dir: str, variant: Optional[str] = None) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if variant and rec.get("variant") != variant:
            continue
        out.append(rec)
    return out


def analyse(rec: Dict) -> Dict:
    r = dict(rec)
    roof = rec.get("roofline", {})
    terms = {k: roof.get(k, 0.0) for k in
             ("compute_s", "memory_s", "collective_s")}
    dominant = max(terms, key=terms.get) if any(terms.values()) else "n/a"
    bound_s = max(terms.values()) if terms else 0.0
    mf = _model_flops(rec)
    flops_dev = rec.get("corrected", {}).get(
        "flops_per_device", rec.get("flops_per_device", 0.0))
    hlo_global = flops_dev * rec.get("devices", 1)
    r.update({
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound_s,
        "model_flops": mf,
        "useful_ratio": (mf / hlo_global) if (mf and hlo_global) else None,
        # roofline fraction: useful-FLOPs time / achievable (bounded) time
        "roofline_frac": (
            (mf / rec["devices"] / PEAK_FLOPS) / bound_s
            if (mf and bound_s) else None),
    })
    return r


def table(art_dir: str = "benchmarks/artifacts/dryrun",
          variant: str = "baseline", mesh: Optional[str] = "single"):
    rows = []
    for rec in load_records(art_dir, variant):
        if mesh and rec.get("mesh") != mesh:
            continue
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "error": rec.get("error", "?")})
            continue
        rows.append(analyse(rec))
    return rows


def main():
    rows = table()
    hdr = (f"{'arch':20s} {'shape':14s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dom':>5s} {'useful':>7s} {'roofl%':>7s}")
    print(hdr)
    for r in rows:
        if "error" in r:
            print(f"{r['arch']:20s} {r['shape']:14s} ERROR {r['error'][:60]}")
            continue
        roof = r["roofline"]
        ur = f"{r['useful_ratio']:.2f}" if r["useful_ratio"] else "-"
        rf = f"{100 * r['roofline_frac']:.1f}" if r["roofline_frac"] else "-"
        print(f"{r['arch']:20s} {r['shape']:14s} {roof['compute_s']:9.2e} "
              f"{roof['memory_s']:9.2e} {roof['collective_s']:9.2e} "
              f"{r['dominant']:>5s} {ur:>7s} {rf:>7s}")
    return rows


if __name__ == "__main__":
    main()
