"""Paper Figure 2: scoring latency vs catalogue size on simulated data.

Exactly the paper's RQ2 protocol: the backbone is excluded (phi is a random
vector), the sub-id embeddings are random, and we measure scoring + top-k
(top-k included, as its cost also depends on |I|).  m in {8, 64}.

Default sweep: 10^4 .. 10^6 (CI-friendly).  ``--full`` extends to 10^7
(and 10^8 items PQ-only); like the paper's 128 GB box losing the Default
line past 10^7, the dense baseline is the first to hit the memory wall —
we cap it at the size whose W matrix fits the budget.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.timing import time_fn
from repro import compat
from repro.core import pruning, scoring
from repro.kernels.pqtopk import ops as pq_ops

D_MODEL = 512
K = 10
DENSE_MEM_BUDGET = 8e9    # bytes of W we allow the dense baseline (CPU host)
# Largest catalogue the fused Pallas kernel is timed at in interpret mode
# (CPU containers emulate the kernel; past this it measures the emulator).
FUSED_INTERPRET_CAP = 100_000
PRUNE_TILE = 1024         # pruning granularity for the cascaded route


def bench_point(n_items: int, m: int, b: int = 256, *, repeats: int = 5,
                methods=("dense", "recjpq", "pqtopk", "pqtopk_fused",
                         "pqtopk_pruned")):
    """One (n_items, m) cell.  Returns {method: timing-dict-or-None};
    the pruned route's timing dict additionally carries
    ``survival_fraction``/``n_seed_used`` (figure2 uses uniform random
    codes, so every tile tends to contain every sub-id and the bound prunes
    little — the kernel-section skewed sweep shows the favourable regime).
    Rows measured through the Pallas *interpreter* (the fused kernel on a
    non-TPU host) carry ``"interpret": True`` — they time the emulator, not
    the kernel, and must be excluded from items/s trend comparisons."""
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    phi = jax.random.normal(key, (1, D_MODEL), jnp.float32)
    s = jax.random.normal(key, (1, m, b), jnp.float32)
    codes = jnp.asarray(rng.integers(0, b, (n_items, m)), jnp.int32)
    out = {}
    for method in methods:
        if method == "dense":
            if n_items * D_MODEL * 4 > DENSE_MEM_BUDGET:
                out[method] = None    # memory wall (paper: OOM past 1e7)
                continue
            w = jax.random.normal(key, (n_items, D_MODEL), jnp.float32)
            fn = jax.jit(lambda w_, p_: jax.lax.top_k(
                scoring.score_dense(w_, p_), K))
            out[method] = time_fn(lambda: fn(w, phi), repeats=repeats)
            del w
        elif method == "pqtopk_fused":
            if not compat.on_tpu() and n_items > FUSED_INTERPRET_CAP:
                out[method] = None    # interpret-mode guard (see cap above)
                continue
            t = time_fn(lambda: pq_ops.pq_topk(codes, s, K),
                        repeats=repeats)
            t["interpret"] = not compat.on_tpu()
            out[method] = t
        elif method == "pqtopk_pruned":
            # Single-dispatch in-graph cascade; metadata built once here
            # (in serving it rides in the param tree).
            state = pruning.build_pruned_state(codes, b, PRUNE_TILE)
            fn = jax.jit(lambda c_, s_: pruning.cascade_topk_ingraph(
                c_, s_, K, state))
            _, _, stats = pruning.cascade_topk_ingraph(codes, s, K, state,
                                                       return_stats=True)
            t = time_fn(lambda: fn(codes, s), repeats=repeats)
            t["survival_fraction"] = float(stats["survival_fraction"])
            t["n_seed_used"] = int(stats["n_seed_used"])
            # Self-describing pruned-row tags (BENCH trend comparisons):
            # figure2 times the full-buffer cascade — no ladder, so the
            # rung-hit fraction is vacuously 0 on a 1-rung ladder.
            t["bound_backend"] = state.backend
            t["ladder"] = None
            t["rung_hit_fraction"] = None
            out[method] = t
        else:
            alg = {"recjpq": scoring.score_recjpq,
                   "pqtopk": scoring.score_pqtopk,
                   "pqtopk_onehot": scoring.score_pqtopk_onehot}[method]
            fn = jax.jit(lambda c_, s_: jax.lax.top_k(alg(c_, s_), K))
            out[method] = time_fn(lambda: fn(codes, s), repeats=repeats)
    return out


def run(full: bool = False, repeats: int = 5):
    sizes = [10_000, 100_000, 1_000_000]
    if full:
        sizes += [10_000_000]
    rows = []
    for m in (8, 64):
        for n in sizes:
            res = bench_point(n, m, repeats=repeats)
            for method, t in res.items():
                row = {
                    "n_items": n, "m": m, "method": method,
                    "scoring_ms": None if t is None
                    else t["median_s"] * 1e3,
                    "timing": t,
                }
                for tag in ("survival_fraction", "n_seed_used", "interpret",
                            "bound_backend", "ladder", "rung_hit_fraction"):
                    if t and tag in t:
                        row[tag] = t[tag]
                rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)
    rows = run(args.full, args.repeats)
    print(f"{'m':>3s} {'n_items':>11s} {'method':14s} {'scoring_ms':>11s}")
    for r in rows:
        if r["scoring_ms"] is None:
            ms = ("interp-guard" if r["method"] == "pqtopk_fused"
                  else "OOM-guard")
        else:
            ms = f"{r['scoring_ms']:.2f}"
        surv = (f"  surv={r['survival_fraction']:.2f}"
                if "survival_fraction" in r else "")
        print(f"{r['m']:3d} {r['n_items']:11,d} {r['method']:14s} "
              f"{ms:>12s}{surv}")
    return rows


if __name__ == "__main__":
    main()
