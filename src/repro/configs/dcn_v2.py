"""DCN-v2 [arXiv:2008.13535; Criteo: 13 dense, 26 sparse, 3 cross layers].

Retrieval shape uses the PQ cascade: PQTopK over PQ-compressed item-id
embeddings -> full cross+MLP re-rank of the top slate (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig, PQConfig, RecsysConfig, recsys_shapes

# Standard Criteo-Kaggle categorical vocab sizes (26 fields).
CRITEO_VOCABS = (
    1460, 583, 10_131_227, 2_202_608, 305, 24, 12_517, 633, 3, 93_145,
    5_683, 8_351_593, 3_194, 27, 14_992, 5_461_306, 10, 5_652, 2_173, 4,
    7_046_547, 18, 15, 286_181, 105, 142_572,
)

CONFIG = ArchConfig(
    arch_id="dcn-v2",
    family="recsys",
    model=RecsysConfig(
        name="dcn-v2",
        kind="dcn",
        n_dense=13,
        n_sparse=26,
        embed_dim=16,
        table_rows=CRITEO_VOCABS,
        mlp=(1024, 1024, 512),
        n_cross_layers=3,
        n_items=1_000_000,
        pq=PQConfig(m=4, b=256),
    ),
    shapes=recsys_shapes(),
    source="arXiv:2008.13535",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = RecsysConfig(
        name="dcn-v2-reduced",
        kind="dcn",
        n_dense=4, n_sparse=6, embed_dim=8,
        table_rows=(64, 32, 128, 16, 8, 256),
        mlp=(64, 32), n_cross_layers=2,
        n_items=512,
        pq=PQConfig(m=2, b=16),
    )
    return replace(CONFIG, model=model)
