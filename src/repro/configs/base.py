"""Config dataclasses + arch registry.

Every assigned architecture lives in its own module (``repro/configs/<id>.py``)
exposing ``CONFIG`` (the exact published config) and ``reduced()`` (a tiny
same-family config for CPU smoke tests).  ``get_config(arch_id)`` resolves
either by registry id (``--arch qwen2.5-14b``).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Tuple

# ---------------------------------------------------------------------------
# Paper technique: Product-Quantised retrieval head (RecJPQ + PQTopK).
# ---------------------------------------------------------------------------


#: Largest codebook width each storage dtype can index.  8-bit codes cut
#: the retrieval head's HBM code traffic 4x vs int32 (the fused kernel
#: widens in VMEM); the standard b=256 paper setting fits uint8.
CODE_DTYPE_CAPACITY = {"int8": 128, "uint8": 256, "int16": 32_768,
                       "uint16": 65_536, "int32": 2 ** 31 - 1}


def min_code_dtype(b: int) -> str:
    """Narrowest supported storage dtype for a codebook of width ``b``."""
    for name in ("uint8", "uint16", "int32"):
        if b <= CODE_DTYPE_CAPACITY[name]:
            return name
    raise ValueError(f"b={b} exceeds int32 code storage")


@dataclass(frozen=True)
class PQConfig:
    """Sub-item-id decomposition (RecJPQ) of a large id space."""

    m: int = 8          # number of splits (sub-ids per item)
    b: int = 256        # distinct sub-ids per split (codebook width)
    assign: str = "svd"  # codebook builder: svd | kmeans | random
    code_dtype: str = "int32"
    # theta-seeding policy for the pruned cascade (docs/PRUNING.md):
    # "greedy" scores exactly ``seed_tiles`` tiles; "adaptive" grows the
    # seed set geometrically (seed_tiles -> seed_max_tiles) until the
    # estimated survival fraction moves by <= seed_stab_tol between stages.
    # The growth loop is trace-static (fixed trip count), so either policy
    # stays inside the single-dispatch in-graph cascade.
    seed_policy: str = "greedy"
    seed_tiles: int = 2
    seed_max_tiles: int = 16
    seed_stab_tol: float = 0.05
    # Bound backend for the pruned cascade's per-tile upper bounds
    # (docs/PRUNING.md §Bound backends):
    #   "bitmask" — uint32 code-presence bitmasks, O(T*m*b/8) bytes,
    #               tightest bounds (exact per-tile code sets);
    #   "range"   — per-tile min/max code ranges as int16 lo/hi,
    #               O(T*m*2*2) bytes and two gathers per bound via a
    #               segment-max table — 1/8 the metadata at b=256, looser
    #               bounds when code distributions have holes.
    # Both are exact (bounds dominate true scores either way); the choice
    # only moves the survival fraction and the metadata footprint.
    bound_backend: str = "bitmask"
    # Per-query pruned survival (docs/PRUNING.md §Per-query survival):
    # query_grouping=True seeds theta per query, keeps per-query survival
    # bitmasks, buckets queries by survivor-set overlap into ~n_groups
    # groups, and hands the fused kernel a 2D (group, slot) tile table so
    # each kernel batch tile scores only its group's survivors —
    # sum_g B_g * S_g work instead of the batch-any B * |union|, which is
    # what keeps large mixed batches from degrading toward exhaustive
    # scoring.  n_groups=1 recovers the batch-any route exactly.  Exact
    # either way (every query still sees a superset of its surviving
    # tiles).
    query_grouping: bool = False
    n_groups: int = 8
    # Hierarchical super-tile bounds (docs/PRUNING.md §Hierarchical
    # bounds): super_factor > 1 groups that many consecutive child tiles
    # into super-tiles with their own (OR-ed / hulled) metadata, and the
    # cascade inserts a pass 0 that prunes super-tiles against theta
    # before any child tile bound is gathered — O(T/factor + survivors)
    # bound work instead of O(T), bit-identical results.  0 disables the
    # level.  Mutually exclusive with query_grouping (per-query survival
    # has no super-tile pass-0).
    super_factor: int = 0

    def __post_init__(self):
        if self.b > 2 ** 16:
            raise ValueError("b > 65536 not supported (codes stored <= int32)")
        cap = CODE_DTYPE_CAPACITY.get(self.code_dtype)
        if cap is None:
            raise ValueError(f"unsupported code_dtype {self.code_dtype!r}; "
                             f"one of {sorted(CODE_DTYPE_CAPACITY)}")
        if self.b > cap:
            raise ValueError(
                f"b={self.b} does not fit code_dtype={self.code_dtype!r} "
                f"(max {cap}); use {min_code_dtype(self.b)!r}")
        if self.seed_policy not in ("greedy", "adaptive"):
            raise ValueError(f"unknown seed_policy {self.seed_policy!r}; "
                             "one of ('greedy', 'adaptive')")
        if not 1 <= self.seed_tiles <= self.seed_max_tiles:
            raise ValueError(
                f"need 1 <= seed_tiles ({self.seed_tiles}) <= "
                f"seed_max_tiles ({self.seed_max_tiles})")
        if self.seed_stab_tol <= 0:
            raise ValueError("seed_stab_tol must be positive")
        if self.bound_backend not in ("bitmask", "range"):
            raise ValueError(
                f"unknown bound_backend {self.bound_backend!r}; "
                "one of ('bitmask', 'range')")
        if self.bound_backend == "range" and self.b > 2 ** 15:
            raise ValueError(
                f"bound_backend='range' stores int16 code ranges; "
                f"b={self.b} exceeds int16 — use bound_backend='bitmask'")
        if self.n_groups < 1:
            raise ValueError(f"n_groups must be >= 1, got {self.n_groups}")
        if self.super_factor < 0 or self.super_factor == 1:
            raise ValueError(
                f"super_factor must be 0 (no super level) or >= 2, got "
                f"{self.super_factor}")
        if self.super_factor > 1 and self.query_grouping:
            raise ValueError(
                "super_factor > 1 and query_grouping are mutually "
                "exclusive: the hierarchical pass-0 prunes batch-any "
                "super-tiles, which per-query grouped survival bypasses")


# ---------------------------------------------------------------------------
# LM family.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # Sliding-window mix: every ``local_global_ratio``+1-th layer is global,
    # the rest are local with window ``window``.  0 => all layers global.
    window: int = 0
    local_global_ratio: int = 0

    def layer_is_global(self, layer_idx: int) -> bool:
        if self.local_global_ratio <= 0 or self.window <= 0:
            return True
        return (layer_idx + 1) % (self.local_global_ratio + 1) == 0


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attention: AttentionConfig
    act: str = "silu"         # silu | gelu | relu | sqrelu
    gated_mlp: bool = True    # GLU-style two-matrix up-projection
    moe: Optional[MoEConfig] = None
    moe_impl: str = "dense"   # dense (GShard one-hot) | sort (gather/scatter)
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    tie_embeddings: bool = True
    causal: bool = True       # False => encoder-style (BERT4Rec)
    # PQ-compressed unembedding for decode-time vocab scoring (beyond-paper
    # application of the technique to LM heads).
    pq_head: Optional[PQConfig] = PQConfig()
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"   # bf16 for 340B-scale (see DESIGN.md §8)
    remat: bool = True
    scan_layers: bool = True

    @property
    def q_dim(self) -> int:
        return self.attention.n_heads * self.attention.head_dim

    @property
    def kv_dim(self) -> int:
        return self.attention.n_kv_heads * self.attention.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        a = self.attention
        attn = self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model
        if self.moe is None:
            n_mat = 3 if self.gated_mlp else 2
            ffn = n_mat * self.d_model * self.d_ff
        else:
            n_mat = 3 if self.gated_mlp else 2
            ffn = self.moe.n_experts * n_mat * self.d_model * self.moe.d_ff_expert
            ffn += self.d_model * self.moe.n_experts  # router
            ffn += self.moe.n_shared * n_mat * self.d_model * self.moe.d_ff_expert
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn) + emb

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        a = self.attention
        attn = self.d_model * (self.q_dim + 2 * self.kv_dim) + self.q_dim * self.d_model
        n_mat = 3 if self.gated_mlp else 2
        ffn = (self.moe.top_k + self.moe.n_shared) * n_mat * self.d_model * self.moe.d_ff_expert
        ffn += self.d_model * self.moe.n_experts
        emb = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        return self.n_layers * (attn + ffn) + emb


# ---------------------------------------------------------------------------
# Sequential-recommendation family (the paper's own models).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SeqRecConfig:
    name: str
    backbone: str              # sasrec | bert4rec
    n_items: int
    d_model: int = 512
    n_blocks: int = 2
    n_heads: int = 8
    d_ff: int = 1024
    max_seq_len: int = 200
    dropout: float = 0.0       # inference-focused; kept for completeness
    pq: PQConfig = field(default_factory=PQConfig)
    dtype: str = "float32"
    param_dtype: str = "float32"
    moment_dtype: str = "float32"
    # gBCE negative sampling (gSASRec / gBERT4Rec training)
    n_negatives: int = 256
    gbce_t: float = 0.75
    # Default scoring route for serving (retrieval_head.TOP_ITEMS_METHODS);
    # "pqtopk_fused" = the Pallas fused score+top-k kernel.
    serve_method: str = "pqtopk"


# ---------------------------------------------------------------------------
# RecSys CTR/retrieval family.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                  # dcn | bst | dien | fm
    n_dense: int = 0
    n_sparse: int = 26
    embed_dim: int = 16
    table_rows: Tuple[int, ...] = ()   # one entry per sparse field
    mlp: Tuple[int, ...] = ()
    n_cross_layers: int = 0
    seq_len: int = 0           # behaviour-sequence length (bst / dien)
    n_blocks: int = 0
    n_heads: int = 0
    gru_dim: int = 0           # dien
    n_items: int = 1_000_000   # retrieval catalogue for retrieval_cand
    pq: Optional[PQConfig] = field(default_factory=PQConfig)
    dtype: str = "float32"
    param_dtype: str = "float32"
    moment_dtype: str = "float32"

    def total_rows(self) -> int:
        return sum(self.table_rows)


# ---------------------------------------------------------------------------
# GNN family.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 128
    aggregator: str = "mean"
    sample_sizes: Tuple[int, ...] = (25, 10)
    n_classes: int = 41
    dtype: str = "float32"
    param_dtype: str = "float32"
    moment_dtype: str = "float32"


# ---------------------------------------------------------------------------
# Shapes.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape × step-kind) cell of the dry-run matrix."""

    name: str
    kind: str        # train | prefill | decode | serve | retrieval
    dims: Any = field(default_factory=dict)
    skip_reason: str = ""   # non-empty => documented skip (DESIGN.md §4)


def lm_shapes(*, sub_quadratic: bool, decoder: bool = True) -> Tuple[ShapeSpec, ...]:
    shapes = [
        ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
        ShapeSpec("prefill_32k", "prefill", {"seq_len": 32_768, "global_batch": 32}),
        ShapeSpec(
            "decode_32k", "decode", {"seq_len": 32_768, "global_batch": 128},
            skip_reason="" if decoder else "encoder-only arch: no autoregressive decode",
        ),
        ShapeSpec(
            "long_500k", "decode", {"seq_len": 524_288, "global_batch": 1},
            skip_reason=""
            if (sub_quadratic and decoder)
            else (
                "pure full-attention arch: no sub-quadratic mechanism (DESIGN.md §4)"
                if decoder
                else "encoder-only arch: no autoregressive decode"
            ),
        ),
    ]
    return tuple(shapes)


def recsys_shapes() -> Tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("train_batch", "train", {"global_batch": 65_536}),
        ShapeSpec("serve_p99", "serve", {"global_batch": 512}),
        ShapeSpec("serve_bulk", "serve", {"global_batch": 262_144}),
        ShapeSpec("retrieval_cand", "retrieval", {"global_batch": 1, "n_candidates": 1_000_000}),
    )


def gnn_shapes() -> Tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("full_graph_sm", "train",
                  {"n_nodes": 2_708, "n_edges": 10_556, "d_feat": 1_433, "n_classes": 7}),
        ShapeSpec("minibatch_lg", "train",
                  {"n_nodes": 232_965, "n_edges": 114_615_892, "batch_nodes": 1_024,
                   "fanout": (15, 10), "d_feat": 602, "n_classes": 41}),
        ShapeSpec("ogb_products", "train",
                  {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
                   "n_classes": 47}),
        ShapeSpec("molecule", "train",
                  {"n_nodes": 30, "n_edges": 64, "graph_batch": 128, "d_feat": 16,
                   "n_classes": 2}),
    )


def seqrec_shapes(n_items: int) -> Tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("train_seq", "train", {"global_batch": 4096, "seq_len": 200}),
        ShapeSpec("serve_users", "retrieval",
                  {"global_batch": 2048, "seq_len": 200, "n_candidates": n_items}),
    )


# ---------------------------------------------------------------------------
# Arch registry.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                  # lm | seqrec | recsys | gnn
    model: Any
    shapes: Tuple[ShapeSpec, ...]
    source: str = ""
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id}: unknown shape {name!r}")

    def active_shapes(self) -> Tuple[ShapeSpec, ...]:
        return tuple(s for s in self.shapes if not s.skip_reason)


_REGISTRY = {
    "qwen2.5-14b": "qwen2_5_14b",
    "nemotron-4-340b": "nemotron_4_340b",
    "gemma3-27b": "gemma3_27b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "dbrx-132b": "dbrx_132b",
    "graphsage-reddit": "graphsage_reddit",
    "dcn-v2": "dcn_v2",
    "bst": "bst",
    "dien": "dien",
    "fm": "fm",
    # the paper's own models
    "sasrec-recjpq": "sasrec_recjpq",
    "gbert4rec-recjpq": "gbert4rec_recjpq",
}


def list_archs() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch_id]}")
    return mod.CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch_id]}")
    return mod.reduced()


__all__ = [
    "PQConfig", "CODE_DTYPE_CAPACITY", "min_code_dtype",
    "MoEConfig", "AttentionConfig", "LMConfig", "SeqRecConfig",
    "RecsysConfig", "GNNConfig", "ShapeSpec", "ArchConfig",
    "lm_shapes", "recsys_shapes", "gnn_shapes", "seqrec_shapes",
    "list_archs", "get_config", "get_reduced", "replace",
]
