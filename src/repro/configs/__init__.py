from repro.configs.base import (
    ArchConfig, AttentionConfig, GNNConfig, LMConfig, MoEConfig, PQConfig,
    RecsysConfig, SeqRecConfig, ShapeSpec, get_config, get_reduced, list_archs,
)

__all__ = [
    "ArchConfig", "AttentionConfig", "GNNConfig", "LMConfig", "MoEConfig",
    "PQConfig", "RecsysConfig", "SeqRecConfig", "ShapeSpec",
    "get_config", "get_reduced", "list_archs",
]
