"""SASRec + RecJPQ @ Gowalla scale — the paper's primary model (Table 3).

2 Transformer blocks, d=512, m=8 splits (paper §4), b=512 sub-ids/split
(RecJPQ's Gowalla setting), 1,271,638 items.
"""
from repro.configs.base import ArchConfig, PQConfig, SeqRecConfig, seqrec_shapes

N_ITEMS = 1_271_638   # Gowalla (paper Table 1)

CONFIG = ArchConfig(
    arch_id="sasrec-recjpq",
    family="seqrec",
    model=SeqRecConfig(
        name="sasrec-recjpq",
        backbone="sasrec",
        n_items=N_ITEMS,
        d_model=512,
        n_blocks=2,
        n_heads=8,
        d_ff=512,
        max_seq_len=200,
        pq=PQConfig(m=8, b=512, assign="svd", code_dtype="uint16"),
        serve_method="pqtopk_fused",
    ),
    shapes=seqrec_shapes(N_ITEMS),
    source="RecSys'24 (this paper) + RecJPQ [WSDM'24]",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = SeqRecConfig(
        name="sasrec-recjpq-reduced",
        backbone="sasrec",
        n_items=1000, d_model=32, n_blocks=2, n_heads=2, d_ff=32,
        max_seq_len=16, n_negatives=16,
        pq=PQConfig(m=4, b=16, assign="svd"),
        serve_method="pqtopk_fused",
    )
    return replace(CONFIG, model=model)
