"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family; dense, GQA, QKV bias]."""
from repro.configs.base import (
    ArchConfig, AttentionConfig, LMConfig, PQConfig, lm_shapes,
)

CONFIG = ArchConfig(
    arch_id="qwen2.5-14b",
    family="lm",
    model=LMConfig(
        name="qwen2.5-14b",
        n_layers=48,
        d_model=5120,
        d_ff=13824,
        vocab=152064,
        attention=AttentionConfig(
            n_heads=40, n_kv_heads=8, head_dim=128,
            qkv_bias=True, rope_theta=1_000_000.0,
        ),
        act="silu",
        gated_mlp=True,
        tie_embeddings=False,
        pq_head=PQConfig(m=8, b=256),
    ),
    # Pure full attention => long_500k documented-skip.
    shapes=lm_shapes(sub_quadratic=False),
    source="hf:Qwen/Qwen2.5-14B",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = LMConfig(
        name="qwen2.5-14b-reduced",
        n_layers=2, d_model=64, d_ff=128, vocab=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16, qkv_bias=True),
        act="silu", gated_mlp=True, tie_embeddings=False,
        pq_head=PQConfig(m=4, b=16),
        dtype="float32", param_dtype="float32",
    )
    return replace(CONFIG, model=model)
