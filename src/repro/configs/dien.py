"""DIEN [arXiv:1809.03672; interest evolution with AUGRU over 100-step history]."""
from repro.configs.base import ArchConfig, PQConfig, RecsysConfig, recsys_shapes

CONFIG = ArchConfig(
    arch_id="dien",
    family="recsys",
    model=RecsysConfig(
        name="dien",
        kind="dien",
        n_dense=0,
        n_sparse=2,                      # (item, category) per position
        embed_dim=18,
        table_rows=(1_000_000, 2_000),
        mlp=(200, 80),
        seq_len=100,
        gru_dim=108,
        n_items=1_000_000,
        pq=PQConfig(m=6, b=256),
    ),
    shapes=recsys_shapes(),
    source="arXiv:1809.03672",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = RecsysConfig(
        name="dien-reduced",
        kind="dien",
        n_dense=0, n_sparse=2, embed_dim=8,
        table_rows=(512, 32),
        mlp=(32, 16), seq_len=10, gru_dim=24,
        n_items=512,
        pq=PQConfig(m=2, b=16),
    )
    return replace(CONFIG, model=model)
