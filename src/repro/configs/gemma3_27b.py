"""Gemma3-27B [hf:google/gemma-3-27b-pt family; 5:1 local:global, 128k ctx]."""
from repro.configs.base import (
    ArchConfig, AttentionConfig, LMConfig, PQConfig, lm_shapes,
)

CONFIG = ArchConfig(
    arch_id="gemma3-27b",
    family="lm",
    model=LMConfig(
        name="gemma3-27b",
        n_layers=62,
        d_model=5376,
        d_ff=21504,
        vocab=262144,
        attention=AttentionConfig(
            n_heads=32, n_kv_heads=16, head_dim=128,
            qkv_bias=False, qk_norm=True, rope_theta=1_000_000.0,
            window=1024, local_global_ratio=5,   # 5 local : 1 global
        ),
        act="gelu",
        gated_mlp=True,          # GeGLU
        tie_embeddings=True,
        pq_head=PQConfig(m=8, b=256),
    ),
    # 5/6 of layers are O(window) sliding attention => long_500k runs.
    shapes=lm_shapes(sub_quadratic=True),
    source="hf:google/gemma-3-27b-pt",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = LMConfig(
        name="gemma3-27b-reduced",
        n_layers=6, d_model=64, d_ff=128, vocab=512,
        attention=AttentionConfig(
            n_heads=4, n_kv_heads=2, head_dim=16, qk_norm=True,
            window=8, local_global_ratio=5,
        ),
        act="gelu", gated_mlp=True, tie_embeddings=True,
        pq_head=PQConfig(m=4, b=16),
        dtype="float32", param_dtype="float32",
    )
    return replace(CONFIG, model=model)
