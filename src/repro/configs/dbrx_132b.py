"""DBRX-132B [hf:databricks/dbrx-base; MoE 16 experts top-4, fine-grained]."""
from repro.configs.base import (
    ArchConfig, AttentionConfig, LMConfig, MoEConfig, PQConfig, lm_shapes,
)

CONFIG = ArchConfig(
    arch_id="dbrx-132b",
    family="lm",
    model=LMConfig(
        name="dbrx-132b",
        n_layers=40,
        d_model=6144,
        d_ff=10752,              # per-expert d_ff
        vocab=100352,
        attention=AttentionConfig(
            n_heads=48, n_kv_heads=8, head_dim=128,
            qkv_bias=False, rope_theta=500_000.0,
        ),
        act="silu",
        gated_mlp=True,
        moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752, n_shared=0),
        norm="layernorm",
        tie_embeddings=False,
        pq_head=PQConfig(m=8, b=256),
    ),
    shapes=lm_shapes(sub_quadratic=False),
    source="hf:databricks/dbrx-base",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = LMConfig(
        name="dbrx-132b-reduced",
        n_layers=2, d_model=64, d_ff=64, vocab=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16),
        act="silu", gated_mlp=True, norm="layernorm",
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
        tie_embeddings=False,
        pq_head=PQConfig(m=4, b=16),
        dtype="float32", param_dtype="float32",
    )
    return replace(CONFIG, model=model)
