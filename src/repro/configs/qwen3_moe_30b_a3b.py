"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B; MoE 128 experts top-8, GQA kv=4]."""
from repro.configs.base import (
    ArchConfig, AttentionConfig, LMConfig, MoEConfig, PQConfig, lm_shapes,
)

CONFIG = ArchConfig(
    arch_id="qwen3-moe-30b-a3b",
    family="lm",
    model=LMConfig(
        name="qwen3-moe-30b-a3b",
        n_layers=48,
        d_model=2048,
        d_ff=768,                # per-expert d_ff
        vocab=151936,
        attention=AttentionConfig(
            n_heads=32, n_kv_heads=4, head_dim=128,
            qkv_bias=False, qk_norm=True, rope_theta=1_000_000.0,
        ),
        act="silu",
        gated_mlp=True,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768, n_shared=0),
        tie_embeddings=False,
        pq_head=PQConfig(m=8, b=256),
    ),
    shapes=lm_shapes(sub_quadratic=False),
    source="hf:Qwen/Qwen3-30B-A3B",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = LMConfig(
        name="qwen3-moe-30b-a3b-reduced",
        n_layers=2, d_model=64, d_ff=32, vocab=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=16, qk_norm=True),
        act="silu", gated_mlp=True,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32),
        tie_embeddings=False,
        pq_head=PQConfig(m=4, b=16),
        dtype="float32", param_dtype="float32",
    )
    return replace(CONFIG, model=model)
