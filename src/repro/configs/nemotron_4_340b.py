"""Nemotron-4-340B [arXiv:2402.16819; dense, GQA, squared-ReLU, non-gated]."""
from repro.configs.base import (
    ArchConfig, AttentionConfig, LMConfig, PQConfig, lm_shapes,
)

CONFIG = ArchConfig(
    arch_id="nemotron-4-340b",
    family="lm",
    model=LMConfig(
        name="nemotron-4-340b",
        n_layers=96,
        d_model=18432,
        d_ff=73728,
        vocab=256000,
        attention=AttentionConfig(
            n_heads=96, n_kv_heads=8, head_dim=192,
            qkv_bias=False, rope_theta=10_000.0,
        ),
        act="sqrelu",
        gated_mlp=False,          # Nemotron uses a plain 2-matrix FFN
        norm="layernorm",
        tie_embeddings=False,
        pq_head=PQConfig(m=8, b=256),
        moment_dtype="bfloat16",  # 340B: bf16 Adam moments (DESIGN.md §8)
    ),
    shapes=lm_shapes(sub_quadratic=False),
    source="arXiv:2402.16819",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = LMConfig(
        name="nemotron-4-340b-reduced",
        n_layers=2, d_model=96, d_ff=384, vocab=512,
        attention=AttentionConfig(n_heads=6, n_kv_heads=2, head_dim=16),
        act="sqrelu", gated_mlp=False, norm="layernorm", tie_embeddings=False,
        pq_head=PQConfig(m=4, b=16),
        dtype="float32", param_dtype="float32",
    )
    return replace(CONFIG, model=model)
