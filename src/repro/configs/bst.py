"""BST — Behavior Sequence Transformer [arXiv:1905.06874; Alibaba/Taobao]."""
from repro.configs.base import ArchConfig, PQConfig, RecsysConfig, recsys_shapes

CONFIG = ArchConfig(
    arch_id="bst",
    family="recsys",
    model=RecsysConfig(
        name="bst",
        kind="bst",
        n_dense=0,
        n_sparse=2,                      # (item, category) per position
        embed_dim=32,
        table_rows=(4_000_000, 10_000),  # Taobao-scale items + categories
        mlp=(1024, 512, 256),
        seq_len=20,
        n_blocks=1,
        n_heads=8,
        n_items=4_000_000,
        pq=PQConfig(m=8, b=256),
    ),
    shapes=recsys_shapes(),
    source="arXiv:1905.06874",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = RecsysConfig(
        name="bst-reduced",
        kind="bst",
        n_dense=0, n_sparse=2, embed_dim=16,
        table_rows=(512, 32),
        mlp=(64, 32), seq_len=8, n_blocks=1, n_heads=4,
        n_items=512,
        pq=PQConfig(m=4, b=16),
    )
    return replace(CONFIG, model=model)
