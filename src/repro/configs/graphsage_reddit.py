"""GraphSAGE [arXiv:1706.02216; 2 layers, d=128, mean aggregator, fanout 25-10].

The paper's PQ/PQTopK technique is NOT applicable to this arch (node
classification: no million-id scoring step) — see DESIGN.md §4.  Implemented
without the technique, sharing the segment_sum message-passing substrate.
"""
from repro.configs.base import ArchConfig, GNNConfig, gnn_shapes

CONFIG = ArchConfig(
    arch_id="graphsage-reddit",
    family="gnn",
    model=GNNConfig(
        name="graphsage-reddit",
        n_layers=2,
        d_hidden=128,
        aggregator="mean",
        sample_sizes=(25, 10),
        n_classes=41,
    ),
    shapes=gnn_shapes(),
    source="arXiv:1706.02216",
    notes="PQ retrieval head inapplicable (DESIGN.md §4).",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = GNNConfig(
        name="graphsage-reduced",
        n_layers=2, d_hidden=16, aggregator="mean",
        sample_sizes=(5, 3), n_classes=7,
    )
    return replace(CONFIG, model=model)
