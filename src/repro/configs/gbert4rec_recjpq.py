"""gBERT4Rec + RecJPQ @ Booking.com scale (paper Table 3, BERT rows).

3 Transformer blocks, d=512, bidirectional encoder trained with gBCE +
negative sampling [gSASRec, RecSys'23]; m=8 splits, 34,742 items.
"""
from repro.configs.base import ArchConfig, PQConfig, SeqRecConfig, seqrec_shapes

N_ITEMS = 34_742   # Booking.com (paper Table 1)

CONFIG = ArchConfig(
    arch_id="gbert4rec-recjpq",
    family="seqrec",
    model=SeqRecConfig(
        name="gbert4rec-recjpq",
        backbone="bert4rec",
        n_items=N_ITEMS,
        d_model=512,
        n_blocks=3,
        n_heads=8,
        d_ff=2048,
        max_seq_len=200,
        pq=PQConfig(m=8, b=256, assign="svd", code_dtype="uint8"),
        serve_method="pqtopk_fused",
    ),
    shapes=seqrec_shapes(N_ITEMS),
    source="RecSys'24 (this paper) + gSASRec [RecSys'23]",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = SeqRecConfig(
        name="gbert4rec-recjpq-reduced",
        backbone="bert4rec",
        n_items=1000, d_model=32, n_blocks=2, n_heads=2, d_ff=64,
        max_seq_len=16, n_negatives=16,
        pq=PQConfig(m=4, b=16, assign="svd", code_dtype="uint8"),
        serve_method="pqtopk_fused",
    )
    return replace(CONFIG, model=model)
