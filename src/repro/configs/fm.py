"""Factorization Machine [Rendle, ICDM'10; 39 fields, k=10, O(nk) sum-square].

The FM item term <v_user, v_item> is exactly dot-product retrieval, so the
``retrieval_cand`` shape is a *direct* application of the paper's PQTopK
(d=10 -> m=2 splits of 5).
"""
from repro.configs.base import ArchConfig, PQConfig, RecsysConfig, recsys_shapes
from repro.configs.dcn_v2 import CRITEO_VOCABS

# 13 bucketised dense features (64 buckets each) + 26 categorical fields.
FM_VOCABS = tuple([64] * 13) + CRITEO_VOCABS

CONFIG = ArchConfig(
    arch_id="fm",
    family="recsys",
    model=RecsysConfig(
        name="fm",
        kind="fm",
        n_dense=0,
        n_sparse=39,
        embed_dim=10,
        table_rows=FM_VOCABS,
        n_items=1_000_000,
        pq=PQConfig(m=2, b=256),
    ),
    shapes=recsys_shapes(),
    source="Rendle ICDM'10",
)


def reduced() -> ArchConfig:
    from dataclasses import replace
    model = RecsysConfig(
        name="fm-reduced",
        kind="fm",
        n_dense=0, n_sparse=6, embed_dim=8,
        table_rows=(64, 32, 128, 16, 8, 256),
        n_items=512,
        pq=PQConfig(m=2, b=16),
    )
    return replace(CONFIG, model=model)
