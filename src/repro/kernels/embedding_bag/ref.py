"""Pure-jnp oracle: embedding-bag = take + masked weighted reduce."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: jax.Array | None = None,
                  mode: str = "sum") -> jax.Array:
    """table (V,d), indices (n_bags,bag) int (-1 = padding),
    weights (n_bags,bag) or None -> (n_bags, d)."""
    mask = (indices >= 0).astype(table.dtype)
    w = mask if weights is None else weights * mask
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0)   # (n_bags,bag,d)
    acc = (rows * w[..., None]).sum(axis=1)
    if mode == "mean":
        acc = acc / jnp.maximum(w.sum(axis=1), 1.0)[:, None]
    return acc
