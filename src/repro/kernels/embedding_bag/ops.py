"""Jitted wrapper for the embedding-bag Pallas kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels.embedding_bag import kernel as _k


@functools.partial(jax.jit, static_argnames=("mode", "bags_per_step",
                                             "interpret"))
def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: jax.Array | None = None, *, mode: str = "sum",
                  bags_per_step: int = _k.DEFAULT_BAGS_PER_STEP,
                  interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = not compat.on_tpu()
    n_bags, bag = indices.shape
    mask = (indices >= 0).astype(jnp.float32)
    w = mask if weights is None else weights.astype(jnp.float32) * mask
    bags_per_step = min(bags_per_step, n_bags)
    pad = (-n_bags) % bags_per_step
    if pad:
        indices = jnp.pad(indices, ((0, pad), (0, 0)), constant_values=-1)
        w = jnp.pad(w, ((0, pad), (0, 0)))
    out = _k.embedding_bag_call(table.astype(jnp.float32),
                                indices.astype(jnp.int32), w, mode=mode,
                                bags_per_step=bags_per_step,
                                interpret=interpret)
    return out[:n_bags]
