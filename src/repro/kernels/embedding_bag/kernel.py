"""Pallas TPU embedding-bag kernel: the recsys lookup hot path.

JAX has no native ``nn.EmbeddingBag``; the pure-jnp substrate builds it from
``take`` + ``segment_sum`` (see ``ref.py`` / ``repro.models.embedding``).
This kernel is the TPU-native version: the table stays in HBM
(``MemorySpace.ANY``) and each bag's rows are fetched by *dynamic-index DMA*
into a VMEM scratch buffer (same indirection pattern as paged-attention
block tables), then reduced on the VPU with padding mask + optional
per-sample weights.

Layout:
  indices (n_bags, bag) int32  -> scalar-prefetch (SMEM): DMA addressing
  weights (n_bags, bag) f32    -> block (TB, bag)
  table   (V, d) f32           -> stays in HBM (ANY), rows DMA'd on demand
  out     (n_bags, d) f32      -> block (TB, d)

Grid: one step per TB bags; bag*TB row-DMAs per step are issued before a
single wait (they can overlap).  Padding entries use index < 0: the DMA is
clamped to row 0 and the row is masked out of the reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

DEFAULT_BAGS_PER_STEP = 8


def embedding_bag_kernel(idx_ref,        # (n_bags, bag) int32, SMEM prefetch
                         weights_ref,    # (TB, bag) f32, VMEM
                         table_ref,      # (V, d) f32, HBM/ANY
                         out_ref,        # (TB, d) f32, VMEM
                         scratch_ref,    # (TB, bag, d) f32, VMEM
                         sem,            # DMA semaphore array (TB, bag)
                         *, bags_per_step: int, bag: int, mode: str):
    step = pl.program_id(0)
    # Issue every row-DMA for this step's bags, then wait once each.
    for t in range(bags_per_step):
        for j in range(bag):
            raw = idx_ref[step * bags_per_step + t, j]
            row = jnp.maximum(raw, 0)                  # clamp padding (-1)
            cp = pltpu.make_async_copy(
                table_ref.at[pl.ds(row, 1), :],
                scratch_ref.at[t, pl.ds(j, 1), :],
                sem.at[t, j],
            )
            cp.start()
    for t in range(bags_per_step):
        for j in range(bag):
            raw = idx_ref[step * bags_per_step + t, j]
            row = jnp.maximum(raw, 0)
            pltpu.make_async_copy(
                table_ref.at[pl.ds(row, 1), :],
                scratch_ref.at[t, pl.ds(j, 1), :],
                sem.at[t, j],
            ).wait()
    rows = scratch_ref[...]                            # (TB, bag, d)
    w = weights_ref[...]                               # (TB, bag)
    # Mask padding; weights already folded by caller for weighted bags.
    acc = (rows * w[:, :, None]).sum(axis=1)           # (TB, d)
    if mode == "mean":
        denom = jnp.maximum(w.sum(axis=1), 1.0)
        acc = acc / denom[:, None]
    out_ref[...] = acc


def embedding_bag_call(table: jax.Array, indices: jax.Array,
                       weights: jax.Array, *, mode: str = "sum",
                       bags_per_step: int = DEFAULT_BAGS_PER_STEP,
                       interpret: bool = False) -> jax.Array:
    """table (V,d) f32, indices (n_bags,bag) i32 (-1 pads), weights
    (n_bags,bag) f32 -> (n_bags, d) f32."""
    n_bags, bag = indices.shape
    v, d = table.shape
    assert n_bags % bags_per_step == 0, (n_bags, bags_per_step)
    grid = (n_bags // bags_per_step,)
    kern = functools.partial(embedding_bag_kernel,
                             bags_per_step=bags_per_step, bag=bag, mode=mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bags_per_step, bag), lambda i, idx: (i, 0)),
            pl.BlockSpec(memory_space=compat.ANY),
        ],
        out_specs=pl.BlockSpec((bags_per_step, d), lambda i, idx: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bags_per_step, bag, d), jnp.float32),
            pltpu.SemaphoreType.DMA((bags_per_step, bag)),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), jnp.float32),
        interpret=interpret,
    )(indices, weights, table)
