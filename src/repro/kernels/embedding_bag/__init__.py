from repro.kernels.embedding_bag import kernel, ops, ref

__all__ = ["kernel", "ops", "ref"]
