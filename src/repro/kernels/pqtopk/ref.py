"""Pure-jnp oracle for the pqtopk kernels (no Pallas)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scoring import tree_sum


def pq_scores(codes: jax.Array, s: jax.Array) -> jax.Array:
    """r[q, i] = sum_k s[q, k, codes[i, k]].  codes (N,m), s (B,m,b) -> (B,N).

    Per-split gathers reduced via tree_sum — the same f32 add order as the
    Pallas kernel and score_pqtopk, so kernel-vs-oracle parity is bit-exact
    (an XLA ``.sum(axis=1)`` reduce picks its own order and drifts by ulps).
    """
    m = codes.shape[1]
    idx = codes.astype(jnp.int32)
    return tree_sum([jnp.take(s[:, k, :].astype(jnp.float32), idx[:, k],
                              axis=1) for k in range(m)])


def pq_topk(codes: jax.Array, s: jax.Array, k: int):
    """Exact global top-k of pq_scores. -> (vals (B,k), ids (B,k))."""
    r = pq_scores(codes, s)
    return jax.lax.top_k(r, k)
