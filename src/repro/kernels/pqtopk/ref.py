"""Pure-jnp oracle for the pqtopk kernels (no Pallas)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pq_scores(codes: jax.Array, s: jax.Array) -> jax.Array:
    """r[q, i] = sum_k s[q, k, codes[i, k]].  codes (N,m), s (B,m,b) -> (B,N)."""
    idx = codes.T[None].astype(jnp.int32)              # (1, m, N)
    return jnp.take_along_axis(s.astype(jnp.float32), idx, axis=2).sum(axis=1)


def pq_topk(codes: jax.Array, s: jax.Array, k: int):
    """Exact global top-k of pq_scores. -> (vals (B,k), ids (B,k))."""
    r = pq_scores(codes, s)
    return jax.lax.top_k(r, k)
