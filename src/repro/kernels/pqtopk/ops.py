"""Jitted public wrappers around the pqtopk Pallas kernels.

Handles padding (item tiles, batch tiles, the pruned route's sentinel
tile), interpret-mode selection (CPU containers run the kernel body in
Python), and the final cross-tile top-k merge.

``pq_topk_tiles`` is the pass-2 entry of the cascaded pruned route: it
scores only the tiles named by a compacted ``tile_idx`` list.  On TPU it
runs the scalar-prefetch Pallas kernel; off TPU it lowers to an XLA
gather + ``pq_scores`` + ``tiled_topk`` pipeline with identical numerics
(shared ``tree_sum`` accumulation order, same value-then-lowest-id tie
break), so CPU hosts get real compute savings instead of timing the
Pallas interpreter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import topk as topk_lib
from repro.kernels.pqtopk import kernel as _k, ref as _ref

# A plain Python float, NOT a jnp scalar: this module is imported lazily
# (sometimes inside an active jit trace), and materialising a module-level
# jnp constant under a trace leaks a tracer.
NEG_INF = float("-inf")


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def effective_batch_tile(bq: int,
                         batch_tile: int = _k.DEFAULT_BATCH_TILE) -> int:
    """The batch-tile size the fused kernel will actually run for a batch
    of ``bq`` queries (small batches round up to 8, never past the
    default).  The grouped cascade builds its per-batch-tile slot table
    against this, so the compaction and the kernel grid must agree."""
    return min(batch_tile, _round_up(bq, 8))


def group_batch_tile(bq: int, n_groups: int,
                     batch_tile: int = _k.DEFAULT_BATCH_TILE) -> int:
    """Batch-tile size for the grouped route: small enough that the batch
    splits into ~``n_groups`` kernel batch tiles (each group gets its own
    slot row), floored at 8 rows (sublane minimum) and capped at the
    exhaustive-route tile.  Grouping trades per-step MXU batch width for
    scored-tile sparsity — the win condition is group survivor sets being
    (near-)disjoint, which is exactly the mixed-batch regime."""
    target = -(-bq // max(n_groups, 1))
    bt = 8
    while bt < target:
        bt *= 2
    return min(bt, effective_batch_tile(bq, batch_tile))


def n_tiles(n: int, tile: int) -> int:
    """Number of item tiles covering an N-item catalogue."""
    return -(-n // tile)


def sentinel_tile(n: int, tile: int) -> int:
    """Tile index used to pad a compacted survivor list: one all-padding
    tile appended past the catalogue, whose every global id is >= n and is
    therefore masked to -inf inside the kernel."""
    return n_tiles(n, tile)


def _pad_codes(codes: jax.Array, tile: int, *, sentinel: bool = False
               ) -> jax.Array:
    n = codes.shape[0]
    pad = (-n) % tile + (tile if sentinel else 0)
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    return codes


def _pad_batch(s: jax.Array, batch_tile: int) -> jax.Array:
    pad = (-s.shape[0]) % batch_tile
    if pad:
        s = jnp.pad(s, ((0, pad), (0, 0), (0, 0)))
    return s


def _merge_slot_winners(tv: jax.Array, ti: jax.Array, k: int):
    """(B, n_slots, K) per-slot winners -> global (B, k).  Slots are in
    ascending tile order, so the stable ``lax.top_k`` over the flattened
    candidates breaks ties by lowest global id, matching the oracle."""
    bq, slots, kk = tv.shape
    fv, fi = jax.lax.top_k(tv.reshape(bq, slots * kk), k)
    return fv, jnp.take_along_axis(ti.reshape(bq, slots * kk), fi, axis=1)


def _remap_dead(fv: jax.Array, fi: jax.Array, n: int):
    """Tombstone-route winner cleanup: any ``-inf`` winner (a dead item, a
    sentinel slot, or a catalogue with < k live items) gets the sentinel id
    ``n`` — callers see one uniform "no item here" id, never a dead row."""
    return fv, jnp.where(fv == NEG_INF, jnp.int32(n), fi)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def pq_scores(codes: jax.Array, s: jax.Array, *, tile: int = _k.DEFAULT_TILE,
              interpret: bool | None = None) -> jax.Array:
    """PQ scores for all items. codes (N,m), s (B,m,b) -> (B,N) f32."""
    if interpret is None:
        interpret = not compat.on_tpu()
    n = codes.shape[0]
    tile = min(tile, _round_up(n, 128))
    padded = _pad_codes(codes, tile)
    out = _k.pq_scores_call(padded, s, tile=tile, interpret=interpret)
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("k", "tile", "batch_tile",
                                             "interpret"))
def pq_topk(codes: jax.Array, s: jax.Array, k: int, *,
            tile: int = _k.DEFAULT_TILE,
            batch_tile: int = _k.DEFAULT_BATCH_TILE,
            interpret: bool | None = None):
    """Fused PQ scoring + hierarchical top-k over the whole catalogue.
    Exact (tile-local winners contain all global winners when k <= tile).
    Batch-tiled: any B; the grid covers ceil(B/batch_tile) batch tiles.
    -> (vals (B,k), ids (B,k))."""
    if interpret is None:
        interpret = not compat.on_tpu()
    n = codes.shape[0]
    bq = s.shape[0]
    tile = min(tile, _round_up(n, 128))
    if k > tile:
        raise ValueError(f"k={k} > tile={tile}")
    padded = _pad_codes(codes, tile)
    idx = jnp.arange(padded.shape[0] // tile, dtype=jnp.int32)
    bt = effective_batch_tile(bq, batch_tile)
    tv, ti = _k.pq_topk_fused_call(padded, _pad_batch(s, bt), k,
                                   tile_idx=idx, n_items=n, tile=tile,
                                   batch_tile=bt, interpret=interpret)
    return _merge_slot_winners(tv[:bq], ti[:bq], k)


def _pq_topk_tiles(codes: jax.Array, s: jax.Array, k: int,
                   tile_idx: jax.Array, *, tile: int, batch_tile: int,
                   use_kernel: bool, interpret: bool, live=None):
    """Non-jitted core of :func:`pq_topk_tiles` (shard_map bodies call this
    directly so the jit boundary stays at the outer dispatch).

    ``tile_idx`` may be 1D (one compacted list for the whole batch) or 2D
    ``(n_batch_tiles, n_slots)`` (the grouped route: each kernel batch
    tile scores its own slot row).

    ``live`` (N,) bool tombstone mask (mutable catalogues): dead items'
    scores are masked to -inf *inside* the tile top-k — post-hoc masking
    would be inexact, a dead item can crowd a live winner out of a tile's
    local candidate set — and dead winners' ids are remapped to the
    sentinel id ``n`` so they are indistinguishable from padding."""
    n, m = codes.shape
    bq = s.shape[0]
    tile = min(tile, _round_up(n, 128))
    if k > tile:
        raise ValueError(f"k={k} > tile={tile}")
    padded = _pad_codes(codes, tile, sentinel=True)
    live2 = None
    if live is not None:
        lv = live.astype(jnp.int8)
        pad = padded.shape[0] - n
        if pad:
            lv = jnp.pad(lv, (0, pad))      # padding + sentinel tile: dead
        live2 = lv.reshape(-1, tile)
    bt = effective_batch_tile(bq, batch_tile)
    grouped = tile_idx.ndim == 2
    if grouped and tile_idx.shape[0] * bt < bq:
        raise ValueError(
            f"2D tile_idx has {tile_idx.shape[0]} batch-tile rows but the "
            f"batch pads to {-(-bq // bt)} tiles of {bt}")
    if use_kernel:
        tv, ti = _k.pq_topk_fused_call(padded, _pad_batch(s, bt), k,
                                       tile_idx=tile_idx, n_items=n,
                                       tile=tile, batch_tile=bt,
                                       live=live2, interpret=interpret)
        fv, fi = _merge_slot_winners(tv[:bq], ti[:bq], k)
        if live is not None:
            fv, fi = _remap_dead(fv, fi, n)
        return fv, fi
    # XLA path: gather the surviving tiles' codes, score them with the
    # shared-accumulation-order oracle, top-k over the compacted axis and
    # map positions back to global ids.  tile_idx is ascending (plus
    # trailing sentinels), so position order == global id order and ties
    # resolve identically to the exhaustive oracle.  ``-1`` sentinel slots
    # (the in-graph cascade's compaction padding) are remapped to the
    # all-padding tile appended past the catalogue, whose global ids are
    # >= n and therefore mask to -inf below.
    tile_idx = jnp.where(tile_idx < 0, sentinel_tile(n, tile), tile_idx)
    codes3 = padded.reshape(-1, tile, m)
    if grouped:
        # Per-group gather + scoring: each batch tile's queries score only
        # that group's slot row — the XLA mirror of the kernel's 2D grid,
        # with the same per-row ascending order (hence identical ties).
        n_slots = tile_idx.shape[1]
        s3 = _pad_batch(s, bt).reshape(-1, bt, m, s.shape[-1])

        def group_fn(idx_row, s_g):
            sel = codes3[idx_row]                       # (S, tile, m)
            sc = _ref.pq_scores(sel.reshape(n_slots * tile, m), s_g)
            gid = (idx_row[:, None] * tile
                   + jnp.arange(tile, dtype=jnp.int32)[None, :]).reshape(-1)
            ok = gid < n
            if live2 is not None:
                ok = ok & (live2[idx_row].reshape(-1) != 0)
            sc = jnp.where(ok[None, :], sc, NEG_INF)
            fv, pos = topk_lib.tiled_topk(sc, k)
            return fv, jnp.take(gid, pos)

        fv, fi = jax.vmap(group_fn)(tile_idx, s3)       # (n_bt, bt, k)
        fv, fi = fv.reshape(-1, k)[:bq], fi.reshape(-1, k)[:bq]
        if live is not None:
            fv, fi = _remap_dead(fv, fi, n)
        return fv, fi
    n_slots = tile_idx.shape[0]
    sel = codes3[tile_idx]                              # (L, tile, m)
    scores = _ref.pq_scores(sel.reshape(n_slots * tile, m), s)
    gid = (tile_idx[:, None] * tile
           + jnp.arange(tile, dtype=jnp.int32)[None, :]).reshape(-1)
    ok = gid < n
    if live2 is not None:
        ok = ok & (live2[tile_idx].reshape(-1) != 0)
    scores = jnp.where(ok[None, :], scores, NEG_INF)
    fv, pos = topk_lib.tiled_topk(scores, k)
    fv, fi = fv, jnp.take(gid, pos)
    if live is not None:
        fv, fi = _remap_dead(fv, fi, n)
    return fv, fi


def _pq_topk_tiles_ladder(codes: jax.Array, s: jax.Array, k: int,
                          slot_lists, count: jax.Array, *, tile: int,
                          batch_tile: int, use_kernel: bool,
                          interpret: bool, live=None):
    """Non-jitted ladder core (shard_map bodies call this directly).

    ``slot_lists`` is a tuple of ``-1``-padded compacted tile buffers of
    strictly increasing static length, the last one full-length
    (exhaustive).  Lowers to a nested ``lax.cond`` chain: the first rung
    whose slot count holds ``count`` scores its buffer; every branch lives
    in the same traced computation, so the dispatch count never changes.
    For the grouped route the buffers are 2D ``(n_batch_tiles, budget)``
    rows and ``count`` is the per-group survivor-count vector — a rung is
    taken when it holds the LARGEST group (one shared ladder; lighter
    groups' spare slots are ``-1`` sentinels and cost ~nothing).
    -> (vals (B, k), ids (B, k), rung i32 — index of the rung taken).
    """
    count_max = jnp.max(count)

    def rung_fn(i):
        def run():
            v, ii = _pq_topk_tiles(codes, s, k, slot_lists[i], tile=tile,
                                   batch_tile=batch_tile,
                                   use_kernel=use_kernel,
                                   interpret=interpret, live=live)
            return v, ii, jnp.int32(i)
        if i == len(slot_lists) - 1:
            return run
        nxt = rung_fn(i + 1)
        budget = slot_lists[i].shape[-1]
        return lambda: jax.lax.cond(count_max <= budget, run, nxt)

    return rung_fn(0)()


def pq_topk_tiles_ladder(codes: jax.Array, s: jax.Array, k: int,
                         slot_lists, count: jax.Array, *, tile: int,
                         batch_tile: int = _k.DEFAULT_BATCH_TILE,
                         live: jax.Array | None = None,
                         use_kernel: bool | None = None,
                         interpret: bool | None = None):
    """Slot-budget-ladder scoring over compacted tile buffers (the
    cascade's scoring stage when a calibrated ladder is active).  See
    :func:`_pq_topk_tiles_ladder`; this wrapper only resolves the
    backend-dependent kernel/interpret defaults — jit the caller (the
    cascade is itself one traced computation)."""
    if use_kernel is None:
        use_kernel = compat.on_tpu()
    if interpret is None:
        interpret = not compat.on_tpu()
    return _pq_topk_tiles_ladder(
        codes, s, k, tuple(jnp.asarray(sl, jnp.int32) for sl in slot_lists),
        count, tile=tile, batch_tile=batch_tile, use_kernel=use_kernel,
        interpret=interpret, live=live)


@functools.partial(jax.jit, static_argnames=("k", "tile", "batch_tile",
                                             "use_kernel", "interpret"))
def pq_topk_tiles(codes: jax.Array, s: jax.Array, k: int,
                  tile_idx: jax.Array, *, tile: int = _k.DEFAULT_TILE,
                  batch_tile: int = _k.DEFAULT_BATCH_TILE,
                  live: jax.Array | None = None,
                  use_kernel: bool | None = None,
                  interpret: bool | None = None):
    """Fused scoring + top-k over a compacted tile list (the cascade's
    scoring stage — fed by host compaction in the legacy route, by the
    in-graph cumsum scatter in the single-dispatch route).

    codes (N, m) raw catalogue codes; tile_idx (n_slots,) int32 ascending
    tile indices, padded with either ``-1`` sentinel slots (in-graph
    compaction; ``@pl.when`` early-exit in the kernel) or legacy
    ``sentinel_tile(N, tile)`` entries.  Work is O(n_slots * tile * m)
    instead of O(N * m).  -> (vals (B,k), ids (B,k)), bit-identical to the
    exhaustive routes for surviving items.
    """
    if use_kernel is None:
        use_kernel = compat.on_tpu()
    if interpret is None:
        interpret = not compat.on_tpu()
    return _pq_topk_tiles(codes, s, k, tile_idx.astype(jnp.int32),
                          tile=tile, batch_tile=batch_tile,
                          use_kernel=use_kernel, interpret=interpret,
                          live=live)
