"""Jitted public wrappers around the pqtopk Pallas kernels.

Handles padding to the tile size, interpret-mode selection (CPU containers
run the kernel body in Python), and the final cross-tile top-k merge.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels.pqtopk import kernel as _k


def _pad_codes(codes: jax.Array, tile: int) -> jax.Array:
    n = codes.shape[0]
    pad = (-n) % tile
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    return codes


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def pq_scores(codes: jax.Array, s: jax.Array, *, tile: int = _k.DEFAULT_TILE,
              interpret: bool | None = None) -> jax.Array:
    """PQ scores for all items. codes (N,m), s (B,m,b) -> (B,N) f32."""
    if interpret is None:
        interpret = not compat.on_tpu()
    n = codes.shape[0]
    tile = min(tile, _round_up(n, 128))
    padded = _pad_codes(codes, tile)
    out = _k.pq_scores_call(padded, s, tile=tile, interpret=interpret)
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def pq_topk(codes: jax.Array, s: jax.Array, k: int, *,
            tile: int = _k.DEFAULT_TILE, interpret: bool | None = None):
    """Fused PQ scoring + hierarchical top-k.  Exact (tile-local winners
    contain all global winners when k <= tile). -> (vals (B,k), ids (B,k))."""
    if interpret is None:
        interpret = not compat.on_tpu()
    n = codes.shape[0]
    tile = min(tile, _round_up(n, 128))
    if k > tile:
        raise ValueError(f"k={k} > tile={tile}")
    padded = _pad_codes(codes, tile)
    tv, ti = _k.pq_topk_fused_call(padded, s, k, n_items=n, tile=tile,
                                   interpret=interpret)
    bq, n_tiles, _ = tv.shape
    cand_v = tv.reshape(bq, n_tiles * k)
    cand_i = ti.reshape(bq, n_tiles * k)
    fv, fi = jax.lax.top_k(cand_v, k)
    return fv, jnp.take_along_axis(cand_i, fi, axis=1)


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
