"""Pallas TPU kernels for PQTopK scoring (Algorithm 1, TPU-native form).

Two kernels:

* ``pq_scores_kernel``     — scores only: for a tile of TN items, expand each
  split's codes to one-hot via iota comparison (in VMEM, never in HBM) and
  accumulate ``S_k @ onehot_k^T`` on the MXU.  HBM traffic: m bytes/item of
  codes (vs 2*d bytes/item for dense scoring).

* ``pq_topk_fused_kernel`` — additionally reduces each tile to its local
  top-K (iterative max-extract in VMEM) so only (B, n_tiles, K) candidates
  ever reach HBM; the final merge over tile winners happens outside.  This
  is the hierarchical top-k of DESIGN.md §3: HBM output drops from
  O(B*N) to O(B*K*N/TN).

Block layout (grid over item tiles):
  codes (N, m) int32/int8  -> block (TN, m)      @ row i
  s     (B, m, b) f32      -> block (B, m, b)    (whole, replicated per step)
  out   (B, N) f32         -> block (B, TN)      @ col i     [pq_scores]
  out_v (B, T, K) f32      -> block (B, 1, K)    @ tile i    [fused]
  out_i (B, T, K) i32      -> block (B, 1, K)    @ tile i    [fused]

VMEM working set per step (TN=2048, b=256, B<=128, f32):
  onehot 2048*256*4 = 2 MiB, acc B*TN*4 <= 1 MiB, S m*b*B*4 <= 1 MiB.
MXU shapes: (B, b) @ (b, TN) — b=256 and TN multiples of 128 line up with
the 128x128 systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.scoring import tree_sum

DEFAULT_TILE = 2048
NEG_INF = float("-inf")


def _tile_scores(codes_ref, s_ref):
    """Shared body: one-hot MXU scoring of one item tile. -> (B, TN) f32."""
    codes = codes_ref[...].astype(jnp.int32)          # (TN, m)
    s = s_ref[...].astype(jnp.float32)                # (B, m, b)
    tn, m = codes.shape
    b = s.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, b), 1)
    parts = []
    for k in range(m):                                # m static -> unrolled
        onehot = (codes[:, k][:, None] == iota).astype(jnp.float32)  # (TN, b)
        parts.append(jax.lax.dot_general(
            s[:, k, :], onehot,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ))                                            # (B, TN)
    # Each one-hot matmul is exact in f32 (a single nonzero per row), so the
    # only rounding happens in the cross-split reduction — tree_sum keeps it
    # bit-identical to score_pqtopk / the jnp oracle (see scoring.tree_sum).
    return tree_sum(parts)


def pq_scores_kernel(codes_ref, s_ref, out_ref):
    out_ref[...] = _tile_scores(codes_ref, s_ref)


def pq_topk_fused_kernel(codes_ref, s_ref, out_v_ref, out_i_ref, *,
                         k: int, tile: int, n_items: int):
    i = pl.program_id(0)
    scores = _tile_scores(codes_ref, s_ref)           # (B, TN)
    bq, tn = scores.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, tn), 1)
    # Mask padding beyond the true catalogue size.
    global_col = col + i * tile
    scores = jnp.where(global_col < n_items, scores, NEG_INF)
    # Iterative max-extract: K passes over the VMEM-resident tile.
    vals = jnp.full((bq, k), NEG_INF, jnp.float32)
    idxs = jnp.zeros((bq, k), jnp.int32)
    for j in range(k):                                # k static -> unrolled
        v = scores.max(axis=1)                        # (B,)
        a = scores.argmax(axis=1).astype(jnp.int32)   # (B,)
        vals = vals.at[:, j].set(v)
        idxs = idxs.at[:, j].set(a + i * tile)
        scores = jnp.where(col == a[:, None], NEG_INF, scores)
    out_v_ref[...] = vals[:, None, :]
    out_i_ref[...] = idxs[:, None, :]


def pq_scores_call(codes: jax.Array, s: jax.Array, *, tile: int = DEFAULT_TILE,
                   interpret: bool = False) -> jax.Array:
    """codes (N, m) int, s (B, m, b) f32 -> scores (B, N) f32. N % tile == 0."""
    n, m = codes.shape
    bq, m2, b = s.shape
    assert m == m2, (m, m2)
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        pq_scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
            pl.BlockSpec((bq, m, b), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((bq, n), jnp.float32),
        interpret=interpret,
    )(codes, s)


def pq_topk_fused_call(codes: jax.Array, s: jax.Array, k: int, *,
                       n_items: int, tile: int = DEFAULT_TILE,
                       interpret: bool = False):
    """-> (vals (B, T, K), ids (B, T, K)) per-tile winners; merge outside."""
    n, m = codes.shape
    bq, m2, b = s.shape
    assert m == m2 and n % tile == 0
    n_tiles = n // tile
    kern = functools.partial(pq_topk_fused_kernel, k=k, tile=tile,
                             n_items=n_items)
    return pl.pallas_call(
        kern,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
            pl.BlockSpec((bq, m, b), lambda i: (0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, 1, k), lambda i: (0, i, 0)),
            pl.BlockSpec((bq, 1, k), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bq, n_tiles, k), jnp.float32),
            jax.ShapeDtypeStruct((bq, n_tiles, k), jnp.int32),
        ],
        interpret=interpret,
    )(codes, s)
