"""Pallas TPU kernels for PQTopK scoring (Algorithm 1, TPU-native form).

Two kernels:

* ``pq_scores_kernel``     — scores only: for a tile of TN items, expand each
  split's codes to one-hot via iota comparison (in VMEM, never in HBM) and
  accumulate ``S_k @ onehot_k^T`` on the MXU.  HBM traffic: 1 byte/item/split
  for int8/uint8 codes (4 for int32) vs 2*d bytes/item for dense scoring.

* ``pq_topk_fused_kernel`` — additionally reduces each (batch tile × item
  tile) block to its local top-K so only (B, n_slots, K) candidates ever
  reach HBM; the final merge over tile winners happens outside.  Rebuilt
  for PR 2 around three hardware-level wins:

  1. **Batch tiling** — grid is (tile slot, batch tile), so B is unbounded:
     each step sees a (TB, m, b) slice of S instead of the whole batch.
  2. **Single-pass top-k** — the old K-pass iterative max-extract re-scanned
     the whole VMEM tile K times.  Now a two-phase reduction: one pass
     computes per-block partial top-q over C = k-oversampled blocks
     (``approx_topk``'s block-max structure, made exact by keeping
     q = min(k, TN/C) per block — every global winner is a within-block
     winner under the same value-then-index order), then an in-VMEM rerank
     merges the C*q candidates.  Data is touched once; the rerank works on
     the reduced candidate set.
  3. **Compacted tile indices** — the item-tile axis is indirected through a
     scalar-prefetched index array (``PrefetchScalarGridSpec``), so the
     pruned retrieval route can run the same kernel over only the tiles
     that survive the upper-bound cascade: codes HBM traffic drops from
     O(N*m) to O(N_survive*m).  The exhaustive route passes the identity
     map.  Slots mapping to the sentinel tile (fully past ``n_items``)
     emit -inf candidates and never reach the final top-k.

     The index array may also be **2D** ``(n_batch_tiles, n_slots)`` (the
     per-query grouped cascade, PR 5): each kernel batch tile then walks
     its OWN compacted slot row — slot i of batch tile j scores codes tile
     ``tile_idx[j, i]`` — so a mixed batch whose query groups survive
     disjoint catalogue regions does ``sum_g B_g * S_g`` work instead of
     ``B * |union|``.  The grid flips to (n_batch_tiles, n_slots), slots
     innermost, so each group's S block stays resident in VMEM while its
     slot row streams codes tiles; ``-1`` sentinels keep the same
     early-exit + clamp-to-block-0 contract per row.

Block layout (1D: grid = (n_slots, n_batch_tiles), batch innermost so each
codes tile is fetched once; 2D: grid = (n_batch_tiles, n_slots), slots
innermost so each group's S block is fetched once):
  tile_idx (n_slots,) i32     -> scalar prefetch (SMEM)
           or (n_batch_tiles, n_slots) i32
  codes (N, m) i8/u8/i32      -> block (TN, m)       @ row tile_idx[...]
  s     (B, m, b) f32         -> block (TB, m, b)    @ batch tile j
  out_v (B, n_slots, K) f32   -> block (TB, 1, K)    @ (j, i)
  out_i (B, n_slots, K) i32   -> block (TB, 1, K)    @ (j, i)

VMEM working set per step (TN=2048, b=256, TB=128, f32):
  onehot 2048*256*4 = 2 MiB, scores TB*TN*4 = 1 MiB, S m*b*TB*4 <= 1 MiB.
MXU shapes: (TB, b) @ (b, TN) — b=256 and TN multiples of 128 line up with
the 128x128 systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from repro.core.scoring import tree_sum

DEFAULT_TILE = 2048
DEFAULT_BATCH_TILE = 128
DEFAULT_OVERSAMPLE = 2
NEG_INF = float("-inf")


def _tile_scores(codes_ref, s_ref):
    """Shared body: one-hot MXU scoring of one item tile. -> (TB, TN) f32.

    ``codes_ref`` may be int8/uint8 (b <= 128 / 256) or int32; the widen to
    int32 happens in VMEM, so the 8-bit dtypes cut HBM code traffic 4x.
    """
    codes = codes_ref[...].astype(jnp.int32)          # (TN, m)
    s = s_ref[...].astype(jnp.float32)                # (TB, m, b)
    tn, m = codes.shape
    b = s.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, b), 1)
    parts = []
    for k in range(m):                                # m static -> unrolled
        onehot = (codes[:, k][:, None] == iota).astype(jnp.float32)  # (TN, b)
        parts.append(jax.lax.dot_general(
            s[:, k, :], onehot,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ))                                            # (TB, TN)
    # Each one-hot matmul is exact in f32 (a single nonzero per row), so the
    # only rounding happens in the cross-split reduction — tree_sum keeps it
    # bit-identical to score_pqtopk / the jnp oracle (see scoring.tree_sum).
    return tree_sum(parts)


def pq_scores_kernel(codes_ref, s_ref, out_ref):
    out_ref[...] = _tile_scores(codes_ref, s_ref)


def pick_blocks(tn: int, k: int, oversample: int = DEFAULT_OVERSAMPLE) -> int:
    """Number of reduction blocks C for the two-phase tile top-k.

    k-oversampled (C >= k*oversample) so the per-block depth q = min(k, TN/C)
    stays shallow, capped at 128 (one lane register) and clamped to divide
    TN (TN is always a multiple of 128 after wrapper rounding, so any
    power-of-two C <= 128 divides it; tiny tiles fall back to C = TN).
    """
    c = 1
    while c < max(1, k) * oversample:
        c *= 2
    c = min(c, 128)
    while tn % c:
        c //= 2
    return max(c, 1)


def _tile_topk(scores, k: int, blocks: int):
    """Exact top-k of one VMEM-resident score tile, single data pass.

    Phase 1: per-block partial top-q (q = min(k, W)) over C contiguous
    blocks of width W = TN/C — the only pass over the (TB, TN) data.
    Phase 2: rerank the (TB, C*q) candidates.  Exact: any global top-k
    element ranks above < k items globally, hence above < k items within
    its own block (same value-then-lowest-index order), hence appears among
    its block's top-q.  Candidate order (block-major, rank-minor) preserves
    ascending-column order among equal values, so ties break identically to
    ``lax.top_k`` over the full tile.
    """
    tb, tn = scores.shape
    w = tn // blocks
    q = min(k, w)
    cube = scores.reshape(tb, blocks, w)
    bv, bw = jax.lax.top_k(cube, q)                   # (TB, C, q)
    base = (jnp.arange(blocks, dtype=jnp.int32) * w)[None, :, None]
    cand_v = bv.reshape(tb, blocks * q)
    cand_i = (bw.astype(jnp.int32) + base).reshape(tb, blocks * q)
    v, sel = jax.lax.top_k(cand_v, k)
    return v, jnp.take_along_axis(cand_i, sel, axis=1)


def pq_topk_fused_kernel(idx_ref, codes_ref, s_ref, *rest,
                         k: int, tile: int, n_items: int, blocks: int,
                         has_live: bool = False):
    if has_live:
        # Tombstone route (mutable catalogues): a (1, TN) int8 live row
        # rides along each codes tile under the SAME clamped index map, so
        # delisted items are masked to -inf inside the tile top-k — before
        # they can crowd a live winner out of the per-tile candidate set.
        live_ref, out_v_ref, out_i_ref = rest
    else:
        out_v_ref, out_i_ref = rest
    if len(idx_ref.shape) == 2:
        # Grouped route: grid (n_batch_tiles, n_slots) — batch tile j's
        # slot i reads its own row of the 2D (group, slot) table.
        tile_id = idx_ref[pl.program_id(0), pl.program_id(1)]
    else:
        tile_id = idx_ref[pl.program_id(0)]

    # Sentinel slots (tile_id == -1): the in-graph pruned route's slot-
    # buffer padding.  Early-exit — no scoring, no top-k; and because the
    # sentinels sit contiguously at the buffer tail and their BlockSpec
    # index map pins them all to codes block 0 (see the clamp in
    # pq_topk_fused_call), the codes DMA is issued at most once for the
    # whole sentinel run.  The grid stays static; skipped slots cost ~no
    # DMA or compute.
    @pl.when(tile_id < 0)
    def _sentinel():
        out_v_ref[...] = jnp.full(out_v_ref.shape, NEG_INF, jnp.float32)
        out_i_ref[...] = jnp.full(out_i_ref.shape, n_items, jnp.int32)

    @pl.when(tile_id >= 0)
    def _score():
        scores = _tile_scores(codes_ref, s_ref)       # (TB, TN)
        tb, tn = scores.shape
        col = jax.lax.broadcasted_iota(jnp.int32, (tb, tn), 1)
        # Mask padding beyond the true catalogue size; legacy past-catalogue
        # sentinel tiles land entirely here.
        global_col = col + tile_id * tile
        ok = global_col < n_items
        if has_live:
            ok = ok & (live_ref[...] != 0)            # (1, TN) broadcast
        scores = jnp.where(ok, scores, NEG_INF)
        vals, cols = _tile_topk(scores, k, blocks)
        out_v_ref[...] = vals[:, None, :]
        out_i_ref[...] = (cols + tile_id * tile)[:, None, :]


def pq_scores_call(codes: jax.Array, s: jax.Array, *, tile: int = DEFAULT_TILE,
                   interpret: bool = False) -> jax.Array:
    """codes (N, m) int, s (B, m, b) f32 -> scores (B, N) f32. N % tile == 0."""
    n, m = codes.shape
    bq, m2, b = s.shape
    assert m == m2, (m, m2)
    assert n % tile == 0, (n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        pq_scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, m), lambda i: (i, 0)),
            pl.BlockSpec((bq, m, b), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((bq, n), jnp.float32),
        interpret=interpret,
    )(codes, s)


def pq_topk_fused_call(codes: jax.Array, s: jax.Array, k: int, *,
                       tile_idx: jax.Array, n_items: int,
                       tile: int = DEFAULT_TILE,
                       batch_tile: int = DEFAULT_BATCH_TILE,
                       oversample: int = DEFAULT_OVERSAMPLE,
                       live: jax.Array = None,
                       interpret: bool = False):
    """-> (vals (B, n_slots, K), ids (B, n_slots, K)) per-slot winners with
    *global* item ids; merge outside.

    ``tile_idx`` (n_slots,) int32 selects which codes tile each grid slot
    scores (identity for the exhaustive route, a compacted survivor list for
    the pruned route).  A 2D ``(B/batch_tile, n_slots)`` table gives every
    batch tile its own slot row (the per-query grouped route); the grid
    then iterates slots innermost so each group's S block is fetched once.
    ``-1`` entries are sentinel slots: their grid step early-exits via
    ``@pl.when`` and the index map clamps their codes block to 0 so the
    pipeline re-uses one already-fetched block instead of issuing per-slot
    DMAs.  ``codes`` rows must cover every indexed tile; ``s``'s batch
    must divide by ``batch_tile``.

    ``live`` (N/tile, tile) int8 is the optional tombstone mask, row t the
    liveness of codes tile t (0 = delisted / padding).  It streams through
    VMEM as a (1, tile) block under the SAME clamped index map as the
    codes tile, so each slot masks ITS tile's dead items to -inf inside
    the tile top-k; the sentinel-clamp contract (``-1`` -> block 0) is
    unchanged.  Extra HBM traffic: 1 byte/item — noise next to the m
    bytes/item of codes.
    """
    n, m = codes.shape
    bq, m2, b = s.shape
    assert m == m2 and n % tile == 0
    assert bq % batch_tile == 0, (bq, batch_tile)
    n_bt = bq // batch_tile
    blocks = pick_blocks(tile, k, oversample)
    if live is not None:
        assert live.shape == (n // tile, tile), (live.shape, n, tile)
    kern = functools.partial(pq_topk_fused_kernel, k=k, tile=tile,
                             n_items=n_items, blocks=blocks,
                             has_live=live is not None)
    # The 1D and 2D layouts share every block shape; they differ only in
    # grid order (1D: batch innermost so each codes tile is fetched once;
    # 2D: slots innermost so each group's S block is fetched once) and in
    # how a grid step finds its codes tile.  `slot`/`bt` map a grid step
    # to its (slot, batch-tile) coordinates under either order.
    if tile_idx.ndim == 2:
        assert tile_idx.shape[0] == n_bt, (tile_idx.shape, n_bt)
        n_slots = tile_idx.shape[1]
        grid = (n_bt, n_slots)
        slot, bt = (lambda j, i: i), (lambda j, i: j)
        codes_block = lambda j, i, idx_ref: jnp.maximum(idx_ref[j, i], 0)
    else:
        n_slots = tile_idx.shape[0]
        grid = (n_slots, n_bt)
        slot, bt = (lambda i, j: i), (lambda i, j: j)
        codes_block = lambda i, j, idx_ref: jnp.maximum(idx_ref[i], 0)
    out_spec = pl.BlockSpec(
        (batch_tile, 1, k), lambda a, c, idx_ref: (bt(a, c), slot(a, c), 0))
    in_specs = [
        pl.BlockSpec((tile, m),
                     lambda a, c, idx_ref: (codes_block(a, c, idx_ref),
                                            0)),
        pl.BlockSpec((batch_tile, m, b),
                     lambda a, c, idx_ref: (bt(a, c), 0, 0)),
    ]
    operands = [codes, s]
    if live is not None:
        # Same clamped tile index map as codes: sentinel slots re-read an
        # already-fetched live row exactly like they re-read codes block 0.
        in_specs.append(pl.BlockSpec(
            (1, tile),
            lambda a, c, idx_ref: (codes_block(a, c, idx_ref), 0)))
        operands.append(live)
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_spec, out_spec],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bq, n_slots, k), jnp.float32),
            jax.ShapeDtypeStruct((bq, n_slots, k), jnp.int32),
        ],
        interpret=interpret,
    )(tile_idx.astype(jnp.int32), *operands)
