"""Pallas TPU kernels for the paper's compute hot-spots.

* ``pqtopk``        — PQTopK scoring (one-hot MXU) + fused block top-k:
                      the paper's Algorithm 1, TPU-native (DESIGN.md §3).
* ``embedding_bag`` — recsys embedding lookup (HBM row-DMA gather-reduce).

Each subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit wrapper, CPU interpret-mode fallback) and ``ref.py``
(pure-jnp oracle; tests assert allclose across shape/dtype sweeps).
"""
from repro.kernels import embedding_bag, pqtopk

__all__ = ["embedding_bag", "pqtopk"]
