"""Serving launcher: paper-mode top-K retrieval over a request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch sasrec-recjpq --reduced \
      --requests 256 --method pqtopk
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.serving.engine import Request, RetrievalEngine


def _ms(v) -> str:
    """Latency field for humans; None (no traffic) is 'n/a', never 0.00."""
    return "n/a" if v is None else f"{v:.2f}ms"


def _serve_replicated(args, params, cfg):
    """Drive the ReplicaRouter fabric: K engine replicas behind one
    submit/pump/drain loop, optionally under a deterministic chaos plan."""
    from repro.serving.router import ReplicaRouter

    fault_plans = None
    if args.chaos:
        from repro.training.fault_tolerance import ReplicaFaultPlan
        # Replica 1 dies for a few dispatches (ejection + re-dispatch +
        # half-open re-admission); replica 2, when present, straggles
        # (hedging + straggler strikes).  Indices are per-replica dispatch
        # counters, so the schedule is reproducible under any interleaving.
        fault_plans = {1: ReplicaFaultPlan(crash_windows=((1, 4),))}
        if args.replicas > 2:
            fault_plans[2] = ReplicaFaultPlan(slow_windows=((0, 3),),
                                              slow_ms=250.0)
    router = ReplicaRouter.for_seqrec(
        params, cfg, n_replicas=args.replicas, k=args.k,
        max_batch=args.max_batch, method=args.method,
        calibrate=not args.no_calibrate,
        fault_plans=fault_plans, hedge=not args.no_hedge)
    rng = np.random.default_rng(0)
    with router:
        router.warmup()
        t0 = time.monotonic()
        for i in range(args.requests):
            hist_len = int(rng.integers(2, cfg.max_seq_len))
            seq = rng.integers(1, cfg.n_items + 1, hist_len)
            router.submit(Request(i, seq, k=args.k))
            router.pump()
        results = router.drain()
        wall = time.monotonic() - t0
        stats = router.stats()
    eng = router.engines[0]
    print(f"served {len(results)} requests in {wall:.2f}s "
          f"({len(results) / wall:.1f} req/s) replicas={args.replicas} "
          f"method={eng.method} chaos={args.chaos}")
    print(f"p50={_ms(stats['p50_ms'])} p99={_ms(stats['p99_ms'])} "
          f"hedges={stats['hedges']} hedge_wins={stats['hedge_wins']} "
          f"dup_suppressed={stats['duplicates_suppressed']} "
          f"redispatched={stats['redispatched']}")
    print(f"degrade_level={stats['degrade_level']} "
          f"degrade_events={stats['degrade_events']} "
          f"recover_events={stats['recover_events']} "
          f"shed_load={stats['shed_load']} "
          f"degraded={dict(stats['degraded_results'])}")
    for rid, rs in stats["replicas"].items():
        print(f"  replica[{rid}] state={rs['state']} "
              f"dispatched={rs['dispatched']} completed={rs['completed']} "
              f"failures={rs['failures']} stragglers={rs['stragglers']} "
              f"ejections={rs['ejections']} "
              f"readmissions={rs['readmissions']} "
              f"n_compiles={rs['n_compiles']}")
    if eng.ladder is not None:
        print(f"ladder={eng.ladder} (shared across replicas)")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sasrec-recjpq")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--method", default=None,
                    choices=["dense", "recjpq", "pqtopk", "pqtopk_onehot",
                             "pqtopk_kernel", "pqtopk_fused",
                             "pqtopk_pruned", "pqtopk_approx"],
                    help="scoring route; default: the arch config's "
                         "serve_method.  pqtopk_pruned = the two-pass "
                         "cascade (upper-bound tile skipping); "
                         "pqtopk_approx = block-max approximate top-k")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed-policy", default=None,
                    choices=["greedy", "adaptive"],
                    help="theta-seeding policy for the pruned cascade "
                         "(overrides the arch config's PQConfig)")
    ap.add_argument("--bound-backend", default=None,
                    choices=["bitmask", "range"],
                    help="pruned-cascade bound backend (overrides the arch "
                         "config's PQConfig): bitmask = uint32 code-"
                         "presence sets; range = int16 min/max code ranges "
                         "(1/8 the metadata, looser bounds)")
    ap.add_argument("--super-factor", type=int, default=None,
                    help="hierarchical super-tile factor for the pruned "
                         "cascade (overrides the arch config's PQConfig): "
                         "groups of this many child tiles get OR-ed/"
                         "hulled pass-0 metadata; 0 disables the level "
                         "(mutually exclusive with --query-grouping)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="disable the build-time slot-budget ladder "
                         "calibration for the pruned cascade (serve the "
                         "full-length compacted buffer instead)")
    ap.add_argument("--query-grouping", action="store_true",
                    help="per-query pruned survival (pqtopk_pruned only): "
                         "seed theta per query, bucket queries by "
                         "survivor-set overlap, and score each group's "
                         "compacted tile list — sum_g B_g*S_g work "
                         "instead of the batch-any B*|union|")
    ap.add_argument("--n-groups", type=int, default=None,
                    help="query-group count for --query-grouping "
                         "(default: the arch config's PQConfig.n_groups; "
                         "1 recovers the batch-any route)")
    ap.add_argument("--mutable", action="store_true",
                    help="serve through a MutableHeadState (pow2-padded "
                         "capacity + tombstone mask): the catalogue "
                         "mutates between batches and the engine "
                         "hot-swaps the head arrays with zero recompiles "
                         "(forces the pqtopk_pruned route)")
    ap.add_argument("--churn-steps", type=int, default=0,
                    help="with --mutable: catalogue mutations "
                         "(update/delete/insert mix) applied + hot-"
                         "swapped between every served batch")
    ap.add_argument("--fail-at", type=int, action="append", default=None,
                    help="batch indices whose dispatch raises a "
                         "SimulatedFailure (repeatable flag); the engine "
                         "retries with exponential backoff and sheds "
                         "after --max-retries instead of crashing")
    ap.add_argument("--fail-repeats", type=int, default=1,
                    help="consecutive failing attempts per --fail-at "
                         "batch (> --max-retries exercises shedding)")
    ap.add_argument("--slow-at", type=int, action="append", default=None,
                    help="batch indices delayed by --slow-ms (synthetic "
                         "stragglers; flagged in stats)")
    ap.add_argument("--slow-ms", type=float, default=50.0)
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=1,
                    help="> 1 serves through the ReplicaRouter fabric: "
                         "pipelined dispatch over health-checked engine "
                         "replicas with hedging and the load-adaptive "
                         "degradation ladder")
    ap.add_argument("--chaos", action="store_true",
                    help="with --replicas: install a deterministic "
                         "ReplicaFaultPlan (a crash window on replica 1, "
                         "a straggle window on replica 2 when present) so "
                         "ejection, re-dispatch, hedging and re-admission "
                         "are all visible in the printed stats")
    ap.add_argument("--no-hedge", action="store_true",
                    help="with --replicas: disable hedged dispatch")
    args = ap.parse_args(argv)

    arch = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    assert arch.family == "seqrec", "serve.py drives the seqrec archs"
    cfg = arch.model
    pq_overrides = {}
    if args.seed_policy is not None:
        pq_overrides["seed_policy"] = args.seed_policy
    if args.bound_backend is not None:
        pq_overrides["bound_backend"] = args.bound_backend
    if args.query_grouping:
        pq_overrides["query_grouping"] = True
    if args.n_groups is not None:
        pq_overrides["n_groups"] = args.n_groups
    if args.super_factor is not None:
        pq_overrides["super_factor"] = args.super_factor
    if pq_overrides:
        if getattr(cfg, "pq", None) is None:
            raise SystemExit(f"arch {args.arch!r} has no PQ head (dense "
                             "item embedding); --seed-policy/--bound-"
                             "backend/--query-grouping only apply to the "
                             "pruned PQ cascade")
        from dataclasses import replace
        cfg = replace(cfg, pq=replace(cfg.pq, **pq_overrides))
    from repro.models import seqrec as m
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)

    faults = None
    if args.fail_at or args.slow_at:
        from repro.training.fault_tolerance import ServeFaultInjector
        faults = ServeFaultInjector(fail_at_batches=tuple(args.fail_at or ()),
                                    fail_repeats=args.fail_repeats,
                                    slow_at_batches=tuple(args.slow_at or ()),
                                    slow_ms=args.slow_ms)

    if args.replicas > 1:
        if args.mutable or args.churn_steps:
            raise SystemExit("--replicas fronts immutable engine replicas; "
                             "--mutable/--churn-steps use the single-engine "
                             "path")
        if args.fail_at or args.slow_at:
            raise SystemExit("--fail-at/--slow-at inject inside ONE engine; "
                             "replica-level chaos is --chaos")
        return _serve_replicated(args, params, cfg)
    if args.chaos:
        raise SystemExit("--chaos needs --replicas > 1")

    mstate = None
    if args.mutable:
        if args.method not in (None, "pqtopk_pruned"):
            raise SystemExit("--mutable serves the tombstone-masked pruned "
                             f"cascade; --method {args.method} has no live-"
                             "mask route")
        if getattr(cfg, "pq", None) is None:
            raise SystemExit(f"arch {args.arch!r} has no PQ head; --mutable "
                             "needs sub-item codes to mutate")
        from repro.core.mutation import MutableHeadState
        mstate = MutableHeadState.build(
            params["item_emb"]["codes"], cfg.pq.b,
            backend=cfg.pq.bound_backend,
            super_factor=cfg.pq.super_factor)
        engine = RetrievalEngine.for_seqrec_mutable(
            params, cfg, mstate, k=args.k, max_batch=args.max_batch,
            calibrate=not args.no_calibrate, faults=faults,
            max_retries=args.max_retries)
    else:
        if args.churn_steps:
            raise SystemExit("--churn-steps requires --mutable")
        engine = RetrievalEngine.for_seqrec(params, cfg, k=args.k,
                                            max_batch=args.max_batch,
                                            method=args.method,
                                            calibrate=not args.no_calibrate,
                                            faults=faults,
                                            max_retries=args.max_retries)
    rng = np.random.default_rng(0)
    # Warm the jit caches (per padding bucket) before the timed stream.
    for b in (1, args.max_batch):
        for i in range(b):
            engine.submit(Request(-1 - i, rng.integers(1, cfg.n_items + 1, 4),
                                  k=args.k))
        engine.drain()
    engine.latencies_ms.clear()
    engine.timeouts = 0
    def churn(step_rng):
        # Update-heavy mix with occasional deletes/inserts, mirroring a
        # live catalogue feed; every mutation only loosens bounds (or is
        # exact, for inserts) so the swapped head stays serve-correct.
        for _ in range(args.churn_steps):
            op = step_rng.random()
            row = step_rng.integers(0, cfg.pq.b, mstate.m)
            if op < 0.2 and (mstate.free or mstate.n_rows < mstate.cap):
                mstate.insert(row)
            elif op < 0.5:
                victim = int(step_rng.integers(1, cfg.n_items + 1))
                if bool(mstate.live[victim]):
                    mstate.delete(victim)
            else:
                victim = int(step_rng.integers(1, cfg.n_items + 1))
                if bool(mstate.live[victim]):
                    mstate.update(victim, row)
        engine.swap_head_state(mstate)

    t0 = time.monotonic()
    results = []
    for i in range(args.requests):
        hist_len = int(rng.integers(2, cfg.max_seq_len))
        seq = rng.integers(1, cfg.n_items + 1, hist_len)
        engine.submit(Request(i, seq, k=args.k))
        if len(engine.batcher.queue) >= args.max_batch:
            results += engine.drain()
            if mstate is not None and args.churn_steps:
                churn(rng)
    results += engine.drain()
    wall = time.monotonic() - t0
    stats = engine.stats()
    print(f"served {len(results)} requests in {wall:.2f}s "
          f"({len(results) / wall:.1f} req/s) method={engine.method}")
    print(f"mRT={_ms(stats['mRT_ms'])} p99={_ms(stats['p99_ms'])} "
          f"timeouts={int(stats['timeouts'])} "
          f"n_compiles={int(stats['n_compiles'])} "
          f"retried={int(stats['retried'])} shed={int(stats['shed'])} "
          f"stragglers={int(stats['stragglers'])}")
    if mstate is not None:
        ms = mstate.stats()
        print(f"catalogue: capacity={int(ms['capacity'])} "
              f"n_live={int(ms['n_live'])} "
              f"n_mutations={int(ms['n_mutations'])} "
              f"stale_tiles={int(ms['stale_tiles'])} "
              f"n_swaps={int(stats['n_swaps'])}")
    if engine.ladder is not None:
        print(f"ladder={engine.ladder} "
              f"rung_hit_fraction={stats['rung_hit_fraction']:.2f} "
              f"rung_counts={stats['rung_counts']}")
    return results


if __name__ == "__main__":
    main()
