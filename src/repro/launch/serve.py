"""Serving launcher: paper-mode top-K retrieval over a request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch sasrec-recjpq --reduced \
      --requests 256 --method pqtopk
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.serving.engine import Request, RetrievalEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sasrec-recjpq")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--method", default=None,
                    choices=["dense", "recjpq", "pqtopk", "pqtopk_onehot",
                             "pqtopk_kernel", "pqtopk_fused",
                             "pqtopk_pruned", "pqtopk_approx"],
                    help="scoring route; default: the arch config's "
                         "serve_method.  pqtopk_pruned = the two-pass "
                         "cascade (upper-bound tile skipping); "
                         "pqtopk_approx = block-max approximate top-k")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed-policy", default=None,
                    choices=["greedy", "adaptive"],
                    help="theta-seeding policy for the pruned cascade "
                         "(overrides the arch config's PQConfig)")
    ap.add_argument("--bound-backend", default=None,
                    choices=["bitmask", "range"],
                    help="pruned-cascade bound backend (overrides the arch "
                         "config's PQConfig): bitmask = uint32 code-"
                         "presence sets; range = int16 min/max code ranges "
                         "(1/8 the metadata, looser bounds)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="disable the build-time slot-budget ladder "
                         "calibration for the pruned cascade (serve the "
                         "full-length compacted buffer instead)")
    ap.add_argument("--query-grouping", action="store_true",
                    help="per-query pruned survival (pqtopk_pruned only): "
                         "seed theta per query, bucket queries by "
                         "survivor-set overlap, and score each group's "
                         "compacted tile list — sum_g B_g*S_g work "
                         "instead of the batch-any B*|union|")
    ap.add_argument("--n-groups", type=int, default=None,
                    help="query-group count for --query-grouping "
                         "(default: the arch config's PQConfig.n_groups; "
                         "1 recovers the batch-any route)")
    args = ap.parse_args(argv)

    arch = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    assert arch.family == "seqrec", "serve.py drives the seqrec archs"
    cfg = arch.model
    pq_overrides = {}
    if args.seed_policy is not None:
        pq_overrides["seed_policy"] = args.seed_policy
    if args.bound_backend is not None:
        pq_overrides["bound_backend"] = args.bound_backend
    if args.query_grouping:
        pq_overrides["query_grouping"] = True
    if args.n_groups is not None:
        pq_overrides["n_groups"] = args.n_groups
    if pq_overrides:
        if getattr(cfg, "pq", None) is None:
            raise SystemExit(f"arch {args.arch!r} has no PQ head (dense "
                             "item embedding); --seed-policy/--bound-"
                             "backend/--query-grouping only apply to the "
                             "pruned PQ cascade")
        from dataclasses import replace
        cfg = replace(cfg, pq=replace(cfg.pq, **pq_overrides))
    from repro.models import seqrec as m
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)

    engine = RetrievalEngine.for_seqrec(params, cfg, k=args.k,
                                        max_batch=args.max_batch,
                                        method=args.method,
                                        calibrate=not args.no_calibrate)
    rng = np.random.default_rng(0)
    # Warm the jit caches (per padding bucket) before the timed stream.
    for b in (1, args.max_batch):
        for i in range(b):
            engine.submit(Request(-1 - i, rng.integers(1, cfg.n_items + 1, 4),
                                  k=args.k))
        engine.drain()
    engine.latencies_ms.clear()
    engine.timeouts = 0
    t0 = time.monotonic()
    for i in range(args.requests):
        hist_len = int(rng.integers(2, cfg.max_seq_len))
        seq = rng.integers(1, cfg.n_items + 1, hist_len)
        engine.submit(Request(i, seq, k=args.k))
    results = engine.drain()
    wall = time.monotonic() - t0
    stats = engine.stats()
    print(f"served {len(results)} requests in {wall:.2f}s "
          f"({len(results) / wall:.1f} req/s) method={engine.method}")
    print(f"mRT={stats['mRT_ms']:.2f}ms p99={stats['p99_ms']:.2f}ms "
          f"timeouts={int(stats['timeouts'])} "
          f"n_compiles={int(stats['n_compiles'])}")
    if engine.ladder is not None:
        print(f"ladder={engine.ladder} "
              f"rung_hit_fraction={stats['rung_hit_fraction']:.2f} "
              f"rung_counts={stats['rung_counts']}")
    return results


if __name__ == "__main__":
    main()
