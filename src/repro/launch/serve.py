"""Serving launcher: paper-mode top-K retrieval over a request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch sasrec-recjpq --reduced \
      --requests 256 --method pqtopk
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.serving.engine import Request, RetrievalEngine


def _ms(v) -> str:
    """Latency field for humans; None (no traffic) is 'n/a', never 0.00."""
    return "n/a" if v is None else f"{v:.2f}ms"


def _churn_ops(shadow, rng, n_steps, b):
    """Draw a batch of valid mutation ops, applying them to ``shadow`` as
    drawn (validity of op i+1 can depend on op i — e.g. no double
    delete).  The caller routes the SAME ops through the durable path;
    ``shadow`` is its local mirror of the committed catalogue."""
    from repro.core.mutation import apply_op

    ops = []
    for _ in range(n_steps):
        r = rng.random()
        row = rng.integers(0, b, shadow.m)
        live = np.where(np.asarray(shadow.live))[0]
        live = live[live > 0]                # row 0 is the padding id
        if (r < 0.2 and (shadow.free or shadow.n_rows < shadow.cap)) \
                or live.size <= 1:
            op = ("insert", row)
        elif r < 0.5:
            op = ("delete", int(rng.choice(live)))
        else:
            op = ("update", int(rng.choice(live)), row)
        apply_op(shadow, op)
        ops.append(op)
    return ops


def _print_durable_stats(stats):
    log_st = stats.get("log")
    print(f"durable: committed_lsn={int(stats['committed_lsn'])} "
          f"mutations={int(stats['mutations_applied'])} "
          f"stale_served={int(stats['stale_served'])} "
          f"catchup_events={int(stats['catchup_events'])} "
          f"staleness_budget={int(stats['staleness_budget'])}")
    if log_st is not None:
        print(f"log: lsn={int(log_st['lsn'])} "
              f"bytes={int(log_st['log_bytes'])} "
              f"fsyncs={int(log_st['n_fsyncs'])} "
              f"snapshots={int(log_st['n_snapshots'])} "
              f"latest_snapshot_lsn={int(log_st['latest_snapshot_lsn'])} "
              f"torn_bytes_dropped={int(log_st['torn_bytes_dropped'])}")


def _serve_replicated_mutable(args, params, cfg):
    """K replicas over ONE durable mutable catalogue: mutation batches
    commit through the WAL between request batches, replicas catch up by
    LSN-fenced replay, and the chaos flags exercise replica crash
    (recover-from-log + gated re-admission) and writer crash (torn
    record; the fabric is rebuilt from ``CatalogueLog.recover``)."""
    from repro.core.mutation import MutableHeadState
    from repro.serving.catalogue_log import CatalogueLog
    from repro.serving.router import ReplicaRouter
    from repro.training.fault_tolerance import SimulatedFailure

    log = None
    if args.log_dir:
        log = CatalogueLog(args.log_dir, snapshot_every=args.snapshot_every)
    if args.recover:
        mstate, lsn0 = log.recover()
        print(f"recovered catalogue from {args.log_dir} at lsn {lsn0} "
              f"(torn bytes dropped: {log.torn_bytes_dropped})")
    else:
        mstate = MutableHeadState.build(
            params["item_emb"]["codes"], cfg.pq.b,
            backend=cfg.pq.bound_backend,
            super_factor=cfg.pq.super_factor)
    shadow = mstate.clone()               # the launcher's committed mirror
    crash_plan = []                       # [(lsn, rid)], ascending
    for spec in args.crash_replica_at or []:
        rid, _, lsn = spec.partition(":")
        crash_plan.append((int(lsn), int(rid)))
    crash_plan.sort()

    def mk_router(state, the_log):
        return ReplicaRouter.for_seqrec_mutable(
            params, cfg, state, n_replicas=args.replicas, k=args.k,
            max_batch=args.max_batch, calibrate=not args.no_calibrate,
            log=the_log, hedge=not args.no_hedge,
            staleness_budget=args.staleness_budget)

    router = mk_router(mstate, log)
    if args.crash_writer_at is not None:
        log.fail_at_lsn = args.crash_writer_at
    rng = np.random.default_rng(0)
    mrng = np.random.default_rng(1)
    results = []
    t0 = time.monotonic()
    i = 0
    with router:
        router.warmup()
        while i < args.requests:
            hist_len = int(rng.integers(2, cfg.max_seq_len))
            seq = rng.integers(1, cfg.n_items + 1, hist_len)
            router.submit(Request(i, seq, k=args.k))
            i += 1
            if args.churn_steps and i % args.max_batch == 0:
                ops = _churn_ops(shadow, mrng, args.churn_steps, cfg.pq.b)
                try:
                    committed = router.apply_mutations(ops)
                except SimulatedFailure as exc:
                    print(f"chaos: {exc}")
                    break
                while crash_plan and committed >= crash_plan[0][0]:
                    _, rid = crash_plan.pop(0)
                    print(f"chaos: crashing replica {rid} at "
                          f"lsn {committed}")
                    router.crash_replica(rid)
                router.pump()
        results += router.drain()
        if log is not None and not getattr(log, "_crashed", False):
            log.sync()                    # clean shutdown: nothing buffered
        stats = router.stats()
    if i < args.requests:
        # Writer died mid-append: stand a NEW fabric up from the durable
        # log (torn-tail truncation + snapshot + replay) and finish the
        # stream — the kill-and-recover path, end to end.
        print("rebuilding the fabric from the durable log ...")
        log = CatalogueLog(args.log_dir,
                           snapshot_every=args.snapshot_every)
        state, lsn = log.recover()
        print(f"recovered at lsn {lsn} "
              f"(torn bytes dropped: {log.torn_bytes_dropped})")
        shadow = state.clone()
        with mk_router(state, log) as router:
            router.warmup()
            while i < args.requests:
                hist_len = int(rng.integers(2, cfg.max_seq_len))
                seq = rng.integers(1, cfg.n_items + 1, hist_len)
                router.submit(Request(i, seq, k=args.k))
                i += 1
                if args.churn_steps and i % args.max_batch == 0:
                    router.apply_mutations(
                        _churn_ops(shadow, mrng, args.churn_steps,
                                   cfg.pq.b))
                    router.pump()
            results += router.drain()
            log.sync()
            stats = router.stats()
    wall = time.monotonic() - t0
    eng = router.engines[0]
    print(f"served {len(results)} requests in {wall:.2f}s "
          f"({len(results) / wall:.1f} req/s) replicas={args.replicas} "
          f"mutable=True durable={args.log_dir is not None}")
    print(f"p50={_ms(stats['p50_ms'])} p99={_ms(stats['p99_ms'])} "
          f"dup_suppressed={stats['duplicates_suppressed']} "
          f"redispatched={stats['redispatched']} "
          f"degraded={dict(stats['degraded_results'])}")
    _print_durable_stats(stats)
    for rid, rs in stats["replicas"].items():
        print(f"  replica[{rid}] state={rs['state']} "
              f"completed={rs['completed']} "
              f"ejections={rs['ejections']} "
              f"readmissions={rs['readmissions']} "
              f"applied_lsn={rs['applied_lsn']} lag={rs['lag']} "
              f"n_compiles={rs['n_compiles']}")
    if eng.ladder is not None:
        print(f"ladder={eng.ladder} (shared across replicas)")
    return results


def _serve_replicated(args, params, cfg):
    """Drive the ReplicaRouter fabric: K engine replicas behind one
    submit/pump/drain loop, optionally under a deterministic chaos plan."""
    from repro.serving.router import ReplicaRouter

    fault_plans = None
    if args.chaos:
        from repro.training.fault_tolerance import ReplicaFaultPlan
        # Replica 1 dies for a few dispatches (ejection + re-dispatch +
        # half-open re-admission); replica 2, when present, straggles
        # (hedging + straggler strikes).  Indices are per-replica dispatch
        # counters, so the schedule is reproducible under any interleaving.
        fault_plans = {1: ReplicaFaultPlan(crash_windows=((1, 4),))}
        if args.replicas > 2:
            fault_plans[2] = ReplicaFaultPlan(slow_windows=((0, 3),),
                                              slow_ms=250.0)
    router = ReplicaRouter.for_seqrec(
        params, cfg, n_replicas=args.replicas, k=args.k,
        max_batch=args.max_batch, method=args.method,
        calibrate=not args.no_calibrate,
        fault_plans=fault_plans, hedge=not args.no_hedge)
    rng = np.random.default_rng(0)
    with router:
        router.warmup()
        t0 = time.monotonic()
        for i in range(args.requests):
            hist_len = int(rng.integers(2, cfg.max_seq_len))
            seq = rng.integers(1, cfg.n_items + 1, hist_len)
            router.submit(Request(i, seq, k=args.k))
            router.pump()
        results = router.drain()
        wall = time.monotonic() - t0
        stats = router.stats()
    eng = router.engines[0]
    print(f"served {len(results)} requests in {wall:.2f}s "
          f"({len(results) / wall:.1f} req/s) replicas={args.replicas} "
          f"method={eng.method} chaos={args.chaos}")
    print(f"p50={_ms(stats['p50_ms'])} p99={_ms(stats['p99_ms'])} "
          f"hedges={stats['hedges']} hedge_wins={stats['hedge_wins']} "
          f"dup_suppressed={stats['duplicates_suppressed']} "
          f"redispatched={stats['redispatched']}")
    print(f"degrade_level={stats['degrade_level']} "
          f"degrade_events={stats['degrade_events']} "
          f"recover_events={stats['recover_events']} "
          f"shed_load={stats['shed_load']} "
          f"degraded={dict(stats['degraded_results'])}")
    for rid, rs in stats["replicas"].items():
        print(f"  replica[{rid}] state={rs['state']} "
              f"dispatched={rs['dispatched']} completed={rs['completed']} "
              f"failures={rs['failures']} stragglers={rs['stragglers']} "
              f"ejections={rs['ejections']} "
              f"readmissions={rs['readmissions']} "
              f"n_compiles={rs['n_compiles']}")
    if eng.ladder is not None:
        print(f"ladder={eng.ladder} (shared across replicas)")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sasrec-recjpq")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--method", default=None,
                    choices=["dense", "recjpq", "pqtopk", "pqtopk_onehot",
                             "pqtopk_kernel", "pqtopk_fused",
                             "pqtopk_pruned", "pqtopk_approx"],
                    help="scoring route; default: the arch config's "
                         "serve_method.  pqtopk_pruned = the two-pass "
                         "cascade (upper-bound tile skipping); "
                         "pqtopk_approx = block-max approximate top-k")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed-policy", default=None,
                    choices=["greedy", "adaptive"],
                    help="theta-seeding policy for the pruned cascade "
                         "(overrides the arch config's PQConfig)")
    ap.add_argument("--bound-backend", default=None,
                    choices=["bitmask", "range"],
                    help="pruned-cascade bound backend (overrides the arch "
                         "config's PQConfig): bitmask = uint32 code-"
                         "presence sets; range = int16 min/max code ranges "
                         "(1/8 the metadata, looser bounds)")
    ap.add_argument("--super-factor", type=int, default=None,
                    help="hierarchical super-tile factor for the pruned "
                         "cascade (overrides the arch config's PQConfig): "
                         "groups of this many child tiles get OR-ed/"
                         "hulled pass-0 metadata; 0 disables the level "
                         "(mutually exclusive with --query-grouping)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="disable the build-time slot-budget ladder "
                         "calibration for the pruned cascade (serve the "
                         "full-length compacted buffer instead)")
    ap.add_argument("--query-grouping", action="store_true",
                    help="per-query pruned survival (pqtopk_pruned only): "
                         "seed theta per query, bucket queries by "
                         "survivor-set overlap, and score each group's "
                         "compacted tile list — sum_g B_g*S_g work "
                         "instead of the batch-any B*|union|")
    ap.add_argument("--n-groups", type=int, default=None,
                    help="query-group count for --query-grouping "
                         "(default: the arch config's PQConfig.n_groups; "
                         "1 recovers the batch-any route)")
    ap.add_argument("--mutable", action="store_true",
                    help="serve through a MutableHeadState (pow2-padded "
                         "capacity + tombstone mask): the catalogue "
                         "mutates between batches and the engine "
                         "hot-swaps the head arrays with zero recompiles "
                         "(forces the pqtopk_pruned route)")
    ap.add_argument("--churn-steps", type=int, default=0,
                    help="with --mutable: catalogue mutations "
                         "(update/delete/insert mix) applied + hot-"
                         "swapped between every served batch")
    ap.add_argument("--fail-at", type=int, action="append", default=None,
                    help="batch indices whose dispatch raises a "
                         "SimulatedFailure (repeatable flag); the engine "
                         "retries with exponential backoff and sheds "
                         "after --max-retries instead of crashing")
    ap.add_argument("--fail-repeats", type=int, default=1,
                    help="consecutive failing attempts per --fail-at "
                         "batch (> --max-retries exercises shedding)")
    ap.add_argument("--slow-at", type=int, action="append", default=None,
                    help="batch indices delayed by --slow-ms (synthetic "
                         "stragglers; flagged in stats)")
    ap.add_argument("--slow-ms", type=float, default=50.0)
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--replicas", type=int, default=1,
                    help="> 1 serves through the ReplicaRouter fabric: "
                         "pipelined dispatch over health-checked engine "
                         "replicas with hedging and the load-adaptive "
                         "degradation ladder")
    ap.add_argument("--chaos", action="store_true",
                    help="with --replicas: install a deterministic "
                         "ReplicaFaultPlan (a crash window on replica 1, "
                         "a straggle window on replica 2 when present) so "
                         "ejection, re-dispatch, hedging and re-admission "
                         "are all visible in the printed stats")
    ap.add_argument("--no-hedge", action="store_true",
                    help="with --replicas: disable hedged dispatch")
    ap.add_argument("--log-dir", default=None,
                    help="with --mutable: durable catalogue state — every "
                         "mutation commits to a checksummed WAL in this "
                         "directory (LSN-keyed snapshots alongside) before "
                         "any engine applies it")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="with --log-dir: cut an LSN-keyed snapshot every "
                         "N committed mutations (0 = only the genesis "
                         "snapshot; recovery then replays the whole log)")
    ap.add_argument("--recover", action="store_true",
                    help="with --log-dir: recover the catalogue from the "
                         "newest valid snapshot + log-tail replay instead "
                         "of building it fresh (the post-crash restart "
                         "path; torn log tails are truncated)")
    ap.add_argument("--staleness-budget", type=int, default=0,
                    help="with --mutable --replicas: max LSNs a replica "
                         "may lag the committed catalogue before its "
                         "results are tagged stale_catalogue and it is "
                         "deprioritised (and re-admission is gated)")
    ap.add_argument("--crash-writer-at", type=int, default=None,
                    metavar="LSN",
                    help="chaos, with --log-dir: the append of this LSN "
                         "writes a torn half-record and dies; the "
                         "launcher then rebuilds the fabric from "
                         "CatalogueLog.recover() and finishes the stream")
    ap.add_argument("--crash-replica-at", action="append", default=None,
                    metavar="RID:LSN",
                    help="chaos, with --mutable --replicas --log-dir: "
                         "crash replica RID (drop its in-memory "
                         "catalogue) once the committed LSN reaches LSN; "
                         "it must recover from the log before the health "
                         "FSM re-admits it (repeatable)")
    args = ap.parse_args(argv)

    arch = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    assert arch.family == "seqrec", "serve.py drives the seqrec archs"
    cfg = arch.model
    pq_overrides = {}
    if args.seed_policy is not None:
        pq_overrides["seed_policy"] = args.seed_policy
    if args.bound_backend is not None:
        pq_overrides["bound_backend"] = args.bound_backend
    if args.query_grouping:
        pq_overrides["query_grouping"] = True
    if args.n_groups is not None:
        pq_overrides["n_groups"] = args.n_groups
    if args.super_factor is not None:
        pq_overrides["super_factor"] = args.super_factor
    if pq_overrides:
        if getattr(cfg, "pq", None) is None:
            raise SystemExit(f"arch {args.arch!r} has no PQ head (dense "
                             "item embedding); --seed-policy/--bound-"
                             "backend/--query-grouping only apply to the "
                             "pruned PQ cascade")
        from dataclasses import replace
        cfg = replace(cfg, pq=replace(cfg.pq, **pq_overrides))
    from repro.models import seqrec as m
    params = m.init_seqrec(jax.random.PRNGKey(0), cfg)

    faults = None
    if args.fail_at or args.slow_at:
        from repro.training.fault_tolerance import ServeFaultInjector
        faults = ServeFaultInjector(fail_at_batches=tuple(args.fail_at or ()),
                                    fail_repeats=args.fail_repeats,
                                    slow_at_batches=tuple(args.slow_at or ()),
                                    slow_ms=args.slow_ms)

    if args.log_dir and not args.mutable:
        raise SystemExit("--log-dir logs catalogue mutations; it needs "
                         "--mutable")
    if args.recover and not args.log_dir:
        raise SystemExit("--recover replays a durable log; it needs "
                         "--log-dir")
    if args.snapshot_every and not args.log_dir:
        raise SystemExit("--snapshot-every needs --log-dir")
    if args.crash_writer_at is not None and not args.log_dir:
        raise SystemExit("--crash-writer-at tears a WAL record; it needs "
                         "--log-dir")
    if args.crash_replica_at and not (args.mutable and args.replicas > 1
                                      and args.log_dir):
        raise SystemExit("--crash-replica-at needs --mutable, --replicas "
                         "> 1 and --log-dir (recovery replays the log)")

    if args.replicas > 1:
        if args.fail_at or args.slow_at:
            raise SystemExit("--fail-at/--slow-at inject inside ONE engine; "
                             "replica-level chaos is --chaos")
        if args.mutable or args.churn_steps:
            if args.chaos:
                raise SystemExit("--chaos drives the immutable fabric; "
                                 "durable chaos is --crash-replica-at / "
                                 "--crash-writer-at")
            if args.method not in (None, "pqtopk_pruned"):
                raise SystemExit("--mutable serves the tombstone-masked "
                                 f"pruned cascade; --method {args.method} "
                                 "has no live-mask route")
            return _serve_replicated_mutable(args, params, cfg)
        return _serve_replicated(args, params, cfg)
    if args.chaos:
        raise SystemExit("--chaos needs --replicas > 1")

    mstate = None
    log = None
    if args.mutable:
        if args.method not in (None, "pqtopk_pruned"):
            raise SystemExit("--mutable serves the tombstone-masked pruned "
                             f"cascade; --method {args.method} has no live-"
                             "mask route")
        if getattr(cfg, "pq", None) is None:
            raise SystemExit(f"arch {args.arch!r} has no PQ head; --mutable "
                             "needs sub-item codes to mutate")
        from repro.core.mutation import MutableHeadState
        if args.log_dir:
            from repro.serving.catalogue_log import CatalogueLog
            log = CatalogueLog(args.log_dir,
                               snapshot_every=args.snapshot_every)
            if args.crash_writer_at is not None:
                log.fail_at_lsn = args.crash_writer_at
        if args.recover:
            mstate, lsn0 = log.recover()
            print(f"recovered catalogue from {args.log_dir} at lsn {lsn0} "
                  f"(torn bytes dropped: {log.torn_bytes_dropped})")
        else:
            mstate = MutableHeadState.build(
                params["item_emb"]["codes"], cfg.pq.b,
                backend=cfg.pq.bound_backend,
                super_factor=cfg.pq.super_factor)
        if log is not None and log.latest_snapshot_lsn() is None:
            log.snapshot(mstate)          # genesis: recovery needs a base
        engine = RetrievalEngine.for_seqrec_mutable(
            params, cfg, mstate, k=args.k, max_batch=args.max_batch,
            calibrate=not args.no_calibrate, faults=faults,
            max_retries=args.max_retries)
    else:
        if args.churn_steps:
            raise SystemExit("--churn-steps requires --mutable")
        engine = RetrievalEngine.for_seqrec(params, cfg, k=args.k,
                                            max_batch=args.max_batch,
                                            method=args.method,
                                            calibrate=not args.no_calibrate,
                                            faults=faults,
                                            max_retries=args.max_retries)
    rng = np.random.default_rng(0)
    # Warm the jit caches (per padding bucket) before the timed stream.
    for b in (1, args.max_batch):
        for i in range(b):
            engine.submit(Request(-1 - i, rng.integers(1, cfg.n_items + 1, 4),
                                  k=args.k))
        engine.drain()
    engine.latencies_ms.clear()
    engine.timeouts = 0
    def churn(step_rng):
        # Update-heavy mix with occasional deletes/inserts, mirroring a
        # live catalogue feed; every mutation only loosens bounds (or is
        # exact, for inserts) so the swapped head stays serve-correct.
        # With --log-dir the same ops commit to the WAL (and snapshots
        # cut on the --snapshot-every cadence) before the hot swap.
        ops = _churn_ops(mstate, step_rng, args.churn_steps, cfg.pq.b)
        if log is not None:
            from repro.training.fault_tolerance import SimulatedFailure
            try:
                log.append_many(ops)
                log.maybe_snapshot(mstate)
            except SimulatedFailure as exc:
                # Torn record on disk; keep serving the in-memory state
                # and demonstrate recovery on the next run (--recover).
                print(f"chaos: {exc}")
                args.churn_steps = 0
        engine.swap_head_state(mstate)

    t0 = time.monotonic()
    results = []
    for i in range(args.requests):
        hist_len = int(rng.integers(2, cfg.max_seq_len))
        seq = rng.integers(1, cfg.n_items + 1, hist_len)
        engine.submit(Request(i, seq, k=args.k))
        if len(engine.batcher.queue) >= args.max_batch:
            results += engine.drain()
            if mstate is not None and args.churn_steps:
                churn(rng)
    results += engine.drain()
    wall = time.monotonic() - t0
    stats = engine.stats()
    print(f"served {len(results)} requests in {wall:.2f}s "
          f"({len(results) / wall:.1f} req/s) method={engine.method}")
    print(f"mRT={_ms(stats['mRT_ms'])} p99={_ms(stats['p99_ms'])} "
          f"timeouts={int(stats['timeouts'])} "
          f"n_compiles={int(stats['n_compiles'])} "
          f"retried={int(stats['retried'])} shed={int(stats['shed'])} "
          f"stragglers={int(stats['stragglers'])}")
    if mstate is not None:
        ms = mstate.stats()
        print(f"catalogue: capacity={int(ms['capacity'])} "
              f"n_live={int(ms['n_live'])} "
              f"n_mutations={int(ms['n_mutations'])} "
              f"stale_tiles={int(ms['stale_tiles'])} "
              f"n_swaps={int(stats['n_swaps'])}")
    if log is not None:
        log.close()
        ls = log.stats()
        print(f"log: lsn={int(ls['lsn'])} bytes={int(ls['log_bytes'])} "
              f"fsyncs={int(ls['n_fsyncs'])} "
              f"snapshots={int(ls['n_snapshots'])} "
              f"latest_snapshot_lsn={int(ls['latest_snapshot_lsn'])}")
    if engine.ladder is not None:
        print(f"ladder={engine.ladder} "
              f"rung_hit_fraction={stats['rung_hit_fraction']:.2f} "
              f"rung_counts={stats['rung_counts']}")
    return results


if __name__ == "__main__":
    main()
