"""Training launcher: end-to-end driver with checkpoint/auto-resume and
failure injection (CPU-scale configs; the production mesh path is exercised
by dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch sasrec-recjpq \
      --reduced --steps 200 --batch 64 --ckpt /tmp/ckpt --fail-at 120
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.training import checkpoint as ckpt_lib, fault_tolerance as ft
from repro.training import optimizer as opt_lib, train_loop


def make_data(arch, batch_size: int, seed: int = 0):
    cfg = arch.model
    if arch.family == "seqrec":
        from repro.data.sequences import SeqRecDataset
        ds = SeqRecDataset.synthetic(
            max(batch_size * 4, 256), cfg.n_items, 10, cfg.max_seq_len,
            seed=seed)
        from repro.models import seqrec as m
        return (ds.batches(batch_size, cfg.n_negatives,
                           backbone=cfg.backbone, seed=seed),
                lambda p, b: m.seqrec_loss(p, b, cfg),
                lambda key: m.init_seqrec(key, cfg))
    if arch.family == "recsys":
        from repro.data.recsys_data import ctr_batches
        from repro.models import recsys as m
        return (ctr_batches(cfg, batch_size, seed=seed),
                lambda p, b: m.ctr_loss(p, b, cfg),
                lambda key: m.init_recsys(key, cfg))
    if arch.family == "gnn":
        from repro.data.graph import NeighborSampler, synthetic_graph
        from repro.models import gnn as m
        d_feat = 32
        g = synthetic_graph(2000, 16000, d_feat, cfg.n_classes, seed=seed)
        sampler = NeighborSampler(g)
        rng = np.random.default_rng(seed)

        def gen():
            while True:
                nodes = rng.integers(0, g.n_nodes, batch_size)
                yield sampler.sample_batch(nodes, tuple(cfg.sample_sizes[:2]),
                                           rng)

        return (gen(), lambda p, b: m.gnn_minibatch_loss(p, b, cfg),
                lambda key: m.init_gnn(key, cfg, d_feat))
    if arch.family == "lm":
        from repro.models import transformer as m
        vocab, seq = cfg.vocab, 64
        rng = np.random.default_rng(seed)

        def gen():
            while True:
                tok = rng.integers(0, vocab, (batch_size, seq + 1))
                yield {"tokens": tok[:, :-1].astype(np.int32),
                       "targets": tok[:, 1:].astype(np.int32)}

        return (gen(), lambda p, b: m.lm_loss(p, b, cfg),
                lambda key: m.init_lm(key, cfg))
    raise ValueError(arch.family)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, action="append", default=[])
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    data, loss_fn, init_fn = make_data(arch, args.batch)
    ocfg = opt_lib.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                               total_steps=args.steps,
                               moment_dtype=arch.model.moment_dtype)
    step_fn = jax.jit(train_loop.make_train_step(loss_fn, ocfg),
                      donate_argnums=(0, 1))
    injector = ft.FailureInjector(args.fail_at)
    straggler = ft.StragglerMonitor()
    mgr = ckpt_lib.CheckpointManager(args.ckpt) if args.ckpt else None

    def make_state():
        params = init_fn(jax.random.PRNGKey(0))
        opt_state = train_loop.init_opt_state(params, ocfg)
        start = 0
        if mgr is not None and mgr.latest_step() is not None:
            start = mgr.latest_step()
            restored = mgr.restore(start, {"params": params,
                                           "opt_state": opt_state})
            params, opt_state = restored["params"], restored["opt_state"]
            print(f"resumed from step {start}")
        return {"params": params, "opt_state": opt_state, "step": start}

    def train(state, restarts):
        params, opt_state = state["params"], state["opt_state"]
        for step in range(state["step"], args.steps):
            t0 = time.monotonic()
            injector.check(step)
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            straggler.record(step, time.monotonic() - t0)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f}")
            if mgr is not None and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, {"params": params, "opt_state": opt_state})
        if mgr is not None:
            mgr.save(args.steps, {"params": params, "opt_state": opt_state},
                     block=True)
            mgr.wait()
        print(f"finished {args.steps} steps "
              f"({len(straggler.flagged)} straggler steps flagged)")
        return {"params": params, "opt_state": opt_state}

    return ft.run_with_restarts(make_state, train,
                                max_restarts=args.max_restarts)


if __name__ == "__main__":
    main()
