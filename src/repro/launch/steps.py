"""Step builders for the dry-run matrix: for every (arch × shape × variant)
produce

  step_fn        — the function to jit
  args           — ShapeDtypeStruct stand-ins for every input (no allocation)
  in_shardings   — NamedShardings matching args
  donate         — argnums to donate
  plan           — the activation ShardingPlan to trace under

Shapes follow ``repro.configs.base``; shardings follow DESIGN.md §5.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, get_config
from repro.distributed import sharding as shd
from repro.training import optimizer as opt_lib, train_loop

S = jax.ShapeDtypeStruct


@dataclass
class StepBundle:
    name: str
    step_fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate: Tuple[int, ...]
    plan: shd.ShardingPlan
    meta: Dict[str, Any]


def _fit(mesh: Mesh, sds, spec: P) -> NamedSharding:
    """NamedSharding with non-dividing axes dropped (replicated)."""
    fixed = []
    for dim, ax in enumerate(spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        ok = axes and sds.shape[dim] % size == 0
        fixed.append((axes if len(axes) > 1 else axes[0]) if ok else None)
    fixed += [None] * (len(sds.shape) - len(fixed))
    return NamedSharding(mesh, P(*fixed))


def _tree_shardings(mesh: Mesh, tree, spec_fn) -> Any:
    return jax.tree.map(lambda x: _fit(mesh, x, spec_fn(x)), tree)


def _batch_spec(mesh: Mesh) -> Tuple[str, ...]:
    return shd.batch_axes(mesh)


def _opt_shardings(mesh: Mesh, opt_abs, param_shard):
    """Optimizer moments mirror the parameter shardings (rank-aware: error
    feedback for frozen integer leaves collapses to scalars -> replicate)."""
    def like(tree):
        return jax.tree.map(
            lambda t, s: s if len(s.spec) <= len(t.shape)
            else NamedSharding(mesh, P()), tree, param_shard)
    return {
        "step": NamedSharding(mesh, P()),
        "m": like(opt_abs["m"]),
        "v": like(opt_abs["v"]),
        **({"ef": like(opt_abs["ef"])} if "ef" in opt_abs else {}),
    }


def _opt_cfg(model) -> opt_lib.AdamWConfig:
    return opt_lib.AdamWConfig(lr=1e-4, warmup_steps=100, total_steps=10_000,
                               moment_dtype=model.moment_dtype)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------

def _lm_bundle(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               variant: str) -> StepBundle:
    from repro.models import transformer as T
    cfg = arch.model
    if variant == "pruned_range_head" and cfg.pq_head is not None:
        # Range-bound backend cell: the abstract PQ head carries int16
        # code-range metadata instead of uint32 presence bitmasks.
        from dataclasses import replace as _rep
        cfg = _rep(cfg, pq_head=_rep(cfg.pq_head, bound_backend="range"))
        arch = _rep(arch, model=cfg)
    if variant == "perquery_head" and cfg.pq_head is not None:
        # Per-query grouped cascade cell: decode-time vocab pruning with
        # per-query thetas + query-grouped compaction (PR 5).
        from dataclasses import replace as _rep
        cfg = _rep(cfg, pq_head=_rep(cfg.pq_head, query_grouping=True))
        arch = _rep(arch, model=cfg)
    plan = shd.lm_activation_plan(
        mesh, shard_seq=variant != "noseq",
        tp_internal=variant in ("seqpar_tp", "seqpar_tp_dots"),
        vocab_tp=variant.startswith("vocab_tp"))
    if variant == "seqpar_tp_dots":
        from dataclasses import replace as _rep
        cfg = _rep(cfg, remat=False)   # trade memory for recompute flops
        arch = _rep(arch, model=cfg)
    if variant in ("moe_sort", "moe_sort_vocab_tp") and cfg.moe is not None:
        from dataclasses import replace as _rep
        cfg = _rep(cfg, moe_impl="sort")
        arch = _rep(arch, model=cfg)
        if variant.endswith("vocab_tp"):
            plan = shd.lm_activation_plan(mesh, shard_seq=True,
                                          vocab_tp=True)
    b_axes = _batch_spec(mesh)
    params_abs = T.abstract_lm(cfg)
    p_shard = shd.param_shardings(mesh, params_abs,
                                  shd.lm_param_rules(cfg.scan_layers))

    if shape.kind == "train":
        bsz, seq = shape.dims["global_batch"], shape.dims["seq_len"]
        batch_abs = {"tokens": S((bsz, seq), jnp.int32),
                     "targets": S((bsz, seq), jnp.int32)}
        batch_shard = _tree_shardings(mesh, batch_abs,
                                      lambda x: P(b_axes, None))
        ocfg = _opt_cfg(cfg)
        powersgd = variant == "powersgd" and "pod" in mesh.axis_names
        if powersgd:
            # Inside the manual-pod shard_map the 'pod' axis is stripped
            # from every activation constraint.
            plan = shd.strip_axis(plan, "pod")
            # XLA SPMD-partitioner workaround: sharded embedding gathers
            # inside a partial-manual region hit a partitioner CHECK
            # (spmd_partitioner_util.cc:504) — replicate the (un)embedding.
            repl = NamedSharding(mesh, P())
            for key in ("embed", "head"):
                if key in p_shard:
                    p_shard[key] = jax.tree.map(lambda _: repl, p_shard[key])
        opt_abs = train_loop.init_opt_state(params_abs, ocfg, abstract=True,
                                            powersgd=powersgd)
        o_shard = _opt_shardings(mesh, opt_abs, p_shard)
        step = train_loop.make_train_step(
            lambda p, b: T.lm_loss(p, b, cfg), ocfg,
            powersgd_axis="pod" if powersgd else None, mesh=mesh,
            grad_shardings=p_shard if variant.endswith("gradrs") else None)
        return StepBundle(
            name=f"{arch.arch_id}__{shape.name}",
            step_fn=step,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(p_shard, o_shard, batch_shard),
            donate=(0, 1), plan=plan,
            meta={"kind": "train", "tokens": bsz * seq},
        )

    if shape.kind == "prefill":
        bsz, seq = shape.dims["global_batch"], shape.dims["seq_len"]
        tok_abs = S((bsz, seq), jnp.int32)
        return StepBundle(
            name=f"{arch.arch_id}__{shape.name}",
            step_fn=lambda p, t: T.lm_prefill(p, t, cfg),
            args=(params_abs, tok_abs),
            in_shardings=(p_shard, _fit(mesh, tok_abs, P(b_axes, None))),
            donate=(), plan=plan,
            meta={"kind": "prefill", "tokens": bsz * seq},
        )

    # decode (decode_32k / long_500k): one token, KV cache of seq_len.
    bsz, seq = shape.dims["global_batch"], shape.dims["seq_len"]
    caches_abs = T.init_caches(cfg, bsz, seq, abstract=True)
    # Batch over data when it divides; sequence over model (+data for B=1).
    if bsz >= max(mesh.shape.get("data", 1), 1):
        cache_spec = P(b_axes, "model", None, None)
    else:
        cache_spec = P(None, ("data", "model"), None, None)
    if isinstance(caches_abs, dict):  # stacked (L, B, S, H, D)
        c_shard = jax.tree.map(
            lambda x: _fit(mesh, x, P(None, *cache_spec)), caches_abs)
    else:
        c_shard = jax.tree.map(lambda x: _fit(mesh, x, cache_spec),
                               caches_abs)
    tok_abs = S((bsz,), jnp.int32)
    pos_abs = S((), jnp.int32)
    # "pruned_head" is decode-loop viable since the single-dispatch
    # cascade: the bit-packed tile metadata rides in params["pq_head"]
    # ["pruned"] (built once at init), so each decode step reads cached
    # bounds metadata and compacts survivors in-graph — no per-step
    # rebuild, no host sync.
    head = {"pqtopk_head": "pqtopk", "dense_head": "dense",
            "onehot_head": "pqtopk_onehot",
            "fused_head": "pqtopk_fused",
            "pruned_head": "pqtopk_pruned",
            # Same cascade, range-bound metadata (cfg.pq_head replaced
            # above) — proves the backend is decode-loop viable too.
            "pruned_range_head": "pqtopk_pruned",
            # Per-query grouped cascade (cfg.pq_head replaced above).
            "perquery_head": "pqtopk_pruned",
            "approx_head": "pqtopk_approx"}.get(variant, "pqtopk")

    def decode(p, tok, pos, caches):
        return T.lm_decode_step(p, tok, pos, caches, cfg, k=64,
                                head_method=head)

    return StepBundle(
        name=f"{arch.arch_id}__{shape.name}",
        step_fn=decode,
        args=(params_abs, tok_abs, pos_abs, caches_abs),
        in_shardings=(p_shard, _fit(mesh, tok_abs, P(b_axes)),
                      NamedSharding(mesh, P()), c_shard),
        donate=(3,), plan=plan,
        meta={"kind": "decode", "tokens": bsz, "kv_len": seq, "head": head},
    )


# ---------------------------------------------------------------------------
# SeqRec family (the paper's models)
# ---------------------------------------------------------------------------

def _seqrec_bundle(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                   variant: str) -> StepBundle:
    from repro.models import seqrec as SR
    cfg = arch.model
    if variant in ("pruned_range_head", "sharded_pruned_range"):
        # Range-bound backend cells: abstract params carry int16 code
        # ranges instead of uint32 presence bitmasks.
        from dataclasses import replace as _rep
        cfg = _rep(cfg, pq=_rep(cfg.pq, bound_backend="range"))
        arch = _rep(arch, model=cfg)
    if variant in ("perquery_head", "sharded_perquery"):
        # Per-query grouped cascade cells (flat and one-shard_map sharded).
        from dataclasses import replace as _rep
        cfg = _rep(cfg, pq=_rep(cfg.pq, query_grouping=True))
        arch = _rep(arch, model=cfg)
    if variant in ("hier_head", "sharded_hier"):
        # Hierarchical super-tile cells (ISSUE 9): the abstract params
        # carry the super metadata arrays, and the serve step traces the
        # two-stage (pass-0 super, pass-1 child) single-dispatch cascade.
        from dataclasses import replace as _rep
        cfg = _rep(cfg, pq=_rep(cfg.pq, super_factor=4))
        arch = _rep(arch, model=cfg)
    plan = shd.lm_activation_plan(mesh, shard_seq=False)
    b_axes = _batch_spec(mesh)
    params_abs = SR.abstract_seqrec(cfg)
    if variant == "mutable_head":
        # Streaming-catalogue serve cell: the pruned head rides with the
        # tombstone mask exactly as RetrievalEngine.swap_head_state
        # threads it (core/mutation.py head_arrays) — `live` is head
        # DATA, not a recompile axis, so this cell traces the same
        # single-dispatch cascade with dead rows masked in-kernel.
        emb_abs = params_abs["item_emb"]
        params_abs = {**params_abs,
                      "item_emb": {**emb_abs,
                                   "live": S((emb_abs["codes"].shape[0],),
                                             jnp.bool_)}}
    p_shard = shd.param_shardings(mesh, params_abs, shd.seqrec_param_rules())

    if shape.kind == "train":
        bsz, seq = shape.dims["global_batch"], shape.dims["seq_len"]
        batch_abs = {
            "input_seq": S((bsz, seq), jnp.int32),
            "targets": S((bsz, seq), jnp.int32),
            "negatives": S((bsz, seq, cfg.n_negatives), jnp.int32),
        }
        batch_shard = _tree_shardings(mesh, batch_abs,
                                      lambda x: P(b_axes, *([None] * (len(x.shape) - 1))))
        ocfg = _opt_cfg(cfg)
        opt_abs = train_loop.init_opt_state(params_abs, ocfg, abstract=True)
        o_shard = _opt_shardings(mesh, opt_abs, p_shard)
        step = train_loop.make_train_step(
            lambda p, b: SR.seqrec_loss(p, b, cfg), ocfg)
        return StepBundle(
            name=f"{arch.arch_id}__{shape.name}", step_fn=step,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(p_shard, o_shard, batch_shard),
            donate=(0, 1), plan=plan,
            meta={"kind": "train", "tokens": bsz * seq},
        )

    # serve_users: retrieval over the full catalogue.
    bsz, seq = shape.dims["global_batch"], shape.dims["seq_len"]
    method = {"dense_head": "dense", "recjpq_head": "recjpq",
              "onehot_head": "pqtopk_onehot",
              "fused_head": "pqtopk_fused",
              # Single-dispatch pruned cascade: bounds, theta, in-graph
              # cumsum-scatter compaction and compacted fused scoring all
              # trace into the one jittable serve step.
              "pruned_head": "pqtopk_pruned",
              # Range-bound backend (cfg.pq replaced above): same
              # single-dispatch cascade off int16 min/max code ranges.
              "pruned_range_head": "pqtopk_pruned",
              # Tombstone-masked cascade over a mutating catalogue
              # (params carry item_emb/live; see core/mutation.py).
              "mutable_head": "pqtopk_pruned",
              "approx_head": "pqtopk_approx",
              "sharded_head": "pqtopk",
              "sharded_head_bm": "pqtopk",
              "sharded_onehot": "pqtopk_onehot",
              "sharded_fused": "pqtopk_fused",
              # Per-query grouped cascade (cfg.pq replaced above): flat
              # and one-shard_map sharded (per-query pmax'd thetas).
              "perquery_head": "pqtopk_pruned",
              "sharded_perquery": "pqtopk_pruned",
              # One-shard_map pruned cascade with pmax-shared theta; the
              # dry-run's abstract state is shards=1, so this cell traces
              # the in-graph shard-aligned rebuild fallback.
              "sharded_pruned": "pqtopk_pruned",
              "sharded_pruned_range": "pqtopk_pruned",
              # Hierarchical super-tile cascade (cfg.pq replaced above):
              # pass-0 super pruning + two-stage compaction, flat and
              # one-shard_map sharded with the shard-skip cond.
              "hier_head": "pqtopk_pruned",
              "sharded_hier": "pqtopk_pruned"}.get(variant, "pqtopk")
    sharded = variant.startswith("sharded_")
    serve_b_axes = b_axes
    if variant.endswith("_bm"):
        # Backbone batch over BOTH axes: 256-way instead of data-only.
        serve_b_axes = tuple(mesh.axis_names)
        plan = shd.ShardingPlan(mesh, {
            "seq_hidden": P(serve_b_axes, None, None),
            "phi": P(serve_b_axes, None),
        })

    seq_abs = S((bsz, seq), jnp.int32)

    def serve(p, seqs):
        return SR.serve_topk(p, seqs, cfg, k=10, method=method,
                             sharded_mesh=mesh if sharded else None)

    return StepBundle(
        name=f"{arch.arch_id}__{shape.name}", step_fn=serve,
        args=(params_abs, seq_abs),
        in_shardings=(p_shard, _fit(mesh, seq_abs, P(serve_b_axes, None))),
        donate=(), plan=plan,
        meta={"kind": "retrieval", "users": bsz,
              "n_items": cfg.n_items, "method": method},
    )


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------

def _recsys_batch_abs(cfg, bsz: int) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    if cfg.kind in ("dcn", "fm"):
        if cfg.n_dense:
            out["dense"] = S((bsz, cfg.n_dense), jnp.float32)
        out["sparse"] = S((bsz, cfg.n_sparse), jnp.int32)
    else:
        out["seq"] = S((bsz, cfg.seq_len, 2), jnp.int32)
        out["target"] = S((bsz, 2), jnp.int32)
    return out


def _recsys_bundle(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                   variant: str) -> StepBundle:
    from repro.models import recsys as R
    cfg = arch.model
    plan = shd.recsys_activation_plan(mesh)
    b_axes = _batch_spec(mesh)
    params_abs = R.abstract_recsys(cfg)
    p_shard = shd.param_shardings(mesh, params_abs, shd.recsys_param_rules())
    bsz = shape.dims["global_batch"]

    if shape.kind == "train":
        batch_abs = dict(_recsys_batch_abs(cfg, bsz),
                         label=S((bsz,), jnp.float32))
        batch_shard = _tree_shardings(
            mesh, batch_abs,
            lambda x: P(b_axes, *([None] * (len(x.shape) - 1))))
        ocfg = _opt_cfg(cfg)
        opt_abs = train_loop.init_opt_state(params_abs, ocfg, abstract=True)
        o_shard = _opt_shardings(mesh, opt_abs, p_shard)
        step = train_loop.make_train_step(
            lambda p, b: R.ctr_loss(p, b, cfg), ocfg)
        return StepBundle(
            name=f"{arch.arch_id}__{shape.name}", step_fn=step,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(p_shard, o_shard, batch_shard),
            donate=(0, 1), plan=plan,
            meta={"kind": "train", "examples": bsz},
        )

    if shape.kind == "serve":
        batch_abs = _recsys_batch_abs(cfg, bsz)
        batch_shard = _tree_shardings(
            mesh, batch_abs,
            lambda x: P(b_axes, *([None] * (len(x.shape) - 1))))

        def serve(p, b):
            return R.ctr_logits(p, b, cfg)

        return StepBundle(
            name=f"{arch.arch_id}__{shape.name}", step_fn=serve,
            args=(params_abs, batch_abs),
            in_shardings=(p_shard, batch_shard),
            donate=(), plan=plan,
            meta={"kind": "serve", "examples": bsz},
        )

    # retrieval_cand: PQTopK over the candidate catalogue.
    n_cand = shape.dims["n_candidates"]
    method = {"dense_head": "dense", "recjpq_head": "recjpq",
              "onehot_head": "pqtopk_onehot",
              "fused_head": "pqtopk_fused"}.get(variant, "pqtopk")
    batch_abs = _recsys_batch_abs(cfg, bsz)
    batch_shard = _tree_shardings(
        mesh, batch_abs,
        lambda x: P(b_axes, *([None] * (len(x.shape) - 1))))

    def retrieve(p, b):
        return R.retrieve_topk(p, b, cfg, k=10, method=method)

    return StepBundle(
        name=f"{arch.arch_id}__{shape.name}", step_fn=retrieve,
        args=(params_abs, batch_abs),
        in_shardings=(p_shard, batch_shard),
        donate=(), plan=plan,
        meta={"kind": "retrieval", "n_candidates": n_cand, "method": method},
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------

def _gnn_bundle(arch: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                variant: str) -> StepBundle:
    from repro.models import gnn as G
    cfg = arch.model
    plan = shd.gnn_activation_plan(mesh)
    all_axes = tuple(mesh.axis_names)
    d = shape.dims
    params_abs = G.abstract_gnn(cfg, d["d_feat"])
    p_shard = shd.param_shardings(mesh, params_abs, shd.gnn_param_rules())
    ocfg = _opt_cfg(cfg)
    opt_abs = train_loop.init_opt_state(params_abs, ocfg, abstract=True)
    o_shard = _opt_shardings(mesh, opt_abs, p_shard)

    if shape.name == "minibatch_lg":
        f1, f2 = d["fanout"]
        bn = d["batch_nodes"]
        batch_abs = {
            "feats_b": S((bn, d["d_feat"]), jnp.float32),
            "feats_n1": S((bn, f1, d["d_feat"]), jnp.float32),
            "feats_n2": S((bn, f1, f2, d["d_feat"]), jnp.float32),
            "labels": S((bn,), jnp.int32),
        }
        loss = G.gnn_minibatch_loss
        batch_shard = _tree_shardings(
            mesh, batch_abs,
            lambda x: P(_batch_spec(mesh), *([None] * (len(x.shape) - 1))))
    elif shape.name == "molecule":
        gbatch, n, e = d["graph_batch"], d["n_nodes"], d["n_edges"]
        batch_abs = {
            "feats": S((gbatch * n, d["d_feat"]), jnp.float32),
            "edges": S((gbatch * e, 2), jnp.int32),
            "graph_ids": S((gbatch * n,), jnp.int32),
            "labels": S((gbatch,), jnp.int32),
        }
        loss = G.gnn_graph_batch_loss
        batch_shard = {
            "feats": _fit(mesh, batch_abs["feats"], P(all_axes, None)),
            "edges": _fit(mesh, batch_abs["edges"], P(all_axes, None)),
            "graph_ids": _fit(mesh, batch_abs["graph_ids"], P(all_axes)),
            "labels": _fit(mesh, batch_abs["labels"], P(all_axes)),
        }
    else:  # full_graph_sm / ogb_products: full-batch edge-list training
        batch_abs = {
            "feats": S((d["n_nodes"], d["d_feat"]), jnp.float32),
            "edges": S((d["n_edges"], 2), jnp.int32),
            "labels": S((d["n_nodes"],), jnp.int32),
            "label_mask": S((d["n_nodes"],), jnp.float32),
        }
        loss = G.gnn_loss
        batch_shard = {
            "feats": _fit(mesh, batch_abs["feats"], P()),       # replicated
            "edges": _fit(mesh, batch_abs["edges"], P(all_axes, None)),
            "labels": _fit(mesh, batch_abs["labels"], P()),
            "label_mask": _fit(mesh, batch_abs["label_mask"], P()),
        }

    n_classes = d.get("n_classes", cfg.n_classes)
    if n_classes != cfg.n_classes:
        from dataclasses import replace
        cfg = replace(cfg, n_classes=n_classes)
        params_abs = G.abstract_gnn(cfg, d["d_feat"])
        p_shard = shd.param_shardings(mesh, params_abs, shd.gnn_param_rules())
        opt_abs = train_loop.init_opt_state(params_abs, ocfg, abstract=True)
        o_shard = _opt_shardings(mesh, opt_abs, p_shard)

    step = train_loop.make_train_step(
        functools.partial(lambda p, b, c: loss(p, b, c), c=cfg), ocfg)
    return StepBundle(
        name=f"{arch.arch_id}__{shape.name}", step_fn=step,
        args=(params_abs, opt_abs, batch_abs),
        in_shardings=(p_shard, o_shard, batch_shard),
        donate=(0, 1), plan=plan,
        meta={"kind": "train", "shape": shape.name},
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_BUILDERS = {
    "lm": _lm_bundle,
    "seqrec": _seqrec_bundle,
    "recsys": _recsys_bundle,
    "gnn": _gnn_bundle,
}


def build_step(arch_id: str, shape_name: str, mesh: Mesh,
               variant: str = "baseline",
               arch_override: Optional[ArchConfig] = None) -> StepBundle:
    arch = arch_override if arch_override is not None else get_config(arch_id)
    shape = arch.shape(shape_name)
    if shape.skip_reason:
        raise ValueError(
            f"{arch_id}/{shape_name} is a documented skip: {shape.skip_reason}")
    bundle = _BUILDERS[arch.family](arch, shape, mesh, variant)
    bundle.meta["variant"] = variant
    bundle.meta["family"] = arch.family
    return bundle
