"""Production mesh factory.

Single pod: 256 TPU v5e chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the ``pod``
axis crosses DCI and carries only data-parallel (optionally PowerSGD-
compressed) gradient traffic.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small mesh over however many (fake) devices exist — used by tests."""
    n = len(jax.devices())
    if multi_pod:
        pod = 2 if n % 2 == 0 and n >= 2 else 1
        rest = n // pod
        data = _largest_factor(rest)
        return jax.make_mesh((pod, data, rest // data),
                             ("pod", "data", "model"))
    data = _largest_factor(n)
    return jax.make_mesh((data, n // data), ("data", "model"))


def _largest_factor(n: int) -> int:
    f = int(n ** 0.5)
    while n % f:
        f -= 1
    return max(f, 1)
