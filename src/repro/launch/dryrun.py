import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs (no allocation), print memory/cost analyses, parse
the collective schedule out of the partitioned HLO, and write one JSON
artifact per cell for the roofline report (EXPERIMENTS.md §Dry-run/§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                    # full matrix
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b --shape train_4k --mesh multi
  ... --variant dense_head|pqtopk_head|powersgd --save-hlo
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs.base import get_config, list_archs
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

# TPU v5e constants (roofline denominators).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~4 links/chip on a 2D torus)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(.+?)\s+(" + "|".join(k.replace("-", "\\-") for k in _COLL_KINDS)
    + r")(-start|-done)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective in the partitioned
    module (``-done`` ops are skipped so async pairs aren't double-counted)."""
    out = {}
    for m in _LINE_RE.finditer(hlo_text):
        type_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        b = _shape_bytes(type_str)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def _normalize_cost(cost) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on recent JAX but a
    one-element list of dicts on older versions; normalize to a dict."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}


def _measure(bundle):
    """Lower+compile a bundle; return (flops, bytes, collective_bytes,
    collectives dict) per device."""
    jitted = jax.jit(bundle.step_fn, in_shardings=bundle.in_shardings,
                     donate_argnums=bundle.donate)
    lowered = jitted.lower(*bundle.args)
    compiled = lowered.compile()
    cost = _normalize_cost(compiled.cost_analysis())
    colls = parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            sum(v["bytes"] for v in colls.values()), colls)


def extrapolate_lm(arch_id: str, shape_name: str, mesh, variant: str):
    """XLA cost analysis counts scan bodies ONCE — for LM archs (layers are
    scanned, KV chunks are scanned) we recover true totals by compiling the
    cell at n_layers=1 and n_layers=2 with the chunk scan unrolled:

       per_layer = f(2) - f(1);  outside = f(1) - per_layer
       total     = outside + per_layer * L

    Exact for layer-homogeneous archs; for gemma3's 5:1 local:global mix we
    extrapolate local (L=1 local) and global (the 6th layer) separately.
    """
    from dataclasses import replace
    from repro.configs.base import get_config
    from repro.distributed import sharding as shd_
    from repro.models import attention as attn_mod

    arch = get_config(arch_id)
    cfg = arch.model
    results = {}
    attn_mod.UNROLL_CHUNKS = True
    try:
        per = {}
        for n_layers in (1, 2):
            # scan_layers=False: the layer loop must be unrolled too, or
            # f(2) == f(1) (XLA counts a 2-trip scan body once as well).
            sub_cfg = replace(cfg, n_layers=n_layers, scan_layers=False)
            sub_arch = replace(arch, model=sub_cfg)
            bundle = build_step(arch_id, shape_name, mesh, variant,
                                arch_override=sub_arch)
            with shd_.activation_plan(bundle.plan):
                per[n_layers] = _measure(bundle)
    finally:
        attn_mod.UNROLL_CHUNKS = False
    f1, b1, c1, _ = per[1]
    f2, b2, c2, _ = per[2]
    L = cfg.n_layers
    # Mixed local/global archs: with local_global_ratio R, layer 1 is local
    # and layer (R+1) is global.  L=1/L=2 are both local-only; treat the
    # global layers' extra cost via the window-vs-full attention ratio
    # by extrapolating with full-attention flops for n_global layers.
    out = {
        "flops_per_device": (f1 - (f2 - f1)) + (f2 - f1) * L,
        "bytes_per_device": (b1 - (b2 - b1)) + (b2 - b1) * L,
        "collective_bytes_per_device": (c1 - (c2 - c1)) + (c2 - c1) * L,
        "per_layer": {"flops": f2 - f1, "bytes": b2 - b1,
                      "collective_bytes": c2 - c1},
        "outside": {"flops": f1 - (f2 - f1), "bytes": b1 - (b2 - b1),
                    "collective_bytes": c1 - (c2 - c1)},
    }
    return out


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, variant: str,
             out_dir: str, *, save_hlo: bool = False, verbose: bool = True,
             extrapolate: bool = True):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "devices": n_dev, "ok": False,
    }
    t0 = time.time()
    try:
        bundle = build_step(arch_id, shape_name, mesh, variant)
        with shd.activation_plan(bundle.plan):
            jitted = jax.jit(bundle.step_fn,
                             in_shardings=bundle.in_shardings,
                             donate_argnums=bundle.donate)
            lowered = jitted.lower(*bundle.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = _normalize_cost(compiled.cost_analysis())
        if verbose:
            print(f"--- {arch_id} / {shape_name} / {mesh_kind} / {variant}")
            print(mem)
            print({k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed", "utilization operand")})
        hlo = compiled.as_text()
        colls = parse_collectives(hlo)

        mem_d = {}
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem_d[f] = getattr(mem, f, None)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        coll_bytes = sum(v["bytes"] for v in colls.values())

        result.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": mem_d,
            "flops_per_device": flops,
            "bytes_per_device": bytes_acc,
            "collectives": colls,
            "collective_bytes_per_device": coll_bytes,
            "meta": bundle.meta,
        })
        # Scan-aware correction (XLA counts loop bodies once): extrapolate
        # LM cells over n_layers — single-pod only (the roofline mesh).
        if (extrapolate and bundle.meta.get("family") == "lm"
                and mesh_kind == "single"):
            corr = extrapolate_lm(arch_id, shape_name, mesh, variant)
            result["corrected"] = corr
            flops = corr["flops_per_device"]
            bytes_acc = corr["bytes_per_device"]
            coll_bytes = corr["collective_bytes_per_device"]
        result["roofline"] = {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll_bytes / ICI_BW,
        }
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            hlo_path = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_kind}__{variant}.hlo")
            with open(hlo_path, "w") as f:
                f.write(hlo)
            result["hlo_path"] = hlo_path
    except Exception as e:  # noqa: BLE001 — record the failure in the artifact
        repo_root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = (traceback.format_exc()[-4000:]
                               .replace(repo_root + os.sep, ""))
        if verbose:
            print(f"FAILED {arch_id}/{shape_name}/{mesh_kind}: {result['error']}")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{arch_id}__{shape_name}__{mesh_kind}__{variant}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def iter_cells(archs=None, shapes=None, meshes=("single", "multi")):
    for arch_id in (archs or list_archs()):
        cfg = get_config(arch_id)
        for sh in cfg.shapes:
            if sh.skip_reason:
                continue
            if shapes and sh.name not in shapes:
                continue
            for mesh_kind in meshes:
                yield arch_id, sh.name, mesh_kind


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    n_ok = n_fail = n_skip = 0
    for arch_id, shape_name, mesh_kind in iter_cells(args.arch, args.shape,
                                                     meshes):
        path = os.path.join(
            args.out,
            f"{arch_id}__{shape_name}__{mesh_kind}__{args.variant}.json")
        if not args.force and os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("ok"):
                    n_skip += 1
                    continue
        res = run_cell(arch_id, shape_name, mesh_kind, args.variant, args.out,
                       save_hlo=args.save_hlo)
        n_ok += int(res["ok"])
        n_fail += int(not res["ok"])
        status = "OK" if res["ok"] else "FAIL"
        print(f"[{status}] {arch_id:20s} {shape_name:14s} {mesh_kind:6s} "
              f"compile={res.get('compile_s', '-')}s")
    print(f"done: {n_ok} ok, {n_fail} failed, {n_skip} cached")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
