"""SASRec / gBERT4Rec backbones with the RecJPQ item layer — the paper's
own models (Table 3).

Item id 0 is padding; real items are 1..n_items.  The PQ embedding is
*shared* between the input layer and the scoring head (as in RecJPQ).
Training uses gBCE with uniform negative sampling [gSASRec, RecSys'23] so
large catalogues are trainable; serving scores the full catalogue through
any of the paper's scoring algorithms.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SeqRecConfig
from repro.core import retrieval_head
from repro.distributed.sharding import constrain
from repro.models import attention as attn_lib, layers

Params = Dict[str, Any]


def init_seqrec(key: jax.Array, cfg: SeqRecConfig, codes=None,
                centroids=None) -> Params:
    ks = jax.random.split(key, cfg.n_blocks + 3)
    dtype = jnp.dtype(cfg.param_dtype)
    head_dim = cfg.d_model // cfg.n_heads
    from repro.configs.base import AttentionConfig
    acfg = AttentionConfig(n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
                           head_dim=head_dim)
    blocks = []
    for i in range(cfg.n_blocks):
        bks = jax.random.split(ks[i], 2)
        blocks.append({
            "attn": attn_lib.attention_init(bks[0], acfg, cfg.d_model, dtype),
            "ln1": layers.norm_init(cfg.d_model, "layernorm", dtype),
            "ln2": layers.norm_init(cfg.d_model, "layernorm", dtype),
            "mlp": layers.mlp_init(bks[1], cfg.d_model, cfg.d_ff,
                                   gated=False, dtype=dtype),
        })
    p: Params = {
        # +1 row for padding id 0.
        "item_emb": retrieval_head.init(ks[-3], cfg.n_items + 1, cfg.d_model,
                                        cfg.pq, codes=codes,
                                        centroids=centroids, dtype=dtype),
        "pos_emb": layers.embedding_init(ks[-2], cfg.max_seq_len, cfg.d_model,
                                         dtype),
        "final_norm": layers.norm_init(cfg.d_model, "layernorm", dtype),
        "blocks": blocks,
    }
    if cfg.backbone == "bert4rec":
        p["mask_emb"] = (jax.random.normal(ks[-1], (cfg.d_model,), jnp.float32)
                         * 0.02).astype(dtype)
    return p


def abstract_seqrec(cfg: SeqRecConfig) -> Params:
    return jax.eval_shape(functools.partial(init_seqrec, cfg=cfg),
                          jax.random.PRNGKey(0))


def _attn_cfg(cfg: SeqRecConfig):
    from repro.configs.base import AttentionConfig
    return AttentionConfig(n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
                           head_dim=cfg.d_model // cfg.n_heads)


def seqrec_hidden(params: Params, item_seq: jax.Array, cfg: SeqRecConfig,
                  ) -> jax.Array:
    """item_seq (B, S) int32 (0 = pad) -> hidden (B, S, d)."""
    b, s = item_seq.shape
    x = retrieval_head.embed(params["item_emb"], item_seq)
    x = x * (item_seq != 0)[..., None].astype(x.dtype)
    x = x + params["pos_emb"]["table"][None, :s].astype(x.dtype)
    x = constrain(x, "seq_hidden")
    acfg = _attn_cfg(cfg)
    causal = cfg.backbone == "sasrec"
    for blk in params["blocks"]:
        h = layers.apply_norm(blk["ln1"], x, "layernorm")
        h = attn_lib.full_attention(blk["attn"], acfg, h, causal=causal)
        x = x + h
        h = layers.apply_norm(blk["ln2"], x, "layernorm")
        x = x + layers.mlp(blk["mlp"], h, "gelu")
    return layers.apply_norm(params["final_norm"], x, "layernorm")


# ---------------------------------------------------------------------------
# training: gBCE with uniform negatives
# ---------------------------------------------------------------------------

def gbce_loss(pos_scores: jax.Array, neg_scores: jax.Array, mask: jax.Array,
              n_items: int, n_negatives: int, t: float) -> jax.Array:
    """Generalised BCE [gSASRec].  beta = alpha*(t*(1-1/alpha)+1/alpha),
    sigma^beta(s+) applied via logits: log(sigma^beta(s)) = beta*logsigmoid(s)."""
    alpha = n_negatives / max(n_items - 1, 1)
    beta = alpha * (t * (1.0 - 1.0 / alpha) + 1.0 / alpha)
    pos = beta * jax.nn.log_sigmoid(pos_scores)                   # (B, S)
    neg = jax.nn.log_sigmoid(-neg_scores).sum(-1)                 # (B, S)
    per_pos = -(pos + neg)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (per_pos * mask).sum() / denom


def seqrec_loss(params: Params, batch: Dict[str, jax.Array],
                cfg: SeqRecConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: input_seq (B,S), targets (B,S), negatives (B,S,n_neg) — all
    item ids (0 pad).  SASRec: next-item at every position; BERT4Rec: the
    data pipeline pre-masks inputs and sets targets only at masked slots."""
    hidden = seqrec_hidden(params, batch["input_seq"], cfg)       # (B,S,d)
    emb = params["item_emb"]
    pos_emb = retrieval_head.embed(emb, batch["targets"])         # (B,S,d)
    neg_emb = retrieval_head.embed(emb, batch["negatives"])       # (B,S,n,d)
    h32 = hidden.astype(jnp.float32)
    pos_scores = jnp.einsum("bsd,bsd->bs", h32, pos_emb.astype(jnp.float32))
    neg_scores = jnp.einsum("bsd,bsnd->bsn", h32, neg_emb.astype(jnp.float32))
    mask = (batch["targets"] != 0).astype(jnp.float32)
    loss = gbce_loss(pos_scores, neg_scores, mask, cfg.n_items,
                     cfg.n_negatives, cfg.gbce_t)
    return loss, {"nll": loss}


# ---------------------------------------------------------------------------
# serving: sequence embedding phi + catalogue scoring (the paper's pipeline)
# ---------------------------------------------------------------------------

def sequence_embedding(params: Params, item_seq: jax.Array, cfg: SeqRecConfig,
                       ) -> jax.Array:
    """phi for each user: last position (SASRec) / mask slot appended at the
    end (BERT4Rec single-step next-item inference, as served in the paper)."""
    if cfg.backbone == "bert4rec":
        b = item_seq.shape[0]
        # Shift left, append the [MASK] position.
        seq = jnp.concatenate(
            [item_seq[:, 1:], jnp.zeros((b, 1), item_seq.dtype)], axis=1)
        x = retrieval_head.embed(params["item_emb"], seq)
        x = x * (seq != 0)[..., None].astype(x.dtype)
        x = x.at[:, -1, :].set(params["mask_emb"].astype(x.dtype))
        x = x + params["pos_emb"]["table"][None, :seq.shape[1]].astype(x.dtype)
        acfg = _attn_cfg(cfg)
        for blk in params["blocks"]:
            h = layers.apply_norm(blk["ln1"], x, "layernorm")
            h = attn_lib.full_attention(blk["attn"], acfg, h, causal=False)
            x = x + h
            h = layers.apply_norm(blk["ln2"], x, "layernorm")
            x = x + layers.mlp(blk["mlp"], h, "gelu")
        x = layers.apply_norm(params["final_norm"], x, "layernorm")
        return x[:, -1, :].astype(jnp.float32)
    hidden = seqrec_hidden(params, item_seq, cfg)
    return hidden[:, -1, :].astype(jnp.float32)


def serve_topk(params: Params, item_seq: jax.Array, cfg: SeqRecConfig, *,
               k: int = 10, method: str = "pqtopk", sharded_mesh=None,
               ladder=None, pin_rung: bool = False,
               return_rung: bool = False):
    """Full serving path: backbone -> phi -> scoring -> TopK (Table 3).

    ``sharded_mesh``: item-sharded distributed retrieval (shard-local
    PQTopK + O(k x shards) merge instead of an O(B x N) score gather).

    ``ladder``/``pin_rung``/``return_rung`` apply to
    ``method="pqtopk_pruned"`` only: the calibrated slot-budget ladder for
    the cascade, whether to pin it to its cheapest rung (the router's
    load-degraded mode — bounded cost, possibly inexact, every result
    served through it must be tagged), and whether to additionally return
    the rung taken (i32 scalar — still one dispatch; the serving engine
    uses it to track ``rung_hit_fraction``)."""
    phi = constrain(sequence_embedding(params, item_seq, cfg), "phi")
    if method != "pqtopk_pruned" and return_rung:
        raise ValueError("return_rung is only meaningful for the pruned "
                         "cascade (method='pqtopk_pruned')")
    if pin_rung and sharded_mesh is not None:
        raise ValueError("pin_rung is not threaded through the sharded "
                         "cascade; degrade the flat replicas instead")
    if sharded_mesh is not None:
        if method == "pqtopk_pruned" and return_rung:
            vals, ids, stats = retrieval_head.top_items_pruned_sharded(
                params["item_emb"], phi, k, sharded_mesh, pq_cfg=cfg.pq,
                ladder=ladder, return_stats=True)
            return ids, vals, stats["rung_hit"]
        vals, ids = retrieval_head.top_items_sharded(
            params["item_emb"], phi, k, sharded_mesh, method=method,
            pq_cfg=cfg.pq, ladder=ladder)
    else:
        out = retrieval_head.top_items(params["item_emb"], phi, k,
                                       method=method, pq_cfg=cfg.pq,
                                       ladder=ladder, pin_rung=pin_rung,
                                       return_rung=return_rung)
        if return_rung:
            vals, ids, rung = out
            return ids, vals, rung
        vals, ids = out
    return ids, vals
