"""Configurable decoder/encoder LM covering every assigned LM arch:
GQA + RoPE (+QK-norm, QKV-bias), sliding/global layer interleave, dense or
MoE FFN, squared-ReLU / SiLU / GeGLU, scan-over-layers + remat, and the
PQ-compressed retrieval head on the decode path (the paper's technique
applied to vocab scoring — DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.core import retrieval_head
from repro.distributed.sharding import constrain
from repro.models import attention, layers, moe as moe_lib

Params = Dict[str, Any]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_cast(x, dtype):
    """Identity fwd; cast the cotangent to ``dtype`` in bwd — pins the
    backward residual stream to bf16 so weight gathers / grad psums move
    2-byte data (§Perf 'bf16_grads' iteration)."""
    return x


def _grad_cast_fwd(x, dtype):
    return x, None


def _grad_cast_bwd(dtype, _, g):
    return (g.astype(dtype),)


_grad_cast.defvjp(_grad_cast_fwd, _grad_cast_bwd)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key: jax.Array, cfg: LMConfig) -> Params:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p = {
        "attn": attention.attention_init(ks[0], cfg.attention, cfg.d_model, dtype),
        "ln1": layers.norm_init(cfg.d_model, cfg.norm, dtype),
        "ln2": layers.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if cfg.moe is None:
        p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                   gated=cfg.gated_mlp, dtype=dtype)
    else:
        p["moe"] = moe_lib.moe_init(ks[1], cfg.moe, cfg.d_model,
                                    gated=cfg.gated_mlp, dtype=dtype)
    return p


def init_lm(key: jax.Array, cfg: LMConfig) -> Params:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    if cfg.scan_layers:
        blocks = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
    else:
        blocks = [init_block(k, cfg) for k in layer_keys]
    p: Params = {
        "embed": layers.embedding_init(ks[1], cfg.vocab, cfg.d_model, dtype),
        "layers": blocks,
        "final_norm": layers.norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = layers.dense_init(ks[2], cfg.d_model, cfg.vocab,
                                      dtype=dtype)
    if cfg.pq_head is not None:
        p["pq_head"] = retrieval_head.init(ks[3], cfg.vocab, cfg.d_model,
                                           cfg.pq_head, dtype=jnp.float32)
    return p


def abstract_lm(cfg: LMConfig) -> Params:
    """ShapeDtypeStruct tree — dry-run stand-in, no allocation."""
    return jax.eval_shape(functools.partial(init_lm, cfg=cfg),
                          jax.random.PRNGKey(0))


def layer_types(cfg: LMConfig) -> np.ndarray:
    """Per-layer is_global flags (sliding/global interleave)."""
    return np.array([cfg.attention.layer_is_global(i)
                     for i in range(cfg.n_layers)])


# ---------------------------------------------------------------------------
# forward (train / prefill): scan over layers
# ---------------------------------------------------------------------------

def _block_fwd(blk: Params, cfg: LMConfig, x: jax.Array,
               is_global: jax.Array) -> Tuple[jax.Array, jax.Array]:
    x = _grad_cast(x, jnp.dtype(cfg.dtype))
    h = layers.apply_norm(blk["ln1"], x, cfg.norm)
    h = attention.full_attention(blk["attn"], cfg.attention, h,
                                 is_global=is_global, causal=cfg.causal)
    x = x + h
    h = layers.apply_norm(blk["ln2"], x, cfg.norm)
    if cfg.moe is None:
        h, aux = layers.mlp(blk["mlp"], h, cfg.act), jnp.float32(0.0)
    else:
        h, aux = moe_lib.moe_ffn(blk["moe"], cfg.moe, h, cfg.act,
                                 impl=cfg.moe_impl)
    x = constrain(x + h, "hidden")
    return x, aux


def lm_hidden(params: Params, tokens: jax.Array, cfg: LMConfig) -> Tuple[jax.Array, jax.Array]:
    """tokens (B, S) -> (hidden (B, S, d), aux_loss)."""
    x = jnp.take(params["embed"]["table"], tokens, axis=0)
    x = constrain(x.astype(jnp.dtype(cfg.dtype)), "hidden")
    flags = jnp.asarray(layer_types(cfg))

    def body(x, xs):
        blk, is_global = xs
        x, aux = _block_fwd(blk, cfg, x, is_global)
        return x, aux

    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(body, x, (params["layers"], flags))
        aux = auxs.sum()
    else:
        aux = jnp.float32(0.0)
        for i, blk in enumerate(params["layers"]):
            x, a = body(x, (blk, flags[i]))
            aux = aux + a
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def unembed(params: Params, hidden: jax.Array, cfg: LMConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(hidden.dtype)   # (V, d)
        logits = jnp.einsum("bsd,vd->bsv", hidden, w)
    else:
        logits = layers.dense(params["head"], hidden)
    return constrain(logits, "logits")


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: LMConfig,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal-LM cross entropy over the (model-sharded) vocab."""
    hidden, aux = lm_hidden(params, batch["tokens"], cfg)
    logits = unembed(params, hidden, cfg).astype(jnp.float32)
    targets = batch["targets"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with PQ head
# ---------------------------------------------------------------------------

def _uniform_layers(cfg: LMConfig) -> bool:
    flags = layer_types(cfg)
    return bool(flags.all()) and cfg.scan_layers


def init_caches(cfg: LMConfig, batch: int, max_len: int, *, abstract=False):
    """KV caches.

    * Homogeneous all-global archs (qwen/nemotron/dbrx/qwen3-moe): one
      *stacked* (L, B, S, H, D) cache pair so decode can scan over layers
      (small HLO — critical for 96-layer compiles).
    * Mixed sliding/global archs (gemma3): per-layer list; sliding layers
      get an O(window) ring buffer — the memory shape that makes long_500k
      viable (DESIGN.md §4).
    """
    mk = attention.abstract_cache if abstract else attention.init_cache
    flags = layer_types(cfg)
    dtype = jnp.dtype(cfg.dtype)
    if _uniform_layers(cfg):
        one = mk(batch, max_len, cfg.attention, is_global=True, dtype=dtype)
        if abstract:
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((cfg.n_layers,) + s.shape,
                                               s.dtype), one)
        return jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), one)
    return [mk(batch, max_len, cfg.attention, is_global=bool(flags[i]),
               dtype=dtype) for i in range(cfg.n_layers)]


def lm_decode_step(params: Params, token: jax.Array, pos: jax.Array,
                   caches, cfg: LMConfig, *, k: int = 64,
                   head_method: str = "pqtopk"):
    """One decode step. token (B,), pos scalar.

    Returns (topk_ids (B,k), topk_scores (B,k), updated caches).
    Vocab scoring goes through the PQ retrieval head (paper technique) or
    the dense unembedding (baseline), selected by ``head_method``.
    """
    x = jnp.take(params["embed"]["table"], token[:, None], axis=0)
    x = x.astype(jnp.dtype(cfg.dtype))
    flags = layer_types(cfg)

    def body(x, blk, cache, is_global):
        h = layers.apply_norm(blk["ln1"], x, cfg.norm)
        h, new_cache = attention.decode_attend(blk["attn"], cfg.attention, h,
                                               cache, pos, is_global)
        x = x + h
        h = layers.apply_norm(blk["ln2"], x, cfg.norm)
        if cfg.moe is None:
            h = layers.mlp(blk["mlp"], h, cfg.act)
        else:
            h, _ = moe_lib.moe_ffn(blk["moe"], cfg.moe, h, cfg.act,
                                   impl=cfg.moe_impl)
        return x + h, new_cache

    if _uniform_layers(cfg):
        # Homogeneous layers: scan with stacked caches (compact HLO).
        def scan_body(x, xs):
            blk, cache = xs
            return body(x, blk, cache, True)

        x, new_caches = jax.lax.scan(scan_body, x,
                                     (params["layers"], caches))
    else:
        # Mixed sliding/global: unroll so each layer keeps its own cache
        # shape (ring buffers for sliding layers).
        new_caches = []
        for i in range(cfg.n_layers):
            blk = (jax.tree.map(lambda a: a[i], params["layers"])
                   if cfg.scan_layers else params["layers"][i])
            x, nc = body(x, blk, caches[i], bool(flags[i]))
            new_caches.append(nc)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm)
    phi = constrain(x[:, 0, :].astype(jnp.float32), "phi")     # (B, d)

    if head_method == "dense":
        w = (params["embed"]["table"] if cfg.tie_embeddings
             else params["head"]["w"].T)
        scores = jnp.einsum("bd,vd->bv", phi, w.astype(jnp.float32))
        scores = constrain(scores, "scores")
        vals, ids = jax.lax.top_k(scores, k)
    elif head_method in ("pqtopk_fused", "pqtopk_pruned", "pqtopk_approx"):
        # Fused kernel / single-dispatch pruned cascade / block-max approx:
        # the (B, vocab) score matrix is not the route's public activation,
        # so there is no "scores" constraint to apply.  The pruned cascade
        # reads its bit-packed tile metadata straight from params["pq_head"]
        # ["pruned"] — built once at init, never rebuilt in the decode loop.
        vals, ids = retrieval_head.top_items(params["pq_head"], phi, k,
                                             method=head_method,
                                             pq_cfg=cfg.pq_head)
    else:
        scores = retrieval_head.score_all(params["pq_head"], phi, head_method)
        scores = constrain(scores, "scores")
        vals, ids = jax.lax.top_k(scores, k)
    return ids, vals, new_caches


def lm_prefill(params: Params, tokens: jax.Array, cfg: LMConfig):
    """Prefill: full forward returning last-position hidden (the serving
    engine fills KV caches incrementally through decode; the dry-run prefill
    cell measures the full-sequence forward)."""
    hidden, _ = lm_hidden(params, tokens, cfg)
    return hidden[:, -1, :]
