from repro.models import (attention, embedding, gnn, layers, moe, recsys,
                          seqrec, transformer)

__all__ = ["attention", "embedding", "gnn", "layers", "moe", "recsys",
           "seqrec", "transformer"]
