"""GQA attention: chunked (flash-style) training/prefill path + KV-cache
decode path, with optional sliding window and QK-norm.

The chunked path is pure JAX (lax.scan over KV chunks with online-softmax
carry) so it lowers on any backend — this is what the multi-pod dry-run
compiles.  On TPU the same interface can dispatch to a Pallas kernel; the
distribution-level analysis is identical (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models import layers

Params = Dict[str, Any]

DEFAULT_KV_CHUNK = 1024
NEG_INF = -1e30

# Analysis mode: unroll the KV-chunk scan into a python loop so XLA cost
# analysis (which counts while-loop bodies ONCE) sees every chunk.  Set by
# launch/dryrun.py during roofline-extrapolation compiles only.
UNROLL_CHUNKS = False


def attention_init(key: jax.Array, cfg: AttentionConfig, d_model: int,
                   dtype: Any = jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    q_dim = cfg.n_heads * cfg.head_dim
    kv_dim = cfg.n_kv_heads * cfg.head_dim
    p = {
        "wq": layers.dense_init(ks[0], d_model, q_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wk": layers.dense_init(ks[1], d_model, kv_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wv": layers.dense_init(ks[2], d_model, kv_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wo": layers.dense_init(ks[3], q_dim, d_model, dtype=dtype,
                                scale=q_dim ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.norm_init(cfg.head_dim, "rmsnorm", dtype)
        p["k_norm"] = layers.norm_init(cfg.head_dim, "rmsnorm", dtype)
    return p


def _project_qkv(p: Params, cfg: AttentionConfig, x: jax.Array,
                 positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    q = layers.dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = layers.dense(p["wk"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = layers.dense(p["wv"], x).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = layers.apply_norm(p["q_norm"], q, "rmsnorm")
        k = layers.apply_norm(p["k_norm"], k, "rmsnorm")
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      kv_chunk: int = DEFAULT_KV_CHUNK) -> jax.Array:
    """Online-softmax attention, scanning KV in chunks.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D); Hq = Hkv * G.
    window > 0 limits attention to the last ``window`` positions (inclusive
    of self).  Peak memory: one (B, Hkv, G, Sq, chunk) score block.
    """
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    kv_chunk = min(kv_chunk, sk)
    n_chunks = sk // kv_chunk if sk % kv_chunk == 0 else -(-sk // kv_chunk)
    pad = n_chunks * kv_chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(b, n_chunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, n_chunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(sq)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        idx, k_i, v_i = xs
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        s_blk = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_i.astype(jnp.float32))
        mask = k_pos[None, :] < sk                 # padding
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s_blk = jnp.where(mask[None, None, None], s_blk, NEG_INF)
        m_cur = jnp.maximum(m_prev, s_blk.max(-1))
        p_blk = jnp.exp(s_blk - m_cur[..., None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + p_blk.sum(-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p_blk, v_i.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    if UNROLL_CHUNKS:
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, (jnp.int32(i), kc[i], vc[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


def full_attention(p: Params, cfg: AttentionConfig, x: jax.Array, *,
                   is_global: jax.Array | bool = True, causal: bool = True,
                   kv_chunk: int = DEFAULT_KV_CHUNK) -> jax.Array:
    """Self-attention over a full sequence (train / prefill).

    ``is_global`` may be a traced bool (scan over heterogeneous layers):
    local layers apply the sliding window by adding the window mask, chosen
    with a where() on the two mask variants inside the chunk scan — we
    implement it by running the windowed mask with window size selected per
    layer (window or "infinite").
    """
    from repro.distributed.sharding import constrain
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    # TP hook: shard query heads over 'model' (Megatron-SP plans set
    # "attn_q_heads"; no-op in the baseline plan).
    q = constrain(q, "attn_q_heads")
    if isinstance(is_global, bool):
        window = 0 if is_global else cfg.window
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                kv_chunk=kv_chunk)
    else:
        # Traced layer type: compute the window mask with an effective
        # window of `s` (= no-op) for global layers.  One attention pass.
        eff_window = jnp.where(is_global, jnp.int32(s + 1),
                               jnp.int32(cfg.window))
        out = _chunked_attention_dyn_window(q, k, v, causal=causal,
                                            window=eff_window,
                                            kv_chunk=kv_chunk)
    out = constrain(out, "attn_q_heads")
    b_, s_, hq, d = out.shape
    return layers.dense(p["wo"], out.reshape(b_, s_, hq * d))


def _chunked_attention_dyn_window(q, k, v, *, causal, window, kv_chunk):
    """chunked_attention with a traced (dynamic) window size."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    kv_chunk = min(kv_chunk, sk)
    n_chunks = -(-sk // kv_chunk)
    pad = n_chunks * kv_chunk - sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = kp.reshape(b, n_chunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, n_chunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(sq)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        idx, k_i, v_i = xs
        k_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        s_blk = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_i.astype(jnp.float32))
        mask = k_pos[None, :] < sk
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        mask &= k_pos[None, :] > q_pos[:, None] - window
        s_blk = jnp.where(mask[None, None, None], s_blk, NEG_INF)
        m_cur = jnp.maximum(m_prev, s_blk.max(-1))
        p_blk = jnp.exp(s_blk - m_cur[..., None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * alpha + p_blk.sum(-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p_blk, v_i.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_cur, l_cur, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    if UNROLL_CHUNKS:
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, (jnp.int32(i), kc[i], vc[i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------

def init_cache(batch: int, max_len: int, cfg: AttentionConfig, *,
               is_global: bool, dtype: Any = jnp.bfloat16,
               ) -> Dict[str, jax.Array]:
    """Global layers cache max_len positions; local layers a ring buffer of
    ``window`` positions (O(window) memory — what makes long_500k viable for
    sliding-window archs)."""
    length = max_len if is_global else min(cfg.window, max_len)
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_cache(batch: int, max_len: int, cfg: AttentionConfig, *,
                   is_global: bool, dtype: Any = jnp.bfloat16):
    length = max_len if is_global else min(cfg.window, max_len)
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def decode_attend(p: Params, cfg: AttentionConfig, x: jax.Array,
                  cache: Dict[str, jax.Array], pos: jax.Array,
                  is_global: jax.Array | bool,
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One decode step.  x: (B, 1, d_model); pos: scalar current position.

    Writes the new KV at ``pos`` (global) or ``pos % window`` (ring buffer),
    then attends over the valid cache region.
    """
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(p, cfg, x, jnp.full((b, 1), pos))
    cache_len = cache["k"].shape[1]
    slot = jnp.where(jnp.asarray(is_global), pos, pos % cache_len)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    hq, hkv, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32))
    # Valid region: ring slots written so far (local) or positions <= pos.
    slots = jnp.arange(cache_len)
    valid_global = slots <= pos
    valid_local = slots <= jnp.minimum(pos, cache_len - 1)  # ring fills up
    valid = jnp.where(jnp.asarray(is_global), valid_global, valid_local)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v.astype(jnp.float32))
    out = out.reshape(b, 1, hq * d).astype(x.dtype)
    return layers.dense(p["wo"], out), {"k": k, "v": v}
