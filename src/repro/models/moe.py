"""Mixture-of-Experts FFN: top-k routing + GShard-style grouped dense
dispatch (capacity-factor), expert-parallel over the ``model`` mesh axis.

The dispatch/combine tensors are built per token *group*; groups are sized
~GROUP_TOKENS so the (G, Tg, E, C) one-hot stays VMEM-friendly and shards
over the token axes while experts shard over ``model`` — GSPMD materialises
the all-to-all between the two layouts (visible in the dry-run HLO).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import layers

Params = Dict[str, Any]

GROUP_TOKENS = 2048


def moe_init(key: jax.Array, cfg: MoEConfig, d_model: int, *, gated: bool,
             dtype: Any = jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_ff_expert
    scale_in = d_model ** -0.5
    scale_out = f ** -0.5
    p = {
        "router": layers.dense_init(ks[0], d_model, e, dtype=jnp.float32),
        "up": (jax.random.normal(ks[1], (e, d_model, f), jnp.float32)
               * scale_in).astype(dtype),
        "down": (jax.random.normal(ks[2], (e, f, d_model), jnp.float32)
                 * scale_out).astype(dtype),
    }
    if gated:
        p["gate"] = (jax.random.normal(ks[3], (e, d_model, f), jnp.float32)
                     * scale_in).astype(dtype)
    if cfg.n_shared:
        p["shared"] = layers.mlp_init(ks[4], d_model,
                                      cfg.n_shared * f, gated=gated,
                                      dtype=dtype)
    return p


def _capacity(tokens_per_group: int, cfg: MoEConfig) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8 lanes


def moe_ffn(p: Params, cfg: MoEConfig, x: jax.Array, act: str,
            impl: str = "dense") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    impl="dense": GShard one-hot dispatch/combine matmuls (baseline).
    impl="sort":  argsort-based dispatch — tokens sorted by expert, gathered
                  into (E, C, d) buffers, expert GEMMs, weighted scatter-add
                  back (MegaBlocks-flavoured; kills the O(T*E*C*d) dispatch
                  FLOPs, §Perf 'moe_sort' iteration).

    aux_loss is the Switch/GShard load-balance loss (mean over groups).
    """
    if impl == "sort":
        return _moe_ffn_sort(p, cfg, x, act)
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    g = max(1, t // GROUP_TOKENS)
    while t % g:
        g -= 1
    tg = t // g
    xg = xt.reshape(g, tg, d)

    logits = (xg.astype(jnp.float32) @ p["router"]["w"])        # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, cfg.top_k)            # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                 # renormalise

    e = cfg.n_experts
    c = _capacity(tg, cfg)
    onehot = jax.nn.one_hot(sel, e, dtype=jnp.float32)          # (G, Tg, k, E)
    # Position of each (token, choice) within its expert queue.
    pos = jnp.cumsum(onehot.reshape(g, tg * cfg.top_k, e), axis=1) - 1.0
    pos = pos.reshape(g, tg, cfg.top_k, e)
    keep = (pos < c) & (onehot > 0)                             # capacity drop
    pos_c = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32)
    pos_c = pos_c * keep[..., None]
    # dispatch: (G, Tg, E, C) 0/1; combine carries gate values.
    dispatch = pos_c.sum(2)                                     # over k
    combine = (pos_c * gate_vals[..., None, None]).sum(2)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg.astype(jnp.float32))
    xe = xe.astype(x.dtype)                                     # (G, E, C, d)
    f = layers.activation(act)
    h = jnp.einsum("gecd,edf->gecf", xe, p["up"].astype(x.dtype))
    if "gate" in p:
        hg = jnp.einsum("gecd,edf->gecf", xe, p["gate"].astype(x.dtype))
        h = f(hg) * h
    else:
        h = f(h)
    ye = jnp.einsum("gecf,efd->gecd", h, p["down"].astype(x.dtype))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(jnp.float32),
                     ye.astype(jnp.float32))
    out = out.reshape(b, s, d).astype(x.dtype)

    if "shared" in p:
        out = out + layers.mlp(p["shared"], x, act)

    # Load-balance auxiliary loss (fraction routed * router prob mass).
    frac_routed = dispatch.sum((1, 3)) / tg                     # (G, E)
    prob_mass = probs.mean(1)                                   # (G, E)
    aux = (frac_routed * prob_mass).sum(-1).mean() * e
    return out, aux.astype(jnp.float32)


def _moe_ffn_sort(p: Params, cfg: MoEConfig, x: jax.Array, act: str,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Sort-based dispatch: same semantics as the dense path (top-k routing,
    capacity drop, gate-weighted combine) with gather/scatter data movement
    instead of one-hot matmuls.

    Sorting/scatter is done per token GROUP (vmap) so indices stay
    shard-local — a global sort would force GSPMD to all-gather the whole
    token tensor (measured: 6x collective blow-up; §Perf moe_sort v1).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = max(1, t // GROUP_TOKENS)
    while t % g:
        g -= 1
    tg = t // g
    xg = x.reshape(g, tg, d)
    c = _capacity(tg, cfg)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"]["w"])                       # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, k)                    # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    def group_dispatch(xg_, sel_, gates_):
        """One group: (Tg, d), (Tg, k), (Tg, k) -> (E, C, d), slot, st, keep."""
        flat_e = sel_.reshape(tg * k)
        flat_tok = jnp.repeat(jnp.arange(tg), k)
        order = jnp.argsort(flat_e, stable=True)
        se, st = flat_e[order], flat_tok[order]
        sg = gates_.reshape(tg * k)[order]
        start = jnp.searchsorted(se, jnp.arange(e), side="left")
        rank = jnp.arange(tg * k) - start[se]
        keep = rank < c
        slot = jnp.where(keep, se * c + rank, e * c)
        buf = jnp.zeros((e * c + 1, d), xg_.dtype).at[slot].set(xg_[st])
        return buf[:e * c].reshape(e, c, d), slot, st, sg, keep

    xe, slot, st, sg, keep = jax.vmap(group_dispatch)(xg, sel, gate_vals)
    # xe: (G, E, C, d) — same layout as the dense path's dispatched tensor,
    # so the EP sharding (E over 'model') and its all-to-all are unchanged.
    f = layers.activation(act)
    h = jnp.einsum("gecd,edf->gecf", xe, p["up"].astype(x.dtype))
    if "gate" in p:
        hg = jnp.einsum("gecd,edf->gecf", xe, p["gate"].astype(x.dtype))
        h = f(hg) * h
    else:
        h = f(h)
    ye = jnp.einsum("gecf,efd->gecd", h, p["down"].astype(x.dtype))

    def group_combine(ye_, slot_, st_, sg_, keep_):
        ye_flat = jnp.concatenate(
            [ye_.reshape(e * c, d), jnp.zeros((1, d), ye_.dtype)], axis=0)
        contrib = ye_flat[slot_] * (sg_ * keep_)[:, None].astype(ye_.dtype)
        return jax.ops.segment_sum(contrib, st_, num_segments=tg)

    out = jax.vmap(group_combine)(ye, slot, st, sg, keep)       # (G, Tg, d)
    out = out.reshape(b, s, d).astype(x.dtype)

    if "shared" in p:
        out = out + layers.mlp(p["shared"], x, act)

    density = jax.nn.one_hot(sel, e, dtype=jnp.float32).sum((1, 2)) / (tg * k)
    aux = ((density * probs.mean(1)).sum(-1) * e).mean()
    return out, aux.astype(jnp.float32)
