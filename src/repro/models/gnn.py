"""GraphSAGE [arXiv:1706.02216] — mean aggregator, full-batch and sampled.

Message passing is built on ``jax.ops.segment_sum`` over an edge index
(JAX has no CSR SpMM — per the brief this scatter/gather substrate IS part
of the system).  Three forward modes map to the assigned shapes:

* full-batch (full_graph_sm / ogb_products): edges (E, 2) + features (N, F);
  edges shard over every mesh axis, partial segment-sums psum-reduce.
* sampled minibatch (minibatch_lg): fanout-sampled neighbor id tensors from
  ``repro.data.graph.NeighborSampler``.
* batched small graphs (molecule): same edge-list path with a graph-id
  segment reduce for the readout.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.distributed.sharding import constrain
from repro.models import layers

Params = Dict[str, Any]


def init_gnn(key: jax.Array, cfg: GNNConfig, d_feat: int) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 1)
    dtype = jnp.dtype(cfg.param_dtype)
    lyrs = []
    d_in = d_feat
    for i in range(cfg.n_layers):
        # SAGE layer: W @ [h_self || h_neigh]
        lyrs.append({
            "w_self": layers.dense_init(jax.random.fold_in(ks[i], 0), d_in,
                                        cfg.d_hidden, bias=True, dtype=dtype),
            "w_neigh": layers.dense_init(jax.random.fold_in(ks[i], 1), d_in,
                                         cfg.d_hidden, dtype=dtype),
        })
        d_in = cfg.d_hidden
    return {
        "layers": lyrs,
        "out": layers.dense_init(ks[-1], cfg.d_hidden, cfg.n_classes,
                                 bias=True, dtype=dtype),
    }


def abstract_gnn(cfg: GNNConfig, d_feat: int) -> Params:
    return jax.eval_shape(
        functools.partial(init_gnn, cfg=cfg, d_feat=d_feat),
        jax.random.PRNGKey(0))


def _aggregate(h: jax.Array, edges: jax.Array, n_nodes: int,
               aggregator: str) -> jax.Array:
    """Mean/sum of neighbor features: messages h[src] scattered to dst."""
    src, dst = edges[:, 0], edges[:, 1]
    msgs = jnp.take(h, src, axis=0)
    msgs = constrain(msgs, "edge_feats")
    agg = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    if aggregator == "mean":
        deg = jax.ops.segment_sum(jnp.ones_like(dst, h.dtype), dst,
                                  num_segments=n_nodes)
        agg = agg / jnp.maximum(deg, 1.0)[:, None]
    return agg


def gnn_forward(params: Params, feats: jax.Array, edges: jax.Array,
                cfg: GNNConfig) -> jax.Array:
    """Full-batch forward. feats (N, F), edges (E, 2) -> logits (N, C)."""
    h = feats
    n = feats.shape[0]
    for lyr in params["layers"]:
        neigh = _aggregate(h, edges, n, cfg.aggregator)
        h = jax.nn.relu(layers.dense(lyr["w_self"], h)
                        + layers.dense(lyr["w_neigh"], neigh))
        # L2 normalisation as in the paper.
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    return layers.dense(params["out"], h)


def gnn_loss(params: Params, batch: Dict[str, jax.Array], cfg: GNNConfig,
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-batch node-classification CE on masked (labeled) nodes."""
    logits = gnn_forward(params, batch["feats"], batch["edges"], cfg)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    mask = batch["label_mask"].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    nll = ((logz - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll, {"nll": nll}


# ---------------------------------------------------------------------------
# sampled minibatch (fanout f1-f2): dense neighbor id tensors
# ---------------------------------------------------------------------------

def gnn_minibatch_forward(params: Params, feats_b: jax.Array,
                          feats_n1: jax.Array, feats_n2: jax.Array,
                          cfg: GNNConfig) -> jax.Array:
    """2-layer sampled GraphSAGE.

    feats_b (B, F) batch nodes, feats_n1 (B, f1, F) their neighbors,
    feats_n2 (B, f1, f2, F) 2-hop.  -> logits (B, C).
    """
    l1, l2 = params["layers"][0], params["layers"][1]

    def sage(lyr, h_self, h_neigh_mean):
        h = jax.nn.relu(layers.dense(lyr["w_self"], h_self)
                        + layers.dense(lyr["w_neigh"], h_neigh_mean))
        return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True),
                               1e-6)

    h1_n1 = sage(l1, feats_n1, feats_n2.mean(2))     # (B, f1, d)
    h1_b = sage(l1, feats_b, feats_n1.mean(1))       # (B, d)
    h2_b = sage(l2, h1_b, h1_n1.mean(1))             # (B, d)
    return layers.dense(params["out"], h2_b)


def gnn_minibatch_loss(params: Params, batch: Dict[str, jax.Array],
                       cfg: GNNConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = gnn_minibatch_forward(params, batch["feats_b"],
                                   batch["feats_n1"], batch["feats_n2"],
                                   cfg).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll}


# ---------------------------------------------------------------------------
# batched small graphs (molecule): graph-level readout
# ---------------------------------------------------------------------------

def gnn_graph_batch_loss(params: Params, batch: Dict[str, jax.Array],
                         cfg: GNNConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """feats (G*n, F), edges (G*e, 2) with global node ids, graph_ids (G*n,),
    labels (G,)."""
    n_total = batch["feats"].shape[0]
    n_graphs = batch["labels"].shape[0]
    h = batch["feats"]
    for lyr in params["layers"]:
        neigh = _aggregate(h, batch["edges"], n_total, cfg.aggregator)
        h = jax.nn.relu(layers.dense(lyr["w_self"], h)
                        + layers.dense(lyr["w_neigh"], neigh))
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6)
    pooled = jax.ops.segment_sum(h, batch["graph_ids"],
                                 num_segments=n_graphs)
    cnt = jax.ops.segment_sum(jnp.ones((n_total,), h.dtype),
                              batch["graph_ids"], num_segments=n_graphs)
    pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
    logits = layers.dense(params["out"], pooled).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=1)[:, 0]
    nll = (logz - gold).mean()
    return nll, {"nll": nll}
