"""RecSys CTR/retrieval models: DCN-v2, BST, DIEN (AUGRU), FM.

All four share the embedding substrate (``models/embedding.py``) and a PQ
item catalogue for the ``retrieval_cand`` path: candidates are scored with
PQTopK (the paper's technique) and, where the model has a non-factorised
interaction (DCN/BST/DIEN), the top slate is re-ranked by the full model
(DESIGN.md §4 cascade).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.core import retrieval_head
from repro.distributed.sharding import constrain
from repro.models import attention as attn_lib, embedding, layers

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# shared init
# ---------------------------------------------------------------------------

def _mlp_tower_init(key: jax.Array, d_in: int, widths, dtype) -> list:
    ks = jax.random.split(key, len(widths) + 1)
    tower = []
    prev = d_in
    for i, w in enumerate(widths):
        tower.append(layers.dense_init(ks[i], prev, w, bias=True, dtype=dtype))
        prev = w
    tower.append(layers.dense_init(ks[-1], prev, 1, bias=True, dtype=dtype))
    return tower


def _mlp_tower(tower: list, x: jax.Array) -> jax.Array:
    for p in tower[:-1]:
        x = jax.nn.relu(layers.dense(p, x))
    return layers.dense(tower[-1], x)[..., 0]


def init_recsys(key: jax.Array, cfg: RecsysConfig, codes=None,
                centroids=None) -> Params:
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.param_dtype)
    p: Params = {"emb": embedding.init_tables(ks[0], cfg.table_rows,
                                              cfg.embed_dim, dtype)}
    d_emb = cfg.n_sparse * cfg.embed_dim

    if cfg.kind == "dcn":
        d0 = cfg.n_dense + d_emb
        cross = []
        cks = jax.random.split(ks[1], cfg.n_cross_layers)
        for i in range(cfg.n_cross_layers):
            cross.append(layers.dense_init(cks[i], d0, d0, bias=True,
                                           dtype=dtype))
        p["cross"] = cross
        p["mlp"] = _mlp_tower_init(ks[2], d0, cfg.mlp, dtype)
        p["user_proj"] = layers.dense_init(ks[3], d0, cfg.embed_dim,
                                           dtype=dtype)
    elif cfg.kind == "bst":
        head_dim = cfg.embed_dim * cfg.n_sparse // cfg.n_heads
        d_tok = cfg.embed_dim * cfg.n_sparse        # item+cate per position
        from repro.configs.base import AttentionConfig
        acfg = AttentionConfig(n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
                               head_dim=max(head_dim, 8))
        blocks = []
        for i in range(cfg.n_blocks):
            bks = jax.random.split(jax.random.fold_in(ks[1], i), 2)
            blocks.append({
                "attn": attn_lib.attention_init(bks[0], acfg, d_tok, dtype),
                "ln1": layers.norm_init(d_tok, "layernorm", dtype),
                "ln2": layers.norm_init(d_tok, "layernorm", dtype),
                "mlp": layers.mlp_init(bks[1], d_tok, 4 * d_tok, gated=False,
                                       dtype=dtype),
            })
        p["blocks"] = blocks
        p["pos_emb"] = layers.embedding_init(ks[2], cfg.seq_len + 1, d_tok,
                                             dtype)
        p["mlp"] = _mlp_tower_init(ks[3], d_tok * (cfg.seq_len + 1), cfg.mlp,
                                   dtype)
    elif cfg.kind == "dien":
        d_in = cfg.embed_dim * cfg.n_sparse          # item+cate concat
        p["gru"] = _gru_init(ks[1], d_in, cfg.gru_dim, dtype)
        p["augru"] = _gru_init(ks[2], cfg.gru_dim, cfg.gru_dim, dtype)
        p["att"] = layers.dense_init(ks[3], cfg.gru_dim, d_in, dtype=dtype)
        p["mlp"] = _mlp_tower_init(ks[4], cfg.gru_dim + d_in, cfg.mlp, dtype)
    elif cfg.kind == "fm":
        p["linear"] = {
            "w": [jnp.zeros((r,), dtype) for r in cfg.table_rows],
            "b": jnp.zeros((), dtype),
        }
    else:
        raise ValueError(cfg.kind)

    if cfg.pq is not None:
        # PQ item catalogue for retrieval_cand (query dim = embed_dim).
        p["item_emb"] = retrieval_head.init(ks[6], cfg.n_items, cfg.embed_dim,
                                            cfg.pq, codes=codes,
                                            centroids=centroids,
                                            dtype=jnp.float32)
    return p


def abstract_recsys(cfg: RecsysConfig) -> Params:
    return jax.eval_shape(functools.partial(init_recsys, cfg=cfg),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# GRU / AUGRU (DIEN)
# ---------------------------------------------------------------------------

def _gru_init(key: jax.Array, d_in: int, d_h: int, dtype) -> Params:
    ks = jax.random.split(key, 2)
    scale = (d_in + d_h) ** -0.5
    wx = jax.random.normal(ks[0], (d_in, 3 * d_h), jnp.float32) * scale
    wh = jax.random.normal(ks[1], (d_h, 3 * d_h), jnp.float32) * scale
    return {"wx": wx.astype(dtype), "wh": wh.astype(dtype),
            "b": jnp.zeros((3 * d_h,), dtype)}


def _gru_cell(p: Params, h: jax.Array, x: jax.Array,
              a: jax.Array | None = None) -> jax.Array:
    d_h = h.shape[-1]
    gates = x @ p["wx"].astype(x.dtype) + h @ p["wh"].astype(x.dtype) \
        + p["b"].astype(x.dtype)
    r, z, n = jnp.split(gates, 3, axis=-1)
    r, z = jax.nn.sigmoid(r), jax.nn.sigmoid(z)
    n = jnp.tanh(n + (r - 1.0) * (h @ p["wh"].astype(x.dtype))[..., 2 * d_h:])
    if a is not None:                      # AUGRU: attention-scaled update
        z = z * a[..., None]
    return (1.0 - z) * n + z * h


def gru_scan(p: Params, xs: jax.Array, att: jax.Array | None = None,
             ) -> jax.Array:
    """xs (B, S, d_in) -> all hidden states (B, S, d_h)."""
    b = xs.shape[0]
    d_h = p["wh"].shape[0]
    h0 = jnp.zeros((b, d_h), xs.dtype)

    def step(h, inp):
        if att is None:
            x = inp
            h = _gru_cell(p, h, x)
        else:
            x, a = inp
            h = _gru_cell(p, h, x, a)
        return h, h

    seq = xs.swapaxes(0, 1)
    inputs = seq if att is None else (seq, att.swapaxes(0, 1))
    _, hs = jax.lax.scan(step, h0, inputs)
    return hs.swapaxes(0, 1)


# ---------------------------------------------------------------------------
# forward per kind: pointwise CTR score
# ---------------------------------------------------------------------------

def ctr_logits(params: Params, batch: Dict[str, jax.Array],
               cfg: RecsysConfig) -> jax.Array:
    """Pointwise (user, item) scoring -> logit (B,)."""
    if cfg.kind == "dcn":
        emb = embedding.lookup_fields(params["emb"], batch["sparse"])
        x0 = jnp.concatenate(
            [batch["dense"].astype(emb.dtype),
             emb.reshape(emb.shape[0], -1)], axis=-1)
        x0 = constrain(x0, "hidden")
        x = x0
        for cp in params["cross"]:
            x = x0 * layers.dense(cp, x) + x      # DCN-v2 cross layer
        return _mlp_tower(params["mlp"], x)
    if cfg.kind == "bst":
        # behaviour sequence (B, S, 2) ids + target (B, 2): embed, concat
        # fields per position, prepend target, transformer, MLP.
        seq_emb = _bst_tokens(params, batch["seq"], batch["target"], cfg)
        x = seq_emb
        from repro.configs.base import AttentionConfig
        d_tok = x.shape[-1]
        acfg = AttentionConfig(n_heads=cfg.n_heads, n_kv_heads=cfg.n_heads,
                               head_dim=max(d_tok // cfg.n_heads, 8))
        for blk in params["blocks"]:
            h = layers.apply_norm(blk["ln1"], x, "layernorm")
            h = attn_lib.full_attention(blk["attn"], acfg, h, causal=False)
            x = x + h
            h = layers.apply_norm(blk["ln2"], x, "layernorm")
            x = x + layers.mlp(blk["mlp"], h, "relu")
        return _mlp_tower(params["mlp"], x.reshape(x.shape[0], -1))
    if cfg.kind == "dien":
        seq_emb = embedding.lookup_fields(params["emb"],
                                          batch["seq"].reshape(-1, 2))
        b, s = batch["seq"].shape[:2]
        seq_emb = seq_emb.reshape(b, s, -1)             # (B, S, 2*emb)
        tgt_emb = embedding.lookup_fields(params["emb"], batch["target"])
        tgt_emb = tgt_emb.reshape(b, -1)                # (B, 2*emb)
        hs = gru_scan(params["gru"], seq_emb)           # interest extraction
        att_logits = jnp.einsum(
            "bsd,bd->bs", layers.dense(params["att"], hs), tgt_emb)
        att = jax.nn.softmax(att_logits, axis=-1)
        hs2 = gru_scan(params["augru"], hs, att)        # interest evolution
        final = hs2[:, -1, :]
        x = jnp.concatenate([final, tgt_emb], axis=-1)
        return _mlp_tower(params["mlp"], x)
    if cfg.kind == "fm":
        emb = embedding.lookup_fields(params["emb"], batch["sparse"])
        sum_v = emb.sum(1)
        sum_sq = jnp.square(emb).sum(1)
        pairwise = 0.5 * (jnp.square(sum_v) - sum_sq).sum(-1)
        lin = params["linear"]["b"].astype(pairwise.dtype)
        for i, w in enumerate(params["linear"]["w"]):
            lin = lin + jnp.take(w, batch["sparse"][:, i])
        return lin + pairwise
    raise ValueError(cfg.kind)


def _bst_tokens(params: Params, seq: jax.Array, target: jax.Array,
                cfg: RecsysConfig) -> jax.Array:
    b, s = seq.shape[:2]
    seq_emb = embedding.lookup_fields(params["emb"], seq.reshape(-1, 2))
    seq_emb = seq_emb.reshape(b, s, -1)
    tgt_emb = embedding.lookup_fields(params["emb"], target).reshape(b, 1, -1)
    x = jnp.concatenate([seq_emb, tgt_emb], axis=1)     # (B, S+1, d_tok)
    return x + params["pos_emb"]["table"][None, :s + 1].astype(x.dtype)


def ctr_loss(params: Params, batch: Dict[str, jax.Array], cfg: RecsysConfig,
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = ctr_logits(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    loss = -(y * jax.nn.log_sigmoid(logits)
             + (1 - y) * jax.nn.log_sigmoid(-logits)).mean()
    return loss, {"bce": loss}


# ---------------------------------------------------------------------------
# retrieval: PQTopK over the item catalogue (paper technique)
# ---------------------------------------------------------------------------

def user_query(params: Params, batch: Dict[str, jax.Array],
               cfg: RecsysConfig) -> jax.Array:
    """User-side query vector in item-embedding space (B, embed_dim)."""
    if cfg.kind == "dcn":
        emb = embedding.lookup_fields(params["emb"], batch["sparse"])
        x0 = jnp.concatenate(
            [batch["dense"].astype(emb.dtype),
             emb.reshape(emb.shape[0], -1)], axis=-1)
        return layers.dense(params["user_proj"], x0).astype(jnp.float32)
    if cfg.kind == "bst":
        seq_emb = embedding.lookup_fields(
            params["emb"], batch["seq"].reshape(-1, 2))
        b, s = batch["seq"].shape[:2]
        # Mean-pooled history, item-field half only.
        return seq_emb.reshape(b, s, 2, -1)[:, :, 0, :].mean(1).astype(
            jnp.float32)
    if cfg.kind == "dien":
        seq_emb = embedding.lookup_fields(
            params["emb"], batch["seq"].reshape(-1, 2))
        b, s = batch["seq"].shape[:2]
        seq_emb = seq_emb.reshape(b, s, -1)
        hs = gru_scan(params["gru"], seq_emb)
        # Final interest state projected onto the item half via att weights.
        return layers.dense(params["att"], hs[:, -1, :])[
            :, :cfg.embed_dim].astype(jnp.float32)
    if cfg.kind == "fm":
        emb = embedding.lookup_fields(params["emb"], batch["sparse"])
        return emb.sum(1).astype(jnp.float32)   # FM user-side sum of factors
    raise ValueError(cfg.kind)


def retrieve_topk(params: Params, batch: Dict[str, jax.Array],
                  cfg: RecsysConfig, *, k: int = 10,
                  method: str = "pqtopk"):
    """retrieval_cand path: PQTopK over the n_items catalogue."""
    phi = constrain(user_query(params, batch, cfg), "hidden")
    vals, ids = retrieval_head.top_items(params["item_emb"], phi, k,
                                         method=method)
    return ids, vals
