"""Shared NN building blocks (pure-functional: init_* returns a params dict,
apply functions are free functions)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int, *, bias: bool = False,
               dtype: Any = jnp.float32, scale: float | None = None) -> Params:
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embedding_init(key: jax.Array, vocab: int, d: int,
                   dtype: Any = jnp.float32, scale: float = 0.02) -> Params:
    t = jax.random.normal(key, (vocab, d), jnp.float32) * scale
    return {"table": t.astype(dtype)}


# ---------------------------------------------------------------------------
# norms (fp32 compute)
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str = "rmsnorm", dtype: Any = jnp.float32) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(kind)
    y = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "sqrelu": lambda x: jnp.square(jax.nn.relu(x)),   # Primer / Nemotron
        "tanh": jnp.tanh,
        "sigmoid": jax.nn.sigmoid,
    }[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D), positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                            # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated GLU or plain 2-matrix)
# ---------------------------------------------------------------------------

def mlp_init(key: jax.Array, d_model: int, d_ff: int, *, gated: bool,
             dtype: Any = jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
        "down": dense_init(ks[1], d_ff, d_model, dtype=dtype,
                           scale=d_ff ** -0.5),
    }
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype=dtype)
    return p


def mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    from repro.distributed.sharding import constrain
    f = activation(act)
    h = dense(p["up"], x)
    if "gate" in p:
        h = f(dense(p["gate"], x)) * h
    else:
        h = f(h)
    # TP hook: keeps the d_ff intermediate model-sharded (Megatron-SP
    # layouts set "mlp_hidden" in the activation plan; no-op otherwise).
    h = constrain(h, "mlp_hidden")
    return dense(p["down"], h)
