"""Sparse-feature embedding substrate for recsys archs.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — per the brief this
is built here: per-field tables + ``take``-based single-valued lookup +
bag (multi-hot) lookup via take + masked segment reduce.  Tables are
row-sharded over the ``model`` mesh axis (DESIGN.md §5); the Pallas
``embedding_bag`` kernel is the TPU fast path for the bag case.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def init_tables(key: jax.Array, rows: Sequence[int], dim: int,
                dtype: Any = jnp.float32) -> Params:
    keys = jax.random.split(key, len(rows))
    return {
        "tables": [
            (jax.random.normal(k, (r, dim), jnp.float32) * 0.02).astype(dtype)
            for k, r in zip(keys, rows)
        ]
    }


def lookup_fields(params: Params, ids: jax.Array) -> jax.Array:
    """Single-valued categorical fields.  ids: (B, n_fields) ->
    (B, n_fields, dim)."""
    outs = [jnp.take(t, ids[:, i], axis=0)
            for i, t in enumerate(params["tables"])]
    return jnp.stack(outs, axis=1)


def lookup_bag(table: jax.Array, indices: jax.Array,
               weights: jax.Array | None = None, mode: str = "sum",
               use_kernel: bool = False) -> jax.Array:
    """EmbeddingBag over one table: indices (B, bag), -1 = padding."""
    if use_kernel:
        from repro.kernels.embedding_bag import ops
        return ops.embedding_bag(table, indices, weights, mode=mode)
    mask = (indices >= 0).astype(table.dtype)
    w = mask if weights is None else weights.astype(table.dtype) * mask
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0)
    acc = (rows * w[..., None]).sum(axis=1)
    if mode == "mean":
        acc = acc / jnp.maximum(w.sum(axis=1), 1.0)[:, None]
    return acc


def segment_embedding_bag(table: jax.Array, flat_indices: jax.Array,
                          segment_ids: jax.Array, n_bags: int,
                          weights: jax.Array | None = None,
                          mode: str = "sum") -> jax.Array:
    """Ragged EmbeddingBag: CSR-style (values, segment ids) layout built on
    ``jax.ops.segment_sum`` — the canonical JAX form of torch's
    EmbeddingBag(include_last_offset) API."""
    rows = jnp.take(table, flat_indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    acc = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            jnp.ones_like(flat_indices, table.dtype), segment_ids,
            num_segments=n_bags)
        acc = acc / jnp.maximum(cnt, 1.0)[:, None]
    return acc
