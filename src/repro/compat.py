"""JAX version-compatibility layer — the single import point for symbols
that drifted across JAX releases.

Policy
------
Library code in ``repro`` must not reach into ``jax.experimental`` (or probe
``jax`` top-level attributes) for any symbol whose home has moved between
JAX releases.  Each such symbol is resolved exactly once, here, at import
time, and re-exported under a stable name:

* ``shard_map``    — ``jax.shard_map`` (new) falling back to
  ``jax.experimental.shard_map.shard_map`` (old).  The wrapper accepts the
  *new* keyword surface (``check_vma``, ``axis_names``) and translates to
  the legacy one (``check_rep``, ``auto``) when running on an old JAX.
* ``ANY`` / ``VMEM`` / ``SMEM`` — Pallas TPU memory-space symbols.  New
  releases expose ``pltpu.MemorySpace``; older ones ``pltpu.TPUMemorySpace``
  (same enum values, different name).
* ``on_tpu()``     — backend probe shared by the kernel wrappers to pick
  interpret mode on CPU containers.

Adding a shim: resolve the newest spelling first, fall back to older ones,
and keep the exported surface matching the *newest* JAX API so that call
sites never degrade and the fallback branch is the one that eventually
rots away.  Never version-sniff with ``jax.__version__`` — probe for the
symbol itself.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

# ---------------------------------------------------------------------------
# shard_map: jax.shard_map (>= 0.4.35ish top-level export, keyword surface
# check_vma/axis_names) vs jax.experimental.shard_map.shard_map
# (check_rep/auto).
# ---------------------------------------------------------------------------

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _LEGACY_SHARD_MAP
else:
    _LEGACY_SHARD_MAP = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              axis_names: Optional[Any] = None):
    """``jax.shard_map`` with a version-stable keyword surface.

    ``check_vma`` maps to the legacy ``check_rep``; ``axis_names`` (the set
    of mesh axes the body is Manual over — all axes when ``None``) maps to
    the legacy complement argument ``auto``.
    """
    if _NEW_SHARD_MAP is not None:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return _NEW_SHARD_MAP(f, **kwargs)
    kwargs = dict(check_rep=check_vma)
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _LEGACY_SHARD_MAP(f, mesh, in_specs, out_specs, **kwargs)


# ---------------------------------------------------------------------------
# axis_size: jax.lax.axis_size (new) vs psum(1, axis) (works everywhere but
# costs a trivial collective on old JAX; new JAX reads the mesh statically).
# ---------------------------------------------------------------------------

def axis_size(axis_name) -> Any:
    """Size of a mapped mesh axis, inside a Manual region."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# Pallas TPU memory spaces: pltpu.MemorySpace (new) vs pltpu.TPUMemorySpace.
# Import lazily-ish but resolve eagerly: pallas is always present in this
# container; guard anyway so non-kernel code can import repro.compat on a
# jax build without pallas extras.
# ---------------------------------------------------------------------------

try:
    from jax.experimental.pallas import tpu as _pltpu
except ImportError:                                    # pragma: no cover
    _pltpu = None

if _pltpu is not None:
    _MEMORY_SPACE = getattr(_pltpu, "MemorySpace",
                            getattr(_pltpu, "TPUMemorySpace", None))
    ANY = _MEMORY_SPACE.ANY
    VMEM = _MEMORY_SPACE.VMEM
    SMEM = _MEMORY_SPACE.SMEM
else:                                                  # pragma: no cover
    _MEMORY_SPACE = ANY = VMEM = SMEM = None


def tpu_memory_space():
    """The Pallas TPU memory-space enum under whichever name this JAX has."""
    if _MEMORY_SPACE is None:                          # pragma: no cover
        raise ImportError("jax.experimental.pallas.tpu is unavailable")
    return _MEMORY_SPACE


def prefetch_scalar_grid_spec(*, num_scalar_prefetch, grid, in_specs,
                              out_specs):
    """``pltpu.PrefetchScalarGridSpec`` — the TPU grid spec whose scalar
    operands are available to BlockSpec index maps (the mechanism behind
    the compacted tile-index grid of the pruned retrieval route)."""
    if _pltpu is None:                                 # pragma: no cover
        raise ImportError("jax.experimental.pallas.tpu is unavailable")
    return _pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=num_scalar_prefetch, grid=grid,
        in_specs=in_specs, out_specs=out_specs)


# ---------------------------------------------------------------------------
# Backend probe shared by the kernel wrappers.
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    """True when the default JAX backend is a TPU (kernels compile);
    False on CPU/GPU containers (kernels run in interpret mode)."""
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:
        return False
