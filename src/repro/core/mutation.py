"""Streaming catalogue mutation over the pruned PQ head (ISSUE 7).

Production catalogues churn — new items, delistings, embedding drift —
but the cascade's exactness argument (docs/PRUNING.md) only needs tile
bounds that *dominate* live item scores.  That asymmetry is the whole
design:

* **insert** — OR the new row's presence bits into its tile (bitmask) or
  widen the tile's code range (range).  The tile's bound now covers the
  new item exactly; every other item's coverage is untouched.  Exact,
  never stale.
* **delete** — flip the row's ``live`` bit off and leave its metadata
  bits in place.  The bound can only be *looser* than a fresh build
  (it still covers a code set that is a superset of the live items'),
  so it still dominates and the cascade stays exact; the tombstoned item
  itself is masked to ``-inf`` inside the scoring kernel and can never
  surface in the top-k.  A per-tile staleness counter records the debt.
* **update** — delete's bound-loosening plus insert's OR-in/widen for
  the new codes, on the same row.  Exact, increasingly loose.

Loose bounds cost *work* (fewer tiles pruned), never *answers* —
:meth:`MutableHeadState.retighten` rebuilds the stalest tiles' metadata
exactly (one ``dynamic_slice`` per tile, off the serve path) and resets
their counters.  A full retighten is bit-identical to
:func:`repro.core.pruning.build_pruned_state_masked` over the current
codes + live mask — the rebuilt-from-scratch oracle the churn property
tests compare against.

Serving never sees any of this machinery: the engine consumes
:meth:`head_arrays` — ``{"codes", "pruned", "live"}`` with *static*
shapes (the catalogue is padded to a fixed power-of-two capacity and
``live`` is a traced data array) — so hot-swapping a mutated head into
``RetrievalEngine`` is a pure data swap, zero recompiles
(``serving/engine.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import (BOUND_BACKENDS, DEFAULT_PRUNE_TILE,
                                PrunedHeadState, build_pruned_state_masked,
                                pack_presence, with_super)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, int(n) - 1).bit_length()


class CapacityError(RuntimeError):
    """Raised by insert when every capacity slot is live (the caller must
    rebuild at a larger capacity — a shape change, hence a recompile)."""


@jax.jit
def _set_row(codes, live, slot, row):
    return codes.at[slot].set(row), live.at[slot].set(True)


@jax.jit
def _clear_row(live, slot):
    return live.at[slot].set(False)


@partial(jax.jit, static_argnames=("b",))
def _or_in_presence(packed, t, row, b):
    """OR one row's presence bits into tile t's packed bitmask.  Built via
    :func:`pack_presence` on the row's one-hot so the bit layout is
    consistent with the bulk builders by construction."""
    iota = jnp.arange(b, dtype=jnp.int32)
    present = row.astype(jnp.int32)[:, None] == iota[None, :]      # (m, b)
    word = pack_presence(present[None])[0]                         # (m, W)
    return packed.at[t].set(packed[t] | word)


@jax.jit
def _widen_range(lo, hi, t, row):
    c = row.astype(jnp.int16)
    return lo.at[t].min(c), hi.at[t].max(c)


@jax.jit
def _set_point_range(lo, hi, t, row):
    """Exact point range for a tile whose ONLY live row is ``row``.  The
    masked builder clamps an empty tile to [0, 0], and min-widening can
    never lift that phantom ``lo=0`` back up — so the first insert into
    an empty tile must SET, not widen (else the tile is permanently
    looser than the rebuild oracle and retighten parity breaks)."""
    c = row.astype(jnp.int16)
    return lo.at[t].set(c), hi.at[t].set(c)


@partial(jax.jit, static_argnames=("b", "tile"))
def _retighten_tile_packed(packed, codes, live, t, b, tile):
    """Exact rebuild of ONE tile's presence bitmask from its live rows."""
    m = codes.shape[1]
    rows = jax.lax.dynamic_slice(codes, (t * tile, 0), (tile, m))
    lv = jax.lax.dynamic_slice(live, (t * tile,), (tile,))
    iota = jnp.arange(b, dtype=jnp.int32)
    present = ((rows.astype(jnp.int32)[:, :, None] == iota)
               & lv[:, None, None]).any(axis=0)                    # (m, b)
    return jax.lax.dynamic_update_slice(packed, pack_presence(present[None]),
                                        (t, 0, 0))


@partial(jax.jit, static_argnames=("factor",))
def _recompute_super_packed(super_packed, packed, sup, factor):
    """Exact rebuild of ONE super-tile's bitmask = OR of its (current)
    child tile bitmasks.  Children may themselves still be stale; OR of
    dominating masks dominates, so the super stays safe either way."""
    m, w = packed.shape[1], packed.shape[2]
    kids = jax.lax.dynamic_slice(packed, (sup * factor, 0, 0),
                                 (factor, m, w))
    word = kids[0]
    for i in range(1, factor):
        word = word | kids[i]
    return jax.lax.dynamic_update_slice(super_packed, word[None],
                                        (sup, 0, 0))


@partial(jax.jit, static_argnames=("factor",))
def _recompute_super_range(super_lo, super_hi, lo, hi, sup, factor):
    """Exact rebuild of ONE super-tile's [lo, hi] hull over its children."""
    m = lo.shape[1]
    klo = jax.lax.dynamic_slice(lo, (sup * factor, 0), (factor, m))
    khi = jax.lax.dynamic_slice(hi, (sup * factor, 0), (factor, m))
    return (jax.lax.dynamic_update_slice(super_lo, klo.min(axis=0)[None],
                                         (sup, 0)),
            jax.lax.dynamic_update_slice(super_hi, khi.max(axis=0)[None],
                                         (sup, 0)))


@partial(jax.jit, static_argnames=("tile",))
def _retighten_tile_range(lo, hi, codes, live, t, tile):
    """Exact rebuild of ONE tile's [lo, hi] code range from its live rows
    (same empty-tile clamp as ``_build_code_ranges_masked``)."""
    m = codes.shape[1]
    rows = jax.lax.dynamic_slice(codes, (t * tile, 0),
                                 (tile, m)).astype(jnp.int32)
    lv = jax.lax.dynamic_slice(live, (t * tile,), (tile,))[:, None]
    lo_t = jnp.where(lv, rows, jnp.int32(2 ** 15 - 1)).min(axis=0)
    hi_t = jnp.where(lv, rows, jnp.int32(0)).max(axis=0)
    lo_t = jnp.minimum(lo_t, hi_t)
    hi_t = jnp.maximum(hi_t, lo_t)
    return (jax.lax.dynamic_update_slice(lo, lo_t[None].astype(jnp.int16),
                                         (t, 0)),
            jax.lax.dynamic_update_slice(hi, hi_t[None].astype(jnp.int16),
                                         (t, 0)))


class MutableHeadState:
    """Host-side manager of a mutable PQ catalogue + its pruning metadata.

    Holds capacity-padded device arrays with STATIC shapes — ``codes``
    (cap, m), ``live`` (cap,) bool, a flat :class:`PrunedHeadState` over
    the padded catalogue — plus host bookkeeping: a freelist of
    tombstoned slots (insert reuses them, so capacity is an amortised
    bound on *live* items, not on mutation count) and a per-tile
    staleness counter driving lazy re-tightening.

    Not a pytree and never traced: mutations are tiny jitted updates
    (O(tile) or O(m·b), one compile each for the life of the process),
    and serving reads one immutable snapshot via :meth:`head_arrays`.
    Like the frozen head, row 0 is the id-0 padding row and stays live.
    """

    def __init__(self, codes, live, state: PrunedHeadState,
                 staleness: np.ndarray, free: list, n_rows: int):
        self.codes = codes
        self.live = live
        self.state = state
        self.staleness = staleness
        self.free = free
        self.n_rows = n_rows          # high-water mark of ever-used slots
        self.n_mutations = 0

    # -- construction -----------------------------------------------------

    @classmethod
    def build(cls, codes, b: int, tile: int = DEFAULT_PRUNE_TILE, *,
              backend: str = "bitmask",
              capacity: Optional[int] = None,
              super_factor: int = 0) -> "MutableHeadState":
        """Pad ``codes`` (n, m) to a pow2 capacity (>= tile, a tile
        multiple — so every tile slice is full and `dynamic_slice` stays
        in bounds), mark rows [0, n) live, and build exact live-masked
        tile metadata.  Pass ``capacity`` for extra insert headroom; any
        later capacity change is a shape change (rebuild + recompile).

        ``super_factor > 1`` adds the hierarchical super-tile level
        (:func:`repro.core.pruning.with_super`); capacity is then rounded
        to a ``tile * super_factor`` multiple so every super-tile owns
        exactly ``super_factor`` real children and the per-super
        ``dynamic_slice`` recompute never straddles a padded edge."""
        if backend not in BOUND_BACKENDS:
            raise ValueError(f"unknown bound backend {backend!r}")
        n, m = codes.shape
        tile = max(1, min(int(tile), n))
        super_factor = 0 if super_factor <= 1 else int(super_factor)
        grain = tile * super_factor if super_factor else tile
        cap = next_pow2(max(n, 1)) if capacity is None else int(capacity)
        cap = max(cap, tile, n)
        cap = -(-cap // grain) * grain
        codes_cap = jnp.zeros((cap, m), codes.dtype).at[:n].set(codes)
        live = jnp.zeros((cap,), jnp.bool_).at[:n].set(True)
        state = build_pruned_state_masked(codes_cap, live, b, tile,
                                          backend=backend)
        if super_factor:
            state = with_super(state, super_factor)
        return cls(codes_cap, live, state,
                   staleness=np.zeros(state.n_tiles, np.int64),
                   free=[], n_rows=n)

    # -- properties -------------------------------------------------------

    @property
    def cap(self) -> int:
        return self.codes.shape[0]

    @property
    def m(self) -> int:
        return self.codes.shape[1]

    @property
    def tile(self) -> int:
        return self.state.tile

    @property
    def b(self) -> int:
        return self.state.b

    @property
    def backend(self) -> str:
        return self.state.backend

    @property
    def n_live(self) -> int:
        return int(self.live.sum())

    @property
    def super_factor(self) -> int:
        return self.state.super_factor

    # -- mutations --------------------------------------------------------

    def _check_row(self, row):
        row = jnp.asarray(row, self.codes.dtype)
        if row.shape != (self.m,):
            raise ValueError(f"item row shape {row.shape} != ({self.m},)")
        return row

    def _absorb(self, slot: int, row) -> None:
        """OR/widen tile metadata so it covers ``row`` at ``slot`` — the
        exact-on-insert half of every mutation.  A hierarchical state
        absorbs the row at BOTH levels (the super helpers are the same
        jitted updates over the super arrays — loosen-only, so the
        super bound keeps dominating its children's)."""
        t = slot // self.tile
        st = self.state
        if self.backend == "range":
            t0 = t * self.tile
            solo = int(self.live[t0:t0 + self.tile].sum()) == 1
            if solo:
                lo, hi = _set_point_range(st.code_lo, st.code_hi, t, row)
                st = dataclasses.replace(st, code_lo=lo, code_hi=hi)
                # The tile is exactly [row, row] == the oracle's rebuild:
                # whatever debt its dead predecessors left is gone.
                self.staleness[t] = 0
                if st.has_super:
                    # The child just got TIGHTER, which widening can't
                    # express — recompute its super from current children
                    # (dominating whether or not siblings are stale).
                    slo, shi = _recompute_super_range(
                        st.super_lo, st.super_hi, st.code_lo, st.code_hi,
                        t // st.super_factor, factor=st.super_factor)
                    st = dataclasses.replace(st, super_lo=slo,
                                             super_hi=shi)
            else:
                lo, hi = _widen_range(st.code_lo, st.code_hi, t, row)
                st = dataclasses.replace(st, code_lo=lo, code_hi=hi)
                if st.has_super:
                    slo, shi = _widen_range(st.super_lo, st.super_hi,
                                            t // st.super_factor, row)
                    st = dataclasses.replace(st, super_lo=slo,
                                             super_hi=shi)
        else:
            packed = _or_in_presence(st.packed, t, row, self.b)
            st = dataclasses.replace(st, packed=packed)
            if st.has_super:
                sp = _or_in_presence(st.super_packed,
                                     t // st.super_factor, row, self.b)
                st = dataclasses.replace(st, super_packed=sp)
        self.state = st

    def insert(self, row) -> int:
        """Add an item; returns its slot (= item id).  Reuses the oldest
        tombstoned slot when one exists.  Exact: the new row's bits enter
        the tile metadata immediately; a reused slot's tile keeps its
        previous staleness (the dead predecessor's bits are still there)."""
        row = self._check_row(row)
        if self.free:
            slot = self.free.pop(0)
        elif self.n_rows < self.cap:
            slot = self.n_rows
            self.n_rows += 1
        else:
            raise CapacityError(
                f"catalogue capacity {self.cap} exhausted ({self.n_live} "
                f"live); rebuild with MutableHeadState.build(capacity="
                f"{self.cap * 2}) and engine swap at the new shape")
        self.codes, self.live = _set_row(self.codes, self.live, slot, row)
        self._absorb(slot, row)
        self.n_mutations += 1
        return slot

    def delete(self, item_id: int) -> None:
        """Tombstone an item: live bit off, metadata untouched (bounds go
        stale-but-dominating), slot queued for reuse."""
        item_id = int(item_id)
        if not (0 < item_id < self.cap):
            raise ValueError(f"item id {item_id} out of range (0, {self.cap})"
                             " — row 0 is the reserved padding id")
        if not bool(self.live[item_id]):
            raise ValueError(f"item {item_id} is not live")
        self.live = _clear_row(self.live, item_id)
        self.free.append(item_id)
        self.staleness[item_id // self.tile] += 1
        self.n_mutations += 1

    def update(self, item_id: int, row) -> None:
        """Re-code a live item in place: the new codes are absorbed
        (exact), the old codes' bits linger (stale)."""
        item_id = int(item_id)
        if not (0 <= item_id < self.cap) or not bool(self.live[item_id]):
            raise ValueError(f"item {item_id} is not live")
        row = self._check_row(row)
        self.codes, self.live = _set_row(self.codes, self.live, item_id, row)
        self._absorb(item_id, row)
        self.staleness[item_id // self.tile] += 1
        self.n_mutations += 1

    # -- durability hooks (serving/catalogue_log.py) ----------------------

    def clone(self) -> "MutableHeadState":
        """Independent manager over the SAME current snapshot.  Device
        arrays are immutable (every mutation functionally replaces them),
        so they are shared; the host bookkeeping — staleness tallies and
        the FIFO freelist, whose order decides which slot the next insert
        reuses — is copied.  Replicas each own a clone and replay the
        same op stream, which is what makes their states bit-identical."""
        c = MutableHeadState(self.codes, self.live, self.state,
                             self.staleness.copy(), list(self.free),
                             self.n_rows)
        c.n_mutations = self.n_mutations
        return c

    @classmethod
    def from_snapshot(cls, codes, live, free, n_rows: int, b: int,
                      tile: int, *, backend: str = "bitmask",
                      super_factor: int = 0) -> "MutableHeadState":
        """Rebuild a manager from durably stored arrays: capacity-padded
        ``codes``/``live``, the freelist IN ORDER, and the slot
        high-water mark.  The pruning metadata is rebuilt exactly from
        codes + live — i.e. the restored state IS :meth:`rebuild_oracle`
        of the snapshot, so staleness restarts at zero (the snapshot
        writer's incremental debt is not an observable of the catalogue,
        only of its serving cost)."""
        codes = jnp.asarray(codes)
        live = jnp.asarray(live, jnp.bool_)
        state = build_pruned_state_masked(codes, live, b, tile,
                                          backend=backend)
        if super_factor:
            state = with_super(state, super_factor)
        return cls(codes, live, state,
                   staleness=np.zeros(state.n_tiles, np.int64),
                   free=[int(s) for s in free], n_rows=int(n_rows))

    # -- maintenance ------------------------------------------------------

    def retighten(self, tile_ids=None, max_tiles: Optional[int] = None):
        """Exactly rebuild the stalest tiles' metadata (off the serve
        path).  Default: every tile with staleness > 0, stalest first;
        ``max_tiles`` bounds the work per call.  Returns the tile ids
        re-tightened.  After retightening ALL stale tiles the state is
        bit-identical to :meth:`rebuild_oracle`."""
        if tile_ids is None:
            order = np.argsort(-self.staleness, kind="stable")
            tile_ids = [int(t) for t in order if self.staleness[t] > 0]
        else:
            tile_ids = [int(t) for t in tile_ids]
        if max_tiles is not None:
            tile_ids = tile_ids[:int(max_tiles)]
        st = self.state
        touched_supers = set()
        for t in tile_ids:
            if st.backend == "range":
                lo, hi = _retighten_tile_range(st.code_lo, st.code_hi,
                                               self.codes, self.live, t,
                                               tile=st.tile)
                st = dataclasses.replace(st, code_lo=lo, code_hi=hi)
            else:
                packed = _retighten_tile_packed(st.packed, self.codes,
                                                self.live, t, b=st.b,
                                                tile=st.tile)
                st = dataclasses.replace(st, packed=packed)
            if st.has_super:
                touched_supers.add(t // st.super_factor)
            self.staleness[t] = 0
        # Each touched super is recomputed ONCE from its current children
        # (after all of this call's child rebuilds): OR/hull of dominating
        # child metadata dominates, and once every stale child is exact
        # the super is exact too — bit-identical to `rebuild_oracle`.
        for sup in sorted(touched_supers):
            if st.backend == "range":
                slo, shi = _recompute_super_range(
                    st.super_lo, st.super_hi, st.code_lo, st.code_hi,
                    sup, factor=st.super_factor)
                st = dataclasses.replace(st, super_lo=slo, super_hi=shi)
            else:
                sp = _recompute_super_packed(st.super_packed, st.packed,
                                             sup, factor=st.super_factor)
                st = dataclasses.replace(st, super_packed=sp)
        self.state = st
        return tile_ids

    def rebuild_oracle(self) -> PrunedHeadState:
        """From-scratch exact state over the current codes + live mask —
        the bit-parity reference for retighten and the churn tests.
        Carries the same super level as the managed state."""
        st = build_pruned_state_masked(self.codes, self.live, self.b,
                                       self.tile, backend=self.backend)
        if self.super_factor:
            st = with_super(st, self.super_factor)
        return st

    # -- serving snapshot -------------------------------------------------

    def head_arrays(self) -> Dict[str, object]:
        """Immutable snapshot for the serving head: merge into
        ``params["item_emb"]`` (or hand to ``engine.swap_head_state``).
        All shapes/dtypes are mutation-invariant, so swapping snapshots
        never recompiles."""
        return {"codes": self.codes, "pruned": self.state,
                "live": self.live}

    def stats(self) -> Dict[str, float]:
        return {"capacity": float(self.cap), "n_live": float(self.n_live),
                "n_free": float(len(self.free)),
                "n_mutations": float(self.n_mutations),
                "stale_tiles": float(int((self.staleness > 0).sum())),
                "max_staleness": float(int(self.staleness.max()))}


def apply_op(state: MutableHeadState, op) -> Optional[int]:
    """Apply one logged mutation op to ``state``.

    Ops are the wire/tuple form the catalogue WAL records:
    ``("insert", row)``, ``("delete", item_id)``, ``("update", item_id,
    row)``.  Validation (liveness, range, capacity) happens BEFORE any
    mutation inside the insert/delete/update methods, so a rejected op
    leaves the state untouched — the log writer relies on that to keep
    invalid ops out of the durable stream.  Replaying a logged stream in
    LSN order through this function is deterministic (the FIFO freelist
    decides slot reuse), which is what makes log replay reproduce the
    writer's catalogue bit-for-bit."""
    kind = op[0]
    if kind == "insert":
        return state.insert(op[1])
    if kind == "delete":
        state.delete(op[1])
        return None
    if kind == "update":
        state.update(op[1], op[2])
        return None
    raise ValueError(f"unknown catalogue op kind {kind!r}")
