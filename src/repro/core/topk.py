"""Top-K selection: exact, tiled (two-stage), and shard-local + merge.

On TPUs ``lax.top_k`` over 10⁶–10⁹ columns is sort-bound; the two-stage tiled
variant reduces the sorted set from N to (N/tile)*k first-stage winners, and
the distributed variant keeps collective volume at O(k * n_shards) instead of
O(N) (DESIGN.md §3/§5).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

# A plain Python float, NOT a jnp scalar: modules can be imported lazily
# inside an active jit trace, and materialising a module-level jnp
# constant under a trace leaks a tracer (enforced by the ast-lint pass).
NEG_INF = float("-inf")


def topk(scores: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Exact top-k. scores: (B, N) -> (values (B,k), indices (B,k))."""
    return jax.lax.top_k(scores, k)


def tiled_topk(scores: jax.Array, k: int, tile: int = 8192,
               ) -> Tuple[jax.Array, jax.Array]:
    """Two-stage exact top-k: per-tile top-k, then top-k over winners.

    Exact because every global top-k element is a top-k element of its tile.
    Non-dividing N is padded with ``-inf`` (padding can never win, and ties
    among real elements keep their lowest-index order), so odd catalogue
    sizes stay on the tiled path instead of falling back to a full
    ``lax.top_k`` sort over N.
    """
    b, n = scores.shape
    if n <= tile:
        return jax.lax.top_k(scores, k)
    if n % tile:
        scores = jnp.pad(scores, ((0, 0), (0, (-n) % tile)),
                         constant_values=NEG_INF)
    n_tiles = scores.shape[1] // tile
    kk = min(k, tile)
    tiles = scores.reshape(b, n_tiles, tile)
    tv, ti = jax.lax.top_k(tiles, kk)                  # (B, T, kk)
    base = (jnp.arange(n_tiles, dtype=jnp.int32) * tile)[None, :, None]
    cand_v = tv.reshape(b, n_tiles * kk)
    cand_i = (ti.astype(jnp.int32) + base).reshape(b, n_tiles * kk)
    fv, fi = jax.lax.top_k(cand_v, k)
    return fv, jnp.take_along_axis(cand_i, fi, axis=1)


def merge_local_topk(local_vals: jax.Array, local_ids: jax.Array, k: int,
                     axis_name: str) -> Tuple[jax.Array, jax.Array]:
    """Inside ``shard_map``: merge per-shard top-k candidates.

    local_vals/local_ids: (B, k_local) shard-local winners with *global*
    item ids.  Collective: O(k_local * n_shards) values + indices,
    independent of N — the merge half of the item-sharded retrieval path,
    shared by the XLA scorers and the fused Pallas kernel (whose shard-local
    top-k already happened tile-by-tile in VMEM).
    """
    all_v = jax.lax.all_gather(local_vals, axis_name, axis=1, tiled=True)
    all_i = jax.lax.all_gather(local_ids, axis_name, axis=1, tiled=True)
    fv, fi = jax.lax.top_k(all_v, k)                   # (B, S*k_local) -> k
    return fv, jnp.take_along_axis(all_i, fi, axis=1)


def local_then_merge_topk(scores_local: jax.Array, k: int, axis_name: str,
                          shard_offset: jax.Array,
                          ) -> Tuple[jax.Array, jax.Array]:
    """Inside ``shard_map``: local top-k then all-gather + final top-k.

    scores_local: (B, N_local) on each shard; shard_offset: scalar global
    offset of this shard's first item.  Collective: O(k * n_shards) values +
    indices, independent of N.
    """
    lv, li = jax.lax.top_k(scores_local, min(k, scores_local.shape[-1]))
    gi = li.astype(jnp.int32) + shard_offset.astype(jnp.int32)
    return merge_local_topk(lv, gi, k, axis_name)


def approx_topk_maxblock(scores: jax.Array, k: int,
                         oversample: int = 2) -> Tuple[jax.Array, jax.Array]:
    """Approximate top-k: split N into k*oversample blocks, take each block's
    max (TPU-friendly: one reduction, no sort over N).  Recall ~= 1 - k/(2B)
    for random score placement [Chern+ 2022, arXiv:2206.14286].
    """
    b, n = scores.shape
    n_blocks = min(k * oversample, n)
    pad = (-n) % n_blocks
    if pad:
        scores = jnp.pad(scores, ((0, 0), (0, pad)), constant_values=NEG_INF)
    blk = scores.reshape(b, n_blocks, -1)
    bv = blk.max(axis=2)
    bi = blk.argmax(axis=2).astype(jnp.int32)
    width = blk.shape[2]
    gi = bi + (jnp.arange(n_blocks, dtype=jnp.int32) * width)[None, :]
    fv, fi = jax.lax.top_k(bv, k)
    return fv, jnp.take_along_axis(gi, fi, axis=1)
