"""Codebook builders: item id -> m sub-ids (Eq. 1 of the paper).

Three strategies:

* ``svd``    — RecJPQ [WSDM'24]: truncated SVD of the user-item interaction
               matrix gives item factors; each of the m factor sub-spaces is
               k-means-clustered into b centroids; an item's sub-id in split k
               is its cluster in sub-space k.  Centroids initialise the
               sub-embeddings.
* ``kmeans`` — classic PQ [Jégou+ TPAMI'11] on a given embedding matrix.
* ``random`` — uniform random codes (used by the paper's RQ2 simulations and
               by our scaling benchmarks; scoring cost is independent of the
               assignment quality).

All builders are host-side (numpy/scipy) — codebook construction happens once
before training, like building a tokenizer.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.configs.base import PQConfig


def _kmeans(x: np.ndarray, n_clusters: int, n_iter: int = 25,
            seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Lloyd's k-means (numpy). Returns (centroids [b,d], assignment [n])."""
    n, d = x.shape
    rng = np.random.default_rng(seed)
    if n <= n_clusters:
        # Degenerate: fewer points than clusters — pad with noise copies.
        centroids = np.zeros((n_clusters, d), x.dtype)
        centroids[:n] = x
        centroids[n:] = x[rng.integers(0, n, n_clusters - n)] + rng.normal(
            0, 1e-3, (n_clusters - n, d)).astype(x.dtype)
        return centroids, np.arange(n) % n_clusters
    # k-means++ style seeding (cheap variant: distinct random picks).
    centroids = x[rng.choice(n, n_clusters, replace=False)].copy()
    assign = np.zeros(n, np.int64)
    for _ in range(n_iter):
        # Chunked distance computation to bound memory at n*b floats.
        d2 = (
            (x ** 2).sum(1, keepdims=True)
            - 2.0 * x @ centroids.T
            + (centroids ** 2).sum(1)[None, :]
        )
        new_assign = d2.argmin(1)
        if (new_assign == assign).all():
            assign = new_assign
            break
        assign = new_assign
        for c in range(n_clusters):
            mask = assign == c
            if mask.any():
                centroids[c] = x[mask].mean(0)
            else:  # dead centroid: re-seed on the farthest point
                centroids[c] = x[d2.min(1).argmax()]
    return centroids.astype(np.float32), assign.astype(np.int64)


def build_random(n_items: int, pq: PQConfig, seed: int = 0) -> np.ndarray:
    """Uniform random codes, shape (n_items, m)."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, pq.b, size=(n_items, pq.m), dtype=np.int64)


def build_kmeans(embeddings: np.ndarray, pq: PQConfig, seed: int = 0,
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Classic PQ: split embedding dims into m sub-spaces, k-means each.

    Returns (codes [n,m] int64, centroids [m,b,d/m] f32).
    """
    n, d = embeddings.shape
    if d % pq.m:
        raise ValueError(f"d={d} not divisible by m={pq.m}")
    sub = d // pq.m
    codes = np.zeros((n, pq.m), np.int64)
    cents = np.zeros((pq.m, pq.b, sub), np.float32)
    for k in range(pq.m):
        c, a = _kmeans(embeddings[:, k * sub:(k + 1) * sub].astype(np.float32),
                       pq.b, seed=seed + k)
        cents[k], codes[:, k] = c, a
    return codes, cents


def build_svd(user_ids: np.ndarray, item_ids: np.ndarray, n_users: int,
              n_items: int, d_model: int, pq: PQConfig, seed: int = 0,
              ) -> Tuple[np.ndarray, np.ndarray]:
    """RecJPQ codebook: truncated SVD of the interaction matrix + per-split
    k-means.  Returns (codes [n_items,m], centroid init [m,b,d_model/m]).
    """
    from scipy.sparse import coo_matrix
    from scipy.sparse.linalg import svds

    rank = min(max(pq.m * 4, 8), min(n_users, n_items) - 1, 128)
    mat = coo_matrix(
        (np.ones(len(user_ids), np.float32), (user_ids, item_ids)),
        shape=(n_users, n_items),
    ).tocsr()
    _, s, vt = svds(mat, k=rank, random_state=np.random.default_rng(seed))
    item_factors = (vt.T * s[None, :]).astype(np.float32)  # (n_items, rank)
    # Split the factor space into m sub-spaces (pad rank up to a multiple).
    pad = (-item_factors.shape[1]) % pq.m
    if pad:
        item_factors = np.pad(item_factors, ((0, 0), (0, pad)))
    sub = item_factors.shape[1] // pq.m
    codes = np.zeros((n_items, pq.m), np.int64)
    for k in range(pq.m):
        _, codes[:, k] = _kmeans(item_factors[:, k * sub:(k + 1) * sub],
                                 pq.b, seed=seed + k)
    # Centroid init in model space: zeros-mean gaussian scaled like the
    # factors (the trainable sub-embeddings are learned afterwards; RecJPQ
    # only needs the *assignment* from SVD).
    rng = np.random.default_rng(seed)
    if d_model % pq.m:
        raise ValueError(f"d_model={d_model} not divisible by m={pq.m}")
    cents = rng.normal(0.0, 0.02, (pq.m, pq.b, d_model // pq.m)).astype(np.float32)
    return codes, cents


def build_codebook(pq: PQConfig, n_items: int, *, d_model: Optional[int] = None,
                   embeddings: Optional[np.ndarray] = None,
                   interactions: Optional[Tuple[np.ndarray, np.ndarray, int]] = None,
                   seed: int = 0) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Dispatch on ``pq.assign``. Returns (codes, centroid_init or None)."""
    if pq.assign == "random":
        return build_random(n_items, pq, seed), None
    if pq.assign == "kmeans":
        if embeddings is None:
            raise ValueError("kmeans assignment needs an embedding matrix")
        return build_kmeans(embeddings, pq, seed)
    if pq.assign == "svd":
        if interactions is None:
            raise ValueError("svd assignment needs (user_ids, item_ids, n_users)")
        if d_model is None:
            raise ValueError("svd assignment needs d_model")
        u, i, n_users = interactions
        return build_svd(u, i, n_users, n_items, d_model, pq, seed)
    raise ValueError(f"unknown assignment strategy {pq.assign!r}")
