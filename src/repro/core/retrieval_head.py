"""RetrievalHead: "score a huge id space against a query vector, top-K".

The paper's technique packaged as the first-class head used by every arch
that retrieves from a large id space (seqrec items, LM vocab at decode,
recsys candidate catalogues).  Holds either

* a PQ representation  — ``{"codes": (N, m), "sub_emb": (m, b, d/m)}``, or
* a dense table        — ``{"table": (N, d)}`` (Transformer-Default baseline)

and exposes scoring via any of the paper's three algorithms plus the Pallas
kernel path and the item-sharded distributed path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import PQConfig
from repro.core import pq as pq_lib
from repro.core import pruning, scoring, topk as topk_lib
from repro.distributed.sharding import manual_axis_map

Params = Dict[str, Any]

#: Methods accepted by ``top_items``/``serve_topk`` — the paper's three
#: algorithms plus the Pallas routes (scores-only kernel, fused
#: score+top-k kernel), the cascaded pruned route, and the approximate
#: block-max route.
TOP_ITEMS_METHODS = ("dense", "recjpq", "pqtopk", "pqtopk_onehot",
                     "pqtopk_kernel", "pqtopk_fused", "pqtopk_pruned",
                     "pqtopk_approx")

#: Methods whose full cascade needs host orchestration (a device->host sync
#: between the bound pass and the compacted scoring pass).  Inside jit,
#: ``top_items`` falls back to an in-graph masked variant that is exact but
#: scores all tiles; ``top_items_pruned`` is the real two-dispatch cascade.
HOST_CASCADE_METHODS = ("pqtopk_pruned",)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(key: jax.Array, n_items: int, d_model: int,
         pq: Optional[PQConfig] = None, codes=None, centroids=None,
         dtype: Any = jnp.float32) -> Params:
    if pq is None:
        table = jax.random.normal(key, (n_items, d_model), jnp.float32) * 0.02
        return {"table": table.astype(dtype)}
    return pq_lib.init_pq_embedding(key, pq, n_items, d_model, codes,
                                    centroids, dtype)


def abstract(n_items: int, d_model: int, pq: Optional[PQConfig] = None,
             dtype: Any = jnp.float32) -> Params:
    if pq is None:
        return {"table": jax.ShapeDtypeStruct((n_items, d_model), dtype)}
    return pq_lib.abstract_pq_embedding(pq, n_items, d_model, dtype)


def is_pq(params: Params) -> bool:
    return "codes" in params


def n_items(params: Params) -> int:
    return (params["codes"] if is_pq(params) else params["table"]).shape[0]


def embed(params: Params, ids: jax.Array) -> jax.Array:
    """Input-embedding lookup (shared with the head, as in RecJPQ)."""
    if is_pq(params):
        return pq_lib.reconstruct(params, ids)
    return jnp.take(params["table"], ids, axis=0)


# ---------------------------------------------------------------------------
# scoring
# ---------------------------------------------------------------------------

def score_all(params: Params, phi: jax.Array, method: str = "pqtopk",
              ) -> jax.Array:
    """All item scores (B, N) via the selected algorithm."""
    if method == "dense":
        w = (pq_lib.reconstruct_all(params) if is_pq(params)
             else params["table"])
        return scoring.score_dense(w.astype(phi.dtype), phi)
    if not is_pq(params):
        raise ValueError(f"method {method!r} requires a PQ head")
    s = scoring.subid_scores(params["sub_emb"].astype(jnp.float32),
                             phi.astype(jnp.float32))
    if method == "recjpq":
        return scoring.score_recjpq(params["codes"], s)
    if method == "pqtopk":
        return scoring.score_pqtopk(params["codes"], s)
    if method == "pqtopk_onehot":
        return scoring.score_pqtopk_onehot(params["codes"], s)
    if method == "pqtopk_kernel":
        from repro.kernels.pqtopk import ops as kernel_ops
        return kernel_ops.pq_scores(params["codes"], s)
    raise ValueError(f"unknown scoring method {method!r}")


def score_candidates(params: Params, phi: jax.Array, item_ids: jax.Array,
                     method: str = "pqtopk") -> jax.Array:
    """Scores for a candidate subset V (Algorithm 1's optional V)."""
    if method == "dense":
        w = embed(params, item_ids)
        return scoring.score_dense(w.astype(phi.dtype), phi)
    s = scoring.subid_scores(params["sub_emb"].astype(jnp.float32),
                             phi.astype(jnp.float32))
    if method in ("pqtopk_kernel", "pqtopk_fused"):
        # Fused-path subset scoring: gather V's codes, run the one-hot MXU
        # kernel over just those rows (no per-tile top-k — V is small).
        from repro.kernels.pqtopk import ops as kernel_ops
        return kernel_ops.pq_scores(params["codes"][item_ids], s)
    return scoring.score_pqtopk(params["codes"][item_ids], s)


def top_items(params: Params, phi: jax.Array, k: int,
              method: str = "pqtopk", tile: int = 8192,
              ) -> Tuple[jax.Array, jax.Array]:
    """TopK(score, K) — returns (values (B,k), item ids (B,k)).

    ``method="pqtopk_fused"`` routes through the fused Pallas kernel: scores
    and per-tile winners stay in VMEM and only (B, n_tiles, k) candidates
    reach HBM — O(B*K*N/TN) output traffic instead of the O(B*N) score
    matrix that every score_all + tiled_topk route materialises.
    """
    if method == "pqtopk_fused":
        if not is_pq(params):
            raise ValueError("method 'pqtopk_fused' requires a PQ head")
        s = scoring.subid_scores(params["sub_emb"].astype(jnp.float32),
                                 phi.astype(jnp.float32))
        from repro.kernels.pqtopk import ops as kernel_ops
        return kernel_ops.pq_topk(params["codes"], s, k)
    if method == "pqtopk_pruned":
        if not is_pq(params):
            raise ValueError("method 'pqtopk_pruned' requires a PQ head")
        return _top_items_pruned_ingraph(params, phi, k, tile)
    if method == "pqtopk_approx":
        if not is_pq(params):
            raise ValueError("method 'pqtopk_approx' requires a PQ head")
        r = score_all(params, phi, "pqtopk")
        return topk_lib.approx_topk_maxblock(r, k)
    r = score_all(params, phi, method)
    return topk_lib.tiled_topk(r, k, tile)


# ---------------------------------------------------------------------------
# cascaded pruned retrieval (upper-bound tile skipping, docs/PRUNING.md)
# ---------------------------------------------------------------------------

DEFAULT_PRUNE_TILE = 2048
DEFAULT_SEED_TILES = 2


_subid_scores_jit = jax.jit(
    lambda sub_emb, phi: scoring.subid_scores(sub_emb.astype(jnp.float32),
                                              phi.astype(jnp.float32)))


def _top_items_pruned_ingraph(params, phi, k, tile,
                              seed_tiles: int = DEFAULT_SEED_TILES):
    """Jit-compatible pruned variant: mask, don't compact.

    Runs the full bound cascade in-graph and masks pruned tiles' scores to
    -inf before the top-k, so the result is bit-identical to the compacted
    route (and the exhaustive oracle) but every tile is still scored — use
    :func:`top_items_pruned` outside jit for the real O(N_survive) pass 2.
    """
    codes, sub_emb = params["codes"], params["sub_emb"]
    b = sub_emb.shape[1]
    n = codes.shape[0]
    prune_tile = min(DEFAULT_PRUNE_TILE, n)
    present = pruning._build_present(codes, b, prune_tile)
    s = scoring.subid_scores(sub_emb.astype(jnp.float32),
                             phi.astype(jnp.float32))
    mask, _, _ = pruning.pruned_pass1(codes, present, s, k, tile=prune_tile,
                                      n_seed=seed_tiles)
    r = scoring.score_pqtopk(codes, s)
    item_tile = jnp.arange(n, dtype=jnp.int32) // prune_tile
    r = jnp.where(mask[item_tile][None, :], r, -jnp.inf)
    return topk_lib.tiled_topk(r, k, tile)


def top_items_pruned(params: Params, phi: jax.Array, k: int, *,
                     tile: int = DEFAULT_PRUNE_TILE,
                     seed_tiles: int = DEFAULT_SEED_TILES,
                     use_kernel: Optional[bool] = None,
                     interpret: Optional[bool] = None,
                     return_stats: bool = False):
    """Two-pass cascaded retrieval (``method="pqtopk_pruned"``), host mode.

    Pass 1 (jitted): per-tile upper bounds from cached code-presence
    metadata, theta from a greedy exact pass over the ``seed_tiles`` most
    promising tiles, survival mask.  Host sync: compact surviving tile
    indices (power-of-two slot bucket, sentinel-padded).  Pass 2 (jitted
    per bucket size): fused scoring + top-k over surviving tiles only.

    Exact: every skipped tile's bound is below theta, and at least k items
    score >= theta, so the top-k (values AND ids, ties included) matches
    the exhaustive oracle bit-for-bit.  With ``return_stats`` also returns
    {"n_tiles", "n_survived", "n_scored", "survival_fraction"}.
    """
    if not is_pq(params):
        raise ValueError("top_items_pruned requires a PQ head")
    s = _subid_scores_jit(params["sub_emb"], phi)
    return pruning.cascade_topk(params["codes"], s, k, tile=tile,
                                seed_tiles=seed_tiles, use_kernel=use_kernel,
                                interpret=interpret,
                                return_stats=return_stats)


def top_items_pruned_sharded(params: Params, phi: jax.Array, k: int, mesh,
                             axis: str = "model", *,
                             tile: int = DEFAULT_PRUNE_TILE,
                             seed_tiles: int = DEFAULT_SEED_TILES,
                             use_kernel: Optional[bool] = None,
                             interpret: Optional[bool] = None,
                             return_stats: bool = False):
    """Item-sharded cascade: per-shard pruning with a shared theta.

    Pass 1 (one shard_map): each shard bounds its local tiles, seeds a
    local theta from its own most promising tiles, then the global theta is
    the pmax over shards — each local theta certifies >= k items somewhere,
    so the max is still certified and is the tightest such bound.  Local
    bound blocks are all-gathered (out-spec concatenation along the tile
    axis) so the host computes one global survivor mask.  Pass 2 (second
    shard_map): each shard scores its own compacted survivor list (padded
    to the max per-shard count for SPMD uniformity) and contributes k
    candidates to the same O(k * shards) merge as every other route.
    """
    if not is_pq(params):
        raise ValueError("top_items_pruned_sharded requires a PQ head")
    from repro.kernels.pqtopk import ops as kernel_ops
    codes, sub_emb = params["codes"], params["sub_emb"]
    n = codes.shape[0]
    n_shards = mesh.shape[axis]
    pad = (-n) % n_shards
    codes_p = jnp.pad(codes, ((0, pad), (0, 0))) if pad else codes
    n_local = (n + pad) // n_shards
    # Pass 2 oversamples the local top-(k + pad) so shard-padding rows can
    # be masked out afterwards; the tile must be able to hold that many
    # winners (k <= tile is required everywhere, k + pad only here).
    tile = min(max(tile, k + pad), n_local)
    t_local = -(-n_local // tile)
    b = sub_emb.shape[1]
    if use_kernel is None:
        from repro import compat
        use_kernel = compat.on_tpu()
    if interpret is None:
        from repro import compat
        interpret = not compat.on_tpu()

    def pass1_shard(codes_local, sub_emb_, phi_):
        s = scoring.subid_scores(sub_emb_.astype(jnp.float32),
                                 phi_.astype(jnp.float32))
        present = pruning._build_present(codes_local, b, tile)
        offset = jax.lax.axis_index(axis) * n_local
        bounds = pruning.tile_upper_bounds(present, s)
        theta_local = pruning.theta_from_seed(
            codes_local, s, bounds, k, tile=tile, n_seed=seed_tiles,
            n_items=n, id_offset=offset)
        theta = jax.lax.pmax(theta_local, axis)
        return bounds, theta, s

    fn1 = manual_axis_map(
        pass1_shard, mesh,
        in_specs=(P(axis, None), P(), P()),
        out_specs=(P(None, axis), P(), P()))
    bounds, theta, s = fn1(codes_p, sub_emb, phi)

    mask = np.asarray(pruning.survival_mask(bounds, theta))
    per_shard = mask.reshape(n_shards, t_local)
    counts = per_shard.sum(axis=1)
    n_slots = pruning.slot_bucket(int(counts.max()), k, tile)
    sentinel = kernel_ops.sentinel_tile(n_local, tile)
    idx_all = np.full((n_shards, n_slots), sentinel, np.int32)
    for sh in range(n_shards):
        local = np.nonzero(per_shard[sh])[0]
        idx_all[sh, :len(local)] = local
    k_local = min(k + pad, n_local)

    def pass2_shard(codes_local, s_, idx_local):
        lv, li = kernel_ops._pq_topk_tiles(
            codes_local, s_, k_local, idx_local, tile=tile,
            batch_tile=kernel_ops._k.DEFAULT_BATCH_TILE,
            use_kernel=use_kernel, interpret=interpret)
        offset = jax.lax.axis_index(axis) * n_local
        gid = li.astype(jnp.int32) + offset.astype(jnp.int32)
        lv = jnp.where(gid < n, lv, -jnp.inf)
        if k_local > k:
            lv, sel = jax.lax.top_k(lv, k)
            gid = jnp.take_along_axis(gid, sel, axis=1)
        return topk_lib.merge_local_topk(lv, gid, k, axis)

    fn2 = manual_axis_map(
        pass2_shard, mesh,
        in_specs=(P(axis, None), P(), P(axis)),
        out_specs=(P(), P()))
    vals, ids = fn2(codes_p, s, jnp.asarray(idx_all.reshape(-1)))
    if not return_stats:
        return vals, ids
    total = int(mask.size)
    stats = {"n_tiles": total, "n_survived": int(mask.sum()),
             "n_scored": int(n_shards * n_slots),
             "survival_fraction": float(mask.sum()) / max(total, 1)}
    return vals, ids, stats


# ---------------------------------------------------------------------------
# distributed: items sharded over a mesh axis, O(k * shards) merge
# ---------------------------------------------------------------------------

def top_items_sharded(params: Params, phi: jax.Array, k: int, mesh,
                      axis: str = "model", method: str = "pqtopk",
                      ) -> Tuple[jax.Array, jax.Array]:
    """Item-sharded retrieval: codes sharded over ``axis``; each shard runs
    PQTopK locally and contributes k candidates to an all-gather merge.

    Per-shard collective volume: k * (4 + 4) bytes * n_shards — independent
    of N (DESIGN.md §5).
    """
    if not is_pq(params):
        return _dense_top_items_sharded(params, phi, k, mesh, axis)
    if method == "pqtopk_pruned":
        return top_items_pruned_sharded(params, phi, k, mesh, axis)
    n = params["codes"].shape[0]
    n_shards = mesh.shape[axis]
    pad = (-n) % n_shards
    codes = params["codes"]
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    n_local = (n + pad) // n_shards

    if method == "pqtopk_fused":
        shard_fn = _fused_shard_fn(k, n, n_local, pad, axis)
    else:
        scorer = {"pqtopk": scoring.score_pqtopk,
                  "pqtopk_onehot": scoring.score_pqtopk_onehot,
                  "pqtopk_kernel": scoring.score_pqtopk,
                  "recjpq": scoring.score_recjpq}[method]

        def shard_fn(codes_local, sub_emb, phi_):
            s = scoring.subid_scores(sub_emb.astype(jnp.float32),
                                     phi_.astype(jnp.float32))
            r_local = scorer(codes_local, s)
            offset = jax.lax.axis_index(axis) * n_local
            # Mask padding rows (global id >= n) out of the top-k.
            gid = offset + jnp.arange(n_local)
            r_local = jnp.where(gid[None, :] < n, r_local, -jnp.inf)
            return topk_lib.local_then_merge_topk(r_local, k, axis, offset)

    fn = manual_axis_map(
        shard_fn, mesh,
        in_specs=(P(axis, None), P(), P()),
        out_specs=(P(), P()),   # outputs are replicated post-all_gather
    )
    return fn(codes, params["sub_emb"], phi)


def _fused_shard_fn(k: int, n: int, n_local: int, pad: int, axis: str):
    """Shard body for the fused route: the Pallas kernel produces this
    shard's top-k directly (per-tile winners merged in the wrapper — the
    (B, N_local) score matrix never exists), then the cross-shard merge is
    the same O(k * shards) all-gather as every other method.

    Shard-level padding rows (zero codes, only on the last shard) are real
    rows to the kernel, so we oversample the local top-(k + pad): at most
    ``pad`` winners can be padding, which we mask to -inf after mapping to
    global ids — the surviving candidates still contain the true local
    top-k, keeping the route exact.
    """
    from repro.kernels.pqtopk import ops as kernel_ops
    k_local = min(k + pad, n_local)

    def shard_fn(codes_local, sub_emb, phi_):
        s = scoring.subid_scores(sub_emb.astype(jnp.float32),
                                 phi_.astype(jnp.float32))
        lv, li = kernel_ops.pq_topk(codes_local, s, k_local)
        offset = jax.lax.axis_index(axis) * n_local
        gid = li.astype(jnp.int32) + offset.astype(jnp.int32)
        lv = jnp.where(gid < n, lv, -jnp.inf)
        if pad:
            # Re-rank after masking so each shard contributes its best k.
            lv, sel = jax.lax.top_k(lv, min(k, k_local))
            gid = jnp.take_along_axis(gid, sel, axis=1)
        return topk_lib.merge_local_topk(lv, gid, k, axis)

    return shard_fn


def _dense_top_items_sharded(params: Params, phi: jax.Array, k: int, mesh,
                             axis: str) -> Tuple[jax.Array, jax.Array]:
    n = params["table"].shape[0]
    n_local = n // mesh.shape[axis]

    def shard_fn(table_local, phi_):
        r_local = scoring.score_dense(table_local.astype(phi_.dtype), phi_)
        offset = jax.lax.axis_index(axis) * n_local
        return topk_lib.local_then_merge_topk(
            r_local.astype(jnp.float32), k, axis, offset)

    fn = manual_axis_map(
        shard_fn, mesh,
        in_specs=(P(axis, None), P()),
        out_specs=(P(), P()),
    )
    return fn(params["table"], phi)
