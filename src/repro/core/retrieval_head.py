"""RetrievalHead: "score a huge id space against a query vector, top-K".

The paper's technique packaged as the first-class head used by every arch
that retrieves from a large id space (seqrec items, LM vocab at decode,
recsys candidate catalogues).  Holds either

* a PQ representation  — ``{"codes": (N, m), "sub_emb": (m, b, d/m)}``, or
* a dense table        — ``{"table": (N, d)}`` (Transformer-Default baseline)

and exposes scoring via any of the paper's three algorithms plus the Pallas
kernel path and the item-sharded distributed path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import PQConfig
from repro.core import pq as pq_lib
from repro.core import pruning, scoring, topk as topk_lib
from repro.distributed.sharding import manual_axis_map

Params = Dict[str, Any]

#: Methods accepted by ``top_items``/``serve_topk`` — the paper's three
#: algorithms plus the Pallas routes (scores-only kernel, fused
#: score+top-k kernel), the cascaded pruned route, and the approximate
#: block-max route.  Every method — including ``pqtopk_pruned``, whose
#: cascade is a single in-graph dispatch since PR 3 — is a pure traced
#: function of (params, phi): jittable, decode-loop and shard_map safe.
TOP_ITEMS_METHODS = ("dense", "recjpq", "pqtopk", "pqtopk_onehot",
                     "pqtopk_kernel", "pqtopk_fused", "pqtopk_pruned",
                     "pqtopk_approx")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(key: jax.Array, n_items: int, d_model: int,
         pq: Optional[PQConfig] = None, codes=None, centroids=None,
         dtype: Any = jnp.float32) -> Params:
    if pq is None:
        table = jax.random.normal(key, (n_items, d_model), jnp.float32) * 0.02
        return {"table": table.astype(dtype)}
    params = pq_lib.init_pq_embedding(key, pq, n_items, d_model, codes,
                                      centroids, dtype)
    # Query-independent pruning metadata (bit-packed code presence or
    # min/max code ranges, per pq.bound_backend), built once here and
    # carried in the param tree so the in-graph pruned cascade never
    # rebuilds it — not even inside a decode loop.  A frozen integer
    # buffer to the optimizer, like "codes".
    params["pruned"] = pruning.build_pruned_state(
        params["codes"], pq.b, DEFAULT_PRUNE_TILE,
        backend=pq.bound_backend)
    if pq.super_factor > 1:
        # Hierarchical super-tile level (docs/PRUNING.md §Hierarchical
        # bounds): built once here by reduction over the child metadata;
        # the cascade auto-detects it and inserts the super pass-0.
        params["pruned"] = pruning.with_super(params["pruned"],
                                              pq.super_factor)
    return params


def abstract(n_items: int, d_model: int, pq: Optional[PQConfig] = None,
             dtype: Any = jnp.float32) -> Params:
    if pq is None:
        return {"table": jax.ShapeDtypeStruct((n_items, d_model), dtype)}
    params = pq_lib.abstract_pq_embedding(pq, n_items, d_model, dtype)
    params["pruned"] = pruning.abstract_pruned_state(
        n_items, pq.m, pq.b, DEFAULT_PRUNE_TILE,
        backend=pq.bound_backend, super_factor=pq.super_factor)
    return params


def is_pq(params: Params) -> bool:
    return "codes" in params


def n_items(params: Params) -> int:
    return (params["codes"] if is_pq(params) else params["table"]).shape[0]


def embed(params: Params, ids: jax.Array) -> jax.Array:
    """Input-embedding lookup (shared with the head, as in RecJPQ)."""
    if is_pq(params):
        return pq_lib.reconstruct(params, ids)
    return jnp.take(params["table"], ids, axis=0)


# ---------------------------------------------------------------------------
# scoring
# ---------------------------------------------------------------------------

def score_all(params: Params, phi: jax.Array, method: str = "pqtopk",
              ) -> jax.Array:
    """All item scores (B, N) via the selected algorithm."""
    if method == "dense":
        w = (pq_lib.reconstruct_all(params) if is_pq(params)
             else params["table"])
        return scoring.score_dense(w.astype(phi.dtype), phi)
    if not is_pq(params):
        raise ValueError(f"method {method!r} requires a PQ head")
    s = scoring.subid_scores(params["sub_emb"].astype(jnp.float32),
                             phi.astype(jnp.float32))
    if method == "recjpq":
        return scoring.score_recjpq(params["codes"], s)
    if method == "pqtopk":
        return scoring.score_pqtopk(params["codes"], s)
    if method == "pqtopk_onehot":
        return scoring.score_pqtopk_onehot(params["codes"], s)
    if method == "pqtopk_kernel":
        from repro.kernels.pqtopk import ops as kernel_ops
        return kernel_ops.pq_scores(params["codes"], s)
    raise ValueError(f"unknown scoring method {method!r}")


def score_candidates(params: Params, phi: jax.Array, item_ids: jax.Array,
                     method: str = "pqtopk") -> jax.Array:
    """Scores for a candidate subset V (Algorithm 1's optional V)."""
    if method == "dense":
        w = embed(params, item_ids)
        return scoring.score_dense(w.astype(phi.dtype), phi)
    s = scoring.subid_scores(params["sub_emb"].astype(jnp.float32),
                             phi.astype(jnp.float32))
    if method in ("pqtopk_kernel", "pqtopk_fused"):
        # Fused-path subset scoring: gather V's codes, run the one-hot MXU
        # kernel over just those rows (no per-tile top-k — V is small).
        from repro.kernels.pqtopk import ops as kernel_ops
        return kernel_ops.pq_scores(params["codes"][item_ids], s)
    return scoring.score_pqtopk(params["codes"][item_ids], s)


def top_items(params: Params, phi: jax.Array, k: int,
              method: str = "pqtopk", tile: int = 8192,
              pq_cfg: Optional[PQConfig] = None,
              ladder=None, pin_rung: bool = False,
              return_rung: bool = False,
              ) -> Tuple[jax.Array, jax.Array]:
    """TopK(score, K) — returns (values (B,k), item ids (B,k)).

    ``method="pqtopk_fused"`` routes through the fused Pallas kernel: scores
    and per-tile winners stay in VMEM and only (B, n_tiles, k) candidates
    reach HBM — O(B*K*N/TN) output traffic instead of the O(B*N) score
    matrix that every score_all + tiled_topk route materialises.

    ``method="pqtopk_pruned"`` runs the single-dispatch in-graph cascade
    (bounds -> theta -> compaction -> compacted fused scoring, all in one
    traced computation; ``pq_cfg`` supplies the theta-seeding policy knobs,
    ``ladder`` the calibrated slot budgets, and ``return_rung=True`` makes
    the route additionally return the ladder rung taken — still one
    dispatch).
    """
    if params.get("live") is not None and method != "pqtopk_pruned":
        raise ValueError(
            f"params carry a tombstone mask ('live') but method {method!r} "
            f"would ignore it and could return delisted items; mutable "
            f"catalogues serve via 'pqtopk_pruned'")
    if pin_rung and method != "pqtopk_pruned":
        raise ValueError("pin_rung (the load-degraded cascade) is only "
                         "meaningful for method='pqtopk_pruned'")
    if method == "pqtopk_fused":
        if not is_pq(params):
            raise ValueError("method 'pqtopk_fused' requires a PQ head")
        s = scoring.subid_scores(params["sub_emb"].astype(jnp.float32),
                                 phi.astype(jnp.float32))
        from repro.kernels.pqtopk import ops as kernel_ops
        return kernel_ops.pq_topk(params["codes"], s, k)
    if method == "pqtopk_pruned":
        if not is_pq(params):
            raise ValueError("method 'pqtopk_pruned' requires a PQ head")
        return _top_items_pruned_ingraph(params, phi, k, pq_cfg=pq_cfg,
                                         ladder=ladder, pin_rung=pin_rung,
                                         return_rung=return_rung)
    if method == "pqtopk_approx":
        if not is_pq(params):
            raise ValueError("method 'pqtopk_approx' requires a PQ head")
        r = score_all(params, phi, "pqtopk")
        return topk_lib.approx_topk_maxblock(r, k)
    r = score_all(params, phi, method)
    return topk_lib.tiled_topk(r, k, tile)


# ---------------------------------------------------------------------------
# cascaded pruned retrieval (upper-bound tile skipping, docs/PRUNING.md)
# ---------------------------------------------------------------------------

DEFAULT_PRUNE_TILE = pruning.DEFAULT_PRUNE_TILE
DEFAULT_SEED_TILES = pruning.DEFAULT_SEED_TILES


_subid_scores_jit = jax.jit(
    lambda sub_emb, phi: scoring.subid_scores(sub_emb.astype(jnp.float32),
                                              phi.astype(jnp.float32)))


def _seed_kwargs(pq_cfg: Optional[PQConfig]) -> Dict[str, Any]:
    """theta-seeding knobs for the in-graph cascade, from PQConfig."""
    if pq_cfg is None:
        return {}
    return {"seed_policy": pq_cfg.seed_policy,
            "seed_tiles": pq_cfg.seed_tiles,
            "seed_max_tiles": pq_cfg.seed_max_tiles,
            "seed_stab_tol": pq_cfg.seed_stab_tol}


def _grouping_kwargs(pq_cfg: Optional[PQConfig]) -> Dict[str, Any]:
    """Per-query grouping knobs for the pruned cascade, from PQConfig."""
    if pq_cfg is None:
        return {}
    return {"query_grouping": pq_cfg.query_grouping,
            "n_groups": pq_cfg.n_groups}


def _pruned_state(params: Params) -> Optional[pruning.PrunedHeadState]:
    st = params.get("pruned")
    return st if isinstance(st, pruning.PrunedHeadState) else None


def _top_items_pruned_ingraph(params, phi, k, *,
                              pq_cfg: Optional[PQConfig] = None,
                              slot_budget: Optional[int] = None,
                              ladder=None, pin_rung: bool = False,
                              return_rung: bool = False):
    """The single-dispatch pruned route: one traced computation.

    Reads the :class:`pruning.PrunedHeadState` threaded through the param
    tree (bit-packed presence or code ranges, per its bound backend;
    rebuilding it in-graph only for legacy param dicts that predate the
    state) and runs ``pruning.cascade_topk_ingraph`` — bounds, theta
    seeding, cumsum-scatter compaction into ``-1``-padded slot buffers
    (one per ladder rung), and the compacted fused scoring, with no
    device->host sync.  Bit-identical to the exhaustive oracle; jit /
    decode-loop safe.  ``return_rung=True`` appends the ladder rung taken
    (i32) to the outputs — same single dispatch.

    A ``"live"`` entry in params (mutable catalogues, core/mutation.py)
    is the tombstone mask: threaded into the cascade as traced data, so
    churn never recompiles and dead items never reach the top-k.
    """
    codes, sub_emb = params["codes"], params["sub_emb"]
    live = params.get("live")
    s = scoring.subid_scores(sub_emb.astype(jnp.float32),
                             phi.astype(jnp.float32))
    state = _pruned_state(params)
    if state is not None and state.shards != 1:
        # A shard-aligned state (installed by ensure_sharded_pruned_state)
        # tiles the catalogue per shard; the flat route needs the shards=1
        # layout, so rebuild in-graph rather than misread the tiles.
        state = None
    if state is None:
        # Legacy param dicts / sharded-state fallback: rebuild in-graph,
        # honouring the config's bound backend and super-tile factor.
        state = pruning.build_pruned_state(
            codes, int(sub_emb.shape[1]), DEFAULT_PRUNE_TILE,
            backend=pq_cfg.bound_backend if pq_cfg is not None
            else "bitmask")
        if pq_cfg is not None and pq_cfg.super_factor > 1:
            state = pruning.with_super(state, pq_cfg.super_factor)
    out = pruning.cascade_topk_ingraph(codes, s, k, state,
                                       tile=DEFAULT_PRUNE_TILE,
                                       slot_budget=slot_budget,
                                       ladder=ladder, pin_rung=pin_rung,
                                       live=live,
                                       return_stats=return_rung,
                                       **_seed_kwargs(pq_cfg),
                                       **_grouping_kwargs(pq_cfg))
    if return_rung:
        vals, ids, stats = out
        return vals, ids, stats["rung_hit"]
    return out


def top_items_pruned(params: Params, phi: jax.Array, k: int, *,
                     tile: int = DEFAULT_PRUNE_TILE,
                     seed_tiles: int = DEFAULT_SEED_TILES,
                     use_kernel: Optional[bool] = None,
                     interpret: Optional[bool] = None,
                     return_stats: bool = False):
    """Two-pass cascaded retrieval, host mode (PR 2 reference path).

    Pass 1 (jitted): per-tile upper bounds from cached code-presence
    metadata, theta from a greedy exact pass over the ``seed_tiles`` most
    promising tiles, survival mask.  Host sync: compact surviving tile
    indices (power-of-two slot bucket, sentinel-padded).  Pass 2 (jitted
    per bucket size): fused scoring + top-k over surviving tiles only.

    The serving path no longer uses this — ``method="pqtopk_pruned"``
    through :func:`top_items` is the single-dispatch in-graph cascade.
    Kept as the host-orchestrated reference the in-graph route is
    parity-tested against (and for interactive use where a per-call
    device->host sync is acceptable).

    Exact: every skipped tile's bound is below theta, and at least k items
    score >= theta, so the top-k (values AND ids, ties included) matches
    the exhaustive oracle bit-for-bit.  With ``return_stats`` also returns
    {"n_tiles", "n_survived", "n_scored", "survival_fraction"}.
    """
    if not is_pq(params):
        raise ValueError("top_items_pruned requires a PQ head")
    s = _subid_scores_jit(params["sub_emb"], phi)
    return pruning.cascade_topk(params["codes"], s, k, tile=tile,
                                seed_tiles=seed_tiles, use_kernel=use_kernel,
                                interpret=interpret,
                                return_stats=return_stats)


def ensure_sharded_pruned_state(params: Params, mesh, axis: str = "model", *,
                                k_hint: int = 64,
                                tile: int = DEFAULT_PRUNE_TILE,
                                backend: Optional[str] = None,
                                super_factor: Optional[int] = None
                                ) -> Params:
    """Return ``params`` with a :class:`pruning.PrunedHeadState` whose tile
    layout is aligned to ``mesh``'s ``axis`` (tiles never straddle shard
    boundaries, so the metadata arrays split evenly over the mesh).

    A no-op when the threaded state is already compatible (same shard
    layout AND same bound backend); otherwise builds the shard-aligned
    state ONCE (engine/head build time) so the sharded serve path never
    rebuilds metadata per call.  ``k_hint`` is the largest k the route
    will serve — the tile must hold the per-shard oversampled top-(k +
    pad) winners.  ``backend=None`` preserves the threaded state's
    backend (default ``"bitmask"``); ``super_factor=None`` likewise
    preserves the threaded state's super-tile factor (the rebuilt sharded
    state regroups supers PER SHARD, so the hierarchical pass-0 and the
    shard-skip both stay shard-local).
    """
    if not is_pq(params):
        return params
    codes = params["codes"]
    n = codes.shape[0]
    n_shards = mesh.shape[axis]
    pad = (-n) % n_shards
    n_local = (n + pad) // n_shards
    k_local = min(k_hint + pad, n_local)
    st = _pruned_state(params)
    if backend is None:
        backend = st.backend if st is not None else "bitmask"
    if super_factor is None:
        super_factor = st.super_factor if st is not None else 0
    super_factor = 0 if super_factor <= 1 else int(super_factor)
    if (st is not None and st.shards == n_shards and st.tile >= k_local
            and st.backend == backend and st.super_factor == super_factor):
        return params
    b = params["sub_emb"].shape[1]
    need = min(max(tile, k_local), n_local)
    new = pruning.build_pruned_state(codes, b, need, shards=n_shards,
                                     backend=backend)
    if super_factor:
        new = pruning.with_super(new, super_factor)
    return {**params, "pruned": new}


def top_items_pruned_sharded(params: Params, phi: jax.Array, k: int, mesh,
                             axis: str = "model", *,
                             tile: int = DEFAULT_PRUNE_TILE,
                             seed_tiles: Optional[int] = None,
                             pq_cfg: Optional[PQConfig] = None,
                             ladder=None,
                             super_ladder=None,
                             use_kernel: Optional[bool] = None,
                             interpret: Optional[bool] = None,
                             return_stats: bool = False):
    """Item-sharded cascade in ONE ``shard_map`` — single device dispatch.

    Each shard: bounds its local tiles from its slice of the bit-packed
    presence state, seeds a local theta from its own most promising tiles,
    shares ``theta = pmax(theta_local)`` (each local theta certifies >= k
    items somewhere, so the max is still certified and is the tightest such
    bound), compacts its local survivors with the in-graph cumsum scatter
    into a ``-1``-padded slot buffer (full per-shard length — SPMD uniform
    by construction, no cross-shard max needed), scores them through the
    compacted fused kernel, and contributes k candidates to the same
    O(k * shards) all-gather merge as every other sharded route.  The PR 2
    version needed two shard_maps with a host compaction between them;
    theta sharing and compaction now both live inside the single Manual
    region, so the route is jit- and decode-loop safe.

    Uses the shard-aligned state threaded through ``params`` when present
    (see :func:`ensure_sharded_pruned_state`); otherwise builds one
    in-graph — still a single dispatch, just with per-call rebuild cost.

    With ``pq_cfg.query_grouping`` the per-query route runs inside the
    same Manual region: each shard seeds per-query local thetas over its
    own tiles, the certified threshold is the per-query
    ``pmax(theta_local)`` over shards, and each shard then buckets queries
    by ITS local survivor sets, compacts a 2D (group, slot) table, scores
    it, and un-permutes its winners back to request order before the
    all-gather merge — shards may group differently (survivor overlap is
    a local property), which is safe because every cross-shard op runs in
    request order.

    With a hierarchical state (``with_super``; super-tiles grouped PER
    SHARD) each shard seeds theta from its SUPER-tile bounds, shares the
    ``pmax`` theta, and then runs the two-stage tail behind a shard-local
    ``lax.cond``: when NONE of the shard's super-tiles survive the shared
    theta, the shard skips the child-bound gather and the scoring kernel
    entirely and contributes ``-inf`` candidates pointing at the global
    sentinel id — super-tile bounds decide which shards a query batch
    touches at all.  Every collective (theta ``pmax``, the all-gather
    merge, the stats reductions) stays OUTSIDE the cond: the predicate is
    shard-divergent, and a collective inside a divergent branch would
    deadlock the mesh.
    """
    if not is_pq(params):
        raise ValueError("top_items_pruned_sharded requires a PQ head")
    from repro.kernels.pqtopk import ops as kernel_ops
    codes, sub_emb = params["codes"], params["sub_emb"]
    live = params.get("live")
    n = codes.shape[0]
    n_shards = mesh.shape[axis]
    pad = (-n) % n_shards
    n_local = (n + pad) // n_shards
    # The local pass oversamples the top-(k + pad) so shard-padding rows
    # can be masked out afterwards; the tile must hold that many winners
    # (k <= tile is required everywhere, k + pad only here).
    k_local = min(k + pad, n_local)
    b = sub_emb.shape[1]
    state = _pruned_state(params)
    want_backend = (state.backend if state is not None else
                    (pq_cfg.bound_backend if pq_cfg is not None
                     else "bitmask"))
    want_super = (state.super_factor if state is not None else
                  (pq_cfg.super_factor if pq_cfg is not None else 0))
    if (state is None or state.shards != n_shards or state.tile < k_local
            or state.backend != want_backend):
        state = pruning.build_pruned_state(
            codes, b, min(max(tile, k_local), n_local), shards=n_shards,
            backend=want_backend)
        if want_super > 1:
            state = pruning.with_super(state, want_super)
    hier = state.has_super
    tile = state.tile
    t_local = state.tiles_per_shard
    codes_p = jnp.pad(codes, ((0, pad), (0, 0))) if pad else codes
    if use_kernel is None:
        from repro import compat
        use_kernel = compat.on_tpu()
    if interpret is None:
        from repro import compat
        interpret = not compat.on_tpu()
    # Precedence: explicit seed_tiles argument > PQConfig knobs > defaults.
    seed_kw = _seed_kwargs(pq_cfg)
    if seed_tiles is not None:
        seed_kw["seed_tiles"] = seed_tiles
        seed_kw["seed_max_tiles"] = max(
            seed_tiles, seed_kw.get("seed_max_tiles",
                                    pruning.DEFAULT_SEED_MAX_TILES))
    # Per-shard ladder: budgets apply to the shard's local tile count.
    # Each shard escalates on its own survivor count (lax.cond branches
    # hold no collectives, so divergent rungs across shards are fine); the
    # final rung is always the full local buffer — exhaustive per shard.
    rungs = pruning.normalize_ladder(ladder, t_local, k_local, tile)
    # The backend's metadata arrays all carry the tile axis first, so one
    # P(axis, ...) spec per array shards them alongside the codes.  A
    # hierarchical state's super arrays ride the same axis (supers are
    # grouped per shard), appended after the child arrays.
    n_child_parts = len(state.meta_arrays())
    meta_parts = state.meta_arrays()
    if hier:
        factor = state.super_factor
        s_per_shard = state.supers_per_shard
        sup_rungs = pruning.normalize_ladder(
            pruning.default_super_ladder(s_per_shard)
            if super_ladder is None else super_ladder,
            s_per_shard, k_local, factor * tile)
        meta_parts = meta_parts + state.super_meta_arrays()
    meta_specs = tuple(P(axis, *([None] * (a.ndim - 1)))
                       for a in meta_parts)
    grp_kw = _grouping_kwargs(pq_cfg)
    grouped = grp_kw.get("query_grouping", False) and \
        grp_kw.get("n_groups", 1) > 1
    if hier and grouped:
        raise ValueError(
            "query_grouping and hierarchical super-tiles are mutually "
            "exclusive on the sharded route too; strip the super level "
            "or disable grouping")
    n_groups = grp_kw.get("n_groups", pruning.DEFAULT_N_GROUPS)
    bq = phi.shape[0]
    bt = (kernel_ops.group_batch_tile(bq, n_groups) if grouped
          else kernel_ops.effective_batch_tile(bq))
    b_pad = -(-bq // bt) * bt

    def shard_body(codes_local, meta_local, sub_emb_, phi_,
                   live_local=None):
        s = scoring.subid_scores(sub_emb_.astype(jnp.float32),
                                 phi_.astype(jnp.float32))
        child_local = meta_local[:n_child_parts]
        offset = jax.lax.axis_index(axis) * n_local
        if hier:
            sup_local = meta_local[n_child_parts:]
            sup_bounds = pruning.bounds_from_parts(state.backend,
                                                   sup_local, s)
            theta_local, n_seed_used, _sf = pruning.theta_seed_ingraph(
                codes_local, s, sup_bounds, k, tile=factor * tile,
                n_items=n, id_offset=offset,
                degenerate=pruning.degenerate_from_parts(
                    state.backend, sup_local, state.b),
                live=live_local, **seed_kw)
            theta = jax.lax.pmax(theta_local, axis)
            sup_mask = pruning.survival_mask(sup_bounds, theta)
            sup_slots, sup_count = pruning.compact_mask(sup_mask)

            def hier_tail(r_sup, i_sup):
                sup_ids = sup_slots[:r_sup]
                gid_t = (sup_ids[:, None] * factor
                         + jnp.arange(factor, dtype=jnp.int32)[None, :]
                         ).reshape(-1)
                valid = (gid_t >= 0) & (gid_t < t_local)
                safe = jnp.clip(gid_t, 0, t_local - 1)
                parts_sel = tuple(p[safe] for p in child_local)
                cb = pruning.bounds_from_parts(state.backend, parts_sel, s)
                cmask = pruning.survival_mask(cb, theta) & valid
                child_slots, child_count = pruning.compact_values(cmask,
                                                                  gid_t)
                crungs = pruning.normalize_ladder(ladder, r_sup * factor,
                                                  k_local, tile)
                slot_lists = tuple(child_slots[:r] for r in crungs)
                lv, li, crung = kernel_ops._pq_topk_tiles_ladder(
                    codes_local, s, k_local, slot_lists, child_count,
                    tile=tile, batch_tile=bt, live=live_local,
                    use_kernel=use_kernel, interpret=interpret)
                overflow = (child_count > crungs[-2] if len(crungs) > 1
                            else jnp.bool_(False))
                return (lv, li, child_count,
                        jnp.asarray(crungs, jnp.int32)[crung], crung,
                        jnp.int32(len(crungs)), jnp.asarray(overflow),
                        jnp.int32(s_per_shard + r_sup * factor),
                        jnp.int32(i_sup))

            def sup_rung_fn(i):
                def run():
                    return hier_tail(sup_rungs[i], i)
                if i == len(sup_rungs) - 1:
                    return run
                nxt = sup_rung_fn(i + 1)
                return lambda: jax.lax.cond(sup_count <= sup_rungs[i],
                                            run, nxt)

            def skip_tail():
                # Shard-skip: none of this shard's supers survive the
                # shared theta for ANY query — no child bound is gathered
                # and no kernel runs; the shard contributes -inf
                # candidates pointing at the global sentinel id n (the
                # gid map below adds offset back).
                lv = jnp.full((bq, k_local), -jnp.inf, jnp.float32)
                li = jnp.full((bq, k_local), n, jnp.int32) - offset
                return (lv, li, jnp.int32(0), jnp.int32(0), jnp.int32(0),
                        jnp.int32(1), jnp.bool_(False),
                        jnp.int32(s_per_shard), jnp.int32(0))

            # The skip cond's predicate is shard-local (divergent across
            # the mesh); every collective stays outside it.
            (lv, li, count, n_scored_loc, rung, n_rungs_loc, overflow_loc,
             bounds_loc, sup_rung) = jax.lax.cond(
                sup_count == jnp.int32(0), skip_tail, sup_rung_fn(0))
            max_group = count
            pairs = count * jnp.int32(b_pad)
        elif grouped:
            bounds = pruning.bounds_from_parts(state.backend, child_local,
                                               s)
            degenerate = pruning.degenerate_from_parts(
                state.backend, child_local, state.b)
            theta_local, n_seed_used, _sf = pruning.theta_seed_perquery(
                codes_local, s, bounds, k, tile=tile, n_items=n,
                id_offset=offset, degenerate=degenerate, live=live_local,
                **seed_kw)
            # Per-query certified threshold: each shard's theta_q
            # certifies >= k items somewhere score >= theta_q, so the
            # per-query max over shards is still certified — and the
            # tightest any shard proves.
            theta = jax.lax.pmax(theta_local, axis)
            pq_mask = pruning.survival_mask_perquery(bounds, theta)
            perm, inv_p, slots2d, counts = pruning.group_and_compact(
                pq_mask, n_groups=n_groups, batch_tile=bt)
            slot_lists = tuple(slots2d[:, :r] for r in rungs)
            lv, li, rung = kernel_ops._pq_topk_tiles_ladder(
                codes_local, jnp.take(s, perm, axis=0), k_local, slot_lists,
                counts, tile=tile, batch_tile=bt, live=live_local,
                use_kernel=use_kernel, interpret=interpret)
            # Back to request order before anything cross-shard.
            lv = jnp.take(lv, inv_p, axis=0)
            li = jnp.take(li, inv_p, axis=0)
            count = pq_mask.any(axis=0).sum(dtype=jnp.int32)
            max_group = counts.max()
            pairs = (counts * jnp.int32(bt)).sum()
            n_scored_loc = jnp.asarray(rungs, jnp.int32)[rung]
        else:
            bounds = pruning.bounds_from_parts(state.backend, child_local,
                                               s)
            degenerate = pruning.degenerate_from_parts(
                state.backend, child_local, state.b)
            theta_local, n_seed_used, _sf = pruning.theta_seed_ingraph(
                codes_local, s, bounds, k, tile=tile, n_items=n,
                id_offset=offset, degenerate=degenerate, live=live_local,
                **seed_kw)
            theta = jax.lax.pmax(theta_local, axis)
            mask = pruning.survival_mask(bounds, theta)
            # One compaction; rung buffers are prefixes of the full buffer.
            slots_full, count = pruning.compact_mask(mask)
            slot_lists = tuple(slots_full[:r] for r in rungs)
            lv, li, rung = kernel_ops._pq_topk_tiles_ladder(
                codes_local, s, k_local, slot_lists, count, tile=tile,
                batch_tile=bt, live=live_local, use_kernel=use_kernel,
                interpret=interpret)
            max_group = count
            pairs = count * jnp.int32(b_pad)
            n_scored_loc = jnp.asarray(rungs, jnp.int32)[rung]
        gid = li.astype(jnp.int32) + offset.astype(jnp.int32)
        lv = jnp.where(gid < n, lv, -jnp.inf)
        if live_local is not None:
            # Dead winners already carry the LOCAL sentinel id (n_local);
            # re-point every -inf candidate at the GLOBAL sentinel n so
            # the cross-shard merge sees one uniform "no item" id.
            gid = jnp.where(lv == -jnp.inf, jnp.int32(n), gid)
        if k_local > k:
            lv, sel = jax.lax.top_k(lv, k)
            gid = jnp.take_along_axis(gid, sel, axis=1)
        vals, ids = topk_lib.merge_local_topk(lv, gid, k, axis)
        base = (vals, ids, jax.lax.psum(count, axis),
                jax.lax.pmax(n_seed_used, axis),
                jax.lax.pmax(rung, axis),
                jax.lax.psum(n_scored_loc, axis),
                jax.lax.pmax(max_group, axis),
                jax.lax.psum(pairs, axis),
                jax.lax.psum(count * jnp.int32(b_pad), axis))
        if hier:
            return base + (jax.lax.psum(sup_count, axis),
                           jax.lax.pmax(sup_rung, axis),
                           jax.lax.psum(bounds_loc, axis),
                           jax.lax.pmax(n_rungs_loc, axis),
                           jax.lax.pmax(overflow_loc.astype(jnp.int32),
                                        axis))
        return base

    n_out = 14 if hier else 9
    if live is None:
        fn = manual_axis_map(
            shard_body, mesh,
            in_specs=(P(axis, None), meta_specs, P(), P()),
            out_specs=(P(),) * n_out)
        outs = fn(codes_p, meta_parts, sub_emb, phi)
    else:
        # Tombstone mask rides the mesh axis alongside the codes (shard
        # padding rows are dead); everything else is the same ONE
        # shard_map — churn is pure data, so zero recompiles per swap.
        live_p = jnp.pad(live, (0, pad)) if pad else live

        def body_live(codes_local, meta_local, live_local, sub_emb_, phi_):
            return shard_body(codes_local, meta_local, sub_emb_, phi_,
                              live_local=live_local)

        fn = manual_axis_map(
            body_live, mesh,
            in_specs=(P(axis, None), meta_specs, P(axis), P(), P()),
            out_specs=(P(),) * n_out)
        outs = fn(codes_p, meta_parts, live_p, sub_emb, phi)
    (vals, ids, survived, n_seed_used, rung, n_scored, max_group,
     pairs_scored, pairs_union) = outs[:9]
    if not return_stats:
        return vals, ids
    total = n_shards * t_local
    if hier:
        sup_survived, sup_rung, bounds_comp, n_rungs_t, overflow_t = outs[9:]
        n_rungs_stat = n_rungs_t
        overflow_stat = overflow_t != 0
        sup_stats = {"n_super": state.n_super,
                     "n_super_survived": sup_survived,
                     "super_rung_hit": sup_rung,
                     "bounds_computed": bounds_comp}
    else:
        n_rungs_stat = len(rungs)
        # Overflow is per-shard (survivor skew can force one shard to
        # its exhaustive rung while the global total still fits), so
        # derive it from the pmax'd rung, not the psum'd count.
        overflow_stat = (rung == len(rungs) - 1
                         if len(rungs) > 1 else jnp.bool_(False))
        sup_stats = {"n_super": 0, "n_super_survived": 0,
                     "super_rung_hit": 0, "bounds_computed": total}
    stats = {"n_tiles": total, "n_survived": survived,
             "n_scored": n_scored,
             "survival_fraction": survived / jnp.float32(max(total, 1)),
             "n_seed_used": n_seed_used,
             "seed_survival_est": survived / jnp.float32(max(total, 1)),
             "rung_hit": rung, "n_rungs": n_rungs_stat,
             "slot_overflow": overflow_stat,
             "bound_backend": state.backend,
             # Kernel group rows actually built (the 8-row sublane floor
             # can collapse small batches below the requested n_groups).
             "n_groups": b_pad // bt if grouped else 1,
             "max_group_survived": max_group,
             "pairs_scored": pairs_scored, "pairs_union": pairs_union,
             **sup_stats}
    return vals, ids, stats


# ---------------------------------------------------------------------------
# distributed: items sharded over a mesh axis, O(k * shards) merge
# ---------------------------------------------------------------------------

def top_items_sharded(params: Params, phi: jax.Array, k: int, mesh,
                      axis: str = "model", method: str = "pqtopk",
                      pq_cfg: Optional[PQConfig] = None,
                      ladder=None,
                      ) -> Tuple[jax.Array, jax.Array]:
    """Item-sharded retrieval: codes sharded over ``axis``; each shard runs
    PQTopK locally and contributes k candidates to an all-gather merge.

    Per-shard collective volume: k * (4 + 4) bytes * n_shards — independent
    of N (DESIGN.md §5).
    """
    if not is_pq(params):
        return _dense_top_items_sharded(params, phi, k, mesh, axis)
    if method == "pqtopk_pruned":
        return top_items_pruned_sharded(params, phi, k, mesh, axis,
                                        pq_cfg=pq_cfg, ladder=ladder)
    if params.get("live") is not None:
        raise ValueError(
            f"params carry a tombstone mask ('live') but method {method!r} "
            "would ignore it and could return delisted items; mutable "
            "catalogues serve via 'pqtopk_pruned'")
    n = params["codes"].shape[0]
    n_shards = mesh.shape[axis]
    pad = (-n) % n_shards
    codes = params["codes"]
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    n_local = (n + pad) // n_shards

    if method == "pqtopk_fused":
        shard_fn = _fused_shard_fn(k, n, n_local, pad, axis)
    else:
        scorer = {"pqtopk": scoring.score_pqtopk,
                  "pqtopk_onehot": scoring.score_pqtopk_onehot,
                  "pqtopk_kernel": scoring.score_pqtopk,
                  "recjpq": scoring.score_recjpq}[method]

        def shard_fn(codes_local, sub_emb, phi_):
            s = scoring.subid_scores(sub_emb.astype(jnp.float32),
                                     phi_.astype(jnp.float32))
            r_local = scorer(codes_local, s)
            offset = jax.lax.axis_index(axis) * n_local
            # Mask padding rows (global id >= n) out of the top-k.
            gid = offset + jnp.arange(n_local)
            r_local = jnp.where(gid[None, :] < n, r_local, -jnp.inf)
            return topk_lib.local_then_merge_topk(r_local, k, axis, offset)

    fn = manual_axis_map(
        shard_fn, mesh,
        in_specs=(P(axis, None), P(), P()),
        out_specs=(P(), P()),   # outputs are replicated post-all_gather
    )
    return fn(codes, params["sub_emb"], phi)


def _fused_shard_fn(k: int, n: int, n_local: int, pad: int, axis: str):
    """Shard body for the fused route: the Pallas kernel produces this
    shard's top-k directly (per-tile winners merged in the wrapper — the
    (B, N_local) score matrix never exists), then the cross-shard merge is
    the same O(k * shards) all-gather as every other method.

    Shard-level padding rows (zero codes, only on the last shard) are real
    rows to the kernel, so we oversample the local top-(k + pad): at most
    ``pad`` winners can be padding, which we mask to -inf after mapping to
    global ids — the surviving candidates still contain the true local
    top-k, keeping the route exact.
    """
    from repro.kernels.pqtopk import ops as kernel_ops
    k_local = min(k + pad, n_local)

    def shard_fn(codes_local, sub_emb, phi_):
        s = scoring.subid_scores(sub_emb.astype(jnp.float32),
                                 phi_.astype(jnp.float32))
        lv, li = kernel_ops.pq_topk(codes_local, s, k_local)
        offset = jax.lax.axis_index(axis) * n_local
        gid = li.astype(jnp.int32) + offset.astype(jnp.int32)
        lv = jnp.where(gid < n, lv, -jnp.inf)
        if pad:
            # Re-rank after masking so each shard contributes its best k.
            lv, sel = jax.lax.top_k(lv, min(k, k_local))
            gid = jnp.take_along_axis(gid, sel, axis=1)
        return topk_lib.merge_local_topk(lv, gid, k, axis)

    return shard_fn


def _dense_top_items_sharded(params: Params, phi: jax.Array, k: int, mesh,
                             axis: str) -> Tuple[jax.Array, jax.Array]:
    n = params["table"].shape[0]
    n_local = n // mesh.shape[axis]

    def shard_fn(table_local, phi_):
        r_local = scoring.score_dense(table_local.astype(phi_.dtype), phi_)
        offset = jax.lax.axis_index(axis) * n_local
        return topk_lib.local_then_merge_topk(
            r_local.astype(jnp.float32), k, axis, offset)

    fn = manual_axis_map(
        shard_fn, mesh,
        in_specs=(P(axis, None), P()),
        out_specs=(P(), P()),
    )
    return fn(params["table"], phi)
