"""Per-tile score upper bounds for cascaded (pruned) PQ retrieval.

Follow-up to PQTopK: "Efficient Recommendation with Millions of Items by
Dynamic Pruning of Sub-Item Embeddings" (arXiv:2505.00560) observes that
per-split score decomposition admits cheap *upper bounds*: for any item i
in tile t,

    r_i = sum_k S[k, G[i,k]]  <=  sum_k max_{j in C(t,k)} S[k, j] =: ub_t

where C(t,k) is the set of sub-ids that actually occur in split k of tile
t.  A retriever that knows a threshold theta with at least K items scoring
>= theta can skip every tile with ub_t < theta *without changing the exact
top-K* — no skipped item can reach theta (see docs/PRUNING.md for the full
argument, including ties).

This module holds the query-independent half (per-tile code-presence
metadata, built once per catalogue at head-build time) and the
query-dependent half (bounds, theta seeding, survival mask), all pure jnp
so they can run inside jit (pass 1 of the cascade) or under shard_map
(per-shard bounds with a pmax-shared theta).
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scoring import tree_sum

NEG_INF = jnp.float32(-jnp.inf)


# ---------------------------------------------------------------------------
# query-independent metadata (built at head-build time, cached per catalogue)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TileMeta:
    """Code-range metadata for one catalogue at one tile size.

    present[t, k, j] == True iff sub-id j occurs in split k among the items
    of tile t (items t*tile .. (t+1)*tile-1; the last tile may be partial).
    Cost: n_tiles * m * b bools — e.g. 1 MiB for N=2^20, tile=2048, m=8,
    b=256.  Tiles beyond the catalogue are absent; a tile-split with no
    items present bounds to -inf and is auto-pruned.
    """

    tile: int
    n_tiles: int
    n_items: int
    present: jax.Array   # (n_tiles, m, b) bool


@partial(jax.jit, static_argnames=("b", "tile"))
def _build_present(codes: jax.Array, b: int, tile: int) -> jax.Array:
    n, m = codes.shape
    n_tiles = -(-n // tile)
    t_ids = (jnp.arange(n, dtype=jnp.int32) // tile).astype(jnp.int32)
    present = jnp.zeros((n_tiles, m, b), jnp.bool_)
    for k in range(m):
        present = present.at[t_ids, k, codes[:, k].astype(jnp.int32)].set(True)
    return present


def build_tile_metadata(codes: jax.Array, b: int, tile: int) -> TileMeta:
    """O(N*m) scatter over the codebook — head-build-time work."""
    n = codes.shape[0]
    return TileMeta(tile=tile, n_tiles=-(-n // tile), n_items=n,
                    present=_build_present(codes, b, tile))


# Per-catalogue cache keyed by the identity of the codes array; a weakref
# finalizer evicts entries when the array is collected so an id() reuse can
# never serve stale metadata.
_META_CACHE: dict = {}


def get_tile_metadata(codes: jax.Array, b: int, tile: int) -> TileMeta:
    key = (id(codes), b, tile)
    meta = _META_CACHE.get(key)
    if meta is not None:
        return meta
    meta = build_tile_metadata(codes, b, tile)
    try:
        weakref.finalize(codes, _META_CACHE.pop, key, None)
        _META_CACHE[key] = meta
    except TypeError:   # array type not weakref-able: recompute per call
        pass
    return meta


# ---------------------------------------------------------------------------
# query-dependent: bounds -> theta -> survival mask (pass 1 of the cascade)
# ---------------------------------------------------------------------------


def tile_upper_bounds(present: jax.Array, s: jax.Array) -> jax.Array:
    """ub[q, t] = sum_k max_{j: present[t,k,j]} s[q,k,j].

    present (T, m, b) bool, s (B, m, b) f32 -> (B, T) f32.  Cost
    O(B*T*m*b) = O(B*N*m*b/tile) — a factor tile/b cheaper than scoring.
    """
    m = present.shape[1]
    parts = [jnp.where(present[None, :, k, :], s[:, None, k, :], NEG_INF)
             .max(axis=-1) for k in range(m)]          # m x (B, T)
    # Same balanced-tree add order as scoring so a single-item tile's bound
    # is bit-identical to that item's score (bound tightness tests rely on
    # exact equality there).
    return tree_sum(parts)


def theta_from_seed(codes: jax.Array, s: jax.Array, bounds: jax.Array,
                    k: int, *, tile: int, n_seed: int,
                    n_items: Optional[int] = None,
                    id_offset=0) -> jax.Array:
    """Greedy exact pass over the ``n_seed`` most promising tiles.

    Scores the tiles with the largest (batch-max) upper bounds exactly and
    returns theta (B,) = each query's k-th best seeded score — a certified
    threshold: at least k items score >= theta, so any tile with
    ub < theta cannot contribute to the top-k.

    ``id_offset``/``n_items`` mask rows whose *global* id falls outside the
    true catalogue (tile-alignment padding, shard padding); on a shard,
    pass the shard's global offset and the global item count.
    """
    from repro.kernels.pqtopk import ref as pq_ref

    n, m = codes.shape
    n_tiles = -(-n // tile)
    n_seed = min(max(n_seed, -(-k // tile)), n_tiles)
    pad = n_tiles * tile - n
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    seed_tiles = jax.lax.top_k(bounds.max(axis=0), n_seed)[1]     # (n_seed,)
    seed_codes = codes.reshape(n_tiles, tile, m)[seed_tiles]
    scores = pq_ref.pq_scores(seed_codes.reshape(n_seed * tile, m), s)
    local_id = (seed_tiles[:, None] * tile
                + jnp.arange(tile, dtype=jnp.int32)[None, :]).reshape(-1)
    limit = n if n_items is None else n_items
    valid = (id_offset + local_id < limit) & (local_id < n)
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    kk = min(k, n_seed * tile)
    return jax.lax.top_k(scores, kk)[0][:, -1]


def survival_mask(bounds: jax.Array, theta: jax.Array) -> jax.Array:
    """Tile survives iff ANY query in the batch still needs it.

    bounds (B, T), theta (B,) -> (T,) bool.  ``>=`` (not ``>``) keeps
    exactness under ties: an item scoring exactly theta must stay visible.
    """
    return (bounds >= theta[:, None]).any(axis=0)


def pruned_pass1(codes: jax.Array, present: jax.Array, s: jax.Array, k: int,
                 *, tile: int, n_seed: int,
                 n_items: Optional[int] = None,
                 id_offset=0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bounds + theta + survival mask in one jit-friendly call.

    Returns (mask (T,) bool, bounds (B, T), theta (B,)).
    """
    bounds = tile_upper_bounds(present, s)
    theta = theta_from_seed(codes, s, bounds, k, tile=tile, n_seed=n_seed,
                            n_items=n_items, id_offset=id_offset)
    return survival_mask(bounds, theta), bounds, theta


# ---------------------------------------------------------------------------
# the full two-pass cascade (host-orchestrated)
# ---------------------------------------------------------------------------

_pass1_jit = jax.jit(pruned_pass1, static_argnames=("k", "tile", "n_seed"))


def slot_bucket(n_survived: int, k: int, tile: int) -> int:
    """Pad the survivor list to a power-of-two slot count so pass-2 jit
    recompiles stay bounded; always at least enough slots to hold k."""
    need = max(1, n_survived, -(-k // tile))
    return 1 << (need - 1).bit_length()


def cascade_topk(codes: jax.Array, s: jax.Array, k: int, *, tile: int,
                 seed_tiles: int = 2, meta: Optional[TileMeta] = None,
                 use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 return_stats: bool = False):
    """Exact top-k via the two-pass cascade, given the S matrix.

    Pass 1 (jitted): bounds -> theta -> survival mask.  Host sync: compact
    the surviving tile indices (power-of-two slot bucket, sentinel-padded).
    Pass 2 (jitted per bucket size): fused scoring + top-k over surviving
    tiles only.  Bit-identical to ``score_pqtopk`` + ``tiled_topk``; NOT
    jit-compatible (the compaction is a device->host sync) — inside jit use
    the masked in-graph variant in ``retrieval_head``.
    """
    import numpy as np

    from repro.kernels.pqtopk import ops as kernel_ops

    n = codes.shape[0]
    tile = min(tile, n)
    if meta is None:
        meta = get_tile_metadata(codes, int(s.shape[-1]), tile)
    mask, _, _ = _pass1_jit(codes, meta.present, s, k, tile=tile,
                            n_seed=seed_tiles)
    survivors = np.nonzero(np.asarray(mask))[0]
    n_slots = slot_bucket(len(survivors), k, tile)
    tile_idx = np.full(n_slots, kernel_ops.sentinel_tile(n, tile), np.int32)
    tile_idx[:len(survivors)] = survivors
    vals, ids = kernel_ops.pq_topk_tiles(
        codes, s, k, jnp.asarray(tile_idx), tile=tile,
        use_kernel=use_kernel, interpret=interpret)
    if not return_stats:
        return vals, ids
    stats = {"n_tiles": meta.n_tiles, "n_survived": int(len(survivors)),
             "n_scored": int(n_slots),
             "survival_fraction": len(survivors) / max(meta.n_tiles, 1)}
    return vals, ids, stats
