"""Per-tile score upper bounds for cascaded (pruned) PQ retrieval.

Follow-up to PQTopK: "Efficient Recommendation with Millions of Items by
Dynamic Pruning of Sub-Item Embeddings" (arXiv:2505.00560) observes that
per-split score decomposition admits cheap *upper bounds*: for any item i
in tile t,

    r_i = sum_k S[k, G[i,k]]  <=  sum_k max_{j in C(t,k)} S[k, j] =: ub_t

where C(t,k) is the set of sub-ids that actually occur in split k of tile
t.  A retriever that knows a threshold theta with at least K items scoring
>= theta can skip every tile with ub_t < theta *without changing the exact
top-K* — no skipped item can reach theta (see docs/PRUNING.md for the full
argument, including ties).

Two generations of the cascade live here:

* **Single-dispatch in-graph cascade** (PR 3, the serving path):
  :class:`PrunedHeadState` holds the query-independent metadata as
  ``uint32`` presence *bitmasks* (8x smaller than the PR 2 bool array),
  built once at head-build time and threaded through the param tree.
  :func:`cascade_topk_ingraph` computes bounds, seeds theta (greedy or
  adaptive), compacts the surviving tile indices with an in-graph cumsum
  scatter into a ``-1``-padded slot buffer, and hands that buffer to the
  fused kernel's scalar-prefetched tile-index axis — one jitted dispatch,
  no device->host sync, safe inside ``jit`` / ``lm_decode_step`` /
  ``shard_map``.

* **Host two-pass cascade** (PR 2, kept as the reference/comparison
  implementation): :func:`cascade_topk` — jitted bound pass, host
  compaction, jitted compacted scoring pass.  Exact and occasionally
  useful interactively, but every call pays a device->host sync.

Since PR 5 survival can additionally be **per query**: theta is seeded
per query over each query's own most promising tiles
(:func:`theta_seed_perquery`), survival is a per-query bitmask over tiles
(:func:`survival_mask_perquery`), and queries whose survivor sets overlap
are bucketed into groups (:func:`group_queries`,
:func:`group_and_compact`) so each kernel batch tile scores only ITS
group's compacted slot list — ``sum_g B_g * S_g`` work instead of the
batch-any ``B * |union|``, which is what keeps mixed serving batches from
degrading toward exhaustive scoring as B grows.  All of it is pure jnp
(scan + cumsum scatter + stable argsort), so the grouped cascade is still
ONE jitted dispatch.
"""
from __future__ import annotations

import weakref
from dataclasses import dataclass, replace
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.scoring import tree_sum

# Plain Python float (see kernels/pqtopk/ops.py: lazily imported modules
# must not materialise jnp constants at import time).
NEG_INF = float("-inf")

#: Default pruning granularity (items per tile) — matches the fused
#: kernel's item tile so one surviving tile is one kernel grid slot.
DEFAULT_PRUNE_TILE = 2048
#: theta-seeding defaults (see PQConfig for the per-model knobs).
DEFAULT_SEED_TILES = 2
DEFAULT_SEED_MAX_TILES = 16
DEFAULT_SEED_STAB_TOL = 0.05
#: Default query-group count for the per-query grouped cascade
#: (PQConfig.n_groups; n_groups=1 collapses to the batch-any route).
DEFAULT_N_GROUPS = 8
#: Default super-tile width (child tiles per super-tile) for the
#: hierarchical cascade: pass 0 prunes super-tiles against theta before a
#: single child tile bound is gathered, dropping the bound pass from O(T)
#: to O(T/factor + survivors).  64 balances pass-0 cost (T/64 super
#: bounds) against pass-1 granularity (each surviving super admits up to
#: 64 child bounds) at the 10^7-10^8 catalogue scale the ROADMAP targets.
DEFAULT_SUPER_FACTOR = 64

#: Pluggable bound backends (PQConfig.bound_backend):
#:   "bitmask" — uint32 code-presence bitmasks (exact per-tile code sets,
#:               tightest bounds, O(T*m*b/8) bytes);
#:   "range"   — per-tile [code_lo, code_hi] int16 ranges (O(T*m*4) bytes,
#:               bounds via a per-query segment-max table + two gathers,
#:               looser when code distributions have holes).
BOUND_BACKENDS = ("bitmask", "range")

#: Canonical cascade stats schema — every pruned route (host two-pass,
#: in-graph single-dispatch, one-shard_map sharded) returns exactly these
#: keys, so serving/bench consumers never branch on the route.
STATS_KEYS = frozenset({
    "n_tiles", "n_survived", "n_scored", "survival_fraction",
    "n_seed_used", "seed_survival_est", "rung_hit", "n_rungs",
    "slot_overflow", "bound_backend",
    # Per-query grouping (PR 5).  Ungrouped routes report n_groups=1,
    # max_group_survived == n_survived, and pairs_scored == pairs_union
    # == n_survived * padded batch — the batch-any work.
    "n_groups", "max_group_survived", "pairs_scored", "pairs_union",
    # Hierarchical super-tile cascade (PR 9).  Flat routes report
    # n_super=0, n_super_survived=0, super_rung_hit=0, and
    # bounds_computed == n_tiles (every tile bound is gathered); the
    # hierarchical route reports bounds_computed == n_super + the
    # executed super rung's child-bound gather — the pass-0/pass-1 work
    # the BENCH section's >=10x reduction claim is measured on.
    "n_super", "n_super_survived", "super_rung_hit", "bounds_computed"})

_WORD = 32   # presence bits per packed uint32 word


# ---------------------------------------------------------------------------
# query-independent metadata (built at head-build time, cached per catalogue)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TileMeta:
    """Dense-bool code-range metadata (PR 2 layout; reference path only).

    present[t, k, j] == True iff sub-id j occurs in split k among the items
    of tile t (items t*tile .. (t+1)*tile-1; the last tile may be partial).
    Cost: n_tiles * m * b bools — the bit-packed :class:`PrunedHeadState`
    stores the same information in 1/8 the bytes.
    """

    tile: int
    n_tiles: int
    n_items: int
    present: jax.Array   # (n_tiles, m, b) bool


@partial(jax.jit, static_argnames=("b", "tile"))
def _build_present(codes: jax.Array, b: int, tile: int) -> jax.Array:
    n, m = codes.shape
    n_tiles = -(-n // tile)
    t_ids = (jnp.arange(n, dtype=jnp.int32) // tile).astype(jnp.int32)
    present = jnp.zeros((n_tiles, m, b), jnp.bool_)
    for k in range(m):
        present = present.at[t_ids, k, codes[:, k].astype(jnp.int32)].set(True)
    return present


def build_tile_metadata(codes: jax.Array, b: int, tile: int) -> TileMeta:
    """O(N*m) scatter over the codebook — head-build-time work."""
    n = codes.shape[0]
    return TileMeta(tile=tile, n_tiles=-(-n // tile), n_items=n,
                    present=_build_present(codes, b, tile))


# Per-catalogue cache keyed by the identity of the codes array; a weakref
# finalizer evicts entries when the array is collected so an id() reuse can
# never serve stale metadata.
_META_CACHE: dict = {}


def get_tile_metadata(codes: jax.Array, b: int, tile: int) -> TileMeta:
    key = (id(codes), b, tile)
    meta = _META_CACHE.get(key)
    if meta is not None:
        return meta
    meta = build_tile_metadata(codes, b, tile)
    try:
        weakref.finalize(codes, _META_CACHE.pop, key, None)
        _META_CACHE[key] = meta
    except TypeError:   # array type not weakref-able: recompute per call
        pass
    return meta


# ---------------------------------------------------------------------------
# bit-packed presence: (T, m, b) bool -> (T, m, ceil(b/32)) uint32
# ---------------------------------------------------------------------------


def packed_words(b: int) -> int:
    """uint32 words per (tile, split) presence row."""
    return -(-b // _WORD)


def pack_presence(present: jax.Array) -> jax.Array:
    """(T, m, b) bool -> (T, m, ceil(b/32)) uint32, bit j of word w set iff
    present[..., w*32 + j].  8x smaller than the bool array in HBM."""
    t, m, b = present.shape
    w = packed_words(b)
    pad = w * _WORD - b
    if pad:
        present = jnp.pad(present, ((0, 0), (0, 0), (0, pad)))
    bits = present.reshape(t, m, w, _WORD).astype(jnp.uint32)
    weight = jnp.uint32(1) << jnp.arange(_WORD, dtype=jnp.uint32)
    return (bits * weight).sum(axis=-1, dtype=jnp.uint32)


def unpack_presence(packed: jax.Array, b: int) -> jax.Array:
    """Inverse of :func:`pack_presence` -> (T, m, b) bool."""
    t, m, w = packed.shape
    bitpos = jnp.arange(_WORD, dtype=jnp.uint32)
    bits = (packed[..., None] >> bitpos) & jnp.uint32(1)
    return bits.reshape(t, m, w * _WORD)[..., :b] != 0


@dataclass(frozen=True)
class PrunedHeadState:
    """Query-independent pruning metadata as a param-tree citizen.

    Built once at head-build time (``retrieval_head.init``) and threaded
    through the params dict, so the in-graph cascade is a pure function of
    params — jittable, shardable, decode-loop safe, no per-call rebuild.

    The metadata layout is pluggable (``backend``, selected by
    ``PQConfig.bound_backend``):

    * ``"bitmask"`` — ``packed`` holds the code-presence set as uint32
      bitmasks (bit j of word w in ``packed[t, k, w]`` == sub-id ``w*32+j``
      occurs in split k of tile t) — 8x smaller than the PR 2 (T, m, b)
      bool array; ``code_lo``/``code_hi`` are ``None``.
    * ``"range"`` — ``code_lo``/``code_hi`` hold per-(tile, split) min/max
      codes as (T, m) int16 — O(T*m*4) bytes, 1/8 of the packed bitmasks
      at b=256 — and ``packed`` is ``None``.  Bounds come from a per-query
      segment-max table and two gathers (:func:`tile_upper_bounds_range`).

    The static layout fields (including ``backend``) are pytree *metadata*
    (hashable, part of the treedef), so jit specialises on them exactly
    like on a shape; the absent backend's arrays are ``None`` children,
    which flatten to nothing.

    For the item-sharded route (``shards > 1``) the catalogue is padded to
    ``shards * n_local`` rows and tiled *per shard*, so tile boundaries
    never straddle shard boundaries and every metadata array splits evenly
    over the mesh axis (``P(axis, ...)`` on its leading tile dim).

    **Hierarchical super-tiles** (``super_factor > 1``, built by
    :func:`with_super`): groups of ``super_factor`` consecutive child
    tiles carry their own presence/range metadata — the OR of the
    children's presence bitmasks, or the [min lo, max hi] hull of their
    ranges — grouped *per shard* so a super-tile never straddles a shard
    boundary.  A super-tile's bound dominates every child tile's bound
    (same dominance argument one level up: the union's per-split max is
    >= each member's), so pass 0 can prune super-tiles against theta
    before any child tile bound is gathered; children of a pruned super
    provably cannot survive, and the surviving-child set — hence the
    exact top-k — is bit-identical to the flat cascade at the same theta
    (docs/PRUNING.md §Hierarchical bounds).  ``super_factor == 0`` (the
    default) means no super level; the super arrays are ``None`` pytree
    children that flatten to nothing, so flat states are untouched.
    """

    packed: Optional[jax.Array]   # bitmask: (T, m, ceil(b/32)) uint32
    tile: int            # items per tile
    n_items: int         # true catalogue rows (pre-padding)
    b: int               # codebook width
    shards: int = 1      # shard count the tile layout is aligned to
    n_local: int = 0     # items per shard (== n_items when shards == 1)
    backend: str = "bitmask"
    code_lo: Optional[jax.Array] = None   # range: (T, m) int16
    code_hi: Optional[jax.Array] = None   # range: (T, m) int16
    super_factor: int = 0                 # child tiles per super (0 = flat)
    super_packed: Optional[jax.Array] = None  # (S, m, ceil(b/32)) uint32
    super_lo: Optional[jax.Array] = None      # (S, m) int16
    super_hi: Optional[jax.Array] = None      # (S, m) int16

    def meta_arrays(self) -> Tuple[jax.Array, ...]:
        """The backend's metadata arrays, leading dim = total tiles (what
        the sharded route splits over the mesh axis)."""
        if self.backend == "range":
            return (self.code_lo, self.code_hi)
        return (self.packed,)

    def super_meta_arrays(self) -> Tuple[jax.Array, ...]:
        """The backend's super-tile metadata arrays, leading dim = total
        super-tiles (the sharded route splits them like the child arrays)."""
        if self.backend == "range":
            return (self.super_lo, self.super_hi)
        return (self.super_packed,)

    @property
    def has_super(self) -> bool:
        return self.super_factor > 1

    @property
    def n_tiles(self) -> int:
        return self.meta_arrays()[0].shape[0]

    @property
    def tiles_per_shard(self) -> int:
        return self.n_tiles // self.shards

    @property
    def n_super(self) -> int:
        return self.super_meta_arrays()[0].shape[0]

    @property
    def supers_per_shard(self) -> int:
        return self.n_super // self.shards

    @property
    def nbytes(self) -> int:
        """HBM footprint of this backend's metadata."""
        if self.backend == "range":
            t, m = self.code_lo.shape
            return t * m * 2 * 2            # lo + hi, int16
        t, m, w = self.packed.shape
        return t * m * w * 4

    @property
    def bool_nbytes(self) -> int:
        """What the PR 2 dense-bool layout would cost for this catalogue."""
        t = self.n_tiles
        m = self.meta_arrays()[0].shape[1]
        return t * m * self.b


jax.tree_util.register_dataclass(
    PrunedHeadState,
    data_fields=["packed", "code_lo", "code_hi",
                 "super_packed", "super_lo", "super_hi"],
    meta_fields=["tile", "n_items", "b", "shards", "n_local", "backend",
                 "super_factor"])


@partial(jax.jit, static_argnames=("tile",))
def _build_code_ranges(codes: jax.Array, tile: int
                       ) -> Tuple[jax.Array, jax.Array]:
    """Per-(tile, split) min/max codes -> ((T, m) int16 lo, (T, m) hi).

    Tile-alignment padding rows are excluded from the ranges (a padded row
    must not widen the last tile's range to code 0)."""
    n, m = codes.shape
    n_tiles = -(-n // tile)
    pad = n_tiles * tile - n
    c = codes.astype(jnp.int32)
    if pad:
        c = jnp.pad(c, ((0, pad), (0, 0)))
    c3 = c.reshape(n_tiles, tile, m)
    real = (jnp.arange(n_tiles * tile, dtype=jnp.int32) < n
            ).reshape(n_tiles, tile, 1)
    lo = jnp.where(real, c3, jnp.int32(2 ** 15 - 1)).min(axis=1)
    hi = jnp.where(real, c3, jnp.int32(0)).max(axis=1)
    # A tile with no real rows cannot occur flat (T = ceil(n/tile)); keep
    # lo <= hi anyway so the segment-max gather indices stay in range.
    hi = jnp.maximum(hi, lo)
    return lo.astype(jnp.int16), hi.astype(jnp.int16)


def build_pruned_state(codes: jax.Array, b: int,
                       tile: int = DEFAULT_PRUNE_TILE, *,
                       shards: int = 1,
                       backend: str = "bitmask") -> PrunedHeadState:
    """Head-build-time constructor (also trace-safe: pure jnp, so a caller
    without a threaded state can rebuild in-graph as a fallback)."""
    if backend not in BOUND_BACKENDS:
        raise ValueError(f"unknown bound backend {backend!r}; "
                         f"one of {BOUND_BACKENDS}")
    if backend == "range" and b > 2 ** 15:
        raise ValueError(f"bound backend 'range' stores int16 ranges; "
                         f"b={b} exceeds int16")
    n, m = codes.shape
    if shards <= 1:
        t = max(1, min(int(tile), n))
        if backend == "range":
            lo, hi = _build_code_ranges(codes, t)
            return PrunedHeadState(None, tile=t, n_items=n, b=b, shards=1,
                                   n_local=n, backend="range",
                                   code_lo=lo, code_hi=hi)
        return PrunedHeadState(pack_presence(_build_present(codes, b, t)),
                               tile=t, n_items=n, b=b, shards=1, n_local=n)
    pad = (-n) % shards
    n_local = (n + pad) // shards
    t = max(1, min(int(tile), n_local))
    codes_p = jnp.pad(codes, ((0, pad), (0, 0))) if pad else codes
    per_shard = codes_p.reshape(shards, n_local, m)
    if backend == "range":
        # Shard-padding rows are zero codes here (same semantics as the
        # bitmask build, which registers them as present): harmless for
        # dominance, and the gid >= n mask removes them from the top-k.
        lo, hi = jax.vmap(partial(_build_code_ranges, tile=t))(per_shard)
        return PrunedHeadState(None, tile=t, n_items=n, b=b, shards=shards,
                               n_local=n_local, backend="range",
                               code_lo=lo.reshape(-1, m),
                               code_hi=hi.reshape(-1, m))
    present = jax.vmap(partial(_build_present, b=b, tile=t))(per_shard)
    packed = pack_presence(present.reshape(-1, m, b))
    return PrunedHeadState(packed, tile=t, n_items=n, b=b, shards=shards,
                           n_local=n_local)


@partial(jax.jit, static_argnames=("b", "tile"))
def _build_present_masked(codes: jax.Array, live: jax.Array, b: int,
                          tile: int) -> jax.Array:
    """Presence scatter over LIVE rows only — dead rows (tombstones,
    capacity padding of a mutable catalogue) are scattered off the end of
    the tile axis and dropped, so they contribute no presence bits and the
    result equals a fresh build over the live items alone."""
    n, m = codes.shape
    n_tiles = -(-n // tile)
    rows = jnp.arange(n, dtype=jnp.int32)
    t_ids = jnp.where(live, rows // tile, jnp.int32(n_tiles))
    present = jnp.zeros((n_tiles, m, b), jnp.bool_)
    for k in range(m):
        present = present.at[t_ids, k, codes[:, k].astype(jnp.int32)].set(
            True, mode="drop")
    return present


@partial(jax.jit, static_argnames=("tile",))
def _build_code_ranges_masked(codes: jax.Array, live: jax.Array, tile: int
                              ) -> Tuple[jax.Array, jax.Array]:
    """Live-masked variant of :func:`_build_code_ranges`: dead rows are
    excluded from the min/max exactly like tile-alignment padding rows."""
    n, m = codes.shape
    n_tiles = -(-n // tile)
    pad = n_tiles * tile - n
    c = codes.astype(jnp.int32)
    lv = live
    if pad:
        c = jnp.pad(c, ((0, pad), (0, 0)))
        lv = jnp.pad(lv, (0, pad))
    c3 = c.reshape(n_tiles, tile, m)
    real = lv.reshape(n_tiles, tile, 1)
    lo = jnp.where(real, c3, jnp.int32(2 ** 15 - 1)).min(axis=1)
    hi = jnp.where(real, c3, jnp.int32(0)).max(axis=1)
    # A fully-dead tile degenerates to lo=32767 > hi=0; clamp it to the
    # one-code range [0, 0] so the segment-max gather indices stay in
    # bounds.  Its bound is then the code-0 max — sound for a tile whose
    # every item the live mask removes from the top-k anyway.
    lo = jnp.minimum(lo, hi)
    hi = jnp.maximum(hi, lo)
    return lo.astype(jnp.int16), hi.astype(jnp.int16)


def build_pruned_state_masked(codes: jax.Array, live: jax.Array, b: int,
                              tile: int = DEFAULT_PRUNE_TILE, *,
                              backend: str = "bitmask") -> PrunedHeadState:
    """Flat (shards=1) state whose metadata covers LIVE rows only.

    This is the mutable catalogue's fresh-build / re-tighten oracle
    (core/mutation.py): tombstoned and capacity-padding rows contribute
    nothing, so the bounds are as tight as a from-scratch build over the
    live items alone.  ``build_pruned_state(codes, ...)`` equals
    ``build_pruned_state_masked(codes, ones, ...)`` bit-for-bit.
    """
    if backend not in BOUND_BACKENDS:
        raise ValueError(f"unknown bound backend {backend!r}; "
                         f"one of {BOUND_BACKENDS}")
    if backend == "range" and b > 2 ** 15:
        raise ValueError(f"bound backend 'range' stores int16 ranges; "
                         f"b={b} exceeds int16")
    n = codes.shape[0]
    if live.shape != (n,):
        raise ValueError(f"live mask shape {live.shape} != ({n},)")
    t = max(1, min(int(tile), n))
    if backend == "range":
        lo, hi = _build_code_ranges_masked(codes, live, t)
        return PrunedHeadState(None, tile=t, n_items=n, b=b, shards=1,
                               n_local=n, backend="range",
                               code_lo=lo, code_hi=hi)
    return PrunedHeadState(
        pack_presence(_build_present_masked(codes, live, b, t)),
        tile=t, n_items=n, b=b, shards=1, n_local=n)


def _or_reduce_axis(x: jax.Array, axis: int) -> jax.Array:
    """Tree-halving bitwise-OR reduction along ``axis`` (log2(n) ops
    instead of an n-way unrolled chain — super builds at factor=64 stay
    cheap at trace time)."""
    while x.shape[axis] > 1:
        n = x.shape[axis]
        half = n // 2
        a = jax.lax.slice_in_dim(x, 0, half, axis=axis)
        bb = jax.lax.slice_in_dim(x, half, 2 * half, axis=axis)
        merged = a | bb
        if n % 2:
            rest = jax.lax.slice_in_dim(x, 2 * half, n, axis=axis)
            merged = jnp.concatenate([merged, rest], axis=axis)
        x = merged
    return jnp.squeeze(x, axis=axis)


def with_super(state: PrunedHeadState,
               factor: int = DEFAULT_SUPER_FACTOR) -> PrunedHeadState:
    """Attach a super-tile level: groups of ``factor`` consecutive child
    tiles (grouped PER SHARD, so a super never straddles a shard boundary)
    get their own metadata by reduction over the children — presence
    bitmasks OR together (presence of the union set), code ranges take the
    [min lo, max hi] hull.  Either way the super bound dominates every
    child bound, which is the pass-0 pruning invariant.  ``factor <= 1``
    strips the super level.  Pure jnp over the existing child metadata —
    no codes pass — so it composes with any builder (fresh, masked,
    sharded) and with the mutable catalogue's retighten oracle."""
    factor = int(factor)
    if factor <= 1:
        return replace(state, super_factor=0, super_packed=None,
                       super_lo=None, super_hi=None)
    t_local = state.tiles_per_shard
    s_local = -(-t_local // factor)
    pad = s_local * factor - t_local
    if state.backend == "range":
        m = state.code_lo.shape[1]
        lo = state.code_lo.reshape(state.shards, t_local, m)
        hi = state.code_hi.reshape(state.shards, t_local, m)
        if pad:
            # Padding children are the identity of min/max (lo=int16-max,
            # hi=0); every super has >= 1 real child, so no clamp needed.
            lo = jnp.pad(lo, ((0, 0), (0, pad), (0, 0)),
                         constant_values=2 ** 15 - 1)
            hi = jnp.pad(hi, ((0, 0), (0, pad), (0, 0)))
        slo = lo.reshape(state.shards, s_local, factor, m).min(axis=2)
        shi = hi.reshape(state.shards, s_local, factor, m).max(axis=2)
        return replace(state, super_factor=factor, super_packed=None,
                       super_lo=slo.reshape(-1, m).astype(jnp.int16),
                       super_hi=shi.reshape(-1, m).astype(jnp.int16))
    _, m, w = state.packed.shape
    pk = state.packed.reshape(state.shards, t_local, m, w)
    if pad:
        pk = jnp.pad(pk, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = pk.reshape(state.shards, s_local, factor, m, w)
    sup = _or_reduce_axis(sp, axis=2)          # (shards, s_local, m, w)
    return replace(state, super_factor=factor, super_lo=None, super_hi=None,
                   super_packed=sup.reshape(-1, m, w))


def abstract_pruned_state(n_items: int, m: int, b: int,
                          tile: int = DEFAULT_PRUNE_TILE, *,
                          shards: int = 1,
                          backend: str = "bitmask",
                          super_factor: int = 0) -> PrunedHeadState:
    """ShapeDtypeStruct stand-in matching :func:`build_pruned_state`
    (+ :func:`with_super` when ``super_factor > 1``)."""
    if shards <= 1:
        t = max(1, min(int(tile), n_items))
        n_tiles = -(-n_items // t)
        kw = dict(tile=t, n_items=n_items, b=b, shards=1, n_local=n_items)
    else:
        pad = (-n_items) % shards
        n_local = (n_items + pad) // shards
        t = max(1, min(int(tile), n_local))
        n_tiles = shards * -(-n_local // t)
        kw = dict(tile=t, n_items=n_items, b=b, shards=shards,
                  n_local=n_local)
    sh = max(1, shards)
    if super_factor > 1:
        n_super = sh * -(-(n_tiles // sh) // super_factor)
        kw["super_factor"] = int(super_factor)
        if backend == "range":
            sup_sds = jax.ShapeDtypeStruct((n_super, m), jnp.int16)
            kw["super_lo"] = kw["super_hi"] = sup_sds
        else:
            kw["super_packed"] = jax.ShapeDtypeStruct(
                (n_super, m, packed_words(b)), jnp.uint32)
    if backend == "range":
        rng_sds = jax.ShapeDtypeStruct((n_tiles, m), jnp.int16)
        return PrunedHeadState(None, backend="range", code_lo=rng_sds,
                               code_hi=rng_sds, **kw)
    shape = (n_tiles, m, packed_words(b))
    return PrunedHeadState(jax.ShapeDtypeStruct(shape, jnp.uint32), **kw)


# ---------------------------------------------------------------------------
# query-dependent: bounds -> theta -> survival mask
# ---------------------------------------------------------------------------


def tile_upper_bounds(present: jax.Array, s: jax.Array) -> jax.Array:
    """ub[q, t] = sum_k max_{j: present[t,k,j]} s[q,k,j].

    present (T, m, b) bool, s (B, m, b) f32 -> (B, T) f32.  Cost
    O(B*T*m*b) = O(B*N*m*b/tile) — a factor tile/b cheaper than scoring.
    """
    m = present.shape[1]
    parts = [jnp.where(present[None, :, k, :], s[:, None, k, :], NEG_INF)
             .max(axis=-1) for k in range(m)]          # m x (B, T)
    # Same balanced-tree add order as scoring so a single-item tile's bound
    # is bit-identical to that item's score (bound tightness tests rely on
    # exact equality there).
    return tree_sum(parts)


def tile_upper_bounds_packed(packed: jax.Array, s: jax.Array) -> jax.Array:
    """Bounds straight from the uint32 bitmasks: each split's presence row
    is unpacked lane-wise against a broadcast bit table and the max over
    sub-ids is taken under that mask.  Bit-identical to
    :func:`tile_upper_bounds` on the unpacked array — only the stored
    footprint changes (1/8), not the arithmetic.

    packed (T, m, W) uint32, s (B, m, b) f32 -> (B, T) f32.
    """
    return tile_upper_bounds(unpack_presence(packed, s.shape[-1]), s)


def range_max_table(s: jax.Array) -> jax.Array:
    """Sparse (binary-lifting) segment-max table over the sub-id axis.

    s (..., b) -> (..., L, b) where ``table[..., l, j] = max(s[..., j :
    j + 2^l])`` (clamped at b) and ``L = floor(log2(b)) + 1``.  Built once
    per query batch in O(b log b); any range max ``[lo, hi]`` is then the
    max of two overlapping power-of-two windows — two gathers, no 32-lane
    bitmask unpack.
    """
    b = s.shape[-1]
    levels = [s]
    w = 1
    while 2 * w <= b:
        prev = levels[-1]
        pad = jnp.full(prev.shape[:-1] + (w,), NEG_INF, prev.dtype)
        shifted = jnp.concatenate([prev[..., w:], pad], axis=-1)
        levels.append(jnp.maximum(prev, shifted))
        w *= 2
    return jnp.stack(levels, axis=-2)


def tile_upper_bounds_range(code_lo: jax.Array, code_hi: jax.Array,
                            s: jax.Array) -> jax.Array:
    """ub[q, t] = sum_k max_{lo[t,k] <= j <= hi[t,k]} s[q, k, j].

    code_lo/code_hi (T, m) int, s (B, m, b) f32 -> (B, T) f32.  Every code
    present in tile t lies inside [lo, hi], so the range max dominates the
    presence-masked max and hence the true item scores — the range bound
    is the bitmask bound with the presence set relaxed to its convex hull
    (equal when codes cover the whole range, looser when there are holes).

    Range maxes come from :func:`range_max_table`: the max over a length-L
    range is the max of the two 2^level windows anchored at ``lo`` and at
    ``hi - 2^level + 1`` (level = floor(log2(L))) — two gathers per
    (tile, split).  Same balanced-tree accumulation (`tree_sum`) as the
    scorers, so a single-item tile's bound equals that item's score
    bit-for-bit (lo == hi -> both windows are that one entry).
    """
    m, b = s.shape[-2], s.shape[-1]
    table = range_max_table(s)                        # (B, m, L, b)
    n_levels = table.shape[-2]
    lo = code_lo.astype(jnp.int32)
    hi = code_hi.astype(jnp.int32)
    length = hi - lo + 1                              # (T, m), >= 1
    level = jnp.zeros_like(length)
    for lv in range(1, n_levels):
        level = level + (length >= (1 << lv)).astype(jnp.int32)
    right = hi - jnp.left_shift(jnp.int32(1), level) + 1
    bq = s.shape[0]
    flat = table.reshape(bq, m, n_levels * b)
    parts = []
    for k in range(m):
        i1 = level[:, k] * b + lo[:, k]               # (T,)
        i2 = level[:, k] * b + right[:, k]
        parts.append(jnp.maximum(flat[:, k, i1], flat[:, k, i2]))  # (B, T)
    return tree_sum(parts)


def tile_bounds(state: PrunedHeadState, s: jax.Array) -> jax.Array:
    """Backend-dispatched per-tile upper bounds -> (B, T) f32."""
    return bounds_from_parts(state.backend, state.meta_arrays(), s)


def bounds_from_parts(backend: str, parts: Tuple[jax.Array, ...],
                      s: jax.Array) -> jax.Array:
    """Bounds from a backend name + its metadata arrays (the shard_map
    body's entry point: the arrays arrive as per-shard slices)."""
    if backend == "range":
        return tile_upper_bounds_range(*parts, s)
    return tile_upper_bounds_packed(*parts, s)


def theta_from_seed(codes: jax.Array, s: jax.Array, bounds: jax.Array,
                    k: int, *, tile: int, n_seed: int,
                    n_items: Optional[int] = None,
                    id_offset=0) -> jax.Array:
    """Greedy exact pass over the ``n_seed`` most promising tiles.

    Scores the tiles with the largest (batch-max) upper bounds exactly and
    returns theta (B,) = each query's k-th best seeded score — a certified
    threshold: at least k items score >= theta, so any tile with
    ub < theta cannot contribute to the top-k.

    ``id_offset``/``n_items`` mask rows whose *global* id falls outside the
    true catalogue (tile-alignment padding, shard padding); on a shard,
    pass the shard's global offset and the global item count.
    """
    from repro.kernels.pqtopk import ref as pq_ref

    n, m = codes.shape
    n_tiles = -(-n // tile)
    n_seed = min(max(n_seed, -(-k // tile)), n_tiles)
    pad = n_tiles * tile - n
    if pad:
        codes = jnp.pad(codes, ((0, pad), (0, 0)))
    seed_tiles = jax.lax.top_k(bounds.max(axis=0), n_seed)[1]     # (n_seed,)
    seed_codes = codes.reshape(n_tiles, tile, m)[seed_tiles]
    scores = pq_ref.pq_scores(seed_codes.reshape(n_seed * tile, m), s)
    local_id = (seed_tiles[:, None] * tile
                + jnp.arange(tile, dtype=jnp.int32)[None, :]).reshape(-1)
    limit = n if n_items is None else n_items
    valid = (id_offset + local_id < limit) & (local_id < n)
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    kk = min(k, n_seed * tile)
    return jax.lax.top_k(scores, kk)[0][:, -1]


def seed_schedule(policy: str, n_seed: int, n_seed_max: int, k: int,
                  tile: int, n_tiles: int) -> Tuple[int, ...]:
    """Static seed-size schedule (tiles scored after each stage).

    Greedy: one stage.  Adaptive: geometric doubling from ``n_seed`` up to
    ``n_seed_max`` — the stage count is Python-static, so the whole policy
    stays in-graph (each growth stage is a ``lax.cond`` that is skipped at
    runtime once the survival estimate has stabilised).
    """
    floor = max(1, -(-k // tile))              # enough seed rows to hold k
    first = min(max(n_seed, floor), n_tiles)
    if policy == "greedy":
        return (first,)
    sizes = [first]
    while sizes[-1] < min(max(n_seed_max, first), n_tiles):
        sizes.append(min(sizes[-1] * 2, n_tiles, max(n_seed_max, first)))
    return tuple(dict.fromkeys(sizes))


def degenerate_tile_mask(state: PrunedHeadState) -> Optional[jax.Array]:
    """(T,) bool — tiles whose range metadata is a degenerate *full hull*
    in some split (``hi - lo == b - 1``): their range bound for that split
    is the unconditional max over all sub-ids, so the bound is loose and
    — worse — large, which makes greedy seed ordering pick exactly these
    tiles first, wasting the seed budget on uninformative tiles and
    stalling the adaptive growth loop at a loose theta (ROADMAP wrap
    follow-up).  ``None`` for backends whose bounds carry no hull
    (bitmask presence sets are exact — no degenerate notion)."""
    return degenerate_from_parts(state.backend, state.meta_arrays(), state.b)


def degenerate_from_parts(backend: str, parts: Tuple[jax.Array, ...],
                          b: int) -> Optional[jax.Array]:
    """:func:`degenerate_tile_mask` from a backend name + metadata arrays
    (the shard_map body's entry point, like :func:`bounds_from_parts`)."""
    if backend != "range":
        return None
    lo, hi = parts
    span = hi.astype(jnp.int32) - lo.astype(jnp.int32)   # (T, m)
    return (span == b - 1).any(axis=1)


def seed_order_key(bounds: jax.Array,
                   degenerate: Optional[jax.Array]) -> jax.Array:
    """Seed-*ordering* key: the bounds, with degenerate full-hull tiles
    pushed behind every informative tile (bounds shifted down by more than
    the batch's bound span, so relative order within each class is kept).
    Ordering only ever picks WHICH tiles get scored exactly — any seed set
    certifies its theta — so this cannot cost exactness, it only stops
    wrap tiles from hogging the seed budget.  ``bounds`` may be (T,)
    (batch-max order) or (B, T) (per-query order)."""
    if degenerate is None:
        return bounds
    span = bounds.max() - bounds.min() + 1.0
    return bounds - degenerate.astype(bounds.dtype) * span


def theta_seed_ingraph(codes: jax.Array, s: jax.Array, bounds: jax.Array,
                       k: int, *, tile: int,
                       seed_policy: str = "greedy",
                       seed_tiles: int = DEFAULT_SEED_TILES,
                       seed_max_tiles: int = DEFAULT_SEED_MAX_TILES,
                       seed_stab_tol: float = DEFAULT_SEED_STAB_TOL,
                       n_items: Optional[int] = None,
                       id_offset=0,
                       degenerate: Optional[jax.Array] = None,
                       live: Optional[jax.Array] = None):
    """In-graph theta seeding -> (theta (B,), n_seed_used i32, survival f32).

    ``seed_policy="greedy"``: one exact pass over the ``seed_tiles`` most
    promising tiles (bit-identical theta to :func:`theta_from_seed`).

    ``seed_policy="adaptive"``: grow the seed set geometrically
    (``seed_tiles`` -> ``seed_max_tiles``) until the estimated survival
    fraction moves by <= ``seed_stab_tol`` between stages.  Every stage is
    a ``lax.cond`` over a Python-static chunk, so the trip count is fixed
    at trace time and skipped stages cost nothing at runtime — the policy
    is decode-loop and shard_map safe.

    ``degenerate`` (T,) bool de-prioritises full-hull range tiles in the
    seed ordering (:func:`seed_order_key`); theta certification is
    unaffected by ordering.

    ``live`` (n,) bool (tombstone mask over LOCAL rows, mutable
    catalogues) excludes dead items from the exact seed scores.  This is
    a correctness requirement, not an optimisation: a dead high-scorer
    would certify a theta that live items cannot reach, and the scoring
    pass (which masks dead items to -inf) could then return fewer than k
    items above theta — the cascade would no longer be exact over the
    live catalogue.
    """
    from repro.kernels.pqtopk import ref as pq_ref

    n, m = codes.shape
    bq = s.shape[0]
    n_tiles = bounds.shape[1]
    sizes = seed_schedule(seed_policy, seed_tiles, seed_max_tiles, k, tile,
                          n_tiles)
    pad = n_tiles * tile - n
    codes_pad = jnp.pad(codes, ((0, pad), (0, 0))) if pad else codes
    tiles3 = codes_pad.reshape(n_tiles, tile, m)
    order = jax.lax.top_k(seed_order_key(bounds.max(axis=0), degenerate),
                          sizes[-1])[1]                   # (n_max,)
    limit = n if n_items is None else n_items

    def score_chunk(tile_ids):
        """Exact, id-masked scores of the chunk's items -> (B, c*tile)."""
        sc = pq_ref.pq_scores(tiles3[tile_ids].reshape(-1, m), s)
        local = (tile_ids[:, None] * tile
                 + jnp.arange(tile, dtype=jnp.int32)[None, :]).reshape(-1)
        valid = (id_offset + local < limit) & (local < n)
        if live is not None:
            valid = valid & live[local]
        return jnp.where(valid[None, :], sc, NEG_INF)

    def merge(vals, sc):
        cand = jnp.concatenate(
            [vals, jax.lax.top_k(sc, min(k, sc.shape[1]))[0]], axis=1)
        return jax.lax.top_k(cand, k)[0]

    def survival_est(theta):
        return survival_mask(bounds, theta).mean()

    vals = merge(jnp.full((bq, k), NEG_INF), score_chunk(order[:sizes[0]]))
    theta = vals[:, -1]
    sf = survival_est(theta)
    n_used = jnp.int32(sizes[0])
    done = jnp.bool_(False)
    for prev, size in zip(sizes, sizes[1:]):
        chunk = order[prev:size]

        def grow(carry, chunk=chunk, size=size):
            vals, _theta, sf_prev, n_used, _done = carry
            vals = merge(vals, score_chunk(chunk))
            theta = vals[:, -1]
            sf = survival_est(theta)
            stable = jnp.abs(sf - sf_prev) <= seed_stab_tol
            return vals, theta, sf, jnp.int32(size), stable

        carry = (vals, theta, sf, n_used, done)
        vals, theta, sf, n_used, done = jax.lax.cond(
            done, lambda c: c, grow, carry)
    return theta, n_used, sf


def survival_mask(bounds: jax.Array, theta: jax.Array) -> jax.Array:
    """Tile survives iff ANY query in the batch still needs it.

    bounds (B, T), theta (B,) -> (T,) bool.  ``>=`` (not ``>``) keeps
    exactness under ties: an item scoring exactly theta must stay visible.
    """
    return (bounds >= theta[:, None]).any(axis=0)


def survival_mask_perquery(bounds: jax.Array, theta: jax.Array) -> jax.Array:
    """Per-query survival bitmask: mask[q, t] == query q still needs tile t.

    bounds (B, T), theta (B,) -> (B, T) bool.  The batch-any mask is
    exactly ``survival_mask_perquery(...).any(axis=0)`` — the per-query
    form keeps the information the batch-any rule throws away, which is
    what query grouping exploits.  Same ``>=`` tie rule: an item tying a
    query's k-th value keeps its tile visible *to that query*.
    """
    return bounds >= theta[:, None]


def theta_seed_perquery(codes: jax.Array, s: jax.Array, bounds: jax.Array,
                        k: int, *, tile: int,
                        seed_policy: str = "greedy",
                        seed_tiles: int = DEFAULT_SEED_TILES,
                        seed_max_tiles: int = DEFAULT_SEED_MAX_TILES,
                        seed_stab_tol: float = DEFAULT_SEED_STAB_TOL,
                        n_items: Optional[int] = None,
                        id_offset=0,
                        degenerate: Optional[jax.Array] = None,
                        live: Optional[jax.Array] = None):
    """Per-query theta seeding -> (theta (B,), n_seed_used i32, survival).

    Unlike :func:`theta_seed_ingraph` — which seeds one SHARED tile set
    from the batch-max bounds — every query here scores its OWN most
    promising tiles (a batched ``top_k`` over its bound row, then a
    per-query code gather + ``take_along_axis`` scoring pass with the same
    ``tree_sum`` accumulation as the oracle).  For mixed batches whose
    queries care about disjoint catalogue regions, the shared seed set
    dilutes across regions and every theta goes loose; per-query seeding
    keeps each theta anchored to its query's own hot tiles.  Certification
    is per query regardless (theta_q = q's k-th best exactly-scored item),
    so the survival rule stays exact.

    Works unchanged for both bound backends — only ``bounds`` (and the
    optional ``degenerate`` wrap-penalty mask, see :func:`seed_order_key`)
    enter the tile choice.  The adaptive policy's growth stages are shared
    ``lax.cond``\\ s gated on the mean per-query survival estimate, so the
    whole thing stays inside the single dispatch.
    """
    n, m = codes.shape
    bq = s.shape[0]
    n_tiles = bounds.shape[1]
    sizes = seed_schedule(seed_policy, seed_tiles, seed_max_tiles, k, tile,
                          n_tiles)
    pad = n_tiles * tile - n
    codes_pad = jnp.pad(codes, ((0, pad), (0, 0))) if pad else codes
    tiles3 = codes_pad.reshape(n_tiles, tile, m)
    order = jax.lax.top_k(seed_order_key(bounds, degenerate),
                          sizes[-1])[1]                 # (B, n_max)
    limit = n if n_items is None else n_items

    def score_chunk(tile_ids):
        """Exact, id-masked per-query scores -> (B, c*tile); tile_ids is
        (B, c) — each row is that query's own tile chunk."""
        sel = tiles3[tile_ids].reshape(bq, -1, m).astype(jnp.int32)
        parts = [jnp.take_along_axis(s[:, kk, :].astype(jnp.float32),
                                     sel[:, :, kk], axis=1)
                 for kk in range(m)]
        sc = tree_sum(parts)                            # (B, c*tile)
        local = (tile_ids[:, :, None] * tile
                 + jnp.arange(tile, dtype=jnp.int32)[None, None, :]
                 ).reshape(bq, -1)
        valid = (id_offset + local < limit) & (local < n)
        if live is not None:
            # Same tombstone exclusion as theta_seed_ingraph: a dead
            # high-scorer must not certify a theta live items can't reach.
            valid = valid & live[local]
        return jnp.where(valid, sc, NEG_INF)

    def merge(vals, sc):
        cand = jnp.concatenate(
            [vals, jax.lax.top_k(sc, min(k, sc.shape[1]))[0]], axis=1)
        return jax.lax.top_k(cand, k)[0]

    def survival_est(theta):
        return survival_mask_perquery(bounds, theta).mean()

    vals = merge(jnp.full((bq, k), NEG_INF),
                 score_chunk(order[:, :sizes[0]]))
    theta = vals[:, -1]
    sf = survival_est(theta)
    n_used = jnp.int32(sizes[0])
    done = jnp.bool_(False)
    for prev, size in zip(sizes, sizes[1:]):
        chunk = order[:, prev:size]

        def grow(carry, chunk=chunk, size=size):
            vals, _theta, sf_prev, n_used, _done = carry
            vals = merge(vals, score_chunk(chunk))
            theta = vals[:, -1]
            sf = survival_est(theta)
            stable = jnp.abs(sf - sf_prev) <= seed_stab_tol
            return vals, theta, sf, jnp.int32(size), stable

        carry = (vals, theta, sf, n_used, done)
        vals, theta, sf, n_used, done = jax.lax.cond(
            done, lambda c: c, grow, carry)
    return theta, n_used, sf


# ---------------------------------------------------------------------------
# query grouping: bucket queries by survivor-set overlap (PR 5)
# ---------------------------------------------------------------------------


def group_queries(pq_mask: jax.Array, n_groups: int) -> jax.Array:
    """Greedy similarity bucketing of per-query survivor sets -> (B,) i32.

    Scans queries in batch order; each query joins the group whose union
    mask grows by the fewest NEW tiles when it joins (ties broken toward
    the smaller group, so disjoint queries spread over empty groups
    instead of piling onto group 0), and the winning group's union absorbs
    the query's mask.  One ``lax.scan`` over B with a (G, T) bool carry —
    pure jnp, so grouping lives inside the single dispatch.  Grouping is a
    *work* heuristic, never a correctness surface: whatever the
    assignment, each group's slot list is the union of its members'
    survivor sets, so every query still sees a superset of its own
    surviving tiles.
    """
    bq, t = pq_mask.shape

    def step(carry, mq):
        gmask, gsize = carry                     # (G, T) bool, (G,) i32
        union = gmask | mq[None, :]
        added = (union & ~gmask).sum(axis=1, dtype=jnp.int32)   # (G,)
        # Composite key: new-tile count first, group size as tie-break
        # (both bounded by T and B, so the packing cannot overflow i32 at
        # any realistic tile count).
        g = jnp.argmin(added * jnp.int32(bq + 1) + gsize).astype(jnp.int32)
        sel = (jnp.arange(n_groups, dtype=jnp.int32) == g)
        gmask = jnp.where(sel[:, None], union, gmask)
        gsize = gsize + sel.astype(jnp.int32)
        return (gmask, gsize), g

    init = (jnp.zeros((n_groups, t), jnp.bool_),
            jnp.zeros((n_groups,), jnp.int32))
    _, assign = jax.lax.scan(step, init, pq_mask)
    return assign


def group_and_compact(pq_mask: jax.Array, *, n_groups: int,
                      batch_tile: int
                      ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-query masks -> a query permutation + per-batch-tile slot table.

    Returns ``(perm (B,), inv (B,), slots2d (n_bt, T) i32, counts (n_bt,)
    i32)``: queries are permuted so group members sit contiguously
    (stable argsort over the group assignment), the permuted batch is
    padded to a multiple of ``batch_tile`` (padding rows have empty
    masks), each kernel batch tile's union mask is compacted with the
    same cumsum scatter as :func:`compact_mask` into an ascending,
    ``-1``-padded slot row, and ``counts`` is each batch tile's survivor
    count.  ``slots2d`` is exactly the 2D ``(group, slot)`` table the
    fused kernel scalar-prefetches; a rung's table is its ``[:, :budget]``
    prefix, so the ladder costs one compaction total.  Apply ``perm`` to
    the query batch before scoring and ``inv`` to the winners after.
    """
    bq, t = pq_mask.shape
    assign = (group_queries(pq_mask, n_groups) if n_groups > 1
              else jnp.zeros((bq,), jnp.int32))
    # Unique sort keys (group-major, arrival-minor) -> deterministic,
    # stable permutation without relying on argsort stability flags.
    perm = jnp.argsort(assign * jnp.int32(bq)
                       + jnp.arange(bq, dtype=jnp.int32))
    inv = jnp.argsort(perm)
    n_bt = -(-bq // batch_tile)
    pad = n_bt * batch_tile - bq
    mask_p = pq_mask[perm]
    if pad:
        mask_p = jnp.pad(mask_p, ((0, pad), (0, 0)))
    bt_mask = mask_p.reshape(n_bt, batch_tile, t).any(axis=1)
    slots2d, counts = jax.vmap(compact_mask)(bt_mask)
    return perm, inv, slots2d, counts


def compact_mask(mask: jax.Array, n_slots: Optional[int] = None,
                 ) -> Tuple[jax.Array, jax.Array]:
    """In-graph cumsum-scatter compaction of a survivor mask.

    mask (T,) bool -> (slots (n_slots,) int32, count i32): surviving tile
    indices in ascending order at the front, ``-1`` sentinels behind.  The
    scatter destination of pruned tiles (and of survivors past the budget,
    when ``n_slots < T``) is off the end of the buffer and dropped
    (``mode="drop"``) — callers with a budget must branch to an exhaustive
    fallback when ``count > n_slots`` to stay exact.  Pure jnp: safe under
    jit / vmap / shard_map; this is the step that replaced the PR 2 host
    ``np.nonzero`` round-trip.
    """
    t = mask.shape[0]
    n_slots = t if n_slots is None else int(n_slots)
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1          # dest of survivor i
    dest = jnp.where(mask, pos, n_slots)                  # pruned -> dropped
    slots = jnp.full((n_slots,), -1, jnp.int32).at[dest].set(
        jnp.arange(t, dtype=jnp.int32), mode="drop")
    return slots, mask.sum(dtype=jnp.int32)


def compact_values(mask: jax.Array, values: jax.Array,
                   n_slots: Optional[int] = None,
                   ) -> Tuple[jax.Array, jax.Array]:
    """:func:`compact_mask`, but scattering caller-provided VALUES instead
    of positions — the hierarchical cascade's stage-2 compaction, where the
    masked axis enumerates (surviving super, child) pairs and the value is
    the child's GLOBAL tile id.  Slot order follows the mask axis; when
    ``values`` ascends over the surviving entries (super slots ascend and
    children ascend within each super) the slot buffer is ascending — the
    kernel/XLA tie-break contract.  ``-1``-padded, ``mode="drop"`` like
    the mask form."""
    t = mask.shape[0]
    n_slots = t if n_slots is None else int(n_slots)
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    dest = jnp.where(mask, pos, n_slots)
    slots = jnp.full((n_slots,), -1, jnp.int32).at[dest].set(
        values.astype(jnp.int32), mode="drop")
    return slots, mask.sum(dtype=jnp.int32)


def default_super_ladder(n_super: int) -> Tuple[int, ...]:
    """Default pass-0 rung budgets (surviving super-tiles the hierarchical
    tail is sized for): powers of two near S/16 and S/4, before the
    exhaustive rung :func:`normalize_ladder` always appends.  Mirrors the
    child ladder's shape — the common low-survival case runs the cheap
    rung, skew escalates cost but never correctness."""
    rungs = []
    for frac in (16, 4):
        x = max(1, n_super // frac)
        rungs.append(1 << (x - 1).bit_length())
    return tuple(dict.fromkeys(rungs))


def pruned_pass1(codes: jax.Array, present: jax.Array, s: jax.Array, k: int,
                 *, tile: int, n_seed: int,
                 n_items: Optional[int] = None,
                 id_offset=0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bounds + theta + survival mask in one jit-friendly call.

    Returns (mask (T,) bool, bounds (B, T), theta (B,)).
    """
    bounds = tile_upper_bounds(present, s)
    theta = theta_from_seed(codes, s, bounds, k, tile=tile, n_seed=n_seed,
                            n_items=n_items, id_offset=id_offset)
    return survival_mask(bounds, theta), bounds, theta


# ---------------------------------------------------------------------------
# slot-budget ladder: normalisation + calibration
# ---------------------------------------------------------------------------


def normalize_ladder(ladder, n_tiles: int, k: int, tile: int
                     ) -> Tuple[int, ...]:
    """Canonical rung sequence for a tile count: strictly-ascending slot
    budgets clamped to ``[ceil(k/tile), n_tiles]``, with the exhaustive
    rung (``n_tiles`` slots) ALWAYS appended last — whatever the caller
    passed, the final rung scores every tile, so the ladder can never cost
    exactness (only escalate work)."""
    floor = min(max(1, -(-k // tile)), n_tiles)
    # Clamp FIRST, then drop anything at/above the tile count — clamping
    # can raise a budget up to floor == n_tiles, which must not produce a
    # duplicate of the exhaustive rung.
    budgets = sorted({max(min(int(x), n_tiles), floor)
                      for x in (ladder or ())})
    return tuple(x for x in budgets if x < n_tiles) + (n_tiles,)


def calibrate_ladder(survival_counts, n_tiles: int, k: int, tile: int, *,
                     headroom: int = 2) -> Tuple[int, ...]:
    """Pick a 2-3 rung power-of-two slot-budget ladder from observed
    survivor counts (a one-shot calibration pass at engine build, or
    recorded serving stats).

    Candidate rungs come from three anchors of the observed distribution —
    ``headroom``x the *median* (the common case every batch pays for; the
    median, not a high quantile, so a bimodal tail cannot inflate it), the
    95th percentile (the bulk of the tail), and ``headroom``x the 95th
    (tail cushion) — each rounded up to a power of two, deduplicated, and
    clamped by :func:`normalize_ladder`, which drops rungs at or above the
    tile count and ALWAYS appends the exhaustive final rung.  Adversarial
    survival distributions (all-survive, none-survive, bimodal) therefore
    degrade to the exhaustive cost, never to a wrong answer; a backend
    with loose bounds (high survival) still gets a sub-exhaustive rung
    when one fits.  Powers of two keep serving ladders out of
    jit-recompile space.
    """
    import numpy as np

    counts = np.asarray(list(survival_counts), dtype=np.int64).reshape(-1)
    if counts.size == 0:
        counts = np.asarray([n_tiles])
    floor = min(max(1, -(-k // tile)), n_tiles)
    headroom = max(int(headroom), 2)

    def pow2_at_least(x):
        return 1 << (max(int(np.ceil(x)), 1) - 1).bit_length()

    q50, q95 = np.quantile(counts, 0.5), np.quantile(counts, 0.95)
    rungs = (pow2_at_least(max(headroom * q50, floor)),
             pow2_at_least(max(q95, floor)),
             pow2_at_least(max(headroom * q95, floor)))
    return normalize_ladder(rungs, n_tiles, k, tile)


# ---------------------------------------------------------------------------
# the single-dispatch in-graph cascade (PR 3 serving path)
# ---------------------------------------------------------------------------


def cascade_topk_ingraph(codes: jax.Array, s: jax.Array, k: int,
                         state: Optional[PrunedHeadState] = None, *,
                         tile: int = DEFAULT_PRUNE_TILE,
                         seed_policy: str = "greedy",
                         seed_tiles: int = DEFAULT_SEED_TILES,
                         seed_max_tiles: int = DEFAULT_SEED_MAX_TILES,
                         seed_stab_tol: float = DEFAULT_SEED_STAB_TOL,
                         slot_budget: Optional[int] = None,
                         ladder=None,
                         super_ladder=None,
                         pin_rung: bool = False,
                         query_grouping: bool = False,
                         n_groups: int = DEFAULT_N_GROUPS,
                         live: Optional[jax.Array] = None,
                         use_kernel: Optional[bool] = None,
                         interpret: Optional[bool] = None,
                         return_stats: bool = False):
    """Exact pruned top-k as ONE traced computation (no host sync).

    bounds (backend-dispatched: bitmask or min/max code range) -> theta ->
    survival mask -> cumsum-scatter compaction into ``-1``-padded slot
    buffers -> fused scoring over the listed tiles.  On TPU the fused
    kernel's grid stays static at the rung's slot count and sentinel slots
    take an ``@pl.when`` early-exit (~no DMA or compute); off TPU the XLA
    lowering gathers the rung's tiles.

    ``ladder`` is a sequence of slot budgets (``slot_budget=b`` is
    shorthand for ``ladder=(b,)``): the trace carries one nested
    ``lax.cond`` branch per rung, the smallest rung whose budget holds the
    survivor count executes, and the final rung — always appended by
    :func:`normalize_ladder` — scores the full-length compacted buffer, so
    overflow at any skew escalates cost, never correctness.

    ``query_grouping=True`` (with ``n_groups > 1``) switches survival to
    the per-query route: per-query thetas from each query's own seed tiles
    (:func:`theta_seed_perquery`), per-query survival bitmasks, greedy
    overlap bucketing into ``n_groups`` groups, and a 2D ``(group, slot)``
    compacted table so each kernel batch tile scores only its group's
    survivors — ``sum_g B_g * S_g`` work instead of ``B * |union|``.  Rung
    escalation compares each rung's budget against the MAX per-group
    survivor count (one shared ladder, sentinel slots make light groups
    free).  ``n_groups=1`` recovers the batch-any route exactly.

    ``live`` (n,) bool is the mutable-catalogue tombstone mask: dead rows
    (delisted items, capacity padding) are excluded from theta seeding and
    masked to ``-inf`` inside the scoring pass, and their winner ids are
    remapped to the sentinel id ``n`` — so a tombstoned item can never
    surface in the top-k, while stale (loosened) tile bounds still
    dominate every LIVE item's score and the result stays bit-identical
    to a cascade over a freshly rebuilt live-only head
    (docs/PRUNING.md §Catalogue mutation).  ``live`` is a traced *data*
    array, so flipping tombstones never recompiles.

    Pure function of (codes, s, state): jittable, vmappable, decode-loop
    and shard_map safe.  Bit-identical to ``score_pqtopk + tiled_topk``
    (values AND ids, ties included).  With ``return_stats`` the traced
    stats dict follows the canonical :data:`STATS_KEYS` schema (convert on
    host after the call).
    """
    from repro.kernels.pqtopk import ops as kernel_ops

    if state is None:
        state = build_pruned_state(codes, int(s.shape[-1]), tile)
    if state.shards != 1:
        # A shard-aligned state tiles the catalogue per shard (tile
        # boundaries reset at each shard), so interpreting its packed rows
        # as a flat global layout would produce bounds that do not dominate
        # the flat tiles' scores — silently breaking exactness.  The flat
        # route must rebuild (or be handed) a shards=1 state.
        raise ValueError(
            f"cascade_topk_ingraph needs a shards=1 state, got "
            f"shards={state.shards}; use top_items_pruned_sharded for the "
            f"sharded layout")
    tile = state.tile
    bq = s.shape[0]
    if live is not None and live.shape[0] != codes.shape[0]:
        raise ValueError(f"live mask covers {live.shape[0]} rows but the "
                         f"catalogue has {codes.shape[0]}")
    t_total = state.n_tiles
    if ladder is None and slot_budget is not None:
        ladder = (int(slot_budget),)
    # pin_rung (both here and in the hierarchical tail below):
    # load-adaptive degradation (serving/router.py) — pin the cascade to
    # its CHEAPEST calibrated rung and drop the escalation chain.  Bounded
    # cost per batch, but survivors past the rung's budget are silently
    # truncated (ascending tile order), so the result may miss true
    # winners.  This is the ONLY cascade mode that can cost exactness;
    # callers must tag every result served through it (Result.degraded),
    # and with no sub-exhaustive rung in the ladder the pin degenerates to
    # the exact exhaustive route.
    seed_kw = dict(seed_policy=seed_policy, seed_tiles=seed_tiles,
                   seed_max_tiles=seed_max_tiles,
                   seed_stab_tol=seed_stab_tol, live=live)
    grouped = query_grouping and n_groups > 1
    if grouped and state.has_super:
        # Per-query grouped survival has no super-tile pass-0 (per-query
        # super masks would need a per-query two-stage compaction);
        # PQConfig.__post_init__ forbids the combination at config time —
        # this guard catches hand-built states.
        raise ValueError(
            "query_grouping and hierarchical super-tiles are mutually "
            "exclusive; strip the super level (with_super(state, 0)) or "
            "disable grouping")
    if state.has_super:
        # Hierarchical cascade: pass 0 prunes SUPER-tiles against theta,
        # and only the surviving supers' children ever get a tile bound
        # gathered — O(S + survivors*factor) bound work instead of O(T).
        # Exactness: ub_super >= ub_child >= every child item's score, so
        # any tile surviving the flat rule (ub_t >= theta) has a surviving
        # super — the surviving-child set equals the flat survival set at
        # the same theta, and the scored top-k is bit-identical.
        factor = state.super_factor
        n_super = state.n_super
        sup_parts = state.super_meta_arrays()
        sup_bounds = bounds_from_parts(state.backend, sup_parts, s)
        theta, n_seed_used, seed_sf = theta_seed_ingraph(
            codes, s, sup_bounds, k, tile=factor * tile,
            degenerate=degenerate_from_parts(state.backend, sup_parts,
                                             state.b),
            **seed_kw)
        sup_mask = survival_mask(sup_bounds, theta)
        sup_slots, sup_count = compact_mask(sup_mask)
        sup_rungs = normalize_ladder(
            default_super_ladder(n_super) if super_ladder is None
            else super_ladder, n_super, k, factor * tile)
        if pin_rung:
            sup_rungs = sup_rungs[:1]
        child_parts = state.meta_arrays()

        def hier_tail(r_sup, i_sup):
            """Whole post-pass-0 tail for a super rung of ``r_sup`` slots.
            The super-rung ``lax.cond`` branches must agree on every
            output shape, so the child-bound gather, the stage-2
            compaction, the child ladder, AND the per-branch stats all
            live inside the branch."""
            sup_ids = sup_slots[:r_sup]
            gid = (sup_ids[:, None] * factor
                   + jnp.arange(factor, dtype=jnp.int32)[None, :]
                   ).reshape(-1)                     # (r_sup * factor,)
            # -1 sentinel supers map to negative gids; the last real super
            # may own alignment-padding children past T.  Both are barred
            # from the slot buffer whatever their (clamped-gather) bound
            # values come out as.
            valid = (gid >= 0) & (gid < t_total)
            safe = jnp.clip(gid, 0, t_total - 1)
            parts_sel = tuple(p[safe] for p in child_parts)
            cb = bounds_from_parts(state.backend, parts_sel, s)
            cmask = survival_mask(cb, theta) & valid
            # Stage-2 compaction scatters GLOBAL tile ids (the mask axis
            # enumerates (super slot, child) pairs): super slots ascend
            # and children ascend within each super, so the slot buffer
            # stays ascending — the tie-break contract the kernel and the
            # XLA gather both rely on.
            child_slots, child_count = compact_values(cmask, gid)
            crungs = normalize_ladder(ladder, r_sup * factor, k, tile)
            if pin_rung:
                crungs = crungs[:1]
            slot_lists = [child_slots[:r] for r in crungs]
            vals, ids, crung = kernel_ops.pq_topk_tiles_ladder(
                codes, s, k, slot_lists, child_count, tile=tile,
                live=live, use_kernel=use_kernel, interpret=interpret)
            overflow = (child_count > crungs[-2] if len(crungs) > 1
                        else jnp.bool_(False))
            return (vals, ids, child_count,
                    jnp.asarray(crungs, jnp.int32)[crung], crung,
                    jnp.int32(len(crungs)), jnp.asarray(overflow),
                    jnp.int32(n_super + r_sup * factor), jnp.int32(i_sup))

        def sup_rung_fn(i):
            def run():
                return hier_tail(sup_rungs[i], i)
            if i == len(sup_rungs) - 1:
                return run
            nxt = sup_rung_fn(i + 1)
            return lambda: jax.lax.cond(sup_count <= sup_rungs[i], run, nxt)

        (vals, ids, count, n_scored, rung, n_rungs_stat, overflow,
         bounds_computed, sup_rung) = sup_rung_fn(0)()
        bt = kernel_ops.effective_batch_tile(bq)
        max_group = count
        pairs_scored = pairs_union = count * jnp.int32(-(-bq // bt) * bt)
        n_groups_eff = 1
        n_super_stat, sup_survived = n_super, sup_count
    elif grouped:
        rungs = normalize_ladder(ladder, t_total, k, tile)
        if pin_rung:
            rungs = rungs[:1]
        bounds = tile_bounds(state, s)
        bt = kernel_ops.group_batch_tile(bq, n_groups)
        theta, n_seed_used, seed_sf = theta_seed_perquery(
            codes, s, bounds, k, tile=tile,
            degenerate=degenerate_tile_mask(state), **seed_kw)
        pq_mask = survival_mask_perquery(bounds, theta)
        perm, inv, slots2d, counts = group_and_compact(
            pq_mask, n_groups=n_groups, batch_tile=bt)
        slot_lists = [slots2d[:, :r] for r in rungs]
        vals, ids, rung = kernel_ops.pq_topk_tiles_ladder(
            codes, jnp.take(s, perm, axis=0), k, slot_lists, counts,
            tile=tile, batch_tile=bt, live=live, use_kernel=use_kernel,
            interpret=interpret)
        vals = jnp.take(vals, inv, axis=0)
        ids = jnp.take(ids, inv, axis=0)
        count = pq_mask.any(axis=0).sum(dtype=jnp.int32)   # union survivors
        max_group = counts.max()
        n_bt = counts.shape[0]
        pairs_scored = (counts * jnp.int32(bt)).sum()
        pairs_union = count * jnp.int32(n_bt * bt)
        # The stat reports the number of kernel group rows actually built
        # — the 8-row sublane floor can collapse a small batch into fewer
        # groups than requested (bq=8 at n_groups=8 is ONE union row).
        n_groups_eff = n_bt
        n_scored = jnp.asarray(rungs, jnp.int32)[rung]
        n_rungs_stat = len(rungs)
        overflow = (max_group > rungs[-2] if len(rungs) > 1
                    else jnp.bool_(False))
        bounds_computed = t_total
        n_super_stat, sup_survived, sup_rung = 0, 0, 0
    else:
        rungs = normalize_ladder(ladder, t_total, k, tile)
        if pin_rung:
            rungs = rungs[:1]
        bounds = tile_bounds(state, s)
        theta, n_seed_used, seed_sf = theta_seed_ingraph(
            codes, s, bounds, k, tile=tile,
            degenerate=degenerate_tile_mask(state), **seed_kw)
        mask = survival_mask(bounds, theta)
        # One cumsum-scatter compaction; each rung's buffer is exactly the
        # full buffer's length-r prefix (survivors land at ascending
        # positions, -1 sentinels behind), so the smaller rungs are free.
        slots_full, count = compact_mask(mask)
        slot_lists = [slots_full[:r] for r in rungs]
        vals, ids, rung = kernel_ops.pq_topk_tiles_ladder(
            codes, s, k, slot_lists, count, tile=tile, live=live,
            use_kernel=use_kernel, interpret=interpret)
        bt = kernel_ops.effective_batch_tile(bq)
        max_group = count
        pairs_scored = pairs_union = count * jnp.int32(-(-bq // bt) * bt)
        n_groups_eff = 1
        n_scored = jnp.asarray(rungs, jnp.int32)[rung]
        n_rungs_stat = len(rungs)
        overflow = (max_group > rungs[-2] if len(rungs) > 1
                    else jnp.bool_(False))
        bounds_computed = t_total
        n_super_stat, sup_survived, sup_rung = 0, 0, 0
    if not return_stats:
        return vals, ids
    stats = {"n_tiles": t_total, "n_survived": count,
             "n_scored": n_scored,
             "survival_fraction": count / jnp.float32(max(t_total, 1)),
             "n_seed_used": n_seed_used, "seed_survival_est": seed_sf,
             "rung_hit": rung, "n_rungs": n_rungs_stat,
             "slot_overflow": overflow,
             "bound_backend": state.backend,
             "n_groups": n_groups_eff, "max_group_survived": max_group,
             "pairs_scored": pairs_scored, "pairs_union": pairs_union,
             "n_super": n_super_stat, "n_super_survived": sup_survived,
             "super_rung_hit": sup_rung, "bounds_computed": bounds_computed}
    return vals, ids, stats


# ---------------------------------------------------------------------------
# the host two-pass cascade (PR 2 reference implementation)
# ---------------------------------------------------------------------------

_pass1_jit = jax.jit(pruned_pass1, static_argnames=("k", "tile", "n_seed"))


def slot_bucket(n_survived: int, k: int, tile: int) -> int:
    """Pad the survivor list to a power-of-two slot count so pass-2 jit
    recompiles stay bounded; always at least enough slots to hold k."""
    need = max(1, n_survived, -(-k // tile))
    return 1 << (need - 1).bit_length()


def cascade_topk(codes: jax.Array, s: jax.Array, k: int, *, tile: int,
                 seed_tiles: int = 2, meta: Optional[TileMeta] = None,
                 use_kernel: Optional[bool] = None,
                 interpret: Optional[bool] = None,
                 return_stats: bool = False):
    """Exact top-k via the PR 2 host-orchestrated two-pass cascade.

    Pass 1 (jitted): bounds -> theta -> survival mask.  Host sync: compact
    the surviving tile indices (power-of-two slot bucket, sentinel-padded).
    Pass 2 (jitted per bucket size): fused scoring + top-k over surviving
    tiles only.  Bit-identical to ``score_pqtopk`` + ``tiled_topk``; NOT
    jit-compatible (the compaction is a device->host sync) — the serving
    path uses :func:`cascade_topk_ingraph`, which fuses both passes into a
    single dispatch.  Kept as the reference implementation the in-graph
    route is parity-tested against.
    """
    import numpy as np

    from repro.kernels.pqtopk import ops as kernel_ops

    n = codes.shape[0]
    tile = min(tile, n)
    if meta is None:
        meta = get_tile_metadata(codes, int(s.shape[-1]), tile)
    mask, _, _ = _pass1_jit(codes, meta.present, s, k, tile=tile,
                            n_seed=seed_tiles)
    survivors = np.nonzero(np.asarray(mask))[0]
    n_slots = slot_bucket(len(survivors), k, tile)
    tile_idx = np.full(n_slots, kernel_ops.sentinel_tile(n, tile), np.int32)
    tile_idx[:len(survivors)] = survivors
    vals, ids = kernel_ops.pq_topk_tiles(
        codes, s, k, jnp.asarray(tile_idx), tile=tile,
        use_kernel=use_kernel, interpret=interpret)
    if not return_stats:
        return vals, ids
    # Canonical STATS_KEYS schema (shared with the in-graph and sharded
    # routes): the host route has no ladder (its slot bucket is sized to
    # the survivor count, so the single rung always fits) and its greedy
    # seed pass uses a fixed tile count.
    sf = len(survivors) / max(meta.n_tiles, 1)
    n_seed = min(max(seed_tiles, -(-k // tile)), meta.n_tiles)
    stats = {"n_tiles": meta.n_tiles, "n_survived": int(len(survivors)),
             "n_scored": int(n_slots), "survival_fraction": sf,
             "n_seed_used": n_seed, "seed_survival_est": sf,
             "rung_hit": 0, "n_rungs": 1, "slot_overflow": False,
             "bound_backend": "bitmask",
             "n_groups": 1, "max_group_survived": int(len(survivors)),
             "pairs_scored": int(len(survivors)) * int(s.shape[0]),
             "pairs_union": int(len(survivors)) * int(s.shape[0]),
             "n_super": 0, "n_super_survived": 0, "super_rung_hit": 0,
             "bounds_computed": meta.n_tiles}
    return vals, ids, stats


# ---------------------------------------------------------------------------
# calibration observation helper (engine build time)
# ---------------------------------------------------------------------------


def survival_count(codes: jax.Array, s: jax.Array, k: int,
                   state: PrunedHeadState, *,
                   seed_policy: str = "greedy",
                   seed_tiles: int = DEFAULT_SEED_TILES,
                   seed_max_tiles: int = DEFAULT_SEED_MAX_TILES,
                   seed_stab_tol: float = DEFAULT_SEED_STAB_TOL,
                   live: Optional[jax.Array] = None) -> jax.Array:
    """Surviving-tile count for one query batch (i32 scalar) — the cheap
    bounds+theta prefix of the cascade, no scoring pass.  What the engine's
    one-shot calibration runs to collect the survival stats that
    :func:`calibrate_ladder` turns into a slot-budget ladder.

    Hierarchical states seed theta from the SUPER-tile bounds — the same
    seed set (hence the same theta, hence the same survivor distribution)
    the hierarchical serve path produces; seeding from child bounds here
    would calibrate the ladder against thetas the serve path never uses.
    The count is still the surviving CHILD-tile count (children of pruned
    supers provably cannot survive, so it matches the serve path's
    stage-2 survivor count exactly)."""
    if state.has_super:
        sup_parts = state.super_meta_arrays()
        sup_bounds = bounds_from_parts(state.backend, sup_parts, s)
        theta, _, _ = theta_seed_ingraph(
            codes, s, sup_bounds, k, tile=state.tile * state.super_factor,
            seed_policy=seed_policy, seed_tiles=seed_tiles,
            seed_max_tiles=seed_max_tiles, seed_stab_tol=seed_stab_tol,
            degenerate=degenerate_from_parts(state.backend, sup_parts,
                                             state.b),
            live=live)
        bounds = tile_bounds(state, s)
        return survival_mask(bounds, theta).sum(dtype=jnp.int32)
    bounds = tile_bounds(state, s)
    theta, _, _ = theta_seed_ingraph(
        codes, s, bounds, k, tile=state.tile, seed_policy=seed_policy,
        seed_tiles=seed_tiles, seed_max_tiles=seed_max_tiles,
        seed_stab_tol=seed_stab_tol,
        degenerate=degenerate_tile_mask(state), live=live)
    return survival_mask(bounds, theta).sum(dtype=jnp.int32)


def survival_count_grouped(codes: jax.Array, s: jax.Array, k: int,
                           state: PrunedHeadState, *, n_groups: int,
                           batch_tile: Optional[int] = None,
                           seed_policy: str = "greedy",
                           seed_tiles: int = DEFAULT_SEED_TILES,
                           seed_max_tiles: int = DEFAULT_SEED_MAX_TILES,
                           seed_stab_tol: float = DEFAULT_SEED_STAB_TOL,
                           live: Optional[jax.Array] = None,
                           ) -> jax.Array:
    """MAX per-group surviving-tile count for one query batch (i32) — the
    group-aware calibration observable: the grouped ladder escalates on
    the max per-group count, so its rungs must be sized against THAT
    distribution, not the (larger) batch-any union count — calibrating on
    union counts would hand the grouped route needlessly tall rungs and
    forfeit most of the per-group win."""
    from repro.kernels.pqtopk import ops as kernel_ops

    if batch_tile is None:
        batch_tile = kernel_ops.group_batch_tile(s.shape[0], n_groups)
    bounds = tile_bounds(state, s)
    theta, _, _ = theta_seed_perquery(
        codes, s, bounds, k, tile=state.tile, seed_policy=seed_policy,
        seed_tiles=seed_tiles, seed_max_tiles=seed_max_tiles,
        seed_stab_tol=seed_stab_tol,
        degenerate=degenerate_tile_mask(state), live=live)
    pq_mask = survival_mask_perquery(bounds, theta)
    _, _, _, counts = group_and_compact(pq_mask, n_groups=n_groups,
                                        batch_tile=batch_tile)
    return counts.max()
