"""Scoring algorithms — the heart of the paper.

Given a batch of sequence embeddings ``phi (B, d)`` and an item space
described either densely (``W (N, d)``) or by PQ codes (``codes (N, m)`` +
sub-embeddings ``Psi (m, b, d/m)``), compute all item scores ``r (B, N)``.

* ``score_dense``          — Transformer-Default baseline: r = phi @ W.T.
* ``subid_scores``         — S matrix (Eq. 4): S[q,k,j] = psi_{k,j} . phi_{q,k}.
* ``score_recjpq``         — Algorithm 2 (RecJPQ original): *sequential*
                             fori_loop over splits carrying a (B, N)
                             accumulator — faithfully reproduces the
                             non-parallelisable structure of the TF original.
* ``score_pqtopk``         — Algorithm 1 (PQTopK): one vectorised
                             gather-and-sum, parallel over items.
* ``score_pqtopk_onehot``  — TPU-native restatement: per-split one-hot
                             matmul on the MXU (DESIGN.md §3); identical
                             output, different roofline.

All functions take S pre-computed where applicable so benchmarks can isolate
"scoring" exactly like the paper does.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def tree_sum(parts):
    """Balanced-tree reduction of a list of arrays.

    This is THE accumulation order for per-split partial scores: Algorithm 1
    (:func:`score_pqtopk`), the jnp kernel oracle (``kernels/pqtopk/ref.py``)
    and the Pallas tile kernel (``kernels/pqtopk/kernel.py``) all reduce
    through this function, so their f32 rounding is bit-identical — parity
    tests compare them at atol=0.  Also avoids materialising a (B, m, N)
    stack and keeps the adds parallelisable (no loop-carried accumulator).
    """
    parts = list(parts)
    while len(parts) > 1:
        nxt = [parts[i] + parts[i + 1] for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0]


def score_dense(w: jax.Array, phi: jax.Array) -> jax.Array:
    """Default matmul scoring r = W phi. w: (N, d), phi: (B, d) -> (B, N)."""
    return jnp.einsum("bd,nd->bn", phi, w, preferred_element_type=jnp.float32)


def subid_scores(sub_emb: jax.Array, phi: jax.Array) -> jax.Array:
    """Eq. 4. sub_emb: (m, b, d/m), phi: (B, d) -> S: (B, m, b).

    Cost O(B * b * d): independent of the catalogue size N.
    """
    B, d = phi.shape
    m, b, sub = sub_emb.shape
    assert d == m * sub, f"phi dim {d} != m*sub {m * sub}"
    phi_split = phi.reshape(B, m, sub)
    return jnp.einsum("bms,mjs->bmj", phi_split, sub_emb,
                      preferred_element_type=jnp.float32)


def score_pqtopk(codes: jax.Array, s: jax.Array) -> jax.Array:
    """Algorithm 1 (PQTopK): r_i = sum_k S[k, G[i,k]], parallel over items.

    codes: (N, m) int, s: (B, m, b) -> (B, N) f32.

    The m per-split gathers are *independent* (no loop-carried accumulator —
    the paper's point vs Alg. 2) and are reduced as a balanced tree, so no
    (B, m, N) intermediate is materialised.
    """
    m = codes.shape[1]
    parts = [jnp.take(s[:, k, :].astype(jnp.float32),
                      codes[:, k].astype(jnp.int32), axis=1)
             for k in range(m)]                        # m x (B, N)
    return tree_sum(parts)


def score_recjpq(codes: jax.Array, s: jax.Array) -> jax.Array:
    """Algorithm 2 (RecJPQ original): sequential accumulation over splits.

    The outer loop over k is a ``lax.fori_loop`` carrying the full (B, N)
    accumulator — the loop-carried dependency prevents parallelisation over
    splits *and* forces N-sized accumulator traffic per split, exactly the
    structure the paper identifies as the bottleneck.
    """
    n, m = codes.shape
    bq = s.shape[0]

    def body(k, acc):
        # Gather split k's codes for every item, then that split's scores.
        ck = jax.lax.dynamic_slice_in_dim(codes, k, 1, axis=1)[:, 0]  # (N,)
        sk = jax.lax.dynamic_slice_in_dim(s, k, 1, axis=1)[:, 0]      # (B, b)
        return acc + jnp.take(sk, ck.astype(jnp.int32), axis=1)

    return jax.lax.fori_loop(0, m, body, jnp.zeros((bq, n), jnp.float32))


def score_pqtopk_onehot(codes: jax.Array, s: jax.Array) -> jax.Array:
    """MXU restatement of Algorithm 1: scores = sum_k onehot(G_k) @ S_k^T.

    One-hots are built on the fly via iota comparison (never stored in HBM).
    This trades 2*m*b FLOPs/item/query for m bytes/item of HBM traffic —
    the TPU-native adaptation (DESIGN.md §3); output identical to
    ``score_pqtopk``.
    """
    n, m = codes.shape
    b = s.shape[-1]
    iota = jax.lax.broadcasted_iota(codes.dtype, (1, b), 1)  # (1, b)
    acc = None
    for k in range(m):
        onehot = (codes[:, k:k + 1] == iota).astype(s.dtype)  # (N, b)
        part = jnp.einsum("nb,qb->qn", onehot, s[:, k, :],
                          preferred_element_type=jnp.float32)
        acc = part if acc is None else acc + part
    return acc


def score_items_pqtopk(codes: jax.Array, s: jax.Array,
                       item_ids: jax.Array) -> jax.Array:
    """PQTopK over a candidate subset V ⊆ I (Algorithm 1's optional V)."""
    return score_pqtopk(codes[item_ids], s)


SCORERS = {
    "dense": None,  # needs W, dispatched in retrieval_head
    "recjpq": score_recjpq,
    "pqtopk": score_pqtopk,
    "pqtopk_onehot": score_pqtopk_onehot,
}
