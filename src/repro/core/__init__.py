"""Core: the paper's contribution — PQ sub-id retrieval + PQTopK scoring."""
from repro.core import codebook, pq, retrieval_head, scoring, topk

__all__ = ["codebook", "pq", "retrieval_head", "scoring", "topk"]
