"""PQ (RecJPQ-style) embedding: item embedding = concat of m sub-embeddings.

Parameters of a ``PQEmbedding``:
  codes:   (n_items, m) integer codebook G (Eq. 1) — non-trainable.
  sub_emb: (m, b, d/m)  sub-id embedding tables Psi (one per split).

Reconstruction (Eq. 2):  w_i = psi_{1,g_i1} || ... || psi_{m,g_im}.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PQConfig

Params = Dict[str, Any]


def init_pq_embedding(key: jax.Array, pq: PQConfig, n_items: int, d_model: int,
                      codes: Optional[np.ndarray] = None,
                      centroids: Optional[np.ndarray] = None,
                      dtype: Any = jnp.float32) -> Params:
    if d_model % pq.m:
        raise ValueError(f"d_model={d_model} not divisible by m={pq.m}")
    sub = d_model // pq.m
    if codes is None:
        codes = jax.random.randint(key, (n_items, pq.m), 0, pq.b)
    codes = jnp.asarray(codes, jnp.dtype(pq.code_dtype))
    if centroids is None:
        sub_emb = jax.random.normal(key, (pq.m, pq.b, sub), jnp.float32) * 0.02
    else:
        sub_emb = jnp.asarray(centroids, jnp.float32)
        if sub_emb.shape != (pq.m, pq.b, sub):
            raise ValueError(f"centroid shape {sub_emb.shape} != {(pq.m, pq.b, sub)}")
    return {"codes": codes, "sub_emb": sub_emb.astype(dtype)}


def abstract_pq_embedding(pq: PQConfig, n_items: int, d_model: int,
                          dtype: Any = jnp.float32) -> Params:
    """ShapeDtypeStruct stand-in (dry-run: no allocation)."""
    sub = d_model // pq.m
    return {
        "codes": jax.ShapeDtypeStruct((n_items, pq.m), jnp.dtype(pq.code_dtype)),
        "sub_emb": jax.ShapeDtypeStruct((pq.m, pq.b, sub), dtype),
    }


def reconstruct(params: Params, ids: jax.Array) -> jax.Array:
    """Eq. 2: gather sub-embeddings for ``ids`` and concat. (..., d_model)."""
    codes = params["codes"][ids]                       # (..., m)
    sub_emb = params["sub_emb"]                        # (m, b, d/m)
    m = sub_emb.shape[0]
    parts = [jnp.take(sub_emb[k], codes[..., k], axis=0) for k in range(m)]
    return jnp.concatenate(parts, axis=-1)


def reconstruct_all(params: Params) -> jax.Array:
    """Materialise the full (n_items, d) table — tests/small catalogues only."""
    n_items = params["codes"].shape[0]
    return reconstruct(params, jnp.arange(n_items))


def pq_vmem_bytes(pq: PQConfig, d_model: int) -> int:
    """Bytes of the Psi tables + one S matrix — the working set that replaces
    the (n_items × d) embedding matrix."""
    sub = d_model // pq.m
    return pq.m * pq.b * sub * 4 + pq.m * pq.b * 4


def code_nbytes(pq: PQConfig) -> int:
    """Bytes per sub-id in storage (1 for int8/uint8 when b <= 256) — the
    per-split HBM traffic of every code read in the retrieval head."""
    return jnp.dtype(pq.code_dtype).itemsize


def compression_ratio(pq: PQConfig, n_items: int, d_model: int,
                      dense_bytes: int = 4,
                      code_bytes: Optional[int] = None) -> float:
    cb = code_nbytes(pq) if code_bytes is None else code_bytes
    dense = n_items * d_model * dense_bytes
    compressed = n_items * pq.m * cb + pq.m * pq.b * (d_model // pq.m) * dense_bytes
    return dense / compressed
