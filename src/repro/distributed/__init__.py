from repro.distributed import sharding
from repro.distributed.sharding import (ShardingPlan, activation_plan,
                                        constrain, param_shardings)

__all__ = ["sharding", "ShardingPlan", "activation_plan", "constrain",
           "param_shardings"]
