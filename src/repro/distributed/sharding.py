"""Sharding rules: parameter specs by tree-path pattern + an activation
sharding plan (contextvar) the model code consults via ``constrain``.

Conventions (DESIGN.md §5):
  mesh axes    ``(pod, data, model)`` multi-pod / ``(data, model)`` single-pod
  batch        ("pod", "data")  — flattened onto the leading batch dim
  seq (SP)     "model"          — long sequences / KV caches
  vocab / items / experts / table-rows  "model"
  FSDP param dim                "data"
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

# ---------------------------------------------------------------------------
# Activation plan: name -> PartitionSpec, plus the active mesh.
# ---------------------------------------------------------------------------

_PLAN: contextvars.ContextVar[Optional["ShardingPlan"]] = \
    contextvars.ContextVar("activation_plan", default=None)


class ShardingPlan:
    """Named activation specs bound to a mesh."""

    def __init__(self, mesh: Mesh, specs: Dict[str, P]):
        self.mesh = mesh
        self.specs = dict(specs)

    def sharding(self, name: str) -> Optional[NamedSharding]:
        spec = self.specs.get(name)
        if spec is None:
            return None
        return NamedSharding(self.mesh, spec)


@contextlib.contextmanager
def activation_plan(plan: Optional[ShardingPlan]):
    tok = _PLAN.set(plan)
    try:
        yield plan
    finally:
        _PLAN.reset(tok)


def strip_axis(plan: "ShardingPlan", axis: str) -> "ShardingPlan":
    """Plan view with ``axis`` removed from every spec — used inside
    shard_map regions that are Manual over that axis (e.g. PowerSGD's
    manual-pod gradient exchange)."""
    def fix(spec: P) -> P:
        out = []
        for entry in spec:
            if entry == axis:
                out.append(None)
            elif isinstance(entry, tuple):
                kept = tuple(a for a in entry if a != axis)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                out.append(entry)
        return P(*out)
    return ShardingPlan(plan.mesh, {k: fix(v) for k, v in plan.specs.items()})


def constrain(x: jax.Array, name: str) -> jax.Array:
    """Apply the named activation constraint if a plan is active; no-op
    otherwise (single-device tests/smoke runs)."""
    plan = _PLAN.get()
    if plan is None:
        return x
    sh = plan.sharding(name)
    if sh is None or len(sh.spec) > x.ndim:
        return x
    return jax.lax.with_sharding_constraint(x, sh)


def current_plan() -> Optional[ShardingPlan]:
    return _PLAN.get()


def manual_axis_map(fn, mesh: Mesh, in_specs, out_specs, *,
                    axis_names: Optional[set] = None):
    """The repo's standard manual-collective region: ``shard_map`` with
    replication checking off (our regions end in all-gathers whose outputs
    are replicated by construction, which the checker cannot prove).

    Goes through :mod:`repro.compat` so every shard_mapped path — item-
    sharded retrieval, PowerSGD gradient exchange — picks up the right
    ``shard_map``/keyword spelling for the installed JAX.
    """
    return compat.shard_map(fn, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False,
                            axis_names=axis_names)


# ---------------------------------------------------------------------------
# Standard activation plans
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def lm_activation_plan(mesh: Mesh, *, shard_seq: bool = True,
                       tp_internal: bool = False,
                       vocab_tp: bool = False) -> ShardingPlan:
    """``tp_internal`` = Megatron-style sequence-parallel TP: the residual
    stream stays seq-sharded over 'model', but inside each layer the d_ff
    intermediate and the query heads are model-sharded, so per-layer
    collectives are d_model-sized AG/RS at the layer boundary instead of
    d_ff-sized gathers (the §Perf nemotron iteration)."""
    b = batch_axes(mesh)
    seq = "model" if shard_seq else None
    # Logits: when the sequence is model-sharded keep it sharded through the
    # head (vocab unsharded per device) — avoids all-gathering hidden; when
    # seq is unsharded, shard the vocab dim instead (classic TP head).
    logits = P(b, seq, None) if (shard_seq and not vocab_tp) \
        else P(b, None, "model")
    extra = {}
    if tp_internal:
        extra = {
            "mlp_hidden": P(b, None, "model"),
            "attn_q_heads": P(b, None, "model", None),
        }
    return ShardingPlan(mesh, {
        "tokens": P(b, None),
        "hidden": P(b, seq, None),
        "logits": logits,
        **extra,
        "phi": P(b, None),                    # (B, d) decode hidden
        "kv_cache": P(b, "model", None, None),
        "kv_cache_batch1": P(None, ("data", "model"), None, None),
        "moe_group": P(b, seq, None, None),
        "scores": P(b, "model"),              # (B, N) item scores
    })


def recsys_activation_plan(mesh: Mesh) -> ShardingPlan:
    b = batch_axes(mesh)
    return ShardingPlan(mesh, {
        "batch": P(b),
        "dense_feats": P(b, None),
        "sparse_ids": P(b, None),
        "hidden": P(b, None),
        "seq_hidden": P(b, None, None),
        "scores": P(b, "model"),
    })


def gnn_activation_plan(mesh: Mesh) -> ShardingPlan:
    all_axes = tuple(mesh.axis_names)
    return ShardingPlan(mesh, {
        "edges": P(all_axes),                 # edge lists over all devices
        "edge_feats": P(all_axes, None),
        "node_feats": P(None, None),          # replicated (DESIGN.md §5)
        "batch_nodes": P(batch_axes(mesh)),
    })


# ---------------------------------------------------------------------------
# Parameter sharding rules (path-pattern -> PartitionSpec)
# ---------------------------------------------------------------------------

def _match(rules, path: str, ndim: int) -> P:
    for pat, spec in rules:
        if re.search(pat, path):
            if len(spec) > ndim:
                raise ValueError(f"spec {spec} too long for {path} ndim={ndim}")
            return spec
    return P()


def path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):         # GetAttrKey (registered dataclasses
            parts.append(str(p.name))    # like pruning.PrunedHeadState)
        else:
            parts.append(str(p))
    return "/".join(parts)


def lm_param_rules(scan_layers: bool = True):
    """Stacked layer params have a leading L dim (unsharded).

    2-D weight matrices: FSDP dim over 'data', TP dim over 'model'.
    Experts over 'model' (EP); embedding/vocab over 'model'.
    """
    l = (None,) if scan_layers else ()
    return [
        # MoE experts: (L, E, d, f) — E over model, d over data.
        (r"layers/.*moe/(up|gate)$", P(*l, "model", "data", None)),
        (r"layers/.*moe/down$",      P(*l, "model", None, "data")),
        (r"layers/.*moe/router/w$",  P(*l, None, "model")),
        (r"layers/.*moe/shared/.*/w$", P(*l, "data", "model")),
        # Attention + dense MLP 2-D mats: (L, d_in, d_out).
        (r"layers/.*(wq|wk|wv|up|gate)/w$", P(*l, "data", "model")),
        (r"layers/.*(wo|down)/w$",          P(*l, "model", "data")),
        (r"layers/.*/b$", P(*l, "model")),
        (r"layers/.*(scale|bias)$", P(*l, None)),
        # Embedding + unembedding: vocab over model, d over data.
        (r"(embed|head)/table$", P("model", "data")),
        (r"head/w$", P("data", "model")),
        # PQ head: codes over model (items), sub-embeddings replicated.
        (r"pq_head/codes$", P("model", None)),
        (r"pq_head/sub_emb$", P()),
        (r".*", P()),
    ]


def seqrec_param_rules():
    return [
        (r"item_emb/codes$", P("model", None)),
        (r"item_emb/sub_emb$", P()),
        (r"item_emb/table$", P("model", None)),
        (r".*/(wq|wk|wv|up|gate)/w$", P(None, "model")),
        (r".*/(wo|down)/w$", P("model", None)),
        (r".*", P()),
    ]


def recsys_param_rules():
    return [
        (r"tables/.*", P("model", None)),      # embedding rows over model
        (r"item_emb/codes$", P("model", None)),
        (r"item_emb/(sub_emb|table)$", P()),
        (r"mlp/.*w$", P(None, "model")),
        (r".*", P()),
    ]


def gnn_param_rules():
    return [(r".*", P())]        # GraphSAGE params are tiny: replicate


def param_shardings(mesh: Mesh, params: Any, rules) -> Any:
    """Map a params pytree (of arrays OR ShapeDtypeStructs) to NamedShardings."""

    def leaf(path, x):
        spec = _match(rules, path_str(path), len(x.shape))
        # Drop axes that don't divide evenly — replicate those dims instead.
        fixed = []
        for dim, ax in enumerate(spec):
            if ax is None:
                fixed.append(None)
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size = mesh.shape[a] if not isinstance(ax, tuple) else size
            if isinstance(ax, tuple):
                size = 1
                for a in ax:
                    size *= mesh.shape[a]
            fixed.append(ax if x.shape[dim] % size == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(leaf, params)


def replicated(mesh: Mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
