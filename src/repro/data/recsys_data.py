"""Synthetic CTR / behaviour-sequence click logs for the recsys archs."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import RecsysConfig


def ctr_batch(cfg: RecsysConfig, batch: int, seed: int = 0,
              ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    if cfg.kind in ("dcn", "fm"):
        if cfg.n_dense:
            out["dense"] = rng.normal(0, 1, (batch, cfg.n_dense)).astype(
                np.float32)
        out["sparse"] = np.stack(
            [rng.integers(0, r, batch) for r in cfg.table_rows],
            axis=1).astype(np.int32)
    else:  # bst / dien: (item, cate) behaviour sequence + target
        out["seq"] = np.stack([
            rng.integers(0, cfg.table_rows[0], (batch, cfg.seq_len)),
            rng.integers(0, cfg.table_rows[1], (batch, cfg.seq_len)),
        ], axis=-1).astype(np.int32)
        out["target"] = np.stack([
            rng.integers(0, cfg.table_rows[0], batch),
            rng.integers(0, cfg.table_rows[1], batch),
        ], axis=1).astype(np.int32)
    out["label"] = rng.integers(0, 2, batch).astype(np.float32)
    return out


def ctr_batches(cfg: RecsysConfig, batch: int, seed: int = 0,
                ) -> Iterator[Dict[str, np.ndarray]]:
    i = 0
    while True:
        yield ctr_batch(cfg, batch, seed + i)
        i += 1
