from repro.data import graph, recsys_data, sequences

__all__ = ["graph", "recsys_data", "sequences"]
