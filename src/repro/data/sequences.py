"""Synthetic sequential-recommendation data (Booking/Gowalla-scale shapes).

Interactions follow a Zipf item popularity (real catalogues are power-law)
and per-user sequence lengths match the dataset statistics in the paper's
Table 1.  The generator is deterministic in ``seed`` and streams batches —
this is the training data path for the seqrec archs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Tuple

import numpy as np


def zipf_item_sampler(n_items: int, a: float = 1.2,
                      seed: int = 0) -> np.ndarray:
    """Unnormalised Zipf ranks -> sampling distribution over 1..n_items."""
    rng = np.random.default_rng(seed)
    ranks = rng.permutation(n_items) + 1
    p = ranks ** (-a)
    return p / p.sum()


def gen_interactions(n_users: int, n_items: int, avg_len: float,
                     seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (user_ids, item_ids) with items in 1..n_items (0 = pad)."""
    rng = np.random.default_rng(seed)
    lens = np.maximum(rng.poisson(avg_len, n_users), 2)
    probs = zipf_item_sampler(n_items, seed=seed)
    users = np.repeat(np.arange(n_users), lens)
    items = rng.choice(n_items, size=lens.sum(), p=probs) + 1
    return users.astype(np.int64), items.astype(np.int64)


def to_user_sequences(users: np.ndarray, items: np.ndarray, n_users: int,
                      max_len: int) -> np.ndarray:
    """Right-aligned padded sequences (n_users, max_len), 0 = pad."""
    seqs = np.zeros((n_users, max_len), np.int64)
    order = np.argsort(users, kind="stable")
    users, items = users[order], items[order]
    starts = np.searchsorted(users, np.arange(n_users))
    ends = np.searchsorted(users, np.arange(n_users) + 1)
    for u in range(n_users):
        s = items[starts[u]:ends[u]][-max_len:]
        if len(s):
            seqs[u, -len(s):] = s
    return seqs


@dataclass
class SeqRecDataset:
    sequences: np.ndarray          # (n_users, max_len)
    n_items: int

    @classmethod
    def synthetic(cls, n_users: int, n_items: int, avg_len: float,
                  max_len: int, seed: int = 0) -> "SeqRecDataset":
        u, i = gen_interactions(n_users, n_items, avg_len, seed)
        return cls(to_user_sequences(u, i, n_users, max_len), n_items)

    def interactions(self) -> Tuple[np.ndarray, np.ndarray]:
        """Back to (user, item) pairs — input for the SVD codebook builder."""
        users, items = np.nonzero(self.sequences)
        return users.astype(np.int64), self.sequences[users, items] - 1

    def batches(self, batch_size: int, n_negatives: int, *, backbone: str,
                mask_prob: float = 0.2, seed: int = 0,
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Infinite stream of training batches.

        SASRec: input = seq[:-1], target = seq[1:] (next item / position).
        BERT4Rec: random positions masked (input id -> 0), targets set only
        at masked slots.
        """
        rng = np.random.default_rng(seed)
        n = len(self.sequences)
        while True:
            idx = rng.integers(0, n, batch_size)
            seqs = self.sequences[idx]
            if backbone == "sasrec":
                inp = seqs[:, :-1]
                tgt = seqs[:, 1:]
            else:
                inp = seqs.copy()
                mask = (rng.random(seqs.shape) < mask_prob) & (seqs != 0)
                tgt = np.where(mask, seqs, 0)
                inp[mask] = 0
            negs = rng.integers(1, self.n_items + 1,
                                (*tgt.shape, n_negatives))
            yield {
                "input_seq": inp.astype(np.int32),
                "targets": tgt.astype(np.int32),
                "negatives": negs.astype(np.int32),
            }
