"""Graph data: synthetic power-law graphs, the uniform fanout neighbor
sampler (real sampling, host-side — required for minibatch_lg), and batched
small-molecule graphs."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass
class Graph:
    edges: np.ndarray        # (E, 2) int32 src,dst
    feats: np.ndarray        # (N, F) f32
    labels: np.ndarray       # (N,) int32
    n_classes: int

    @property
    def n_nodes(self) -> int:
        return self.feats.shape[0]


def synthetic_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                    seed: int = 0) -> Graph:
    """Power-law-ish random graph (preferential-attachment flavoured)."""
    rng = np.random.default_rng(seed)
    # Degree-biased destination choice approximates preferential attachment.
    dst_pool = rng.zipf(1.5, n_edges * 2) % n_nodes
    src = rng.integers(0, n_nodes, n_edges)
    dst = dst_pool[:n_edges]
    edges = np.stack([src, dst], axis=1).astype(np.int32)
    feats = rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return Graph(edges, feats, labels, n_classes)


class NeighborSampler:
    """Uniform-with-replacement fanout sampling from a CSR adjacency —
    the GraphSAGE minibatch pipeline (host-side, feeds device steps)."""

    def __init__(self, graph: Graph):
        order = np.argsort(graph.edges[:, 1], kind="stable")
        self._sorted_src = graph.edges[order, 0]
        dst_sorted = graph.edges[order, 1]
        self._starts = np.searchsorted(dst_sorted, np.arange(graph.n_nodes))
        self._ends = np.searchsorted(dst_sorted, np.arange(graph.n_nodes) + 1)
        self.graph = graph

    def sample_neighbors(self, nodes: np.ndarray, fanout: int,
                         rng: np.random.Generator) -> np.ndarray:
        """(B,) -> (B, fanout) neighbor ids (self-loop where degree 0)."""
        starts, ends = self._starts[nodes], self._ends[nodes]
        deg = ends - starts
        offs = rng.integers(0, np.maximum(deg, 1)[:, None],
                            (len(nodes), fanout))
        idx = starts[:, None] + offs
        nbrs = self._sorted_src[np.minimum(idx, len(self._sorted_src) - 1)]
        return np.where(deg[:, None] > 0, nbrs, nodes[:, None]).astype(
            np.int32)

    def sample_batch(self, batch_nodes: np.ndarray, fanout: Tuple[int, int],
                     rng: np.random.Generator) -> Dict[str, np.ndarray]:
        f1, f2 = fanout
        n1 = self.sample_neighbors(batch_nodes, f1, rng)           # (B, f1)
        n2 = self.sample_neighbors(n1.reshape(-1), f2, rng)
        n2 = n2.reshape(len(batch_nodes), f1, f2)
        g = self.graph
        return {
            "feats_b": g.feats[batch_nodes],
            "feats_n1": g.feats[n1],
            "feats_n2": g.feats[n2],
            "labels": g.labels[batch_nodes].astype(np.int32),
        }


def molecule_batch(n_graphs: int, n_nodes: int, n_edges: int, d_feat: int,
                   n_classes: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Batched small graphs with a global node id space + graph ids."""
    rng = np.random.default_rng(seed)
    offsets = np.arange(n_graphs)[:, None] * n_nodes
    edges = rng.integers(0, n_nodes, (n_graphs, n_edges, 2)) + offsets[..., None]
    return {
        "feats": rng.normal(0, 1, (n_graphs * n_nodes, d_feat)).astype(
            np.float32),
        "edges": edges.reshape(-1, 2).astype(np.int32),
        "graph_ids": np.repeat(np.arange(n_graphs), n_nodes).astype(np.int32),
        "labels": rng.integers(0, n_classes, n_graphs).astype(np.int32),
    }
