"""Train-step factory: value_and_grad + microbatch gradient accumulation +
optional cross-pod PowerSGD compression (partial-auto shard_map over the
``pod`` axis) + AdamW update.  Pure function of (params, opt_state, batch)
— jitted and donated by the launcher.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.training import compression, optimizer as opt_lib

LossFn = Callable[..., Tuple[jax.Array, Dict[str, jax.Array]]]


def make_train_step(loss_fn: LossFn, opt_cfg: opt_lib.AdamWConfig, *,
                    grad_accum: int = 1,
                    frozen=opt_lib.default_frozen,
                    powersgd_axis: Optional[str] = None,
                    powersgd_rank: int = 4,
                    mesh=None,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).

    ``grad_accum`` > 1 splits the batch leading dim into microbatches and
    accumulates grads with a scan (memory ~ 1/grad_accum activations).
    ``powersgd_axis`` turns on compressed cross-axis gradient reduction
    (error-feedback state lives in opt_state["ef"]).
    """

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True, allow_int=True)(params, batch)
            return loss, metrics, grads

        def micro(carry, mb):
            acc, = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True, allow_int=True)(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32)
                if g.dtype != jax.dtypes.float0 else a, acc, grads)
            return (acc,), (loss, metrics)

        mbs = jax.tree.map(
            lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                *x.shape[1:]), batch)
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape if jnp.issubdtype(p.dtype, jnp.inexact)
                                else (), jnp.float32), params)
        (acc,), (losses, metrics) = jax.lax.scan(micro, (zero,), mbs)
        grads = jax.tree.map(lambda g: g / grad_accum, acc)
        metrics = jax.tree.map(lambda m: m.mean(), metrics)
        return losses.mean(), metrics, grads

    def train_step(params, opt_state, batch):
        if powersgd_axis is not None:
            assert mesh is not None, "powersgd needs the mesh"

            def local_fn(params_, batch_, ef_):
                # Manual over the pod axis: grads here are pod-local
                # (the pod dim of the batch is this shard's slice); the
                # only cross-pod traffic is the compressed P/Q factors.
                loss_, metrics_, grads_ = compute_grads(params_, batch_)
                grads_, new_ef_ = compression.compressed_psum(
                    grads_, ef_, powersgd_axis, rank=powersgd_rank)
                loss_ = jax.lax.pmean(loss_, powersgd_axis)
                metrics_ = jax.tree.map(
                    lambda m: jax.lax.pmean(m, powersgd_axis), metrics_)
                return loss_, metrics_, grads_, new_ef_

            sharded = compat.shard_map(
                local_fn, mesh=mesh,
                in_specs=(P(), P(powersgd_axis), P()),
                out_specs=(P(), P(), P(), P()),
                check_vma=False, axis_names={powersgd_axis})
            loss, metrics, grads, new_ef = sharded(
                params, batch, opt_state["ef"])
        else:
            loss, metrics, grads = compute_grads(params, batch)
            new_ef = opt_state.get("ef")
        if grad_shardings is not None:
            # Pin grads to the parameter layout: XLA lowers the cross-shard
            # reduction as reduce-scatter(s) instead of a full all-reduce.
            grads = jax.tree.map(
                lambda g, s: g if g.dtype == jax.dtypes.float0
                else jax.lax.with_sharding_constraint(g, s),
                grads, grad_shardings)
        inner = {k: v for k, v in opt_state.items() if k != "ef"}
        params, inner, om = opt_lib.adamw_update(grads, inner, params,
                                                 opt_cfg, frozen=frozen)
        if new_ef is not None:
            inner["ef"] = new_ef
        metrics = dict(metrics, loss=loss, **om)
        return params, inner, metrics

    return train_step


def init_opt_state(params, opt_cfg: opt_lib.AdamWConfig, *,
                   powersgd: bool = False, abstract: bool = False):
    mk = opt_lib.abstract_adamw if abstract else opt_lib.adamw_init
    state = mk(params, opt_cfg)
    if powersgd:
        ef = (compression.abstract_error_feedback(params) if abstract
              else compression.init_error_feedback(params))
        state["ef"] = ef
    return state
