"""Checkpointing: atomic step directories, keep-last-k, async save thread,
and **elastic restore** — checkpoints store full (unsharded) arrays plus the
tree manifest, so a restore may target any mesh/sharding (scale-up or -down
after node loss).  No orbax/tensorstore in this environment: npz + msgpack
manifest, written tmp-then-rename so a crash mid-save never corrupts the
latest checkpoint.

Restores are **checksummed** (ISSUE 10): every npz file's CRC32 lands in
the manifest at save time and is verified on load, so a truncated or
bit-rotten file raises :class:`CorruptCheckpointError` instead of
surfacing as a numpy parse error (or worse, silently wrong arrays)
halfway through ``restore``.  :meth:`CheckpointManager.restore_latest`
walks steps newest-first and falls back past corrupt ones — one bad
checkpoint costs recency, never the run.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any

_SEP = "|"


class CorruptCheckpointError(RuntimeError):
    """A checkpoint step directory failed validation: missing/unparsable
    manifest, missing npz, or a checksum mismatch (truncation, torn
    write, bit rot)."""


def _file_crc32(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                return crc
            crc = zlib.crc32(chunk, crc)


def _flatten(tree: Params) -> Dict[str, np.ndarray]:
    from repro.distributed.sharding import path_str
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[path_str(path).replace("/", _SEP)] = np.asarray(
            jax.device_get(leaf))
    return flat


def _tree_def(tree: Params):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, trees: Dict[str, Params], *, block: bool = False):
        """trees: named pytrees, e.g. {"params": ..., "opt_state": ...}."""
        host = {name: _flatten(t) for name, t in trees.items()}
        self.wait()   # drain any in-flight async save first
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()
        else:
            self._write(step, host)

    def _write(self, step: int, host: Dict[str, Dict[str, np.ndarray]]):
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + f".tmp{os.getpid()}-{threading.get_ident()}"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "groups": {},
                    "checksums": {}}
        for name, flat in host.items():
            fname = f"{name}.npz"
            np.savez(os.path.join(tmp, fname), **flat)
            manifest["groups"][name] = {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                for k, v in flat.items()
            }
            # CRC over the file bytes as written: restore re-hashes the
            # same bytes, so any truncation/corruption between save and
            # load is caught before numpy ever parses the archive.
            manifest["checksums"][fname] = _file_crc32(
                os.path.join(tmp, fname))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and ".tmp" not in d:
                if os.path.exists(os.path.join(self.directory, d,
                                               "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def validate_step(self, step: int) -> bool:
        """True when the step directory passes integrity checks: readable
        manifest, every group's npz present, and — for checkpoints written
        with checksums — CRC32 match on the file bytes.  Pre-checksum
        checkpoints (no ``checksums`` manifest key) validate by a best-
        effort parse of each archive's member table instead."""
        base = os.path.join(self.directory, f"step_{step:010d}")
        try:
            with open(os.path.join(base, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, ValueError):
            return False
        checksums = manifest.get("checksums")
        for name in manifest.get("groups", {}):
            path = os.path.join(base, f"{name}.npz")
            if not os.path.exists(path):
                return False
            if checksums is not None:
                want = checksums.get(f"{name}.npz")
                if want is None or _file_crc32(path) != int(want):
                    return False
            else:
                try:
                    with np.load(path) as z:
                        _ = z.files
                except Exception:
                    return False
        return True

    def valid_steps(self) -> List[int]:
        return [s for s in self.all_steps() if self.validate_step(s)]

    def restore_latest(self, templates: Dict[str, Params],
                       shardings: Optional[Dict[str, Params]] = None,
                       ) -> Tuple[int, Dict[str, Params]]:
        """Restore the newest step that passes validation, falling back
        past corrupt/truncated ones (a crash mid-write plus a crash
        mid-GC can leave any suffix of the step list damaged — losing
        recency is recoverable, crashing mid-restore is not).  Returns
        ``(step, trees)``; raises :class:`CorruptCheckpointError` when no
        step survives validation."""
        steps = self.all_steps()
        skipped = []
        for step in reversed(steps):
            if not self.validate_step(step):
                skipped.append(step)
                continue
            try:
                return step, self.restore(step, templates, shardings)
            except CorruptCheckpointError:
                skipped.append(step)   # raced a concurrent writer/GC
        raise CorruptCheckpointError(
            f"no valid checkpoint under {self.directory!r} "
            f"(steps seen: {steps}, failed validation: {skipped})")

    def restore(self, step: int, templates: Dict[str, Params],
                shardings: Optional[Dict[str, Params]] = None,
                ) -> Dict[str, Params]:
        """Restore named trees.  ``templates`` give the pytree structure
        (arrays or ShapeDtypeStructs).  ``shardings`` (optional, same
        structure) re-places every leaf on the *current* mesh — this is the
        elastic path: the stored full arrays don't care how many devices
        wrote them or will read them."""
        from repro.distributed.sharding import path_str
        base = os.path.join(self.directory, f"step_{step:010d}")
        checksums = None
        try:
            with open(os.path.join(base, "manifest.json")) as f:
                checksums = json.load(f).get("checksums")
        except (OSError, ValueError) as e:
            raise CorruptCheckpointError(
                f"step {step}: unreadable manifest ({e})") from e
        out = {}
        for name, template in templates.items():
            path = os.path.join(base, f"{name}.npz")
            if checksums is not None and f"{name}.npz" in checksums:
                if _file_crc32(path) != int(checksums[f"{name}.npz"]):
                    raise CorruptCheckpointError(
                        f"step {step}: checksum mismatch on {name}.npz "
                        "(truncated or corrupt)")
            try:
                with np.load(path) as z:
                    flat = {k: z[k] for k in z.files}
            except Exception as e:
                raise CorruptCheckpointError(
                    f"step {step}: unreadable {name}.npz ({e})") from e
            leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
            shard_tree = shardings.get(name) if shardings else None
            shard_leaves = (jax.tree_util.tree_leaves(shard_tree)
                            if shard_tree is not None else [None] * len(leaves_p))
            new_leaves = []
            for (path, leaf), sh in zip(leaves_p, shard_leaves):
                key = path_str(path).replace("/", _SEP)
                arr = flat[key]
                if tuple(arr.shape) != tuple(leaf.shape):
                    raise ValueError(
                        f"checkpoint leaf {key}: shape {arr.shape} != "
                        f"template {leaf.shape}")
                if sh is not None:
                    new_leaves.append(jax.device_put(arr, sh))
                else:
                    new_leaves.append(jnp.asarray(arr, leaf.dtype))
            out[name] = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return out
