"""Shared loss functions beyond the per-model ones: sampled softmax with
logQ correction (two-tower retrieval training at large catalogue scale) and
plain helpers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sampled_softmax_logq(pos_scores: jax.Array, neg_scores: jax.Array,
                         neg_logq: jax.Array,
                         pos_logq: Optional[jax.Array] = None) -> jax.Array:
    """Sampled softmax with logQ correction [Bengio & Senécal'08; Yi+
    RecSys'19]: subtract log-proposal from sampled logits so the gradient
    is unbiased under non-uniform (e.g. popularity) negative sampling.

    pos_scores (B,), neg_scores (B, n), neg_logq (B, n) or (n,).
    """
    if pos_logq is not None:
        pos_scores = pos_scores - pos_logq
    neg = neg_scores - neg_logq
    logits = jnp.concatenate([pos_scores[:, None], neg], axis=1)
    return (jax.scipy.special.logsumexp(logits, -1) - logits[:, 0]).mean()


def bce_with_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return -(labels * jax.nn.log_sigmoid(logits)
             + (1 - labels) * jax.nn.log_sigmoid(-logits)).mean()


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logz = jax.scipy.special.logsumexp(logits.astype(jnp.float32), -1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()
