"""Optimizers built from scratch (no optax in this environment):
AdamW with configurable moment dtype (bf16 at 340B scale), global-norm
clipping, and warmup-cosine / warmup-rsqrt schedules.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"        # cosine | rsqrt | constant


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "cosine":
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
        decay = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "rsqrt":
        decay = jax.lax.rsqrt(jnp.maximum(step, cfg.warmup_steps) /
                              max(cfg.warmup_steps, 1))
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def adamw_init(params: Params, cfg: AdamWConfig) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def abstract_adamw(params: Params, cfg: AdamWConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct state for dry-runs (no allocation)."""
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, mdt)
    return {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def _is_float(x) -> bool:
    return (hasattr(x, "dtype") and x.dtype != jax.dtypes.float0
            and jnp.issubdtype(x.dtype, jnp.inexact))


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree) if _is_float(x)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float,
                        ) -> Tuple[Params, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(
        lambda g: ((g.astype(jnp.float32) * scale).astype(g.dtype)
                   if _is_float(g) else g), grads), gn


def adamw_update(grads: Params, state: Dict[str, Any], params: Params,
                 cfg: AdamWConfig, *, frozen: Optional[Callable[[str], bool]] = None,
                 ) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  ``frozen(path)`` -> True freezes a leaf (e.g. PQ
    ``codes`` buffers, which are integer constants, are always frozen)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    if cfg.clip_norm > 0:
        grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gn = global_norm(grads)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    from repro.distributed.sharding import path_str

    def leaf(path, p, g, m, v):
        pstr = path_str(path)
        if not jnp.issubdtype(p.dtype, jnp.floating) or (
                frozen is not None and frozen(pstr)):
            return p, m, v
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, m32.astype(mdt), v32.astype(mdt)

    flat = jax.tree_util.tree_map_with_path(
        leaf, params, grads, state["m"], state["v"])
    # Unzip the 3-tuples.
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}


def default_frozen(path: str) -> bool:
    """Integer PQ codes and any explicitly frozen buffers."""
    return path.endswith("codes")


# ---------------------------------------------------------------------------
# Adafactor [Shazeer & Stern, arXiv:1804.04235] — factored second moments:
# O(m+n) optimizer state per (m, n) matrix instead of Adam's O(2mn); the
# realistic choice at 340B scale when even bf16 moments are too heavy.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AdafactorConfig:
    lr: float = 1e-2
    decay: float = 0.8           # beta2_t = 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "rsqrt"

    def as_adamw(self) -> AdamWConfig:
        return AdamWConfig(lr=self.lr, warmup_steps=self.warmup_steps,
                           total_steps=self.total_steps,
                           schedule=self.schedule)


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params: Params, cfg: AdafactorConfig) -> Dict[str, Any]:
    def leaf(p):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return {"_": jnp.zeros((), jnp.float32)}
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"step": jnp.zeros((), jnp.int32),
            "v": jax.tree.map(leaf, params)}


def abstract_adafactor(params: Params, cfg: AdafactorConfig):
    return jax.eval_shape(lambda p: adafactor_init(p, cfg), params)


def adafactor_update(grads: Params, state: Dict[str, Any], params: Params,
                     cfg: AdafactorConfig, *,
                     frozen: Optional[Callable[[str], bool]] = None,
                     ) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    from repro.distributed.sharding import path_str
    step = state["step"] + 1
    lr = schedule_lr(cfg.as_adamw(), step)
    beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay)
    gn = global_norm(grads)

    def leaf(path, p, g, v):
        pstr = path_str(path)
        if not jnp.issubdtype(p.dtype, jnp.floating) or (
                frozen is not None and frozen(pstr)):
            return p, v
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + cfg.eps
        if "vr" in v:
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
            denom = (vr / jnp.maximum(vr.mean(-1, keepdims=True), cfg.eps)
                     )[..., None] * vc[..., None, :]
            upd = g32 * jax.lax.rsqrt(jnp.maximum(denom, cfg.eps))
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = beta2 * v["v"] + (1 - beta2) * g2
            upd = g32 * jax.lax.rsqrt(jnp.maximum(vv, cfg.eps))
            new_v = {"v": vv}
        # Update clipping (RMS <= clip_threshold).
        rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + cfg.eps)
        upd = upd / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), new_v

    # State leaves are dicts ({"vr","vc"} / {"v"}) — map via a manual zip
    # over flattened leaves rather than tree_map.
    is_state = lambda t: isinstance(t, dict) and (
        "v" in t or "vr" in t or "_" in t)
    p_leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_leaves = jax.tree_util.tree_leaves(grads)
    v_leaves = jax.tree_util.tree_leaves(
        state["v"], is_leaf=is_state)
    new_p, new_vs = [], []
    for (path, p), g, v in zip(p_leaves, g_leaves, v_leaves):
        np_, nv = leaf(path, p, g, v)
        new_p.append(np_)
        new_vs.append(nv)
    params_out = jax.tree_util.tree_unflatten(treedef, new_p)
    v_treedef = jax.tree_util.tree_structure(state["v"], is_leaf=is_state)
    v_out = jax.tree_util.tree_unflatten(v_treedef, new_vs)
    return params_out, {"step": step, "v": v_out}, {"grad_norm": gn, "lr": lr}


def adafactor_state_bytes(params: Params) -> int:
    """Factored-state footprint — compare against Adam's 2x param bytes."""
    total = 0
    for p in jax.tree.leaves(params):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            continue
        if _factored(p.shape):
            total += 4 * (int(np.prod(p.shape[:-1]))
                          + int(np.prod(p.shape[:-2] + p.shape[-1:])))
        else:
            total += 4 * p.size
    return total
