from repro.training import (checkpoint, compression, fault_tolerance, losses,
                            optimizer, train_loop)

__all__ = ["checkpoint", "compression", "fault_tolerance", "losses",
           "optimizer", "train_loop"]
