"""Fault tolerance: failure injection, auto-resume, straggler accounting.

Real-cluster wiring (coordinator heartbeats, preemption signals) is
simulated per the brief; the *logic* — resumable loops, deadline-based
straggler detection, elastic restart on a different device count — is real
and tested.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

log = logging.getLogger("repro.fault")


class SimulatedFailure(RuntimeError):
    """A node failure / preemption injected mid-training."""


@dataclass
class FailureInjector:
    """Deterministically fail at given steps (e.g. from a chaos schedule)."""
    fail_at_steps: Sequence[int] = ()
    _fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class StragglerMonitor:
    """Step-deadline straggler mitigation: track a rolling median step time;
    steps slower than ``factor``x median are flagged (on a real cluster the
    coordinator would drop/re-assign that host's shard; here we log and
    count, and the serving engine uses the same deadline logic for request
    timeouts)."""
    factor: float = 3.0
    window: int = 50
    _times: List[float] = field(default_factory=list)
    flagged: List[int] = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self._times.append(seconds)
        hist = self._times[-self.window:]
        if len(hist) >= 5:
            med = float(np.median(hist))
            if seconds > self.factor * med:
                self.flagged.append(step)
                log.warning("straggler step %d: %.3fs > %.1fx median %.3fs",
                            step, seconds, self.factor, med)
                return True
        return False


def run_with_restarts(make_state: Callable[[], Any],
                      train: Callable[[Any, int], Any],
                      *, max_restarts: int = 3) -> Any:
    """Generic resumable loop: ``make_state()`` loads the latest checkpoint
    (or fresh state); ``train(state, restart_count)`` runs until completion
    or raises ``SimulatedFailure``.  Mirrors a cluster-level auto-restart
    policy."""
    restarts = 0
    while True:
        state = make_state()
        try:
            return train(state, restarts)
        except SimulatedFailure as e:
            restarts += 1
            log.warning("restart %d/%d after %s", restarts, max_restarts, e)
            if restarts > max_restarts:
                raise
