"""Fault tolerance: failure injection, auto-resume, straggler accounting.

Real-cluster wiring (coordinator heartbeats, preemption signals) is
simulated per the brief; the *logic* — resumable loops, deadline-based
straggler detection, elastic restart on a different device count — is real
and tested.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

log = logging.getLogger("repro.fault")


class SimulatedFailure(RuntimeError):
    """A node failure / preemption injected mid-training."""


@dataclass
class FailureInjector:
    """Deterministically fail at given steps (e.g. from a chaos schedule)."""
    fail_at_steps: Sequence[int] = ()
    _fired: set = field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclass
class ServeFaultInjector:
    """Serving-side chaos schedule: deterministically fail and/or slow
    specific serve batches.

    ``fail_at_batches`` lists batch indices whose dispatch raises
    :class:`SimulatedFailure`; each listed batch fails ``fail_repeats``
    consecutive attempts (so ``fail_repeats`` <= the engine's retry budget
    exercises retry-and-recover, and a larger value exercises
    retries-exhausted shedding).  ``slow_at_batches`` lists batch indices
    that incur one extra ``slow_ms`` delay — a synthetic straggler the
    engine's :class:`StragglerMonitor` should flag.  Both schedules are
    keyed on the engine's monotonically increasing batch counter, so a
    chaos run is reproducible."""
    fail_at_batches: Sequence[int] = ()
    fail_repeats: int = 1
    slow_at_batches: Sequence[int] = ()
    slow_ms: float = 0.0
    _fail_counts: Dict[int, int] = field(default_factory=dict)
    _slowed: set = field(default_factory=set)

    def check(self, batch_index: int):
        """Raise on this attempt if the batch's failure budget remains."""
        if batch_index in self.fail_at_batches:
            c = self._fail_counts.get(batch_index, 0)
            if c < self.fail_repeats:
                self._fail_counts[batch_index] = c + 1
                raise SimulatedFailure(
                    f"injected serve failure at batch {batch_index} "
                    f"(attempt {c + 1}/{self.fail_repeats})")

    def delay_s(self, batch_index: int) -> float:
        """Extra seconds to sleep for this batch (fires once per batch)."""
        if batch_index in self.slow_at_batches \
                and batch_index not in self._slowed:
            self._slowed.add(batch_index)
            return self.slow_ms / 1e3
        return 0.0


@dataclass
class ReplicaFaultPlan:
    """Replica-level chaos schedule for the multi-replica serving fabric
    (``serving/router.py``): windows over one replica's *own* dispatch
    counter during which every dispatch crashes (raises
    :class:`SimulatedFailure` — a dead / preempted replica) or is slowed
    by ``slow_ms`` (a straggling replica).  Window bounds are half-open
    ``[start, stop)`` dispatch indices, so a schedule is reproducible
    regardless of how the router interleaves replicas: the i-th dispatch
    a replica attempts always sees the same fate.

    This is the layer ABOVE :class:`ServeFaultInjector` (which models
    transient per-batch faults inside one engine and is retried by the
    engine's own backoff loop): a crash window long enough to exhaust the
    router's re-dispatch patience looks like a dead node and must trip
    the health state machine — ejection, re-dispatch of its in-flight
    work, and half-open probe re-admission once the window has passed."""
    crash_windows: Sequence[Tuple[int, int]] = ()
    slow_windows: Sequence[Tuple[int, int]] = ()
    slow_ms: float = 0.0

    @staticmethod
    def _in(windows, idx: int) -> bool:
        return any(lo <= idx < hi for lo, hi in windows)

    def mode(self, dispatch_index: int) -> str:
        """Fate of this replica's ``dispatch_index``-th dispatch:
        ``"crash"`` beats ``"slow"`` when windows overlap."""
        if self._in(self.crash_windows, dispatch_index):
            return "crash"
        if self._in(self.slow_windows, dispatch_index):
            return "slow"
        return "ok"

    def check(self, dispatch_index: int) -> float:
        """Raise on a crashed dispatch; return the extra seconds a slowed
        dispatch must sleep (0.0 when healthy)."""
        m = self.mode(dispatch_index)
        if m == "crash":
            raise SimulatedFailure(
                f"injected replica crash at dispatch {dispatch_index}")
        return self.slow_ms / 1e3 if m == "slow" else 0.0


@dataclass
class StragglerMonitor:
    """Step-deadline straggler mitigation: track a rolling median step time;
    steps slower than ``factor``x median are flagged (on a real cluster the
    coordinator would drop/re-assign that host's shard; here we log and
    count, and the serving engine uses the same deadline logic for request
    timeouts)."""
    factor: float = 3.0
    window: int = 50
    _times: List[float] = field(default_factory=list)
    flagged: List[int] = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self._times.append(seconds)
        hist = self._times[-self.window:]
        if len(hist) >= 5:
            med = float(np.median(hist))
            if seconds > self.factor * med:
                self.flagged.append(step)
                log.warning("straggler step %d: %.3fs > %.1fx median %.3fs",
                            step, seconds, self.factor, med)
                return True
        return False


def run_with_restarts(make_state: Callable[[], Any],
                      train: Callable[[Any, int], Any],
                      *, max_restarts: int = 3) -> Any:
    """Generic resumable loop: ``make_state()`` loads the latest checkpoint
    (or fresh state); ``train(state, restart_count)`` runs until completion
    or raises ``SimulatedFailure``.  Mirrors a cluster-level auto-restart
    policy."""
    restarts = 0
    while True:
        state = make_state()
        try:
            return train(state, restarts)
        except SimulatedFailure as e:
            restarts += 1
            log.warning("restart %d/%d after %s", restarts, max_restarts, e)
            if restarts > max_restarts:
                raise
