"""PowerSGD gradient compression with error feedback [Vogels+ NeurIPS'19],
targeted at the cross-pod (DCI) all-reduce — the slowest link at 1000+ nodes.

For each 2-D gradient G (m x n): P = G @ Q; all-reduce P (r*m floats);
Q' = G^T @ P_orth; all-reduce Q' (r*n floats); G_hat = P_orth @ Q'^T.
Bytes per matrix drop from m*n to r*(m+n).  The residual G - G_hat is kept
locally and added to the next step's gradient (error feedback), which is
what makes low-rank compression converge.

Non-2D leaves (biases, norms, stacked scans are treated per-matrix by
flattening leading dims) below ``min_size`` are reduced uncompressed.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat

Params = Any


def _as_matrix(g: jax.Array) -> Optional[Tuple[jax.Array, Tuple[int, ...]]]:
    """Reshape to 2-D (prod(leading), last) if sensibly matrix-like."""
    if g.ndim < 2:
        return None
    shape = g.shape
    m = 1
    for s in shape[:-1]:
        m *= s
    return g.reshape(m, shape[-1]), shape


def _orthonormalise(p: jax.Array) -> jax.Array:
    """Gram-Schmidt via QR (columns)."""
    q, _ = jnp.linalg.qr(p)
    return q


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32)
                        if jnp.issubdtype(p.dtype, jnp.floating)
                        else jnp.zeros((), jnp.float32), params)


def abstract_error_feedback(params: Params) -> Params:
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(
            p.shape if jnp.issubdtype(p.dtype, jnp.floating) else (),
            jnp.float32),
        params)


def compressed_psum(grads: Params, err: Params, axis: str, *, rank: int = 4,
                    min_size: int = 65536, seed: int = 0,
                    ) -> Tuple[Params, Params]:
    """Inside shard_map (manual over ``axis``): PowerSGD all-reduce.

    Returns (mean-reduced grads, new error feedback).
    """
    n_dev = compat.axis_size(axis)
    key = jax.random.PRNGKey(seed)

    def leaf(path, g, e):
        if not jnp.issubdtype(g.dtype, jnp.floating):
            return g, e
        g32 = g.astype(jnp.float32)
        if g.size < min_size or g.ndim < 2:
            out = jax.lax.pmean(g32, axis)
            return out.astype(g.dtype), e
        gm, shape = _as_matrix(g32 + e.astype(jnp.float32))
        m, n = gm.shape
        r = min(rank, m, n)
        kleaf = jax.random.fold_in(key, hash(str(path)) % (2 ** 31))
        q = jax.random.normal(kleaf, (n, r), jnp.float32)
        p = gm @ q                                  # (m, r)
        p = jax.lax.psum(p, axis) / n_dev           # collective: r*m
        p = _orthonormalise(p)
        qq = gm.T @ p                               # (n, r)
        qq = jax.lax.psum(qq, axis) / n_dev         # collective: r*n
        g_hat = (p @ qq.T).reshape(shape)
        new_e = (g32 + e.astype(jnp.float32) - g_hat)
        return g_hat.astype(g.dtype), new_e.astype(e.dtype)

    flat = jax.tree_util.tree_map_with_path(leaf, grads, err)
    out_g = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    out_e = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return out_g, out_e


def compressed_psum_sharded(grads: Params, err: Params, mesh, axis: str, *,
                            rank: int = 4, min_size: int = 65536,
                            ) -> Tuple[Params, Params]:
    """Standalone shard_mapped wrapper around :func:`compressed_psum` for
    callers (and tests) that are not already inside a Manual region.  Grads
    and error feedback are replicated over ``axis``; the train loop shards
    the batch instead and builds its own region (see train_loop.py)."""

    def f(g, e):
        return compressed_psum(g, e, axis, rank=rank, min_size=min_size)

    return compat.shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check_vma=False, axis_names={axis})(grads, err)


def compression_ratio(params: Params, rank: int = 4,
                      min_size: int = 65536) -> float:
    """Estimated collective-bytes ratio (compressed / uncompressed)."""
    full = 0
    comp = 0
    for p in jax.tree.leaves(params):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            continue
        full += p.size
        if p.size < min_size or p.ndim < 2:
            comp += p.size
        else:
            m = 1
            for s in p.shape[:-1]:
                m *= s
            n = p.shape[-1]
            r = min(rank, m, n)
            comp += r * (m + n)
    return comp / max(full, 1)
