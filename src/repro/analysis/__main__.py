"""CLI for the serve-path static analysis: ``python -m repro.analysis``.

Runs the default passes over the entrypoint registry, prints a pass/fail
table per (entrypoint, pass), optionally writes the JSON report, and
exits non-zero on any error finding — ci.sh gates on it.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level static analysis of the serving routes")
    ap.add_argument("-e", "--entrypoint", action="append", default=None,
                    help="restrict to this entrypoint (repeatable)")
    ap.add_argument("-p", "--pass", dest="passes", action="append",
                    default=None,
                    help="restrict to this pass (repeatable)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the full JSON report here")
    ap.add_argument("--list", action="store_true",
                    help="list registered entrypoints and passes, then exit")
    args = ap.parse_args(argv)

    from repro.analysis import run_default
    from repro.analysis import entrypoints as ep
    from repro.analysis.passes import default_passes

    if args.list:
        print("entrypoints:")
        for name, entry in ep.REGISTRY.items():
            print(f"  {name:22s} [{','.join(entry.tags)}] "
                  f"{entry.description}")
        print("passes:")
        for p in default_passes():
            print(f"  {p.name:22s} {p.description}")
        return 0

    report = run_default(entrypoints=args.entrypoint, passes=args.passes)
    print(report.render())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.to_json(), f, indent=2)
        print(f"json report -> {args.json}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
