"""kernel-contract: static checks on every ``pallas_call`` in the trace.

Four contracts per kernel call, all readable from the traced
``grid_mapping`` without running (or even lowering) the kernel — so CPU
CI verifies them on the interpret-mode trace, closing two caveats that
previously lived in ROADMAP's "validate on real TPU" list:

* **static grid** — ``num_dynamic_grid_bounds == 0`` and every grid dim a
  Python int: a dynamic grid recompiles per shape and defeats the AOT
  variant memoisation.
* **VMEM budget** — Σ(block shape × dtype bytes) over all input+output
  block mappings, doubled for pipelining (Pallas double-buffers blocks so
  DMA overlaps compute), must fit the configurable budget.  TPU VMEM is
  ~16 MiB/core; the default budget is half that, leaving headroom for
  scratch and compiler-managed buffers (see
  ``/opt/skills/guides``' Pallas notes).
* **tiling / divisibility** — each block's last dim must be the full
  array dim or a multiple of 128 (the lane width); the second-minor dim
  must be the full array dim, 1 (degenerate, layout-free), or a multiple
  of the dtype's min sublane tile — 8 for 4-byte types, 16 for 2-byte,
  **32 for int8/uint8 codes**.  Misaligned int8 code blocks are exactly
  the class of mistake that lowers fine in interpret mode and dies (or
  silently pads) on real TPU hardware.
* **sentinel clamp** — the compacted-tile kernels drive the codes block
  index from a scalar-prefetched slot table padded with ``-1`` sentinels;
  the contract is that index maps clamp ``-1`` to block 0 (the kernel
  body then early-exits via ``@pl.when``, and block 0 is always resident
  so the clamped index costs no extra DMA).  The pass *evaluates* each
  index map twice — scalar tables filled with ``-1`` vs ``0`` — after
  discharging the scalar refs to values; the results must be equal and
  in-bounds at every sampled grid point.  An unclamped
  ``idx_ref[i]`` map returns block ``-1`` on the sentinel fill and fails.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.core import (AnalysisPass, EntryContext, Finding,
                                 SEV_ERROR, iter_eqns)

LANE = 128
#: dtype itemsize (bytes) -> minimum sublane (second-minor) tile
SUBLANE_MIN = {1: 32, 2: 16, 4: 8, 8: 8}

DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024
#: cap on exhaustively evaluated grid points per index map (past this,
#: sample the corners + a leading slice)
MAX_GRID_POINTS = 64


def _block_nbytes(bm) -> int:
    shape = [d for d in bm.block_shape if isinstance(d, int)]
    n = 1
    for d in shape:
        n *= d
    return n * bm.array_shape_dtype.dtype.itemsize


def _grid_points(grid) -> List[Tuple[int, ...]]:
    total = 1
    for g in grid:
        total *= int(g)
    pts = itertools.product(*(range(int(g)) for g in grid))
    if total <= MAX_GRID_POINTS:
        return list(pts)
    corners = list(itertools.product(*((0, int(g) - 1) for g in grid)))
    return list(dict.fromkeys(corners + list(itertools.islice(
        pts, MAX_GRID_POINTS - len(corners)))))


def _eval_index_map(imj, grid_pt, scalar_fill: int):
    """Evaluate a block's index-map jaxpr at one grid point with every
    scalar-prefetch table filled with ``scalar_fill``.  The scalar
    operands are SMEM refs inside the jaxpr; ``discharge_state`` converts
    the ref reads into pure ops so the jaxpr evaluates concretely."""
    import jax
    import jax.numpy as jnp
    from jax._src.state.discharge import discharge_state

    n_out = len(imj.jaxpr.outvars)
    dis_jaxpr, dis_consts = discharge_state(imj.jaxpr, imj.consts)
    args = []
    for invar in imj.jaxpr.invars:
        aval = invar.aval
        if getattr(aval, "shape", ()) == () and not hasattr(aval, "inner_aval"):
            args.append(None)          # grid index placeholder
        else:
            shape = getattr(getattr(aval, "inner_aval", aval), "shape",
                            aval.shape)
            dtype = getattr(getattr(aval, "inner_aval", aval), "dtype",
                            jnp.int32)
            args.append(jnp.full(shape, scalar_fill, dtype))
    it = iter(grid_pt)
    args = [jnp.int32(next(it)) if a is None else a for a in args]
    out = jax.core.eval_jaxpr(dis_jaxpr, dis_consts, *args)
    return tuple(int(o) for o in out[:n_out])   # discharge appends final
                                                # ref states; drop them


class PallasContractPass(AnalysisPass):
    name = "kernel-contract"
    description = ("per pallas_call: static grid, VMEM block budget, "
                   "tiling/divisibility (incl. int8 codes), and the -1 "
                   "sentinel index-map clamp")
    scope = "entrypoint"
    requires_trace = True

    def run(self, entrypoint: str, built: Any, ctx: Optional[EntryContext]
            ) -> Tuple[List[Finding], Dict[str, Any]]:
        findings: List[Finding] = []
        jaxpr = ctx.trace()
        budget = built.vmem_budget or DEFAULT_VMEM_BUDGET

        calls = [(eqn, path) for eqn, path in iter_eqns(jaxpr)
                 if eqn.primitive.name == "pallas_call"]
        info: Dict[str, Any] = {"n_pallas_calls": len(calls)}
        if built.expect_pallas and len(calls) < built.expect_pallas:
            findings.append(Finding(
                self.name, entrypoint, SEV_ERROR, "missing-kernel",
                f"expected >= {built.expect_pallas} pallas_call(s) in the "
                f"trace, found {len(calls)}: the route is not hitting the "
                f"kernel",
                details={"expected": built.expect_pallas,
                         "found": len(calls)}))

        max_vmem = 0
        for ci, (eqn, path) in enumerate(calls):
            gm = eqn.params["grid_mapping"]
            where = f"pallas_call#{ci}@{'/'.join(path) or '<top>'}"

            # -- static grid ------------------------------------------------
            dyn = getattr(gm, "num_dynamic_grid_bounds", 0)
            if dyn or not all(isinstance(g, int) for g in gm.grid):
                findings.append(Finding(
                    self.name, entrypoint, SEV_ERROR, "dynamic-grid",
                    f"{where}: grid {gm.grid!r} has "
                    f"{dyn} dynamic bound(s) — per-shape recompiles defeat "
                    f"AOT variant memoisation",
                    details={"grid": [repr(g) for g in gm.grid],
                             "num_dynamic_grid_bounds": int(dyn)}))
                continue   # block/sentinel math needs a concrete grid

            # -- VMEM budget (x2: Pallas double-buffers for pipelining) -----
            vmem = 2 * sum(_block_nbytes(bm) for bm in gm.block_mappings)
            max_vmem = max(max_vmem, vmem)
            if vmem > budget:
                findings.append(Finding(
                    self.name, entrypoint, SEV_ERROR, "vmem-budget",
                    f"{where}: estimated VMEM footprint {vmem} bytes "
                    f"(2x sum of block buffers) exceeds the "
                    f"{budget}-byte budget",
                    details={"vmem_bytes": vmem, "budget": budget,
                             "blocks": [list(bm.block_shape)
                                        for bm in gm.block_mappings]}))

            # -- tiling / divisibility --------------------------------------
            for bi, bm in enumerate(gm.block_mappings):
                block = [d for d in bm.block_shape if isinstance(d, int)]
                arr = bm.array_shape_dtype.shape
                itemsize = bm.array_shape_dtype.dtype.itemsize
                if len(block) < 2 or len(block) != len(arr):
                    continue   # scalars / squeezed blocks: layout-free
                sub_min = SUBLANE_MIN.get(itemsize, 8)
                last, arr_last = block[-1], arr[-1]
                sub, arr_sub = block[-2], arr[-2]
                bad = []
                if last != arr_last and last % LANE != 0:
                    bad.append(f"lane dim {last} (array {arr_last}): not "
                               f"full and not a multiple of {LANE}")
                if sub != arr_sub and sub != 1 and sub % sub_min != 0:
                    bad.append(f"sublane dim {sub} (array {arr_sub}): not "
                               f"full and not a multiple of {sub_min} for "
                               f"itemsize {itemsize}")
                if bad:
                    findings.append(Finding(
                        self.name, entrypoint, SEV_ERROR, "tiling",
                        f"{where} block#{bi} "
                        f"{tuple(bm.block_shape)} on "
                        f"{bm.array_shape_dtype.dtype} array {tuple(arr)}: "
                        + "; ".join(bad),
                        details={"block": list(bm.block_shape),
                                 "array": list(arr),
                                 "dtype": str(bm.array_shape_dtype.dtype),
                                 "violations": bad}))

            # -- sentinel clamp ---------------------------------------------
            if getattr(gm, "num_index_operands", 0):
                pts = _grid_points(gm.grid)
                for bi, bm in enumerate(gm.block_mappings):
                    block = [d for d in bm.block_shape if isinstance(d, int)]
                    arr = bm.array_shape_dtype.shape
                    nblocks = [max(1, -(-a // b)) for a, b in
                               zip(arr, block)] if len(block) == len(arr) \
                        else None
                    for pt in pts:
                        try:
                            neg = _eval_index_map(bm.index_map_jaxpr, pt, -1)
                            zero = _eval_index_map(bm.index_map_jaxpr, pt, 0)
                        except Exception as e:  # noqa: BLE001
                            findings.append(Finding(
                                self.name, entrypoint, SEV_ERROR,
                                "sentinel-uncheckable",
                                f"{where} block#{bi}: index map could not "
                                f"be evaluated statically "
                                f"({type(e).__name__}: {e})",
                                details={"grid_point": list(pt)}))
                            break
                        oob = (nblocks is not None and
                               any(not 0 <= x < nb
                                   for x, nb in zip(neg, nblocks)))
                        if neg != zero or oob:
                            findings.append(Finding(
                                self.name, entrypoint, SEV_ERROR,
                                "sentinel-clamp",
                                f"{where} block#{bi}: index map does not "
                                f"clamp -1 sentinel slots to block 0 — at "
                                f"grid {pt} a -1-filled slot table maps to "
                                f"block {neg} (0-filled table: {zero}"
                                f"{', out of bounds' if oob else ''})",
                                details={"grid_point": list(pt),
                                         "neg_table_block": list(neg),
                                         "zero_table_block": list(zero),
                                         "n_blocks": nblocks}))
                            break

        info["max_vmem_bytes"] = max_vmem
        info["vmem_budget"] = budget
        return findings, info
