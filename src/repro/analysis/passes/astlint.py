"""ast-lint: source-level hazards no jaxpr can show.

Two checks over the ``repro`` package sources (no imports executed —
pure ``ast`` parsing):

* **module-level ``jnp.*`` constants** — the PR 3 tracer-leak class:
  kernel/ops modules are imported *lazily*, sometimes inside an active
  jit trace, and a module-level ``jnp.float32(...)`` / ``jnp.asarray(...)``
  materialised under a trace captures a tracer in module state, poisoning
  every later call.  Module-level code must stay plain Python
  (``float("-inf")``, not ``jnp.float32(-jnp.inf)``).  Import-time
  execution includes class bodies and module-level ``if``/``try`` blocks,
  so those are scanned too; function bodies run at call time and are
  exempt.
* **mutable default arguments** — ``def f(x, acc=[])``: the default is
  evaluated once at import and shared across calls; with jax pytrees in
  play this aliases state across traces.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.analysis.core import (AnalysisPass, Finding, SEV_ERROR)

_FUNCTION_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _jnp_aliases(tree: ast.Module) -> set:
    """Names bound to jax.numpy in this module ('jnp' by convention)."""
    aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "jax.numpy":
                    aliases.add(a.asname or "jax")   # bare: used as
                    #                                  jax.numpy.<attr>
        elif isinstance(node, ast.ImportFrom):
            if node.module == "jax":
                for a in node.names:
                    if a.name == "numpy":
                        aliases.add(a.asname or "numpy")
    return aliases


def _attr_root(func: ast.expr) -> Optional[str]:
    """Root name of an attribute chain: jnp.float32 -> 'jnp';
    jax.numpy.asarray -> 'jax'."""
    while isinstance(func, ast.Attribute):
        func = func.value
    return func.id if isinstance(func, ast.Name) else None


def _import_time_stmts(body) -> Iterator[ast.stmt]:
    """Statements executed at import: module/class bodies and the bodies
    of module-level if/try/with/for — but never function bodies.  Only
    top-level statements are yielded; ``_calls_outside_functions`` walks
    their compound bodies (class/if/try/...) itself, stopping at
    function boundaries, so recursing here would double-count."""
    for stmt in body:
        if isinstance(stmt, _FUNCTION_NODES):
            continue
        yield stmt


def _calls_outside_functions(stmt: ast.stmt) -> Iterator[ast.Call]:
    """Call nodes in a statement, not descending into nested functions
    (their bodies execute at call time, not import time)."""
    stack = [stmt]
    while stack:
        node = stack.pop()
        if isinstance(node, _FUNCTION_NODES):
            continue
        if isinstance(node, ast.Call):
            yield node
        stack.extend(ast.iter_child_nodes(node))


class AstLintPass(AnalysisPass):
    name = "ast-lint"
    description = ("no module-level jnp.* constants (lazy-import tracer "
                   "leak) and no mutable default args in repro sources")
    scope = "global"
    requires_trace = False

    def __init__(self, roots: Optional[List[Path]] = None):
        if roots is None:
            import repro
            # repro may be a namespace package (__file__ is None)
            roots = [Path(next(iter(repro.__path__)))]
        self.roots = [Path(r) for r in roots]

    def lint_source(self, src: str, filename: str) -> List[Finding]:
        findings: List[Finding] = []
        tree = ast.parse(src, filename=filename)
        aliases = _jnp_aliases(tree)

        if aliases:
            for stmt in _import_time_stmts(tree.body):
                for call in _calls_outside_functions(stmt):
                    root = _attr_root(call.func)
                    if root not in aliases:
                        continue
                    # bare `import jax`: only jax.numpy.* chains count
                    if root == "jax" and not ast.unparse(
                            call.func).startswith("jax.numpy."):
                        continue
                    findings.append(Finding(
                        self.name, "<sources>", SEV_ERROR, "module-jnp-const",
                        f"{filename}:{call.lineno}: module-level "
                        f"'{ast.unparse(call.func)}(...)' — materialised at "
                        f"import; lazy import under an active trace leaks a "
                        f"tracer into module state (use a plain Python "
                        f"value)",
                        details={"file": filename, "line": call.lineno,
                                 "call": ast.unparse(call.func)}))

        for node in ast.walk(tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                mutable = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                    isinstance(d, ast.Call)
                    and isinstance(d.func, ast.Name)
                    and d.func.id in ("list", "dict", "set"))
                if mutable:
                    findings.append(Finding(
                        self.name, "<sources>", SEV_ERROR, "mutable-default",
                        f"{filename}:{d.lineno}: mutable default argument "
                        f"in '{node.name}' — evaluated once at import and "
                        f"shared across calls",
                        details={"file": filename, "line": d.lineno,
                                 "function": node.name}))
        return findings

    def run(self, entrypoint: str, built: Any, ctx: Any
            ) -> Tuple[List[Finding], Dict[str, Any]]:
        findings: List[Finding] = []
        n_files = 0
        for root in self.roots:
            for path in sorted(root.rglob("*.py")):
                n_files += 1
                rel = str(path)
                try:
                    findings.extend(self.lint_source(
                        path.read_text(), rel))
                except SyntaxError as e:
                    findings.append(Finding(
                        self.name, "<sources>", SEV_ERROR, "syntax-error",
                        f"{rel}: {e}", details={"file": rel}))
        return findings, {"n_files": n_files,
                          "roots": [str(r) for r in self.roots]}
