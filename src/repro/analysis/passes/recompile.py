"""recompile-hazard: every trace-static argument is drawn from a bounded
bucket set.

Each distinct trace-static value feeding a jit/AOT boundary keys a fresh
XLA compile — seconds of latency and a cache entry that lives forever.
The serving design bounds every such domain on purpose: request batch
sizes and client ks go through power-of-two padding buckets
(``MicroBatcher.bucket`` / ``RetrievalEngine.batch_k``), ladder rungs are
``lax.cond`` branches of ONE computation (never separate compiles), and
``n_groups`` is config-static.  An unbucketed client value — serving raw
``Request.k`` straight into ``jit(static_argnums=...)`` — would let
clients drive a recompile storm.

Entrypoints declare their trace-static surfaces as
:class:`~repro.analysis.entrypoints.StaticArgSpec`: a representative raw
sample, the *production* mapping onto the trace-static key, the allowed
key set, and a variant ceiling.  The pass pushes the sample through the
mapping and verifies the image stays inside ``allowed`` and under
``max_variants``.  Probing the real mapping (not a re-implementation)
means a regression in e.g. ``batch_k`` fails here immediately.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.core import (AnalysisPass, EntryContext, Finding,
                                 SEV_ERROR)


class RecompileHazardPass(AnalysisPass):
    name = "recompile-hazard"
    description = ("trace-static args feeding jit/AOT boundaries map into "
                   "bounded bucket sets (pow2 batch/k buckets, ladder "
                   "rungs, n_groups)")
    scope = "entrypoint"
    requires_trace = False   # operates on declared specs, not the jaxpr

    def run(self, entrypoint: str, built: Any, ctx: Optional[EntryContext]
            ) -> Tuple[List[Finding], Dict[str, Any]]:
        findings: List[Finding] = []
        info: Dict[str, Any] = {"n_specs": len(built.static_specs)}
        if not built.static_specs:
            info["note"] = ("no client-facing trace-static arguments "
                            "declared (fixed-shape trace entrypoint)")
            return findings, info

        for spec in built.static_specs:
            image = {spec.mapper(v) for v in spec.sample}
            info[f"{spec.name}_variants"] = len(image)
            if len(image) > spec.max_variants:
                findings.append(Finding(
                    self.name, entrypoint, SEV_ERROR, "unbounded-static-arg",
                    f"static arg '{spec.name}': {len(spec.sample)} client "
                    f"values map to {len(image)} trace-static variants "
                    f"(ceiling {spec.max_variants}) — unbounded client "
                    f"values can key unbounded compiles",
                    details={"spec": spec.name,
                             "n_sample": len(spec.sample),
                             "n_variants": len(image),
                             "max_variants": spec.max_variants,
                             "variants": sorted(image)[:32]}))
            if spec.allowed is not None:
                stray = image - set(spec.allowed)
                if stray:
                    findings.append(Finding(
                        self.name, entrypoint, SEV_ERROR, "out-of-bucket",
                        f"static arg '{spec.name}': values {sorted(stray)[:8]} "
                        f"escape the allowed bucket set",
                        details={"spec": spec.name,
                                 "stray": sorted(stray)[:32],
                                 "allowed": sorted(spec.allowed)[:32]}))
        return findings, info
