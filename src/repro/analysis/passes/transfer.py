"""host-transfer: no callbacks or host round-trips in serve-path jaxprs.

The runtime ``jax.transfer_guard("disallow")`` is the dynamic defence
against device<->host syncs — but it is blind on the CPU backend, where
D2H is zero-copy and unguarded, which is exactly where CI runs.  This
pass is the static complement:

* **callback primitives** — ``pure_callback`` / ``io_callback`` /
  ``debug_callback`` (and the legacy host_callback spellings) anywhere in
  the nested jaxpr mean the compiled computation re-enters Python per
  dispatch: a synchronisation point and a TPU-incompatibility on the
  serve path.  Flagged wherever they hide (cond branches, scan bodies,
  shard_map bodies).
* **host-constant round-trips** — large raw ``np.ndarray`` consts closed
  over by the jaxpr are re-uploaded host->device copies baked into the
  trace.  Device-resident ``jax.Array`` params (the normal closure
  pattern for model weights) are NOT flagged — only plain numpy buffers
  above a size threshold, which indicate catalogue-sized data taking the
  host path on every trace.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.core import (AnalysisPass, EntryContext, Finding,
                                 SEV_ERROR, iter_eqns)

CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call",
})

#: raw-numpy consts smaller than this ride the trace for free (scalars,
#: tiny index vectors); bigger ones are a per-trace host->device upload.
DEFAULT_CONST_BYTES_LIMIT = 1 << 20


class HostTransferPass(AnalysisPass):
    name = "host-transfer"
    description = ("no callback primitives and no oversized raw-numpy "
                   "host constants anywhere in the serve-path jaxpr")
    scope = "entrypoint"
    requires_trace = True

    def __init__(self, const_bytes_limit: int = DEFAULT_CONST_BYTES_LIMIT):
        self.const_bytes_limit = const_bytes_limit

    def run(self, entrypoint: str, built: Any, ctx: Optional[EntryContext]
            ) -> Tuple[List[Finding], Dict[str, Any]]:
        findings: List[Finding] = []
        jaxpr = ctx.trace()

        n_callbacks = 0
        for eqn, path in iter_eqns(jaxpr):
            if eqn.primitive.name in CALLBACK_PRIMITIVES:
                n_callbacks += 1
                cb = eqn.params.get("callback")
                findings.append(Finding(
                    self.name, entrypoint, SEV_ERROR, "host-callback",
                    f"'{eqn.primitive.name}' primitive on the serve path "
                    f"(at {'/'.join(path) or '<top>'}): compiled dispatch "
                    f"re-enters Python per batch",
                    details={"primitive": eqn.primitive.name,
                             "path": list(path),
                             "callback": repr(cb) if cb is not None
                             else None}))

        n_big_consts = 0
        for c in jaxpr.consts:
            if isinstance(c, np.ndarray) and c.nbytes > self.const_bytes_limit:
                n_big_consts += 1
                findings.append(Finding(
                    self.name, entrypoint, SEV_ERROR, "host-constant",
                    f"raw numpy const of {c.nbytes} bytes "
                    f"(shape {c.shape}, {c.dtype}) closed over by the "
                    f"trace: host->device round-trip on every dispatch — "
                    f"move it to device (jnp.asarray at build time)",
                    details={"nbytes": int(c.nbytes),
                             "shape": list(c.shape),
                             "dtype": str(c.dtype)}))

        return findings, {"n_callbacks": n_callbacks,
                          "n_big_host_consts": n_big_consts,
                          "n_consts": len(jaxpr.consts)}
