"""dispatch-count: prove each serve entrypoint is ONE compiled dispatch.

The paper's core efficiency claim (PAPER.md §3) is that PQTopK removes
RecJPQ's per-item host accumulators so the whole serve path becomes a
single fused device computation.  Statically, that is exactly
"the entrypoint traces into one closed jaxpr": any host orchestration —
the PR 2 ``np.nonzero`` compaction, a Python loop over tiles, a
``float(x)`` sync — blows up tracing with a concretization error, because
the value it needs does not exist until the device runs.  The nested
``lax.cond`` ladder, the grouped route's bucketing scan / argsort /
2D compaction, and ``shard_map`` bodies all live *inside* that one jaxpr,
so they are covered by construction.

For engine entries the entrypoint additionally supplies a runtime
``dispatch_counter`` (wrap every memoised AOT variant in a counter, serve
one guarded batch) — the dynamic complement proving the engine fires
exactly one compiled call per batch.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.core import (AnalysisPass, EntryContext, Finding,
                                 SEV_ERROR, count_primitives)


class DispatchCountPass(AnalysisPass):
    name = "dispatch-count"
    description = ("entrypoint traces into a single closed jaxpr (one "
                   "compiled dispatch); engine entries also count runtime "
                   "dispatches per served batch")
    scope = "entrypoint"
    requires_trace = False   # a trace failure IS this pass's finding

    def run(self, entrypoint: str, built: Any, ctx: Optional[EntryContext]
            ) -> Tuple[List[Finding], Dict[str, Any]]:
        findings: List[Finding] = []
        info: Dict[str, Any] = {}
        jaxpr = ctx.trace()
        if jaxpr is None:
            tf = ctx.trace_failure
            findings.append(Finding(
                self.name, entrypoint, SEV_ERROR, "trace-failure",
                f"entrypoint does not trace into one jaxpr "
                f"({tf.exc_type}): host orchestration on the serve path",
                details={"exc_type": tf.exc_type,
                         "message": tf.message[:500]}))
            return findings, info

        prims = count_primitives(jaxpr)
        info["n_eqns_top"] = len(jaxpr.jaxpr.eqns)
        info["n_eqns_total"] = sum(prims.values())
        info["cond_count"] = prims.get("cond", 0)
        info["scan_count"] = prims.get("scan", 0)
        info["pallas_calls"] = prims.get("pallas_call", 0)

        if built.dispatch_counter is not None:
            n = built.dispatch_counter()
            info["runtime_dispatches"] = n
            if n != 1:
                findings.append(Finding(
                    self.name, entrypoint, SEV_ERROR, "multi-dispatch",
                    f"engine issued {n} compiled dispatches per query "
                    f"batch (expected exactly 1)",
                    details={"dispatches": n}))
        return findings, info
