"""The built-in analysis passes (docs/ANALYSIS.md has the catalogue)."""
from repro.analysis.passes.dispatch import DispatchCountPass
from repro.analysis.passes.transfer import HostTransferPass
from repro.analysis.passes.recompile import RecompileHazardPass
from repro.analysis.passes.pallas import PallasContractPass
from repro.analysis.passes.astlint import AstLintPass

__all__ = ["DispatchCountPass", "HostTransferPass", "RecompileHazardPass",
           "PallasContractPass", "AstLintPass", "default_passes"]


def default_passes():
    """The standard pass list the CLI (and ci.sh) runs."""
    return [DispatchCountPass(), HostTransferPass(), RecompileHazardPass(),
            PallasContractPass(), AstLintPass()]
