"""Core of the serve-path static-analysis framework (docs/ANALYSIS.md).

The repo's efficiency claims rest on invariants that are *structural*
properties of the traced computation — one fused dispatch per batch, no
host callbacks on the serve path, bounded trace-static argument domains,
Pallas kernel contracts (VMEM budgets, tiling, the ``-1`` sentinel
index-map clamp).  This module provides the machinery to check them
statically, on every registered entrypoint, in CI:

* :class:`Finding` / :class:`PassResult` / :class:`Report` — machine-
  readable results (the CLI renders a table and a JSON document).
* a jaxpr walker (:func:`iter_eqns`, :func:`find_eqns`,
  :func:`count_primitives`) that descends into every nested jaxpr —
  ``pjit`` bodies, ``cond`` branches, ``scan``/``while`` bodies, Pallas
  kernel jaxprs — so a pass sees the whole computation, not just the top
  level.
* :class:`EntryContext` — traces an entrypoint to its closed jaxpr once
  and caches the result (or the trace failure) for every pass.
* :class:`AnalysisPass` — the pass protocol; :func:`run_analysis` drives
  a pass list over an entrypoint dict and assembles the report.

Passes live in :mod:`repro.analysis.passes`; the entrypoint registry in
:mod:`repro.analysis.entrypoints`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, Iterator, List, Mapping, \
    Optional, Sequence, Tuple

SEV_ERROR = "error"   # CI-gating: the invariant is violated
SEV_INFO = "info"     # observations that never gate

STATUS_PASS = "pass"
STATUS_FAIL = "fail"
STATUS_SKIP = "skip"  # prerequisite missing (e.g. no jaxpr to walk)


@dataclass
class Finding:
    """One violation (or observation) from one pass on one entrypoint."""

    pass_name: str
    entrypoint: str
    severity: str              # SEV_ERROR | SEV_INFO
    code: str                  # stable machine-readable class, e.g.
                               # "host-callback", "sentinel-clamp"
    message: str               # human-readable one-liner
    details: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return {"pass": self.pass_name, "entrypoint": self.entrypoint,
                "severity": self.severity, "code": self.code,
                "message": self.message, "details": _jsonable(self.details)}


def _jsonable(obj: Any) -> Any:
    """Best-effort conversion of finding details to JSON-safe values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    if hasattr(obj, "item"):          # numpy / jax scalars
        try:
            return obj.item()
        except Exception:
            pass
    return repr(obj)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------

def subjaxprs(eqn) -> Iterator[Any]:
    """Yield every jaxpr nested in one equation's params (``pjit`` bodies,
    ``cond`` branches, ``scan``/``while`` bodies, the Pallas kernel jaxpr,
    custom-derivative subcomputations, ...).  Works on raw ``Jaxpr`` and
    ``ClosedJaxpr`` params alike — callers get the raw jaxpr."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            inner = getattr(v, "jaxpr", None)
            if inner is not None and hasattr(inner, "eqns"):
                yield inner           # ClosedJaxpr -> its raw jaxpr
            elif hasattr(v, "eqns"):
                yield v               # raw Jaxpr param (pallas_call)


def iter_eqns(jaxpr, path: Tuple[str, ...] = ()) -> Iterator[Tuple[Any, Tuple[str, ...]]]:
    """Depth-first iteration over every equation of ``jaxpr`` and all its
    nested jaxprs.  Yields ``(eqn, path)`` where ``path`` is the tuple of
    enclosing primitive names (outermost first) — enough to tell a
    top-level callback from one buried in a ``cond`` branch."""
    raw = getattr(jaxpr, "jaxpr", jaxpr)   # accept ClosedJaxpr too
    for eqn in raw.eqns:
        yield eqn, path
        for sub in subjaxprs(eqn):
            yield from iter_eqns(sub, path + (eqn.primitive.name,))


def find_eqns(jaxpr, names: Iterable[str]) -> List[Tuple[Any, Tuple[str, ...]]]:
    """All ``(eqn, path)`` whose primitive name is in ``names``."""
    names = frozenset(names)
    return [(eqn, path) for eqn, path in iter_eqns(jaxpr)
            if eqn.primitive.name in names]


def count_primitives(jaxpr) -> Dict[str, int]:
    """Primitive-name histogram over the whole (nested) jaxpr."""
    counts: Dict[str, int] = {}
    for eqn, _ in iter_eqns(jaxpr):
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
    return counts


# ---------------------------------------------------------------------------
# entry context: one trace, shared by every pass
# ---------------------------------------------------------------------------

@dataclass
class TraceFailure:
    exc_type: str
    message: str


class EntryContext:
    """Caches the entrypoint's closed jaxpr (or its trace failure).

    Tracing is the expensive, shared prerequisite of most passes; doing it
    once per entrypoint also guarantees every pass reasons about the SAME
    computation.  A trace failure is itself a first-class result — the
    dispatch-count pass turns it into a finding (host orchestration on the
    serve path cannot trace), while jaxpr-dependent passes report
    ``skip`` so a single root cause never multi-counts across passes.
    """

    def __init__(self, name: str, built: "Any"):
        self.name = name
        self.built = built
        self._jaxpr: Optional[Any] = None
        self.trace_failure: Optional[TraceFailure] = None
        self._traced = False

    def trace(self) -> Optional[Any]:
        """The entrypoint's ClosedJaxpr, or None (see ``trace_failure``)."""
        if not self._traced:
            self._traced = True
            import jax
            try:
                self._jaxpr = jax.make_jaxpr(self.built.fn)(*self.built.args)
            except Exception as e:  # noqa: BLE001 — the failure IS the result
                self.trace_failure = TraceFailure(type(e).__name__, str(e))
        return self._jaxpr


# ---------------------------------------------------------------------------
# pass protocol + runner
# ---------------------------------------------------------------------------

class AnalysisPass:
    """Base class for analysis passes.

    ``scope`` is ``"entrypoint"`` (run once per registered entrypoint) or
    ``"global"`` (run once per analysis, e.g. the AST lint over source
    files).  ``requires_trace`` makes the runner skip the pass — with
    ``STATUS_SKIP``, not a failure — when the entrypoint did not trace;
    set it False for passes that handle trace failures themselves.
    """

    name: str = "abstract"
    description: str = ""
    scope: str = "entrypoint"
    requires_trace: bool = True

    def run(self, entrypoint: str, built: Any, ctx: Optional[EntryContext]
            ) -> Tuple[List[Finding], Dict[str, Any]]:
        raise NotImplementedError


@dataclass
class PassResult:
    entrypoint: str
    pass_name: str
    status: str
    findings: List[Finding] = field(default_factory=list)
    info: Dict[str, Any] = field(default_factory=dict)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEV_ERROR]

    def to_json(self) -> Dict[str, Any]:
        return {"entrypoint": self.entrypoint, "pass": self.pass_name,
                "status": self.status,
                "findings": [f.to_json() for f in self.findings],
                "info": _jsonable(self.info)}


@dataclass
class Report:
    results: List[PassResult]
    meta: Dict[str, Any] = field(default_factory=dict)

    @property
    def errors(self) -> List[Finding]:
        return [f for r in self.results for f in r.errors]

    @property
    def ok(self) -> bool:
        return not self.errors

    def result(self, entrypoint: str, pass_name: str) -> Optional[PassResult]:
        for r in self.results:
            if r.entrypoint == entrypoint and r.pass_name == pass_name:
                return r
        return None

    def failing_passes(self, entrypoint: str) -> List[str]:
        """Names of the passes that FAILED for one entrypoint (skips are
        not failures) — what the adversarial negative-control tests assert
        on ("fails its pass, and only its pass")."""
        return [r.pass_name for r in self.results
                if r.entrypoint == entrypoint and r.status == STATUS_FAIL]

    def to_json(self) -> Dict[str, Any]:
        return {"ok": self.ok,
                "n_errors": len(self.errors),
                "meta": _jsonable(self.meta),
                "results": [r.to_json() for r in self.results]}

    def render(self) -> str:
        """Human-readable fixed-width table + finding detail lines."""
        rows = [("entrypoint", "pass", "status", "errors", "info")]
        for r in self.results:
            info = ",".join(f"{k}={v}" for k, v in sorted(r.info.items())
                            if isinstance(v, (int, float, str, bool)))
            rows.append((r.entrypoint, r.pass_name, r.status.upper(),
                         str(len(r.errors)), info[:60]))
        widths = [max(len(row[i]) for row in rows) for i in range(4)]
        lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths)) + "  "
                 + row[4] for row in rows]
        for f in self.errors:
            lines.append(f"FINDING [{f.code}] {f.entrypoint}/{f.pass_name}: "
                         f"{f.message}")
        lines.append(f"{'OK' if self.ok else 'FAIL'}: "
                     f"{len(self.results)} (entrypoint, pass) cells, "
                     f"{len(self.errors)} error finding(s)")
        return "\n".join(lines)


def run_analysis(entrypoints: Mapping[str, Any],
                 passes: Sequence[AnalysisPass],
                 build: Callable[[str], Any]) -> Report:
    """Run ``passes`` over ``entrypoints`` (name -> Entrypoint) and return
    the full report.  ``build(name)`` materialises an entrypoint into a
    BuiltEntry (see :mod:`repro.analysis.entrypoints`); build failures are
    reported as failures of every pass on that entrypoint rather than
    aborting the whole analysis.
    """
    import jax

    results: List[PassResult] = []
    entry_passes = [p for p in passes if p.scope == "entrypoint"]
    global_passes = [p for p in passes if p.scope == "global"]

    for name in entrypoints:
        try:
            built = build(name)
        except Exception as e:  # noqa: BLE001 — report, don't abort
            for p in entry_passes:
                results.append(PassResult(name, p.name, STATUS_FAIL, [
                    Finding(p.name, name, SEV_ERROR, "build-failure",
                            f"entrypoint failed to build: "
                            f"{type(e).__name__}: {e}")]))
            continue
        ctx = EntryContext(name, built)
        for p in entry_passes:
            if p.requires_trace and ctx.trace() is None:
                results.append(PassResult(
                    name, p.name, STATUS_SKIP,
                    info={"reason": "entrypoint did not trace",
                          "trace_error": ctx.trace_failure.exc_type
                          if ctx.trace_failure else None}))
                continue
            try:
                findings, info = p.run(name, built, ctx)
            except Exception as e:  # noqa: BLE001 — a crashing pass is a fail
                findings, info = [Finding(
                    p.name, name, SEV_ERROR, "pass-crash",
                    f"pass raised {type(e).__name__}: {e}")], {}
            status = (STATUS_FAIL
                      if any(f.severity == SEV_ERROR for f in findings)
                      else STATUS_PASS)
            results.append(PassResult(name, p.name, status, findings, info))

    for p in global_passes:
        try:
            findings, info = p.run("<sources>", None, None)
        except Exception as e:  # noqa: BLE001
            findings, info = [Finding(p.name, "<sources>", SEV_ERROR,
                                      "pass-crash",
                                      f"pass raised {type(e).__name__}: {e}"
                                      )], {}
        status = (STATUS_FAIL if any(f.severity == SEV_ERROR
                                     for f in findings) else STATUS_PASS)
        results.append(PassResult("<sources>", p.name, status, findings,
                                  info))

    return Report(results, meta={"jax": jax.__version__,
                                 "backend": jax.default_backend(),
                                 "n_entrypoints": len(entrypoints),
                                 "passes": [p.name for p in passes]})
