"""Serve-path static analysis: jaxpr-level passes over registered
entrypoints (docs/ANALYSIS.md).

Quick use::

    python -m repro.analysis                 # all passes, all entrypoints
    python -m repro.analysis --list          # what would run
    python -m repro.analysis -e flat_pruned --json report.json

Programmatic::

    from repro.analysis import run_default
    report = run_default(entrypoints=["flat_pruned"])
    assert report.ok, report.render()
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.analysis.core import (AnalysisPass, EntryContext, Finding,
                                 PassResult, Report, SEV_ERROR, SEV_INFO,
                                 STATUS_FAIL, STATUS_PASS, STATUS_SKIP,
                                 count_primitives, find_eqns, iter_eqns,
                                 run_analysis)

__all__ = ["AnalysisPass", "EntryContext", "Finding", "PassResult",
           "Report", "SEV_ERROR", "SEV_INFO", "STATUS_FAIL", "STATUS_PASS",
           "STATUS_SKIP", "count_primitives", "find_eqns", "iter_eqns",
           "run_analysis", "run_default"]


def run_default(entrypoints: Optional[Sequence[str]] = None,
                passes: Optional[Sequence[str]] = None) -> Report:
    """Run the default pass list over the registry (optionally filtered by
    entrypoint / pass name)."""
    from repro.analysis import entrypoints as ep
    from repro.analysis.passes import default_passes

    names = list(entrypoints) if entrypoints else list(ep.REGISTRY)
    unknown = [n for n in names if n not in ep.REGISTRY]
    if unknown:
        raise KeyError(f"unknown entrypoint(s) {unknown}; registered: "
                       f"{sorted(ep.REGISTRY)}")
    plist = default_passes()
    if passes:
        unknown_p = [p for p in passes
                     if p not in {x.name for x in plist}]
        if unknown_p:
            raise KeyError(f"unknown pass(es) {unknown_p}; available: "
                           f"{sorted(x.name for x in plist)}")
        plist = [x for x in plist if x.name in set(passes)]
    return run_analysis({n: ep.REGISTRY[n] for n in names}, plist, ep.build)
