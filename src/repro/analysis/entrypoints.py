"""Entrypoint registry for the serve-path static analysis.

An :class:`Entrypoint` names one serving computation worth guarding and
knows how to build it into a :class:`BuiltEntry`: a callable plus abstract
(or concrete) arguments that :func:`jax.make_jaxpr` can trace, together
with the pass-facing contracts — trace-static argument specs for the
recompile-hazard pass, the VMEM budget and expected kernel count for the
Pallas contract pass, and (for engine entries) a runtime dispatch counter.

The registry covers every serving route the repo ships (ISSUE 6 / the
check_single_dispatch lineage):

* ``flat_fused``          — serve_topk via the fused Pallas score+top-k
                            kernel (``pqtopk_fused``)
* ``flat_pruned``         — the single-dispatch in-graph pruned cascade
                            with a slot-budget ladder (nested ``lax.cond``)
* ``grouped_perquery``    — the per-query grouped cascade (bucketing scan,
                            argsort permutation, 2D compaction)
* ``sharded_pruned``      — the item-sharded cascade under ``shard_map``
* ``lm_decode_step``      — the PQ-head pruned cascade inside one LM
                            decode step (stacked-cache scan backbone)
* ``pruned_tiles_kernel`` — the scalar-prefetch Pallas kernel on a 1D
                            ``-1``-padded compacted tile list (interpret
                            mode, so the contract pass sees the real
                            ``pallas_call`` params on CPU CI)
* ``grouped_tiles_kernel``— same kernel with the grouped 2D (batch-tile,
                            slot) table
* ``engine_aot``          — a calibrated RetrievalEngine on the pruned
                            route (AOT-compiled variants, runtime dispatch
                            counting)
* ``engine_aot_grouped``  — the engine on the grouped route
* ``flat_tombstone``      — the pruned cascade over a mutated catalogue:
                            tombstone mask + stale-but-dominating bounds
                            threaded as data through ONE dispatch
* ``tombstone_tiles_kernel`` — the compacted-tile kernel with the live
                            block riding the same clamped sentinel index
                            map as the codes
* ``engine_mutable``      — the hot-swap engine: mutate + swap_head_state
                            between batches, then prove the served batch
                            is still ONE dispatch with ZERO new compiles
* ``router_replicated``   — the replicated fabric: K health-checked
                            replicas behind one submit/drain — a
                            healthy-path batch is ONE compiled dispatch
                            on exactly one replica, and replica id never
                            keys a compile

Builds are cached (`build()`), and the heavyweight shared fixtures
(catalogue params) are built once and reused across entries.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

# NOTE: jax and the repro model stack are imported lazily inside builders
# so `import repro.analysis` stays cheap (and so the AST lint below can
# hold this module to its own no-module-level-jnp-constant rule).

DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024   # bytes; ~half a TPU core's VMEM,
                                        # leaving headroom for scratch and
                                        # the compiler's own buffers


@dataclass(frozen=True)
class StaticArgSpec:
    """One trace-static argument feeding a jit/AOT boundary.

    ``sample`` is a representative set of raw client-side values;
    ``mapper`` is the *real* production mapping from client value to the
    trace-static key (e.g. ``RetrievalEngine.batch_k``).  The recompile
    pass asserts ``{mapper(v) for v in sample}`` stays within ``allowed``
    (when given) and under ``max_variants`` — so unbounded client values
    can never key unbounded compiles.
    """

    name: str
    sample: Tuple[Any, ...]
    mapper: Callable[[Any], Any]
    max_variants: int
    allowed: Optional[frozenset] = None
    note: str = ""


@dataclass
class BuiltEntry:
    """A materialised entrypoint, ready for the passes."""

    fn: Callable                      # traced by jax.make_jaxpr(fn)(*args)
    args: Tuple[Any, ...]             # ShapeDtypeStructs or arrays
    static_specs: Tuple[StaticArgSpec, ...] = ()
    vmem_budget: int = DEFAULT_VMEM_BUDGET
    expect_pallas: int = 0            # min pallas_call count in the trace
    dispatch_counter: Optional[Callable[[], int]] = None
    notes: str = ""


@dataclass(frozen=True)
class Entrypoint:
    name: str
    description: str
    build: Callable[[], BuiltEntry]
    tags: Tuple[str, ...] = ()


REGISTRY: Dict[str, Entrypoint] = {}


def register(name: str, description: str, tags: Tuple[str, ...] = ()):
    def deco(fn):
        REGISTRY[name] = Entrypoint(name, description, fn, tags)
        return fn
    return deco


@functools.lru_cache(maxsize=None)
def build(name: str) -> BuiltEntry:
    return REGISTRY[name].build()


# ---------------------------------------------------------------------------
# shared fixtures
# ---------------------------------------------------------------------------

SEQREC_N_ITEMS = 16384      # several pruning tiles at DEFAULT_PRUNE_TILE
STATIC_LADDER = (2, 4)      # multi-rung (normalised ladder appends the
                            # exhaustive rung) without calibration cost


@functools.lru_cache(maxsize=None)
def _seqrec_setup():
    """Reduced sasrec-recjpq scaled to a multi-tile catalogue with
    position-clustered codes — the same fixture the dispatch guard script
    has always used: clustering gives tiles genuinely distinct bounds, so
    pruning (and ladder calibration, for the engine entries) is real."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from dataclasses import replace
    from repro.configs import get_reduced
    from repro.models import seqrec as seqrec_lib

    cfg = replace(get_reduced("sasrec-recjpq").model, n_items=SEQREC_N_ITEMS)
    rng0 = np.random.default_rng(7)
    centers = (np.arange(cfg.n_items + 1) / (cfg.n_items + 1)
               * cfg.pq.b).astype(np.int64)
    codes = jnp.asarray(
        (centers[:, None] + rng0.integers(-1, 2, (cfg.n_items + 1,
                                                  cfg.pq.m))) % cfg.pq.b,
        jnp.int32)
    params = seqrec_lib.init_seqrec(jax.random.PRNGKey(0), cfg, codes=codes)
    return params, cfg


def _seq_sds(cfg, batch: int = 4):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct((batch, cfg.max_seq_len), jnp.int32)


def _serve_entry(method: str, *, grouped: bool = False, mesh=None,
                 ladder=None, return_rung: bool = False,
                 expect_pallas: int = 0, k: int = 5) -> BuiltEntry:
    from dataclasses import replace
    from repro.models import seqrec as seqrec_lib

    params, cfg = _seqrec_setup()
    if grouped:
        cfg = replace(cfg, pq=replace(cfg.pq, query_grouping=True,
                                      n_groups=4))

    def fn(seqs):
        return seqrec_lib.serve_topk(params, seqs, cfg, k=k, method=method,
                                     sharded_mesh=mesh, ladder=ladder,
                                     return_rung=return_rung)

    return BuiltEntry(fn, (_seq_sds(cfg),), expect_pallas=expect_pallas,
                      notes=f"serve_topk method={method!r} "
                            f"n_items={cfg.n_items} grouped={grouped} "
                            f"sharded={mesh is not None}")


# ---------------------------------------------------------------------------
# serve_topk routes
# ---------------------------------------------------------------------------

@register("flat_fused",
          "serve_topk through the fused Pallas score+top-k kernel "
          "(method='pqtopk_fused') — backbone, subid scores and the "
          "batch-tiled kernel grid in one trace",
          tags=("serve", "kernel"))
def _build_flat_fused() -> BuiltEntry:
    return _serve_entry("pqtopk_fused", expect_pallas=1)


@register("flat_pruned",
          "the single-dispatch in-graph pruned cascade with a multi-rung "
          "slot-budget ladder (nested lax.cond chain) and rung telemetry",
          tags=("serve", "pruned"))
def _build_flat_pruned() -> BuiltEntry:
    return _serve_entry("pqtopk_pruned", ladder=STATIC_LADDER,
                        return_rung=True)


@register("grouped_perquery",
          "the per-query grouped cascade: theta per query, overlap-"
          "bucketing scan, stable-argsort permutation and the 2D "
          "(group, slot) compaction, all in one trace",
          tags=("serve", "pruned", "grouped"))
def _build_grouped_perquery() -> BuiltEntry:
    return _serve_entry("pqtopk_pruned", grouped=True, ladder=STATIC_LADDER,
                        return_rung=True)


@register("sharded_pruned",
          "the item-sharded pruned cascade under shard_map (shard-local "
          "cascade + O(k x shards) merge)",
          tags=("serve", "pruned", "sharded"))
def _build_sharded_pruned() -> BuiltEntry:
    import jax
    from repro.core import retrieval_head

    params, cfg = _seqrec_setup()
    mesh = jax.make_mesh((1,), ("model",))
    params = {**params, "item_emb":
              retrieval_head.ensure_sharded_pruned_state(
                  params["item_emb"], mesh, k_hint=5)}
    from repro.models import seqrec as seqrec_lib

    def fn(seqs):
        return seqrec_lib.serve_topk(params, seqs, cfg, k=5,
                                     method="pqtopk_pruned",
                                     sharded_mesh=mesh)

    return BuiltEntry(fn, (_seq_sds(cfg),),
                      notes="sharded serve_topk, 1-device 'model' mesh")


@register("flat_hier",
          "the hierarchical two-stage cascade: super-tile pass-0 pruning "
          "+ theta seeded from super bounds + two-stage compaction, still "
          "ONE dispatch (nested super-rung/child-rung lax.cond chains)",
          tags=("serve", "pruned", "hier"))
def _build_flat_hier() -> BuiltEntry:
    from repro.core import pruning
    from repro.models import seqrec as seqrec_lib

    params, cfg = _seqrec_setup()
    head = dict(params["item_emb"])
    head["pruned"] = pruning.with_super(head["pruned"], 4)
    params = {**params, "item_emb": head}

    def fn(seqs):
        return seqrec_lib.serve_topk(params, seqs, cfg, k=5,
                                     method="pqtopk_pruned",
                                     ladder=STATIC_LADDER)

    return BuiltEntry(fn, (_seq_sds(cfg),),
                      notes=f"hierarchical serve_topk, super_factor=4, "
                            f"n_super={head['pruned'].n_super}")


@register("sharded_hier",
          "the item-sharded hierarchical cascade: per-shard super-tile "
          "pass-0 behind the shard-local skip cond (collectives outside), "
          "ONE shard_map",
          tags=("serve", "pruned", "sharded", "hier"))
def _build_sharded_hier() -> BuiltEntry:
    import jax
    from repro.core import retrieval_head
    from repro.models import seqrec as seqrec_lib

    params, cfg = _seqrec_setup()
    mesh = jax.make_mesh((1,), ("model",))
    params = {**params, "item_emb":
              retrieval_head.ensure_sharded_pruned_state(
                  params["item_emb"], mesh, k_hint=5, super_factor=4)}

    def fn(seqs):
        return seqrec_lib.serve_topk(params, seqs, cfg, k=5,
                                     method="pqtopk_pruned",
                                     sharded_mesh=mesh)

    return BuiltEntry(fn, (_seq_sds(cfg),),
                      notes="sharded hierarchical serve_topk, "
                            "super_factor=4, 1-device 'model' mesh")


@register("lm_decode_step",
          "one LM decode step (stacked-cache layer scan) with the pruned "
          "PQ vocabulary head — the cascade inside the decode loop",
          tags=("decode", "pruned"))
def _build_lm_decode() -> BuiltEntry:
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_reduced
    from repro.models import transformer as T

    cfg = get_reduced("qwen2.5-14b").model
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    caches = T.init_caches(cfg, 2, 16, abstract=True)
    tok = jax.ShapeDtypeStruct((2,), jnp.int32)

    def fn(token, c):
        return T.lm_decode_step(params, token, jnp.int32(0), c, cfg, k=8,
                                head_method="pqtopk_pruned")

    return BuiltEntry(fn, (tok, caches),
                      notes=f"qwen2.5-14b reduced, vocab={cfg.vocab}, "
                            f"head_method='pqtopk_pruned'")


# ---------------------------------------------------------------------------
# direct Pallas kernel routes (interpret mode: the kernel grid, block
# specs and scalar-prefetch index maps are in the trace on CPU CI too)
# ---------------------------------------------------------------------------

def _kernel_fixture(n: int = 1024, m: int = 8, b: int = 16, bq: int = 16):
    import jax
    import jax.numpy as jnp
    codes = jax.ShapeDtypeStruct((n, m), jnp.int8)
    s = jax.ShapeDtypeStruct((bq, m, b), jnp.float32)
    return codes, s


@register("pruned_tiles_kernel",
          "pq_topk_tiles forced onto the scalar-prefetch Pallas kernel "
          "(interpret mode) with a 1D -1-padded compacted tile list — "
          "the sentinel index-map clamp contract surface",
          tags=("kernel",))
def _build_pruned_tiles_kernel() -> BuiltEntry:
    import jax.numpy as jnp
    from repro.kernels.pqtopk import ops

    codes, s = _kernel_fixture()
    tile_idx = jnp.asarray([0, -1], jnp.int32)   # one live slot + sentinel

    def fn(c, sc):
        return ops.pq_topk_tiles(c, sc, 8, tile_idx, tile=512,
                                 use_kernel=True, interpret=True)

    return BuiltEntry(fn, (codes, s), expect_pallas=1,
                      notes="1D compacted slots, int8 codes, tile=512")


@register("grouped_tiles_kernel",
          "the grouped kernel grid: 2D (batch-tile, slot) table, each "
          "kernel batch tile scoring its own -1-padded slot row",
          tags=("kernel", "grouped"))
def _build_grouped_tiles_kernel() -> BuiltEntry:
    import jax.numpy as jnp
    from repro.kernels.pqtopk import ops

    codes, s = _kernel_fixture()
    tile_idx = jnp.asarray([[0, 1], [1, -1]], jnp.int32)

    def fn(c, sc):
        return ops.pq_topk_tiles(c, sc, 8, tile_idx, tile=512,
                                 batch_tile=8, use_kernel=True,
                                 interpret=True)

    return BuiltEntry(fn, (codes, s), expect_pallas=1,
                      notes="2D grouped slots, batch_tile=8")


# ---------------------------------------------------------------------------
# engine AOT variants (runtime dispatch counting + recompile-key specs)
# ---------------------------------------------------------------------------

def _pow2_buckets(limit: int) -> frozenset:
    out, b = set(), 1
    while b < limit:
        out.add(b)
        b *= 2
    out.add(limit)
    return frozenset(out)


def _count_engine_dispatches(eng, cfg, k: int, base_id: int) -> int:
    """Warm the engine's compile cache, then wrap every memoised compiled
    variant in a counter and serve one guarded batch: the number of
    entries that fire is the per-batch dispatch count.  Runs under
    ``jax.transfer_guard("disallow")`` (additionally catches implicit D2H
    syncs on accelerator backends; on CPU D2H is zero-copy and unguarded,
    so the trace check is the load-bearing one there)."""
    import jax
    import numpy as np
    from repro.serving.engine import Request

    rng = np.random.default_rng(base_id)
    for i in range(4):
        eng.submit(Request(base_id + i,
                           rng.integers(1, cfg.n_items + 1, 8), k=k))
    eng.drain()                                   # warm outside the guard
    calls = []
    for key, f in list(eng._compiled.items()):
        eng._compiled[key] = (
            lambda seqs, _f=f, _key=key: (calls.append(_key), _f(seqs))[1])
    for i in range(4):
        eng.submit(Request(base_id + 10 + i,
                           rng.integers(1, cfg.n_items + 1, 8), k=k))
    with jax.transfer_guard("disallow"):
        results = eng.run_once()
    assert len(results) == 4, f"served {len(results)}/4"
    return len(calls)


def _engine_entry(*, grouped: bool, base_id: int) -> BuiltEntry:
    from dataclasses import replace
    from repro.serving.engine import MicroBatcher, RetrievalEngine

    params, cfg = _seqrec_setup()
    if grouped:
        cfg = replace(cfg, pq=replace(cfg.pq, query_grouping=True,
                                      n_groups=4))
    k, max_batch = 5, 8
    eng = RetrievalEngine.for_seqrec(params, cfg, k=k, max_batch=max_batch,
                                     method="pqtopk_pruned")
    assert eng._jit_serve, "pruned route must be a jitted serve fn"
    # The calibrated ladder must be active: the single-dispatch guarantee
    # has to hold WITH the nested lax.cond rung chain in the trace.
    assert eng.ladder is not None and len(eng.ladder) >= 2, (
        f"expected a calibrated multi-rung ladder, got {eng.ladder!r}")

    import jax
    import jax.numpy as jnp
    sds = jax.ShapeDtypeStruct((4, cfg.max_seq_len), jnp.int32)

    # Every trace-static value that keys a compiled variant, probed
    # through the REAL production mappings (engine.batch_k / bucket):
    specs = (
        StaticArgSpec(
            "batch_bucket",
            sample=tuple(range(1, max_batch + 1)),
            mapper=lambda n, _mb=max_batch: MicroBatcher.bucket(n, _mb),
            allowed=_pow2_buckets(max_batch),
            max_variants=max_batch.bit_length() + 1,
            note="pow2 padding buckets for the request batch size"),
        StaticArgSpec(
            "k_bucket",
            sample=tuple(range(1, 64)) + (200, 1000, 10 ** 9),
            mapper=lambda kv, _e=eng: _e.batch_k([kv]),
            allowed=_pow2_buckets(eng.max_k),
            max_variants=eng.max_k.bit_length() + 1,
            note="client k clamped into [1, max_k] then pow2-bucketed"),
        StaticArgSpec(
            "ladder_rung",
            sample=tuple(eng.ladder),
            mapper=lambda r: r,
            allowed=frozenset(eng.ladder),
            max_variants=4,
            note="calibrated slot budgets baked into ONE serve fn (rungs "
                 "are cond branches, never separate compiles)"),
    )
    if grouped:
        specs += (StaticArgSpec(
            "n_groups", sample=(cfg.pq.n_groups,), mapper=lambda g: g,
            allowed=frozenset({cfg.pq.n_groups}), max_variants=1,
            note="config-static group count"),)

    return BuiltEntry(
        fn=lambda seqs: eng._serve_fn(seqs, k),
        args=(sds,),
        static_specs=specs,
        dispatch_counter=lambda: _count_engine_dispatches(eng, cfg, k,
                                                          base_id),
        notes=f"RetrievalEngine.for_seqrec pqtopk_pruned, calibrated "
              f"ladder={eng.ladder}, grouped={grouped}")


@register("engine_aot",
          "a calibrated RetrievalEngine on the pruned route: AOT variant "
          "keys, client-k bucketing, runtime single-dispatch counting",
          tags=("serve", "engine", "pruned"))
def _build_engine_aot() -> BuiltEntry:
    return _engine_entry(grouped=False, base_id=0)


@register("engine_aot_grouped",
          "the engine on the grouped per-query route: same AOT/bucketing "
          "contracts with the grouped cascade in the trace",
          tags=("serve", "engine", "pruned", "grouped"))
def _build_engine_aot_grouped() -> BuiltEntry:
    return _engine_entry(grouped=True, base_id=100)


# ---------------------------------------------------------------------------
# mutable-catalogue routes (ISSUE 7: tombstones, hot swap)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _mutable_setup():
    """A MutableHeadState over the shared catalogue with a few dozen
    deletions applied — stale (loosened) bounds plus a real tombstone
    mask, i.e. the exact serve-path shape streaming mutation produces."""
    import numpy as np
    from repro.core.mutation import MutableHeadState

    params, cfg = _seqrec_setup()
    mstate = MutableHeadState.build(params["item_emb"]["codes"], cfg.pq.b)
    rng = np.random.default_rng(11)
    for iid in rng.choice(np.arange(1, cfg.n_items + 1), 64, replace=False):
        mstate.delete(int(iid))
    return params, cfg, mstate


@register("flat_tombstone",
          "serve_topk on a mutated catalogue: capacity-padded codes, "
          "stale-but-dominating bounds and the tombstone mask all enter "
          "as DATA — the whole degraded cascade must still be one trace",
          tags=("serve", "pruned", "mutable"))
def _build_flat_tombstone() -> BuiltEntry:
    from repro.models import seqrec as seqrec_lib

    params, cfg, mstate = _mutable_setup()
    p = {**params, "item_emb": {**params["item_emb"],
                                **mstate.head_arrays()}}

    def fn(seqs):
        return seqrec_lib.serve_topk(p, seqs, cfg, k=5,
                                     method="pqtopk_pruned",
                                     ladder=STATIC_LADDER,
                                     return_rung=True)

    return BuiltEntry(fn, (_seq_sds(cfg),),
                      notes=f"mutable head, capacity={mstate.cap}, "
                            f"n_live={mstate.n_live}, ladder rungs in "
                            "trace, tombstones as data")


@register("tombstone_tiles_kernel",
          "the compacted-tile kernel with a live (tombstone) block: the "
          "(1, tile) int8 mask rides the same clamped sentinel index map "
          "as the codes blocks — the mutable kernel contract surface",
          tags=("kernel", "mutable"))
def _build_tombstone_tiles_kernel() -> BuiltEntry:
    import jax
    import jax.numpy as jnp
    from repro.kernels.pqtopk import ops

    codes, s = _kernel_fixture()
    tile_idx = jnp.asarray([0, -1], jnp.int32)   # one live slot + sentinel
    live = jax.ShapeDtypeStruct((codes.shape[0],), jnp.bool_)

    def fn(c, sc, lv):
        return ops.pq_topk_tiles(c, sc, 8, tile_idx, tile=512, live=lv,
                                 use_kernel=True, interpret=True)

    return BuiltEntry(fn, (codes, s, live), expect_pallas=1,
                      notes="1D compacted slots + tombstone mask block, "
                            "tile=512")


@register("engine_mutable",
          "the hot-swap engine: serve, mutate the catalogue, "
          "swap_head_state, serve again — the swapped batch must be ONE "
          "dispatch through the SAME compiled variants (zero recompiles)",
          tags=("serve", "engine", "pruned", "mutable"))
def _build_engine_mutable() -> BuiltEntry:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.serving.engine import (MicroBatcher, Request,
                                      RetrievalEngine)

    params, cfg, mstate = _mutable_setup()
    k, max_batch = 5, 8
    eng = RetrievalEngine.for_seqrec_mutable(params, cfg, mstate, k=k,
                                             max_batch=max_batch)
    assert eng._head_state is not None, "mutable engine must be swappable"

    sds = jax.ShapeDtypeStruct((4, cfg.max_seq_len), jnp.int32)

    def count() -> int:
        rng = np.random.default_rng(200)
        for i in range(4):
            eng.submit(Request(200 + i,
                               rng.integers(1, cfg.n_items + 1, 8), k=k))
        eng.drain()                               # warm outside the guard
        n_variants = len(eng._compiled)
        # Mutate the catalogue and hot-swap it in — the whole point is
        # that the swapped batch below reuses the SAME compiled variant.
        for iid in rng.choice(np.arange(1, cfg.n_items + 1), 16,
                              replace=False):
            if bool(np.asarray(mstate.live)[int(iid)]):
                mstate.delete(int(iid))
        eng.swap_head_state(mstate)
        calls = []
        for key, f in list(eng._compiled.items()):
            eng._compiled[key] = (
                lambda seqs, _f=f, _key=key:
                (calls.append(_key), _f(seqs))[1])
        for i in range(4):
            eng.submit(Request(210 + i,
                               rng.integers(1, cfg.n_items + 1, 8), k=k))
        with jax.transfer_guard("disallow"):
            results = eng.run_once()
        assert len(results) == 4, f"served {len(results)}/4"
        assert len(eng._compiled) == n_variants, (
            f"hot swap minted {len(eng._compiled) - n_variants} new "
            "compiled variant(s)")
        return len(calls)

    specs = (
        StaticArgSpec(
            "batch_bucket",
            sample=tuple(range(1, max_batch + 1)),
            mapper=lambda n, _mb=max_batch: MicroBatcher.bucket(n, _mb),
            allowed=_pow2_buckets(max_batch),
            max_variants=max_batch.bit_length() + 1,
            note="pow2 padding buckets for the request batch size"),
        StaticArgSpec(
            "k_bucket",
            sample=tuple(range(1, 64)) + (200, 1000, 10 ** 9),
            mapper=lambda kv, _e=eng: _e.batch_k([kv]),
            allowed=_pow2_buckets(eng.max_k),
            max_variants=eng.max_k.bit_length() + 1,
            note="client k clamped into [1, max_k] then pow2-bucketed"),
        StaticArgSpec(
            "head_swap",
            sample=(0, 1, 2),
            mapper=lambda _swap: "head-as-data",
            allowed=frozenset({"head-as-data"}),
            max_variants=1,
            note="catalogue mutations are pure data: every swap maps to "
                 "the one compiled head structure"),
    )

    return BuiltEntry(
        fn=lambda seqs: eng._serve_fn(seqs, k, eng._head_state),
        args=(sds,),
        static_specs=specs,
        dispatch_counter=count,
        notes=f"for_seqrec_mutable, capacity={mstate.cap}, "
              f"ladder={eng.ladder}, swap-then-serve under "
              "transfer_guard")


# ---------------------------------------------------------------------------
# replicated fabric (ISSUE 8: router)
# ---------------------------------------------------------------------------

@register("router_replicated",
          "the replicated serving fabric: health-checked replicas behind "
          "one submit/drain — a healthy-path batch is ONE compiled "
          "dispatch on exactly one replica (no fan-out, no duplicated "
          "work) and replica id never keys a compile",
          tags=("serve", "engine", "pruned", "router"))
def _build_router_replicated() -> BuiltEntry:
    import jax
    import jax.numpy as jnp
    from repro.serving.engine import MicroBatcher
    from repro.serving.router import ReplicaRouter

    params, cfg = _seqrec_setup()
    k, max_batch = 5, 8
    router = ReplicaRouter.for_seqrec(params, cfg, n_replicas=2, k=k,
                                      max_batch=max_batch,
                                      method="pqtopk_pruned", hedge=False)
    router.warmup()
    eng = router.engines[0]
    assert eng.ladder is not None and len(eng.ladder) >= 2, (
        f"expected a calibrated multi-rung ladder, got {eng.ladder!r}")
    assert all(e.ladder == eng.ladder for e in router.engines), (
        "replicas must share the lead engine's calibrated ladder")

    sds = jax.ShapeDtypeStruct((4, cfg.max_seq_len), jnp.int32)

    def count() -> int:
        import numpy as np
        from repro.serving.engine import Request

        rng = np.random.default_rng(300)

        def feed(base: int, n: int):
            for i in range(n):
                router.submit(Request(
                    base + i, rng.integers(1, cfg.n_items + 1, 8), k=k))

        # Warm through real router traffic: two full buckets form in one
        # scheduling pass and land on the two least-loaded replicas, so
        # every replica serves before we start counting.
        feed(300, 2 * max_batch)
        router.drain()
        assert all(rs.completed >= 1 for rs in router.replicas), (
            "warm traffic did not reach every replica")
        calls: list = []
        for eng_i in router.engines:
            for key, f in list(eng_i._compiled.items()):
                eng_i._compiled[key] = (
                    lambda seqs, _f=f, _key=key:
                    (calls.append(_key), _f(seqs))[1])
        feed(340, max_batch)                 # exactly ONE full-bucket job
        # The transfer-guard context manager is thread-local and the
        # router launches/completes batches on its worker threads, so
        # the guard has to go through the global config for this serve.
        prev = getattr(jax.config, "jax_transfer_guard", None) or "allow"
        jax.config.update("jax_transfer_guard", "disallow")
        try:
            results = router.drain()
        finally:
            jax.config.update("jax_transfer_guard", prev)
        assert len(results) == max_batch, (
            f"served {len(results)}/{max_batch}")
        assert not any(r.shed for r in results)
        assert not any(r.degraded for r in results), (
            "healthy-path batch must not carry degradation tags")
        return len(calls)

    specs = (
        StaticArgSpec(
            "batch_bucket",
            sample=tuple(range(1, max_batch + 1)),
            mapper=lambda n, _mb=max_batch: MicroBatcher.bucket(n, _mb),
            allowed=_pow2_buckets(max_batch),
            max_variants=max_batch.bit_length() + 1,
            note="pow2 padding buckets for the request batch size"),
        StaticArgSpec(
            "k_bucket",
            sample=tuple(range(1, 64)) + (200, 1000, 10 ** 9),
            mapper=lambda kv, _e=eng: _e.batch_k([kv]),
            allowed=_pow2_buckets(eng.max_k),
            max_variants=eng.max_k.bit_length() + 1,
            note="client k clamped into [1, max_k] then pow2-bucketed"),
        StaticArgSpec(
            "ladder_rung",
            sample=tuple(eng.ladder),
            mapper=lambda r: r,
            allowed=frozenset(eng.ladder),
            max_variants=4,
            note="one shared calibrated ladder across the fleet (rungs "
                 "are cond branches, never separate compiles)"),
        StaticArgSpec(
            "replica",
            sample=tuple(range(router.n_replicas)),
            mapper=lambda _rid: "shared-trace",
            allowed=frozenset({"shared-trace"}),
            max_variants=1,
            note="replica id is pure routing state: every replica "
                 "compiles the one identical serve structure"),
    )

    return BuiltEntry(
        fn=lambda seqs: eng._serve_fn(seqs, k),
        args=(sds,),
        static_specs=specs,
        dispatch_counter=count,
        notes=f"ReplicaRouter.for_seqrec x{router.n_replicas} replicas, "
              f"shared ladder={eng.ladder}, hedging off, global "
              "transfer_guard over the worker threads")


# ---------------------------------------------------------------------------
# durable mutation fabric (ISSUE 10: WAL, LSN watermarks)
# ---------------------------------------------------------------------------

@register("router_durable",
          "the durable mutation fabric: WAL-append + LSN-fenced fan-out + "
          "hot swap on every replica — a post-mutation batch is ONE "
          "compiled dispatch, and neither the LSN watermark nor the "
          "replica id ever keys a compile",
          tags=("serve", "engine", "pruned", "router", "mutable"))
def _build_router_durable() -> BuiltEntry:
    import tempfile
    import time as time_lib

    import jax
    import jax.numpy as jnp
    from repro.core.mutation import MutableHeadState
    from repro.serving.catalogue_log import CatalogueLog
    from repro.serving.engine import MicroBatcher
    from repro.serving.router import ReplicaRouter

    params, cfg = _seqrec_setup()
    # A FRESH state (not the lru-cached _mutable_setup one, which other
    # entrypoints mutate): the log's meta pins the catalogue layout.
    mstate = MutableHeadState.build(params["item_emb"]["codes"], cfg.pq.b)
    log = CatalogueLog(tempfile.mkdtemp(prefix="repro_wal_"),
                       fsync_every=16)
    k, max_batch = 5, 8
    router = ReplicaRouter.for_seqrec_mutable(
        params, cfg, mstate, n_replicas=2, k=k, max_batch=max_batch,
        log=log, hedge=False)
    router.warmup()
    eng = router.engines[0]
    assert all(e.ladder == eng.ladder for e in router.engines), (
        "replicas must share the lead engine's calibrated ladder")
    assert eng._head_state is not None, "fleet must be hot-swappable"

    sds = jax.ShapeDtypeStruct((4, cfg.max_seq_len), jnp.int32)

    def count() -> int:
        import numpy as np
        from repro.serving.engine import Request

        rng = np.random.default_rng(400)

        def feed(base: int, n: int):
            for i in range(n):
                router.submit(Request(
                    base + i, rng.integers(1, cfg.n_items + 1, 8), k=k))

        feed(400, 2 * max_batch)               # warm every replica
        router.drain()
        assert all(rs.completed >= 1 for rs in router.replicas), (
            "warm traffic did not reach every replica")
        n_variants = [len(e._compiled) for e in router.engines]

        # Commit a mutation batch through the WAL and wait for every
        # replica's worker to replay it (hot swap, between dispatches).
        ops = [("delete", int(i)) for i in
               rng.choice(np.arange(1, cfg.n_items + 1), 8, replace=False)]
        committed = router.apply_mutations(ops)
        deadline = time_lib.monotonic() + 30.0
        while any(rep["lag"] != 0
                  for rep in router.stats()["replicas"].values()):
            assert time_lib.monotonic() < deadline, "catch-up stalled"
            time_lib.sleep(0.01)
        assert [len(e._compiled) for e in router.engines] == n_variants, (
            "mutation propagation minted new compiled variant(s)")

        calls: list = []
        for eng_i in router.engines:
            for key, f in list(eng_i._compiled.items()):
                eng_i._compiled[key] = (
                    lambda seqs, _f=f, _key=key:
                    (calls.append(_key), _f(seqs))[1])
        feed(440, max_batch)                   # ONE full-bucket job
        # Global guard: launches/completions happen on worker threads
        # (the thread-local context manager would not reach them).
        prev = getattr(jax.config, "jax_transfer_guard", None) or "allow"
        jax.config.update("jax_transfer_guard", "disallow")
        try:
            results = router.drain()
        finally:
            jax.config.update("jax_transfer_guard", prev)
        assert len(results) == max_batch, (
            f"served {len(results)}/{max_batch}")
        assert not any(r.shed or r.degraded for r in results), (
            "healthy-path post-mutation batch must be untagged")
        assert all(r.lsn == committed for r in results), (
            "every Result must carry the committed-LSN watermark")
        return len(calls)

    specs = (
        StaticArgSpec(
            "batch_bucket",
            sample=tuple(range(1, max_batch + 1)),
            mapper=lambda n, _mb=max_batch: MicroBatcher.bucket(n, _mb),
            allowed=_pow2_buckets(max_batch),
            max_variants=max_batch.bit_length() + 1,
            note="pow2 padding buckets for the request batch size"),
        StaticArgSpec(
            "k_bucket",
            sample=tuple(range(1, 64)) + (200, 1000, 10 ** 9),
            mapper=lambda kv, _e=eng: _e.batch_k([kv]),
            allowed=_pow2_buckets(eng.max_k),
            max_variants=eng.max_k.bit_length() + 1,
            note="client k clamped into [1, max_k] then pow2-bucketed"),
        StaticArgSpec(
            "lsn",
            sample=(0, 1, 8, 123, 10 ** 6),
            mapper=lambda _lsn: "head-as-data",
            allowed=frozenset({"head-as-data"}),
            max_variants=1,
            note="the catalogue version is pure data: every committed "
                 "LSN serves through the one compiled head structure"),
        StaticArgSpec(
            "replica",
            sample=tuple(range(router.n_replicas)),
            mapper=lambda _rid: "shared-trace",
            allowed=frozenset({"shared-trace"}),
            max_variants=1,
            note="replica id is pure routing state: every replica "
                 "compiles the one identical serve structure"),
    )

    return BuiltEntry(
        fn=lambda seqs: eng._serve_fn(seqs, k, eng._head_state),
        args=(sds,),
        static_specs=specs,
        dispatch_counter=count,
        notes=f"ReplicaRouter.for_seqrec_mutable x{router.n_replicas} + "
              f"CatalogueLog WAL, shared ladder={eng.ladder}, "
              "mutate-swap-serve under global transfer_guard")
