"""Replicated serving fabric: a ``ReplicaRouter`` fronts K identical
``RetrievalEngine`` replicas behind the single-engine submit/drain/stats
API and layers on what one engine cannot give you:

* **Pipelined dispatch** — each replica is owned by one worker thread
  that keeps up to ``dispatch_depth`` batches in flight (JAX dispatch is
  async: the host pads and enqueues batch N+1 while the device still owns
  batch N), and partial batches dispatch once the oldest request has
  waited ``max_wait_ms`` — a trickle of traffic never stalls on a full
  bucket.
* **Health-checked failover** — a per-replica state machine (healthy ->
  suspect on straggler/failure strikes -> ejected) with half-open probe
  re-admission after an exponentially backed-off cooldown.  Work in
  flight on a dead replica is re-dispatched to a healthy one; a request
  is NEVER lost, and never answered twice.
* **Hedged dispatch** — a batch outstanding longer than the observed
  p99 job time (floored at ``hedge_floor_ms``) is re-issued to a second
  healthy replica; the first completion wins and the loser's results are
  suppressed by request id.
* **Load-adaptive degradation** — a watermark ladder on total queue
  depth: level 1 caps the batch k, level 2 additionally pins the pruned
  cascade to its cheapest calibrated rung (``RetrievalEngine``'s
  ``serve_fn_pinned`` route), level 3 sheds new work outright.  Every
  result served below full fidelity carries a ``Result.degraded`` tag,
  and recovery is hysteresis-damped (the level only drops after the
  depth has sat below the low watermark for ``recover_patience``
  consecutive scheduling passes) so the ladder cannot thrash.

Threading model: each engine is touched by exactly ONE worker thread
(engines are not thread-safe); the scheduler — health bookkeeping, job
assignment, hedging, the ladder — runs entirely on the caller's thread
inside :meth:`pump` / :meth:`drain`.  The only cross-thread structures
are the per-replica job queues and the shared completion-event queue.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import (InFlightBatch, MicroBatcher, Request,
                                  Result, RetrievalEngine)
from repro.training.fault_tolerance import ReplicaFaultPlan, SimulatedFailure

_STOP = object()

HEALTHY, SUSPECT, EJECTED, PROBING = "healthy", "suspect", "ejected", "probing"


@dataclass
class _Job:
    """One batch's worth of work as handed to a replica worker.  A hedge
    re-issue is a second ``_Job`` with the same ``job_id`` (duplicate
    results are suppressed by request id at delivery)."""
    job_id: int
    requests: List[Request]
    k_cap: Optional[int]
    rung_pin: bool
    replica: int
    hedged: bool = False


@dataclass
class _JobState:
    """Scheduler-side view of one logical job across all its copies."""
    requests: List[Request]
    k_cap: Optional[int]
    rung_pin: bool
    replica: int                      # replica of the primary copy
    copies: int = 1                   # live copies in flight
    hedged: bool = False
    attempts: int = 0                 # failed-and-redispatched count
    first_dispatch_t: float = 0.0


@dataclass
class _Event:
    kind: str                         # "done" | "fail"
    job: _Job
    results: List[Result]
    replica: int
    straggler: bool = False


@dataclass
class ReplicaState:
    """Health state machine for one replica.  Transitions happen only on
    the scheduler thread:

    healthy --strikes>=suspect_after--> suspect
            --strikes>=eject_after-->   ejected  (in-flight work
                                                  re-dispatched on failure)
    ejected --cooldown elapsed-->       probing  (half-open: ONE job)
    probing --probe succeeds-->         healthy  (re-admitted, cooldown
                                                  reset)
            --probe fails-->            ejected  (cooldown doubles)
    """
    state: str = HEALTHY
    strikes: int = 0
    cooldown_ms: float = 100.0
    ejected_at: float = 0.0
    probe_outstanding: bool = False
    inflight: int = 0                 # jobs assigned, not yet resolved
    dispatched: int = 0
    completed: int = 0
    failures: int = 0
    stragglers: int = 0
    ejections: int = 0
    readmissions: int = 0


class ReplicaRouter:
    """Route requests across K ``RetrievalEngine`` replicas (same model,
    same compiled serving route) with failover, hedging and graceful
    degradation.  API mirrors the single engine: :meth:`submit`,
    :meth:`drain`, :meth:`stats`; :meth:`pump` runs one scheduling pass
    for callers driving their own loop.  Use as a context manager (or
    call :meth:`close`) to join the worker threads."""

    def __init__(self, engines: Sequence[RetrievalEngine], *,
                 dispatch_depth: int = 2,
                 max_batch: Optional[int] = None,
                 max_wait_ms: float = 2.0,
                 fault_plans: Optional[Dict[int, ReplicaFaultPlan]] = None,
                 suspect_after: int = 1, eject_after: int = 3,
                 cooldown_ms: float = 100.0,
                 hedge: bool = True, hedge_floor_ms: float = 50.0,
                 max_redispatch: Optional[int] = None,
                 degrade_high: int = 256, degrade_low: int = 64,
                 degrade_k_cap: Optional[int] = None,
                 degrade_patience: int = 1, recover_patience: int = 3):
        if not engines:
            raise ValueError("need at least one replica engine")
        self.engines = list(engines)
        self.n_replicas = len(self.engines)
        self.dispatch_depth = max(1, dispatch_depth)
        mb = max_batch or min(e.batcher.max_batch for e in self.engines)
        self.batcher = MicroBatcher(max_batch=mb, max_wait_ms=max_wait_ms)
        self.fault_plans = dict(fault_plans or {})
        self.suspect_after = suspect_after
        self.eject_after = eject_after
        self.hedge_enabled = hedge and self.n_replicas > 1
        self.hedge_floor_ms = hedge_floor_ms
        self.max_redispatch = (2 * self.n_replicas if max_redispatch is None
                               else max_redispatch)
        self.degrade_high = degrade_high
        self.degrade_low = degrade_low
        self.degrade_k_cap = (degrade_k_cap if degrade_k_cap is not None
                              else min(e.k for e in self.engines))
        self.degrade_patience = max(1, degrade_patience)
        self.recover_patience = max(1, recover_patience)

        self.replicas = [ReplicaState(cooldown_ms=cooldown_ms)
                         for _ in range(self.n_replicas)]
        self._base_cooldown_ms = cooldown_ms
        self._queues: List[queue.Queue] = [queue.Queue()
                                           for _ in range(self.n_replicas)]
        self._events: queue.Queue = queue.Queue()
        self._dispatch_idx = [0] * self.n_replicas   # worker-local counters

        self._jobs: Dict[int, _JobState] = {}
        self._retry: collections.deque[_JobState] = collections.deque()
        self._next_job_id = 0
        self._expected: set = set()
        self._done_ids: set = set()
        self._completed: List[Result] = []
        self._latencies_ms: List[float] = []
        self._job_wall_ms: collections.deque = collections.deque(maxlen=512)

        self.level = 0
        self._over = self._under = 0
        self.degrade_events = 0
        self.recover_events = 0
        self.degraded_results: collections.Counter = collections.Counter()
        self.shed_load = 0
        self.hedges = 0
        self.hedge_wins = 0
        self.duplicates_suppressed = 0
        self.redispatched = 0

        self._closed = False
        self._threads = [
            threading.Thread(target=self._worker, args=(rid,), daemon=True,
                             name=f"replica-{rid}")
            for rid in range(self.n_replicas)]
        for t in self._threads:
            t.start()

    @classmethod
    def for_seqrec(cls, params, cfg, *, n_replicas: int = 2, k: int = 10,
                   max_batch: int = 64, method: Optional[str] = None,
                   sharded_mesh=None, calibrate: Optional[bool] = None,
                   survival_stats: Optional[Sequence[int]] = None,
                   ladder=None, **router_kw) -> "ReplicaRouter":
        """Stand up K identical replicas of a seqrec serving engine.  The
        pruned route's slot-budget ladder is calibrated ONCE (on the
        first replica) and shared, so replicas compile byte-identical
        serve functions — which is what makes the healthy-path
        bit-parity guarantee hold across failover."""
        first = RetrievalEngine.for_seqrec(
            params, cfg, k=k, max_batch=max_batch, method=method,
            sharded_mesh=sharded_mesh, calibrate=calibrate,
            survival_stats=survival_stats, ladder=ladder)
        engines = [first]
        for _ in range(n_replicas - 1):
            engines.append(RetrievalEngine.for_seqrec(
                params, cfg, k=k, max_batch=max_batch, method=method,
                sharded_mesh=sharded_mesh, ladder=first.ladder,
                calibrate=False))
        return cls(engines, **router_kw)

    def warmup(self, ks: Sequence[int] = (), buckets: Sequence[int] = ()):
        """Synchronously compile the hot serve variants on EVERY replica
        (full-bucket batch at the engines' base k plus any extra ``ks`` /
        ``buckets``, and the rung-pinned route where present) before
        traffic arrives.  Cold AOT compiles serialise on a loaded host;
        without warmup the first batches straggle behind multi-second
        compiles, the hedger fires on compile noise, and a latency
        benchmark measures XLA, not serving."""
        for eng in self.engines:
            bks = set(buckets) | {self.batcher.max_batch}
            kks = {eng.batch_k([k]) for k in set(ks) | {eng.k}}
            for b in bks:
                bb = MicroBatcher.bucket(b, eng.batcher.max_batch)
                for kk in kks:
                    eng._variant(bb, kk)
                    if eng.has_pinned:
                        eng._variant(bb, kk, pinned=True)

    # ------------------------------------------------------------------
    # worker side (one thread per replica; the only code touching engines)
    # ------------------------------------------------------------------

    def _worker(self, rid: int):
        eng = self.engines[rid]
        plan = self.fault_plans.get(rid)
        q = self._queues[rid]
        inflight: collections.deque = collections.deque()
        while True:
            job = None
            if len(inflight) < self.dispatch_depth:
                try:
                    # Block only when the pipeline is empty; with work in
                    # flight, poll so completions are not starved.
                    job = q.get(block=not inflight, timeout=0.02)
                except queue.Empty:
                    job = None
            if job is _STOP:
                while inflight:           # never abandon in-flight work
                    self._finish(rid, *inflight.popleft())
                break
            if job is not None:
                self._start(rid, eng, plan, job, inflight)
            elif inflight:
                self._finish(rid, *inflight.popleft())

    def _start(self, rid: int, eng: RetrievalEngine,
               plan: Optional[ReplicaFaultPlan], job: _Job,
               inflight: collections.deque):
        """Prepare + asynchronously launch one job; chaos (the replica
        fault plan) is consulted on this replica's own dispatch counter,
        so a schedule replays identically however the router interleaves
        replicas."""
        d_idx = self._dispatch_idx[rid]
        self._dispatch_idx[rid] = d_idx + 1
        try:
            extra = plan.check(d_idx) if plan is not None else 0.0
            shed, prep = eng.prepare(job.requests, k_cap=job.k_cap,
                                     rung_pin=job.rung_pin)
            if prep is None:
                self._events.put(_Event("done", job, shed, rid))
                return
            if extra:
                time.sleep(extra)         # straggling replica
            inflight.append((job, eng.launch(prep), shed))
        except SimulatedFailure:
            self._events.put(_Event("fail", job, [], rid))

    def _finish(self, rid: int, job: _Job, inf: InFlightBatch,
                shed: List[Result]):
        try:
            res = self.engines[rid].complete(inf)
        except SimulatedFailure:
            # Deadline sheds from prepare() are still final answers — only
            # the dispatched rows are retried elsewhere.
            self._events.put(_Event("fail", job, shed, rid))
        else:
            self._events.put(_Event("done", job, shed + res, rid,
                                    straggler=inf.straggler))

    # ------------------------------------------------------------------
    # scheduler side (caller thread only)
    # ------------------------------------------------------------------

    def submit(self, req: Request):
        """Accept a request (or, at ladder level 3, shed it immediately
        with a ``load_shed``-tagged Result — the client still gets
        exactly one answer)."""
        self._expected.add(req.request_id)
        if self.level >= 3:
            now = time.monotonic()
            lat = (now - req.arrival) * 1e3
            self.shed_load += 1
            self.degraded_results["load_shed"] += 1
            self._done_ids.add(req.request_id)
            self._latencies_ms.append(lat)
            self._completed.append(Result(
                req.request_id, np.empty(0, np.int32),
                np.empty(0, np.float32), lat, shed=True,
                degraded="load_shed"))
            return
        self.batcher.submit(req)

    def pump(self, block: bool = False, timeout: float = 0.05) -> bool:
        """One scheduling pass: absorb completion events, update the
        degradation ladder and replica health, assign ready batches,
        issue hedges.  Returns True if any event was processed."""
        progressed = False
        first = True
        while True:
            try:
                ev = self._events.get(block=block and first, timeout=timeout)
            except queue.Empty:
                break
            first = False
            progressed = True
            self._handle(ev)
        self._update_load()
        self._update_health()
        self._schedule()
        if self.hedge_enabled:
            self._maybe_hedge()
        return progressed

    def drain(self, timeout_s: float = 120.0) -> List[Result]:
        """Pump until every submitted request has exactly one Result; a
        stall (no event for ``timeout_s``) raises rather than hanging —
        by construction (failover + forced probes) that only fires on a
        genuinely wedged fabric."""
        last_progress = time.monotonic()
        while self._expected - self._done_ids:
            if self.pump(block=True, timeout=0.05):
                last_progress = time.monotonic()
            elif time.monotonic() - last_progress > timeout_s:
                missing = sorted(self._expected - self._done_ids)[:10]
                raise RuntimeError(
                    f"router stalled; undelivered request ids {missing}...")
        self.pump()                       # absorb trailing duplicates
        out, self._completed = self._completed, []
        return out

    def close(self):
        if self._closed:
            return
        self._closed = True
        for q in self._queues:
            q.put(_STOP)
        for t in self._threads:
            t.join(timeout=30.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- event handling -------------------------------------------------

    def _handle(self, ev: _Event):
        rs = self.replicas[ev.replica]
        rs.inflight = max(0, rs.inflight - 1)
        st = self._jobs.get(ev.job.job_id)
        if rs.probe_outstanding:
            rs.probe_outstanding = False
        delivered_new = False
        for r in ev.results:
            if r.request_id in self._done_ids:
                self.duplicates_suppressed += 1
                continue
            delivered_new = True
            self._done_ids.add(r.request_id)
            if not r.shed:
                r.replica = ev.replica
                r.hedged = bool(st and st.hedged)
            if r.degraded:
                self.degraded_results[r.degraded] += 1
            self._latencies_ms.append(r.latency_ms)
            self._completed.append(r)
        if ev.kind == "done":
            rs.completed += 1
            if st is not None and st.first_dispatch_t:
                self._job_wall_ms.append(
                    (time.monotonic() - st.first_dispatch_t) * 1e3)
            if ev.job.hedged and delivered_new:
                self.hedge_wins += 1
            if ev.straggler:
                rs.stragglers += 1
                self._strike(ev.replica)
            else:
                self._ok(ev.replica)
        else:
            rs.failures += 1
            self._strike(ev.replica)
        if st is None:
            return
        st.copies -= 1
        if st.copies > 0:
            return
        undone = [r for r in st.requests
                  if r.request_id not in self._done_ids]
        if not undone:
            del self._jobs[ev.job.job_id]
            return
        # Last live copy failed with work undelivered: re-dispatch (the
        # in-flight work of a dead replica is never lost) until the
        # patience budget runs out, then shed — still exactly one Result.
        st.requests = undone
        st.attempts += 1
        del self._jobs[ev.job.job_id]
        if st.attempts <= self.max_redispatch:
            self.redispatched += 1
            self._retry.append(st)
        else:
            now = time.monotonic()
            for r in undone:
                lat = (now - r.arrival) * 1e3
                self._done_ids.add(r.request_id)
                self.degraded_results["redispatch_exhausted"] += 1
                self._latencies_ms.append(lat)
                self._completed.append(Result(
                    r.request_id, np.empty(0, np.int32),
                    np.empty(0, np.float32), lat,
                    timed_out=lat > r.deadline_ms, shed=True,
                    degraded="redispatch_exhausted"))

    # -- health ---------------------------------------------------------

    def _strike(self, rid: int):
        rs = self.replicas[rid]
        now = time.monotonic()
        if rs.state == PROBING:
            # Half-open probe failed: back to ejected, backoff doubled.
            rs.state = EJECTED
            rs.ejected_at = now
            rs.cooldown_ms *= 2.0
            return
        rs.strikes += 1
        if rs.strikes >= self.eject_after and rs.state != EJECTED:
            rs.state = EJECTED
            rs.ejected_at = now
            rs.ejections += 1
        elif rs.strikes >= self.suspect_after and rs.state == HEALTHY:
            rs.state = SUSPECT

    def _ok(self, rid: int):
        rs = self.replicas[rid]
        if rs.state == PROBING:
            rs.state = HEALTHY
            rs.strikes = 0
            rs.cooldown_ms = self._base_cooldown_ms
            rs.readmissions += 1
            return
        if rs.strikes > 0:
            rs.strikes -= 1
            if rs.state == SUSPECT and rs.strikes < self.suspect_after:
                rs.state = HEALTHY

    def _update_health(self):
        now = time.monotonic()
        for rs in self.replicas:
            if rs.state == EJECTED and \
                    (now - rs.ejected_at) * 1e3 >= rs.cooldown_ms:
                rs.state = PROBING
                rs.probe_outstanding = False

    def _eligible(self, exclude: int = -1) -> Optional[int]:
        """Pick the assignable replica: a free half-open probe slot first
        (a probing replica takes at most ONE job, and re-admission can
        only happen by actually trialling it — ranking it behind healthy
        replicas would starve the probe forever on a healthy fleet),
        then healthy before suspect, least-loaded within a rank.  When
        every replica is ejected, force the one closest to cooldown into
        probing — liveness must not wait for a timer while requests hold
        deadlines."""
        rank = {PROBING: 0, HEALTHY: 1, SUSPECT: 2}
        best, best_key = None, None
        for rid, rs in enumerate(self.replicas):
            if rid == exclude or rs.state == EJECTED:
                continue
            if rs.state == PROBING and rs.probe_outstanding:
                continue
            key = (rank[rs.state],
                   rs.inflight + self._queues[rid].qsize())
            if best_key is None or key < best_key:
                best, best_key = rid, key
        if best is None:
            ejected = [(self.replicas[rid].ejected_at
                        + self.replicas[rid].cooldown_ms / 1e3, rid)
                       for rid in range(self.n_replicas)
                       if rid != exclude
                       and self.replicas[rid].state == EJECTED]
            if ejected:
                _, rid = min(ejected)
                self.replicas[rid].state = PROBING
                self.replicas[rid].probe_outstanding = False
                return rid
        return best

    # -- assignment / hedging / ladder ----------------------------------

    def _put(self, rid: int, job: _Job):
        rs = self.replicas[rid]
        rs.dispatched += 1
        rs.inflight += 1
        if rs.state == PROBING:
            rs.probe_outstanding = True
        self._queues[rid].put(job)

    def _assign(self, st: _JobState) -> bool:
        rid = self._eligible()
        if rid is None:
            return False
        st.replica = rid
        st.first_dispatch_t = st.first_dispatch_t or time.monotonic()
        jid = self._next_job_id
        self._next_job_id += 1
        self._jobs[jid] = st
        self._put(rid, _Job(jid, st.requests, st.k_cap, st.rung_pin, rid))
        return True

    def _schedule(self):
        while self._retry:
            st = self._retry[0]
            st.copies = 1
            st.hedged = False
            if not self._assign(st):
                return                    # nothing assignable right now
            self._retry.popleft()
        while self.batcher.ready():
            reqs = self.batcher.next_batch()
            st = _JobState(reqs,
                           k_cap=(self.degrade_k_cap if self.level >= 1
                                  else None),
                           rung_pin=self.level >= 2, replica=-1)
            if not self._assign(st):
                # Put them back at the FRONT: arrival order is preserved
                # and the next pump retries.
                for r in reversed(reqs):
                    self.batcher.queue.appendleft(r)
                    self.batcher._enq_t.appendleft(r.arrival)
                return

    def hedge_delay_ms(self) -> float:
        """Current hedge trigger: observed p99 job wall time, floored —
        with few samples the floor dominates so a cold fabric does not
        hedge on compile noise."""
        if len(self._job_wall_ms) < 16:
            return self.hedge_floor_ms
        return max(self.hedge_floor_ms,
                   float(np.percentile(np.asarray(self._job_wall_ms), 99)))

    def _maybe_hedge(self):
        delay_ms = self.hedge_delay_ms()
        now = time.monotonic()
        for jid, st in list(self._jobs.items()):
            if st.hedged or st.copies != 1:
                continue
            if (now - st.first_dispatch_t) * 1e3 < delay_ms:
                continue
            rid = self._eligible(exclude=st.replica)
            if rid is None or self.replicas[rid].state != HEALTHY:
                continue                  # only hedge onto healthy spares
            st.hedged = True
            st.copies += 1
            self.hedges += 1
            self._put(rid, _Job(jid, st.requests, st.k_cap, st.rung_pin,
                                rid, hedged=True))

    def _load(self) -> int:
        return (len(self.batcher.queue)
                + sum(len(st.requests) for st in self._jobs.values())
                + sum(len(st.requests) for st in self._retry))

    def _update_load(self):
        depth = self._load()
        if depth >= self.degrade_high:
            self._over += 1
            self._under = 0
            if self._over >= self.degrade_patience and self.level < 3:
                self.level += 1
                self.degrade_events += 1
                self._over = 0
        elif depth <= self.degrade_low:
            self._under += 1
            self._over = 0
            if self._under >= self.recover_patience and self.level > 0:
                self.level -= 1
                self.recover_events += 1
                self._under = 0
        else:
            # Hysteresis band between the watermarks: hold the level.
            self._over = self._under = 0

    # -- observability ---------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        lats = self._latencies_ms
        done = len(self._done_ids)
        per_replica = {}
        for rid, rs in enumerate(self.replicas):
            per_replica[rid] = {
                "state": rs.state, "strikes": rs.strikes,
                "ejections": rs.ejections, "readmissions": rs.readmissions,
                "dispatched": rs.dispatched, "completed": rs.completed,
                "failures": rs.failures, "stragglers": rs.stragglers,
                "queue_depth": self._queues[rid].qsize() + rs.inflight,
                "n_compiles": len(self.engines[rid]._compiled),
            }
        lat = np.asarray(lats) if lats else None
        return {
            "count": float(done),
            "pending": float(len(self.batcher.queue)),
            "outstanding": float(sum(len(st.requests)
                                     for st in self._jobs.values())),
            "p50_ms": float(np.percentile(lat, 50)) if lat is not None
            else None,
            "p99_ms": float(np.percentile(lat, 99)) if lat is not None
            else None,
            "hedges": float(self.hedges),
            "hedge_wins": float(self.hedge_wins),
            "hedge_delay_ms": self.hedge_delay_ms(),
            "duplicates_suppressed": float(self.duplicates_suppressed),
            "redispatched": float(self.redispatched),
            "degrade_level": self.level,
            "degrade_events": float(self.degrade_events),
            "recover_events": float(self.recover_events),
            "degraded_results": dict(self.degraded_results),
            "shed_load": float(self.shed_load),
            "replicas": per_replica,
        }
